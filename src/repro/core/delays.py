"""Pluggable worker-delay models: the straggler axis of the simulator.

The paper's claim is that ACPD is *straggler-agnostic*, but the seed simulator
only exercised one delay shape (a deterministic per-worker slowdown with
optional lognormal jitter).  This module makes worker delay a first-class
registry, mirroring the protocol registry in :mod:`repro.core.engine` and the
compressor registry in :mod:`repro.core.compress`, so every
protocol x delay x compressor scenario is one declarative
:class:`repro.api.ExperimentSpec`.

A delay model answers three timing questions for the event loop:

* ``compute_time(k, H, rng)``   -- how long worker ``k``'s next local round of
  ``H`` solver steps takes;
* ``p2p_time(nbytes, k)``       -- how long a ``nbytes`` point-to-point
  message to/from worker ``k`` takes (``k=None`` = an unspecified link);
* ``allreduce_time(d)``         -- how long a ring all-reduce of a d-vector
  takes (synchronous protocols only).

Registry entries:

* ``constant``             -- the seed behavior, bit-for-bit: deterministic
  ``H * unit_time * sigma_k``, times a LogNormal(0, jitter) factor when
  ``ClusterModel.jitter > 0``.  This is the default; the ``group``/``sync``
  reference trajectories are pinned through it.
* ``shifted_exponential``  -- the classic straggler model (e.g. Lee et al.,
  "Speeding Up Distributed Machine Learning Using Codes"): a deterministic
  floor plus an exponential tail,
  ``t = base * (1 + Exp(tail_mean))``.
* ``pareto``               -- heavy-tailed delays: ``t = base * (1 + scale *
  Pareto(shape))``.  Small ``shape`` means occasional extreme stragglers; the
  variance is infinite for ``shape <= 2``.
* ``markov``               -- bursty stragglers: each worker carries a hidden
  fast/slow state evolving as a 2-state Markov chain per local round
  (``p_slow`` to enter, ``p_recover`` to leave, ``slow_factor`` multiplier
  while slow).  Models machines that degrade for a stretch (GC pause, noisy
  neighbor) rather than per-round iid noise.
* ``bandwidth_coupled``    -- compute is deterministic but straggler workers
  sit behind a ``link_slowdown`` x slower NIC, so their message time is
  ``latency + nbytes * link_slowdown / bandwidth``.  Delay is proportional to
  *payload bytes*, closing the loop with the compressor byte accounting: a
  sparser or quantized payload (see :mod:`repro.core.compress`) directly
  shrinks the straggler's delay.

Sampling interfaces: the per-call ``compute_time`` above serves the event
executor's one-draw-per-launch discipline.  Two batched forms sit on top of
it.  A single ``sample_round`` call is bit-equal to K sequential
``compute_time`` calls in worker order, so every *pinned* trajectory
(``constant``, with or without jitter -- the only model the reference
oracle in :mod:`repro.core.acpd` covers) is unmoved.  For ``vector_sampled``
models the group-family event loop's CONSUMPTION changed with this
interface: one size-K draw per server round indexed by worker id, replacing
per-relaunch scalars in arrival order -- group/lag trajectories under
``shifted_exponential``/``pareto`` intentionally moved (this is what makes
the stream pre-sampleable for the scan executor; the two executors remain
bit-identical to each other):

* ``sample_round(H, rng)``       -- one round's compute times for ALL K
  workers as a single vector.  The default implementation loops
  ``compute_time(k, ...)`` in worker order; ``shifted_exponential`` and
  ``pareto`` override it with ONE vectorized numpy draw of size K (bit-equal
  to K scalar draws under ``np.random.Generator``, which the tests pin) --
  the event executor uses this to replace per-message scalar draws.
* ``sample_stream(num_rounds, H, rng, lockstep=...)`` -- the whole run's
  compute times as a ``(num_rounds, K)`` matrix, pre-sampled so the
  scan-fused executor (:mod:`repro.core.executor`) can move the entire round
  loop on device.  With ``lockstep=True`` (synchronous protocols, which
  consume exactly one K-vector per round) every model can stream.  With
  ``lockstep=False`` (group-family rounds, which index the round's vector by
  worker id) a model may return ``None`` when its draws cannot be
  pre-assigned to ``(round, worker)`` cells without changing the event
  executor's stream -- ``markov`` (per-call chain advance) and ``constant``
  with jitter (per-launch draw order is pinned bit-for-bit against the
  reference loops) do so, and the executor falls back to the event queue.

``vector_sampled`` marks models whose event-executor draws are per-round
K-vectors indexed by worker id (the group-family vectorization above);
``link_factors()`` exposes per-worker link slowdowns so in-graph executors
can reproduce ``p2p_time`` arithmetic exactly.

Statefulness: most models are stateless given the run's host RNG, but
``markov`` keeps per-worker chain state.  The engine therefore builds a FRESH
model per run via :meth:`ClusterModel.make_delay` (every
:class:`repro.core.engine.Protocol` does this in ``__init__``), which keeps
runs reproducible from ``(spec, seed)`` alone.  The back-compat delegation
``ClusterModel.compute_time`` uses one lazily-cached instance per
``ClusterModel`` -- fine for the stateless models it exists to serve (the
reference loops in :mod:`repro.core.acpd` only support ``constant``).

Extending: subclass :class:`DelayModel`, decorate with
:func:`register_delay`, accept your parameters as keyword arguments (they
arrive from ``ClusterModel.delay_params``, so they must be JSON scalars).
See ``docs/extending-protocols.md`` for the sibling protocol walkthrough.
"""

from __future__ import annotations

import math

import numpy as np

# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

_DELAYS: dict[str, type["DelayModel"]] = {}


def register_delay(name: str):
    """Class decorator: make a DelayModel constructible via
    ``ClusterModel.delay_model``."""

    def deco(cls: type["DelayModel"]) -> type["DelayModel"]:
        cls.delay_name = name
        _DELAYS[name] = cls
        return cls

    return deco


def available_delays() -> tuple[str, ...]:
    return tuple(sorted(_DELAYS))


def get_delay(name: str) -> type["DelayModel"]:
    try:
        return _DELAYS[name]
    except KeyError:
        raise ValueError(
            f"unknown delay model {name!r}; available: {available_delays()}"
        ) from None


# ---------------------------------------------------------------------------
# Base class.
# ---------------------------------------------------------------------------


class DelayModel:
    """Per-run timing model; see the module docstring for the contract.

    ``cluster`` is the owning :class:`repro.core.simulate.ClusterModel`; its
    ``unit_time`` / ``sigmas()`` / ``latency`` / ``bandwidth`` fields are the
    shared vocabulary every model builds on.  ``base_compute(k, H)`` is the
    deterministic floor ``H * unit_time * sigma_k`` that stochastic models
    decorate with their tail.
    """

    delay_name = "abstract"
    # True for models carrying mutable per-run state (e.g. markov chains).
    # Stateful models are only reachable through ClusterModel.make_delay();
    # the legacy ClusterModel.compute_time delegation refuses them, since its
    # cached instance would silently leak state across runs.
    stateful = False
    # True for models whose message timing depends on WHICH worker is on the
    # link.  The legacy ClusterModel.p2p_time signature cannot carry the
    # worker index, so the delegation refuses these too rather than silently
    # timing every worker on the fast link.
    worker_aware = False
    # True once the model's event-executor draws are per-round K-vectors
    # indexed by worker id (vectorized ``sample_round``); the group-family
    # event loop then draws ONE vector per server round instead of one
    # scalar per relaunched worker, and the scan executor can pre-sample the
    # identical (round, worker) stream.
    vector_sampled = False

    def __init__(self, cluster):
        self.cluster = cluster
        self._sigmas = cluster.sigmas()

    def base_compute(self, k: int, H: int) -> float:
        # Same expression (and therefore the same floats) as the seed's
        # ClusterModel.compute_time.
        return H * self.cluster.unit_time * self._sigmas[k]

    def base_compute_vector(self, H: int) -> np.ndarray:
        """``base_compute`` for all K workers; same floats elementwise."""
        return H * self.cluster.unit_time * self._sigmas

    # -- the three timing hooks -------------------------------------------

    def compute_time(self, k: int, H: int, rng: np.random.Generator) -> float:
        raise NotImplementedError

    def p2p_time(self, nbytes: int, k: int | None = None) -> float:
        return self.cluster.latency + nbytes / self.cluster.bandwidth

    def allreduce_time(self, d: int, value_bytes: int = 4) -> float:
        return self.cluster.allreduce_time(d, value_bytes)

    # -- batched sampling (module docstring: "Sampling interfaces") --------

    def sample_round(self, H: int, rng: np.random.Generator) -> np.ndarray:
        """One round's compute times for all K workers, worker order.

        Default: K sequential ``compute_time`` calls -- byte-identical RNG
        stream to the per-call form.  Vectorized overrides must keep that
        stream (one size-K draw == K scalar draws under numpy Generators).
        """
        return np.asarray([self.compute_time(k, H, rng)
                           for k in range(self.cluster.num_workers)])

    def sample_stream(self, num_rounds: int, H: int,
                      rng: np.random.Generator, *,
                      lockstep: bool = False) -> np.ndarray | None:
        """Pre-sample the whole run: ``(num_rounds, K)`` compute times.

        ``lockstep=True``: the consumer burns exactly one K-vector per round
        in worker order (synchronous protocols) -- always available, any
        model, same stream as the event executor.  ``lockstep=False``: the
        consumer indexes cell ``(round, worker)`` on demand (group-family
        rounds); only available when that assignment reproduces the event
        executor's stream -- i.e. the model is ``vector_sampled`` or fully
        deterministic -- otherwise ``None`` (caller falls back to events).
        """
        if not lockstep and not (self.vector_sampled or self.deterministic):
            return None
        return np.stack([self.sample_round(H, rng)
                         for _ in range(num_rounds)])

    def sample_chunks(self, chunk_steps: tuple[int, ...],
                      rng: np.random.Generator) -> np.ndarray:
        """One chunked round's compute times: ``(n_chunks, K)``, chunk-major.

        Chunk-streaming protocols (``partial_work``) split one local pass of
        ``H`` steps into ``chunk_steps`` pieces; each chunk's duration is an
        independent ``sample_round`` draw at that chunk's step count, taken
        chunk-major so that with ONE chunk the draw is exactly the single
        ``sample_round(H)`` the group family makes -- the bit-identity the
        ``n_chunks=1`` degradation tests pin.
        """
        return np.stack([self.sample_round(h, rng) for h in chunk_steps])

    def sample_chunk_stream(self, num_waves: int, chunk_steps: tuple[int, ...],
                            rng: np.random.Generator) -> np.ndarray | None:
        """Pre-sample ``num_waves`` chunked launch waves:
        ``(num_waves, n_chunks, K)``, or ``None`` when per-``(wave, chunk,
        worker)`` cells cannot reproduce the event executor's stream (same
        eligibility rule as non-lockstep ``sample_stream``)."""
        if not (self.vector_sampled or self.deterministic):
            return None
        return np.stack([self.sample_chunks(chunk_steps, rng)
                         for _ in range(num_waves)])

    @property
    def deterministic(self) -> bool:
        """True when ``compute_time`` never touches the RNG."""
        return False

    def link_factors(self) -> np.ndarray:
        """Per-worker link slowdown factors f_k such that
        ``p2p_time(nbytes, k) == latency + nbytes * f_k / bandwidth`` --
        the exact arithmetic in-graph executors replicate."""
        return np.ones(self.cluster.num_workers)


@register_delay("constant")
class ConstantDelay(DelayModel):
    """The seed model, bit-for-bit: deterministic sigma_k slowdown, optional
    LogNormal(0, jitter) multiplicative noise (drawn only when jitter > 0, so
    the host-RNG draw order matches the pinned reference trajectories)."""

    def compute_time(self, k, H, rng):
        base = self.base_compute(k, H)
        if self.cluster.jitter > 0.0:
            base *= float(rng.lognormal(0.0, self.cluster.jitter))
        return base

    @property
    def deterministic(self):
        # Jitter-free constant delays never consume the RNG, so the whole
        # stream is pre-sampleable for any consumption order.
        return self.cluster.jitter == 0.0


@register_delay("shifted_exponential")
class ShiftedExponentialDelay(DelayModel):
    """Deterministic floor + exponential tail: ``base * (1 + Exp(tail_mean))``.

    ``tail_mean`` is the mean of the exponential tail as a fraction of the
    deterministic base, so the expected round time is ``base * (1 +
    tail_mean)`` and no sample is ever faster than ``base``.
    """

    vector_sampled = True

    def __init__(self, cluster, *, tail_mean: float = 0.5):
        super().__init__(cluster)
        if tail_mean < 0:
            raise ValueError(f"tail_mean must be >= 0, got {tail_mean}")
        self.tail_mean = tail_mean

    def compute_time(self, k, H, rng):
        base = self.base_compute(k, H)
        return base * (1.0 + float(rng.exponential(self.tail_mean)))

    def sample_round(self, H, rng):
        # One size-K draw; numpy Generators make it bit-equal to K scalar
        # draws (pinned by tests/test_delays.py), so per-call and per-round
        # consumers see the same stream.
        K = self.cluster.num_workers
        return self.base_compute_vector(H) * (
            1.0 + rng.exponential(self.tail_mean, size=K))


@register_delay("pareto")
class ParetoDelay(DelayModel):
    """Heavy-tailed delays: ``base * (1 + scale * Pareto(shape))``.

    ``numpy``'s ``rng.pareto(a)`` samples the Lomax form (support ``[0,
    inf)``, mean ``1/(a-1)`` for ``a > 1``), so the expected round time is
    ``base * (1 + scale / (shape - 1))`` -- but unlike the exponential tail,
    extreme stragglers occur at polynomial (not exponential) rarity.
    """

    vector_sampled = True

    def __init__(self, cluster, *, shape: float = 2.5, scale: float = 0.25):
        super().__init__(cluster)
        if shape <= 0 or scale < 0:
            raise ValueError(
                f"need shape > 0 and scale >= 0, got {shape}, {scale}")
        self.shape = shape
        self.scale = scale

    def compute_time(self, k, H, rng):
        base = self.base_compute(k, H)
        return base * (1.0 + self.scale * float(rng.pareto(self.shape)))

    def sample_round(self, H, rng):
        K = self.cluster.num_workers
        return self.base_compute_vector(H) * (
            1.0 + self.scale * rng.pareto(self.shape, size=K))


@register_delay("markov")
class MarkovDelay(DelayModel):
    """Bursty stragglers: a hidden 2-state (fast/slow) Markov chain per worker.

    Each ``compute_time`` call advances worker ``k``'s chain one step:
    a fast worker turns slow with probability ``p_slow``; a slow worker
    recovers with probability ``p_recover``; while slow, compute is
    ``slow_factor`` x the base.  Stationary slow fraction =
    ``p_slow / (p_slow + p_recover)``; mean burst length = ``1 / p_recover``
    rounds.  Stateful -- use a fresh instance per run
    (:meth:`ClusterModel.make_delay`, which the engine protocols do).
    """

    stateful = True

    def __init__(self, cluster, *, p_slow: float = 0.1, p_recover: float = 0.3,
                 slow_factor: float = 5.0):
        super().__init__(cluster)
        for nm, p in (("p_slow", p_slow), ("p_recover", p_recover)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{nm} must be in [0, 1], got {p}")
        if slow_factor <= 0:
            raise ValueError(f"slow_factor must be > 0, got {slow_factor}")
        self.p_slow = p_slow
        self.p_recover = p_recover
        self.slow_factor = slow_factor
        self.slow = np.zeros(cluster.num_workers, dtype=bool)

    def compute_time(self, k, H, rng):
        u = rng.random()
        if self.slow[k]:
            if u < self.p_recover:
                self.slow[k] = False
        elif u < self.p_slow:
            self.slow[k] = True
        base = self.base_compute(k, H)
        return base * (self.slow_factor if self.slow[k] else 1.0)


@register_delay("bandwidth_coupled")
class BandwidthCoupledDelay(ConstantDelay):
    """Stragglers are slow LINKS, not slow CPUs: message time scales with the
    actual payload bytes over a per-worker link speed.

    Workers in ``ClusterModel.straggler_workers`` sit behind a
    ``link_slowdown`` x slower NIC; everyone's compute follows the
    ``constant`` model with ``sigma_k = 1`` semantics left to the cluster's
    own fields.  Because delay is billed on the same ``nbytes`` the
    compressor's ``wire_bytes``/``payload_bytes`` accounting produced, a
    sparser or quantized payload directly shrinks the straggler's delay --
    the compressor <-> delay coupling the paper's communication-efficiency
    argument is about.
    """

    worker_aware = True

    def __init__(self, cluster, *, link_slowdown: float = 10.0):
        super().__init__(cluster)
        if link_slowdown <= 0:
            raise ValueError(f"link_slowdown must be > 0, got {link_slowdown}")
        self.link_slowdown = link_slowdown
        self._slow = np.ones(cluster.num_workers)
        for k in cluster.straggler_workers:
            if 0 <= k < cluster.num_workers:
                self._slow[k] = link_slowdown

    def p2p_time(self, nbytes, k=None):
        factor = 1.0 if k is None else self._slow[k]
        return self.cluster.latency + nbytes * factor / self.cluster.bandwidth

    def link_factors(self):
        return self._slow.copy()

    def allreduce_time(self, d, value_bytes=4):
        # A ring all-reduce moves at the pace of its slowest link.
        c = self.cluster
        K = c.num_workers
        if K <= 1:
            return 0.0
        ring = 2.0 * (K - 1) / K * d * value_bytes / c.bandwidth
        return (ring * float(self._slow.max())
                + 2.0 * math.ceil(math.log2(K)) * c.latency)
