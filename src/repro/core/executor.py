"""Scan-fused lockstep executor: whole runs as ONE compiled computation.

The event engine (:mod:`repro.core.engine` driven by
:class:`repro.api.session.Session`) pays one jitted dispatch per worker group
and per server round.  That is already ~100x fewer host<->device round trips
than the reference loops, but for protocols with *no data-dependent host
control flow* even the per-round dispatch is overhead: the entire run can be
a single ``lax.scan`` over rounds.  This module is that second execution
backend -- selected via ``Session(executor="scan")`` or automatically under
``executor="auto"`` (the default).

Three scan paths:

* **Lockstep** (``sync`` / ``cocoa`` / ``cocoa_plus``): every round is a
  K-barrier with static byte accounting, so timing is fully host-computable.
  Compute-time streams are pre-sampled by
  :meth:`repro.core.delays.DelayModel.sample_stream` (same host-RNG order as
  the event loop, so trajectories are bit-identical), the model state
  ``(w, alpha)`` evolves in one donated scan dispatch, and deferred gap
  certificates reuse the engine's bucketed ``lax.map`` evaluation.

* **LAG** (``lag``): B-of-K arrivals couple timing to device values (reply
  ``nnz`` -> reply bytes -> link time -> arrival order), so the *event queue
  itself* moves in-graph: per-worker arrival times and sequence numbers live
  in the scan carry, the B earliest messages are selected with a
  lexicographic ``lax.sort``, and all timing arithmetic runs in float64 on
  device (traced under ``jax.experimental.enable_x64``; model math stays
  explicitly float32, and ``sdca`` pins its PRNG dtypes, so the f32
  trajectory is bit-identical to the event executor's).  Eligible whenever
  the delay model can pre-sample ``(round, worker)`` compute times without
  changing the event executor's RNG stream (``sample_stream`` contract);
  ``markov`` and jittered ``constant`` cannot, and ``executor="auto"`` falls
  back to the event queue for them.

* **partial_work** (``partial_work``): the lag machinery generalized to
  per-CHUNK carries -- every in-flight chunk's payload/arrival/seq lives in
  the scan state, the round deadline is the B-th *full* arrival (a lex sort
  over final-chunk keys), and harvested chunks fold in via a flattened
  ``K x n_chunks`` arrival-order sort.  Eligible when the delay model can
  pre-sample a (round, chunk, worker) stream
  (:meth:`repro.core.delays.DelayModel.sample_chunk_stream`), there is no
  elastic membership schedule, and no ``pw_quantum`` harvest tick (both are
  host-adaptive and keep the event queue).

Protocols with genuinely host-adaptive control flow (``group``'s
interleaved accounting pins, ``async``, ``adaptive_b``'s observed-latency
feedback, ``hierarchical_b``'s rack-dependent pop counts) keep the event
queue -- they still benefit from the engine's fused multi-arrival server
apply and one-dispatch group relaunches.

``target_gap`` early stop is scan-capable for lockstep runs: the duality-gap
certificate moves in-graph and a ``done`` flag in the carry freezes the
state once the target is reached (:func:`lockstep_run_gap_traced`,
compute-and-mask with post-hoc truncation).  The traced run bodies
(:func:`lockstep_run_traced`, :func:`lag_run_traced`, and the
worker-sharded :func:`lockstep_run_traced_sharded`) are also the building
blocks of :func:`repro.api.sweep.run_sweep`, which maps/vmaps them across
whole protocol x delay x seed x gamma grids and can shard the batched axes
over a device mesh.

Bit-for-bit contract: for every supported (protocol, delay) cell the scan
executor reproduces the event executor's ``RunResult`` exactly --
trajectories, byte/time accounting, and gap certificates (pinned by
tests/test_executor.py across the zoo grid).  ``STATS`` counts compiled-call
and retrace events so tests can assert the one-dispatch-per-run contract.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import zlib
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compress as compress_lib
from repro.core import engine
from repro.core import objectives
from repro.core.acpd import MethodConfig, RunResult
from repro.core.simulate import ClusterModel

LOCKSTEP_PROTOCOLS = ("sync", "cocoa", "cocoa_plus")
# Protocols whose traced run bodies batch into shared sweep cells
# (repro.api.sweep / the serve coalescer): one computation, many variants.
SWEEP_PROTOCOLS = LOCKSTEP_PROTOCOLS + ("lag",)
# Protocols with a single-run scan backend.  partial_work scans solo (its
# per-chunk carries are per-run state) but does NOT batch into sweep cells.
SCAN_PROTOCOLS = SWEEP_PROTOCOLS + ("partial_work",)

# target_gap runs on the scan backend compute-and-mask: every budgeted round
# executes even after the target is hit, so for huge budgets the masked tail
# can dwarf the dispatch overhead the scan saves.  ``executor="auto"`` only
# picks the gap scan up to this round budget and keeps the event loop (which
# stops at the hit) beyond it; forcing ``executor="scan"`` overrides.
GAP_SCAN_AUTO_MAX_ROUNDS = 4096

# Dispatch accounting for the 1-dispatch-per-run contract: "*_calls" counts
# compiled executions (one per run), "*_traces" counts retraces (flat across
# same-shape runs).  tests/test_executor.py + tests/test_sweep.py assert on
# these.  The sweep counters live here (not in repro.api.sweep) so one reset
# covers every scan-family entry point.
STATS = {"lockstep_calls": 0, "lockstep_traces": 0,
         "lockstep_gap_calls": 0, "lockstep_gap_traces": 0,
         "lockstep_segment_calls": 0, "lockstep_segment_traces": 0,
         "lag_calls": 0, "lag_traces": 0,
         "partial_calls": 0, "partial_traces": 0,
         "sweep_calls": 0, "sweep_traces": 0,
         "sweep_lag_calls": 0, "sweep_lag_traces": 0}


def reset_stats() -> None:
    for k in STATS:
        STATS[k] = 0


# ---------------------------------------------------------------------------
# Eligibility.
# ---------------------------------------------------------------------------


def scan_supported(method: MethodConfig, cluster: ClusterModel, *,
                   eval_mode: str = "batched",
                   target_gap: float | None = None,
                   time_budget: float | None = None) -> tuple[bool, str]:
    """Can this run compile to one scan?  Returns (ok, reason-if-not).

    ``target_gap`` early stop is scan-capable for the lockstep protocols:
    the duality-gap certificate moves in-graph and a ``done`` flag in the
    scan carry freezes the state once the target is reached
    (compute-and-mask; see :func:`lockstep_run_gap_traced`).  ``lag`` and
    the group family keep the event loop for early stop, as does
    ``time_budget`` (its stop point depends on interleaved host accounting).
    """
    if method.exact_dual_feedback:
        return False, ("exact_dual_feedback needs a host lstsq per round "
                       "(reference path only)")
    if time_budget is not None:
        return False, "time_budget early stop needs the per-round event loop"
    if target_gap is not None:
        if method.protocol not in LOCKSTEP_PROTOCOLS:
            return False, (
                f"target_gap early stop compiles in-graph only for lockstep "
                f"protocols {LOCKSTEP_PROTOCOLS}; {method.protocol!r} needs "
                f"the per-round event loop")
    elif eval_mode == "stream":
        return False, ("streamed certificates without a gap target need "
                       "the per-round event loop")
    if method.protocol in LOCKSTEP_PROTOCOLS:
        return True, ""
    if method.protocol == "lag":
        model = cluster.make_delay()
        if model.vector_sampled or model.deterministic:
            return True, ""
        return False, (
            f"delay model {cluster.delay_model!r} draws per-launch host "
            f"randomness in arrival order, which cannot be pre-sampled "
            f"into a (round, worker) stream")
    if method.protocol == "partial_work":
        if cluster.membership:
            return False, ("elastic membership drop/rejoin schedules are "
                           "host-adaptive control flow (event loop only)")
        if method.pw_quantum is not None:
            return False, ("pw_quantum harvest ticks pop clock-dependent "
                           "arrival counts (event loop only)")
        model = cluster.make_delay()
        if model.vector_sampled or model.deterministic:
            return True, ""
        return False, (
            f"delay model {cluster.delay_model!r} draws per-launch host "
            f"randomness in arrival order, which cannot be pre-sampled "
            f"into a (round, chunk, worker) stream")
    return False, (
        f"protocol {method.protocol!r} has host-adaptive control flow "
        f"(scan-capable protocols: {SCAN_PROTOCOLS})")


def coalesce_supported(method: MethodConfig, cluster: ClusterModel, *,
                       target_gap: float | None = None,
                       time_budget: float | None = None) -> tuple[bool, str]:
    """Can this (method, cluster) join a SHARED sweep batch?  (ok, why-not).

    The serve-layer admission check (:mod:`repro.serve`): a coalesced batch
    compiles whole fixed-length runs for many tenants at once, so it is
    strictly narrower than :func:`scan_supported` -- early-stopped runs
    never coalesce (their round count is data-dependent; a stopping tenant
    would either truncate or pad every cohort cell), even though a solo
    lockstep ``target_gap`` run can scan.  Ineligible requests are still
    servable, one :class:`repro.api.Session` per request (the solo lane).

    Per-protocol eligibility is the registry's
    :meth:`repro.core.engine.Protocol.coalesce_supported` hook (the
    ``registry-hooks`` analyzer rule requires it on new entries), so a new
    protocol states its own batching story instead of inheriting a silent
    default here -- ``partial_work`` scans solo but declines coalescing (its
    per-chunk carries are per-run state, not shared sweep cells).
    """
    if target_gap is not None:
        return False, ("target_gap early stop makes the round count "
                       "data-dependent; batches compile fixed-length runs "
                       "-- served per-request instead")
    if time_budget is not None:
        return False, ("time_budget early stop needs the per-round event "
                       "loop -- served per-request instead")
    return engine.get_protocol(method.protocol).coalesce_supported(
        method, cluster)


# ---------------------------------------------------------------------------
# Run container handed back to the Session.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RoundAccount:
    """Host-side accounting of one server round (cumulative totals)."""

    arrivals: int
    is_sync: bool
    sim_time: float
    bytes_up: int
    bytes_down: int
    compute_time: float
    comm_time: float


@dataclasses.dataclass
class ScanRun:
    """Everything a Session needs to emit the run's event stream.

    ``eval_ws``/``eval_alphas`` hold the eval-boundary snapshots as ONE
    stacked array each (gathered from the scan outputs in a single op --
    per-snapshot slicing would reintroduce an O(rounds) dispatch tail).
    """

    method: MethodConfig
    rounds: list[RoundAccount]
    eval_rounds: list[int]  # 0-based round index per eval boundary
    eval_ws: jax.Array | None
    eval_alphas: jax.Array | None
    w: jax.Array
    alpha: jax.Array
    alpha_applied: jax.Array | None = None
    # target_gap runs: why/when the run stopped, plus the records already
    # materialized from the in-graph certificates (nothing left to defer).
    stop_reason: str = "completed"
    stream_records: list | None = None

    def materialize_records(self, problem, eval_mode: str):
        """The run's RunRecords; same certificate ops as the event path
        (``batched``: one bucketed ``lax.map``; ``replay``: eager oracle).
        target_gap runs computed their certificates in-graph and carry the
        finished records (``stream_records``)."""
        from repro.core.acpd import RunRecord

        if self.stream_records is not None:
            return self.stream_records
        if not self.eval_rounds:
            return []
        if eval_mode == "replay":
            rows = []
            for i in range(len(self.eval_rounds)):
                cert = objectives.gap_certificate(
                    problem, self.eval_alphas[i], w=self.eval_ws[i])
                rows.append((cert["primal"], cert["dual"], cert["gap"],
                             cert["gap_server"]))
        elif eval_mode == "batched":
            p, dv, gap, gap_srv = engine._eval_bucketed(
                self.eval_ws, self.eval_alphas, problem.X, problem.y,
                problem.lam, loss=problem.loss)
            rows = list(zip(np.asarray(p, np.float64),
                            np.asarray(dv, np.float64),
                            np.asarray(gap, np.float64),
                            np.asarray(gap_srv, np.float64)))
        else:
            raise ValueError(f"unknown eval_mode {eval_mode!r}")
        records = []
        for r, (p_, dv_, gap_, gs_) in zip(self.eval_rounds, rows):
            a = self.rounds[r]
            records.append(RunRecord(
                iteration=r + 1, sim_time=a.sim_time, gap=float(gap_),
                gap_server=float(gs_), primal=float(p_), dual=float(dv_),
                bytes_up=a.bytes_up, bytes_down=a.bytes_down,
                compute_time=a.compute_time, comm_time=a.comm_time))
        return records

    def finalize(self, records) -> RunResult:
        return RunResult(
            self.method, records, np.asarray(self.w), np.asarray(self.alpha),
            alpha_applied=(None if self.alpha_applied is None
                           else np.asarray(self.alpha_applied)))


def run_scan(problem: objectives.Problem, method: MethodConfig,
             cluster: ClusterModel, *, num_outer: int, seed: int,
             eval_every: int, norms_sq=None,
             target_gap: float | None = None) -> ScanRun:
    """Execute one run on the scan backend (caller checked eligibility).

    ``norms_sq``: optional precomputed per-row squared norms (the Session's
    protocol instance already holds them; passing them avoids a second full
    pass over ``X``).  ``target_gap``: gap early stop, lockstep only (the
    certificate moves in-graph; see :func:`lockstep_run_gap_traced`).
    """
    if norms_sq is None:
        norms_sq = jnp.sum(problem.X * problem.X, axis=-1)
    if method.protocol in LOCKSTEP_PROTOCOLS:
        return _run_lockstep(problem, method, cluster, num_outer=num_outer,
                             seed=seed, eval_every=eval_every,
                             norms_sq=norms_sq, target_gap=target_gap)
    if target_gap is not None:
        raise ValueError(
            f"target_gap early stop on the scan backend is lockstep-only; "
            f"{method.protocol!r} runs it through the event loop")
    if method.protocol == "lag":
        return _run_lag(problem, method, cluster, num_outer=num_outer,
                        seed=seed, eval_every=eval_every, norms_sq=norms_sq)
    if method.protocol == "partial_work":
        return _run_partial(problem, method, cluster, num_outer=num_outer,
                            seed=seed, eval_every=eval_every,
                            norms_sq=norms_sq)
    raise ValueError(f"protocol {method.protocol!r} is not scan-capable "
                     f"(supported: {SCAN_PROTOCOLS})")


def _eval_indices(num_rounds: int, eval_every: int) -> list[int]:
    """0-based round indices of eval boundaries (iteration % eval_every == 0)."""
    return [it - 1 for it in range(1, num_rounds + 1) if it % eval_every == 0]


# ---------------------------------------------------------------------------
# Lockstep path: sync / cocoa / cocoa_plus.
# ---------------------------------------------------------------------------


def lockstep_run_traced(key, X, y, norms_sq, lam, n, sigma_p, gamma, *, loss,
                        num_steps, solver, length):
    """The whole lockstep run as a traced computation (scan over rounds,
    workers vmapped inside each round).

    The round body IS the event engine's (``engine._lockstep_round``, the
    single definition both backends inline -- scalars stay traced operands;
    constant-folding them changes XLA's simplifications and breaks
    bit-equality).  Shared by the single-run jit below and the batched sweep
    runner (:mod:`repro.api.sweep`), which maps/vmaps it over run variants.
    """
    K, n_k, d = X.shape
    w0 = jnp.zeros((d,), X.dtype)
    alpha0 = jnp.zeros((K, n_k), X.dtype)

    def step(carry, _):
        key, w, alpha = carry
        key, w, alpha = engine._lockstep_round(
            key, w, alpha, X, y, norms_sq, lam, n, sigma_p, gamma, loss=loss,
            num_steps=num_steps, solver=solver)
        return (key, w, alpha), (w, alpha)

    (key, w, alpha), (ws, alphas) = jax.lax.scan(
        step, (key, w0, alpha0), None, length=length)
    return w, alpha, ws, alphas


def lockstep_run_traced_sharded(key, X, y, norms_sq, lam, n, sigma_p, gamma,
                                *, loss, num_steps, solver, length, axis,
                                num_workers):
    """:func:`lockstep_run_traced` on ONE worker shard of a device mesh.

    Runs inside ``shard_map`` with the worker axis partitioned over mesh
    axis ``axis``: ``X``/``y``/``norms_sq`` are the local ``(K_loc, n_k, d)``
    blocks, ``w`` stays replicated, and each round does exactly one
    cross-shard reduction (the ``psum`` of the shard-local ``sum_k v_k``).
    The PRNG split chain is the global one -- every shard splits the full
    ``num_workers`` keys and slices its block by ``axis_index`` -- so each
    worker sees the same key as the unsharded run.  Per-shard ops keep
    unbatched per-worker shapes inside the local vmap, so kernel-backed
    solvers (e.g. the Pallas SDCA inner loop in
    :mod:`repro.kernels.sdca_inner`) drop in per shard unchanged.

    The partial-sum + psum association differs from the unsharded
    ``sum(v, axis=0)``, so results are deterministic for a fixed mesh but
    NOT bit-identical to ``shard="none"`` -- a perf mode, like
    ``batch="vmap"`` (tests pin allclose agreement instead).
    """
    K_loc, n_k, d = X.shape
    w0 = jnp.zeros((d,), X.dtype)
    alpha0 = jnp.zeros((K_loc, n_k), X.dtype)
    shard = jax.lax.axis_index(axis)

    def step(carry, _):
        key, w, alpha = carry
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, num_workers)
        local_keys = jax.lax.dynamic_slice_in_dim(keys, shard * K_loc, K_loc)
        dalpha, v = engine._lockstep_local_solves(
            w, alpha, X, y, norms_sq, lam, n, sigma_p, local_keys, loss=loss,
            num_steps=num_steps, solver=solver)
        alpha = alpha + gamma * dalpha
        w = w + gamma * jax.lax.psum(jnp.sum(v, axis=0), axis)
        return (key, w, alpha), (w, alpha)

    (key, w, alpha), (ws, alphas) = jax.lax.scan(
        step, (key, w0, alpha0), None, length=length)
    return w, alpha, ws, alphas


@partial(jax.jit, static_argnames=("loss", "num_steps", "solver", "length"))
def _lockstep_scan(key, X, y, norms_sq, lam, n, sigma_p, gamma, *, loss,
                   num_steps, solver, length):
    STATS["lockstep_traces"] += 1  # trace-time side effect, not per call
    return lockstep_run_traced(key, X, y, norms_sq, lam, n, sigma_p, gamma,
                               loss=loss, num_steps=num_steps, solver=solver,
                               length=length)


def gap_floor_f32(target_gap: float) -> np.float32:
    """The largest float32 ``t`` with ``float(t) <= target_gap``.

    The event loop's early stop compares ``float(gap_f32) <= target_gap`` in
    float64; the in-graph test compares float32 against float32.  Flooring
    the target to the f32 grid makes the two predicates decide identically
    for every representable gap value, so the executors stop on the same
    round bit-for-bit.
    """
    t = np.float32(target_gap)
    if float(t) > target_gap:
        t = np.nextafter(t, np.float32(-np.inf), dtype=np.float32)
    return t


def lockstep_run_gap_traced(key, X, y, norms_sq, lam, n, sigma_p, gamma,
                            gap_target, eval_mask, *, loss, num_steps, solver,
                            length):
    """Lockstep run with in-graph duality-gap early stop, as one scan.

    The round body is the shared :func:`engine._lockstep_round`; at eval
    boundaries (``eval_mask``, a static-per-round bool stream) the duality
    gap certificate is computed in-graph via the shared
    :func:`engine._certificate_ops`, and a ``done`` flag in the
    carry freezes ``(w, alpha)`` once the gap reaches ``gap_target``
    (compute-and-mask: later rounds still execute but write nothing).  The
    caller truncates the per-round outputs at the stop boundary post hoc --
    trajectories and certificates up to the stop are bit-identical to the
    event loop's streamed path (pinned by tests/test_executor.py).

    ``gap_target`` must be pre-floored to the f32 grid
    (:func:`gap_floor_f32`) so the f32 comparison decides like the host's
    f64 one.
    """
    K, n_k, d = X.shape
    w0 = jnp.zeros((d,), X.dtype)
    alpha0 = jnp.zeros((K, n_k), X.dtype)

    def certify(args):
        w, alpha = args
        return engine._certificate_ops(w, alpha, X, y, lam, loss=loss)

    def no_cert(args):
        z = jnp.zeros((), args[0].dtype)
        return z, z, z, z

    def step(carry, is_eval):
        key, w, alpha, done = carry
        key, w_new, alpha_new = engine._lockstep_round(
            key, w, alpha, X, y, norms_sq, lam, n, sigma_p, gamma, loss=loss,
            num_steps=num_steps, solver=solver)
        w = jnp.where(done, w, w_new)
        alpha = jnp.where(done, alpha, alpha_new)
        do_cert = is_eval & ~done
        p, dv, gap, gap_srv = jax.lax.cond(do_cert, certify, no_cert,
                                           (w, alpha))
        done = done | (do_cert & (gap <= gap_target))
        return (key, w, alpha, done), (p, dv, gap, gap_srv, done)

    (key, w, alpha, done), ys = jax.lax.scan(
        step, (key, w0, alpha0, jnp.zeros((), bool)), eval_mask,
        length=length)
    return w, alpha, ys


@partial(jax.jit, static_argnames=("loss", "num_steps", "solver", "length"))
def _lockstep_gap_scan(key, X, y, norms_sq, lam, n, sigma_p, gamma,
                       gap_target, eval_mask, *, loss, num_steps, solver,
                       length):
    STATS["lockstep_gap_traces"] += 1  # trace-time side effect, not per call
    return lockstep_run_gap_traced(key, X, y, norms_sq, lam, n, sigma_p,
                                   gamma, gap_target, eval_mask, loss=loss,
                                   num_steps=num_steps, solver=solver,
                                   length=length)


def lockstep_solver(method: MethodConfig):
    """The local solver a lockstep protocol runs: the CoCoA lineage swaps it
    via ``MethodConfig.local_solver``; the hard-wired ``sync`` entry is the
    registry's ``sdca`` (the same vmapped computation)."""
    from repro.core import solvers as solvers_lib

    return solvers_lib.get_solver(
        method.local_solver if method.protocol != "sync" else "sdca")


def lockstep_accounts(method: MethodConfig, cluster: ClusterModel, d: int,
                      *, num_rounds: int, seed: int) -> list[RoundAccount]:
    """Host-side timing/byte accounting of a lockstep run.

    Fully independent of device values: compute streams are pre-sampled
    (same host-RNG order as the event loop's one-K-vector-per-round draws,
    so the floats are bit-identical), allreduce time and ring bytes are
    static per round.
    """
    K = cluster.num_workers
    delay = cluster.make_delay()
    rng = np.random.default_rng(seed)
    durations = delay.sample_stream(num_rounds, method.H, rng, lockstep=True)
    step_comm = delay.allreduce_time(d)
    phase = (K - 1) * d * 4  # ring reduce-scatter == all-gather
    sim = comp_t = comm_t = 0.0
    bu = bd = 0
    rounds: list[RoundAccount] = []
    for r in range(num_rounds):
        step_compute = float(np.max(durations[r]))
        sim += step_compute + step_comm
        comp_t += step_compute
        comm_t += step_comm
        bu += phase
        bd += phase
        rounds.append(RoundAccount(K, True, sim, bu, bd, comp_t, comm_t))
    return rounds


def _run_lockstep(problem, method, cluster, *, num_outer, seed, eval_every,
                  norms_sq, target_gap=None):
    K, n_k, d = problem.X.shape
    R = num_outer
    if R == 0:
        dt = problem.X.dtype
        return ScanRun(method, [], [], None, None, jnp.zeros((d,), dt),
                       jnp.zeros((K, n_k), dt))
    rounds = lockstep_accounts(method, cluster, d, num_rounds=R, seed=seed)
    sigma_p = method.resolved_sigma_prime(K)
    if target_gap is not None:
        return _run_lockstep_gap(problem, method, rounds, sigma_p,
                                 num_outer=R, seed=seed,
                                 eval_every=eval_every, norms_sq=norms_sq,
                                 target_gap=target_gap)
    STATS["lockstep_calls"] += 1
    w, alpha, ws, alphas = _lockstep_scan(
        jax.random.key(seed), problem.X, problem.y, norms_sq, problem.lam,
        K * n_k, sigma_p, method.gamma, loss=problem.loss,
        num_steps=method.H, solver=lockstep_solver(method), length=R)

    evals = _eval_indices(R, eval_every)
    idx = jnp.asarray(evals, jnp.int32)
    return ScanRun(method, rounds, evals, ws[idx], alphas[idx], w, alpha)


def _run_lockstep_gap(problem, method, rounds, sigma_p, *, num_outer, seed,
                      eval_every, norms_sq, target_gap):
    """Lockstep + target_gap: one gap-scan dispatch, records truncated at the
    stop boundary from the in-graph certificates."""
    from repro.core.acpd import RunRecord

    R = num_outer
    eval_mask = np.asarray([(r + 1) % eval_every == 0 for r in range(R)])
    STATS["lockstep_gap_calls"] += 1
    w, alpha, ys = _lockstep_gap_scan(
        jax.random.key(seed), problem.X, problem.y, norms_sq, problem.lam,
        problem.n, sigma_p, method.gamma, gap_floor_f32(target_gap),
        jnp.asarray(eval_mask), loss=problem.loss, num_steps=method.H,
        solver=lockstep_solver(method), length=R)
    p, dv, gap, gap_srv = (np.asarray(a, np.float64) for a in ys[:4])
    done = np.asarray(ys[4])
    hit = bool(done.any())
    stop = int(np.argmax(done)) if hit else R - 1
    records = []
    for r in range(stop + 1):
        if not eval_mask[r]:
            continue
        a = rounds[r]
        records.append(RunRecord(
            iteration=r + 1, sim_time=a.sim_time, gap=float(gap[r]),
            gap_server=float(gap_srv[r]), primal=float(p[r]),
            dual=float(dv[r]), bytes_up=a.bytes_up, bytes_down=a.bytes_down,
            compute_time=a.compute_time, comm_time=a.comm_time))
    return ScanRun(method, rounds[:stop + 1], [], None, None, w, alpha,
                   stop_reason="target_gap" if hit else "completed",
                   stream_records=records)


# ---------------------------------------------------------------------------
# LAG path: the B-of-K event queue in-graph.
# ---------------------------------------------------------------------------


def lag_run_traced(key, X, y, norms_sq, lam, n, sigma_p, gamma, xi, durations,
                   needs, up_bytes, heartbeat_bytes, latency,
                   bandwidth, link_factors, *, loss, num_steps, comp, length,
                   lag_window, dense_reply_bytes):
    """The whole LAG run as a traced computation: in-graph B-of-K event queue.

    Carries per-worker in-flight message state (payload, arrival time f64,
    sequence number) alongside the model state; each round sorts arrivals
    lexicographically by ``(arrival, seq)`` -- exactly the host heap's pop
    order -- applies the group with the event engine's op sequence, then
    relaunches the arrived workers under a ``lax.cond``-guarded rank scan
    that splits the global PRNG key only for launched workers (the event
    path's sequential split chain).  Must be traced under ``enable_x64`` so
    the timing arithmetic is float64 like the host's; all model math is
    pinned float32.  ``dense_reply_bytes`` is 0 for sparse compressors
    (replies billed on in-graph nnz) or the static dense byte count.

    Shared by the single-run jit below and the batched sweep runner
    (:mod:`repro.api.sweep`), which maps/vmaps it over delay x seed x gamma
    cells -- durations, link factors and latency/bandwidth are traced
    operands, so a whole delay-model axis batches into one computation.
    """
    K, n_k, d = X.shape
    dt = X.dtype
    f64 = jnp.float64
    i64 = jnp.int64
    iota = jnp.arange(K, dtype=i64)

    def launch(args, *, initial):
        """Rank-scan relaunching the first ``need`` ranks of ``order``."""
        (key, alpha, residual, payload, applied, arrival, seq, seq_ctr,
         bytes_up, bytes_down, compute_t, comm_t, ref_buf, ref_len, w_local,
         need, order, starts, reply_bytes, down_times, dur_row) = args

        def do_launch(carry, xs):
            (key, alpha, residual, payload, applied, arrival, seq,
             compute_t, comm_t, bytes_up, bytes_down) = carry
            j, k, start, rbytes, down_t = xs
            ref_k = engine._lag_reference(ref_buf[k], ref_len[k], xi)
            key, alpha_k, res_k, dw, sent = engine._local_round(
                key, w_local, alpha[k], residual[k], X[k], y[k], norms_sq[k],
                k, lam, n, sigma_p, gamma, loss=loss, num_steps=num_steps,
                comp=comp)
            send_sq = jnp.vdot(sent, sent)
            skip = send_sq < ref_k
            sent = jnp.where(skip, jnp.zeros_like(sent), sent)
            res_k = jnp.where(skip, dw, res_k)
            nbytes = jnp.where(skip, heartbeat_bytes, up_bytes)
            # Host accounting replica, per worker in arrival order:
            # down-billing, compute, up-billing (the reference float order).
            dur = dur_row[k]
            up_t = latency + nbytes * link_factors[k] / bandwidth
            bytes_down = bytes_down + rbytes
            comm_t = comm_t + down_t
            compute_t = compute_t + dur
            comm_t = comm_t + up_t
            bytes_up = bytes_up + nbytes
            alpha = alpha.at[k].set(alpha_k)
            residual = residual.at[k].set(res_k)
            payload = payload.at[k].set(sent)
            applied = applied.at[k].set(~skip)
            arrival = arrival.at[k].set(start + dur + up_t)
            seq = seq.at[k].set(seq_ctr + j + 1)
            return (key, alpha, residual, payload, applied, arrival, seq,
                    compute_t, comm_t, bytes_up, bytes_down), None

        def no_op(carry, xs):
            return carry, None

        def rank_body(carry, xs):
            return jax.lax.cond(xs[0] < need, do_launch, no_op, carry, xs)

        init = (key, alpha, residual, payload, applied, arrival, seq,
                compute_t, comm_t, bytes_up, bytes_down)
        if initial:
            # No ambiguity on the first launch: every worker, worker order.
            out, _ = jax.lax.scan(do_launch, init,
                                  (iota, order, starts, reply_bytes,
                                   down_times))
        else:
            out, _ = jax.lax.scan(rank_body, init,
                                  (iota, order, starts, reply_bytes,
                                   down_times))
        (key, alpha, residual, payload, applied, arrival, seq, compute_t,
         comm_t, bytes_up, bytes_down) = out
        return (key, alpha, residual, payload, applied, arrival, seq,
                seq_ctr + need, bytes_up, bytes_down, compute_t, comm_t)

    # --- initial state + the t=0 launch wave ------------------------------
    zero64 = jnp.zeros((), f64)
    state = dict(
        key=key,
        w_server=jnp.zeros((d,), dt),
        dw_tilde=jnp.zeros((K, d), dt),
        w_local=jnp.zeros((K, d), dt),
        alpha=jnp.zeros((K, n_k), dt),
        alpha_applied=jnp.zeros((K, n_k), dt),
        residual=jnp.zeros((K, d), dt),
        payload=jnp.zeros((K, d), dt),
        applied=jnp.ones((K,), bool),
        ref_buf=jnp.zeros((K, lag_window), dt),
        ref_len=jnp.zeros((K,), jnp.int32),
        arrival=jnp.zeros((K,), f64),
        seq=jnp.zeros((K,), i64),
        seq_ctr=jnp.zeros((), i64),
        bytes_up=jnp.zeros((), i64),
        bytes_down=jnp.zeros((), i64),
        compute_t=zero64,
        comm_t=zero64,
        sim_time=zero64,
    )
    (state["key"], state["alpha"], state["residual"], state["payload"],
     state["applied"], state["arrival"], state["seq"], state["seq_ctr"],
     state["bytes_up"], state["bytes_down"], state["compute_t"],
     state["comm_t"]) = launch(
        (state["key"], state["alpha"], state["residual"], state["payload"],
         state["applied"], state["arrival"], state["seq"], state["seq_ctr"],
         state["bytes_up"], state["bytes_down"], state["compute_t"],
         state["comm_t"], state["ref_buf"], state["ref_len"],
         state["w_local"], jnp.asarray(K, i64), iota, jnp.zeros((K,), f64),
         jnp.zeros((K,), i64), jnp.zeros((K,), f64), durations[0]),
        initial=True)

    # --- the round loop ---------------------------------------------------

    def round_step(carry, xs):
        s = dict(carry)
        need, dur_row = xs
        need = need.astype(i64)
        # Pop order: lexicographic (arrival, seq) -- the host heap's order.
        _, _, perm = jax.lax.sort((s["arrival"], s["seq"], iota), num_keys=2)
        sorted_arrival = s["arrival"][perm]
        server_time = sorted_arrival[need - 1]
        sel = iota < need

        # Aggregation, summed in arrival order over exactly `need` payloads.
        def agg(j, tot):
            return tot + s["payload"][perm[j]]

        total = jax.lax.fori_loop(0, need, agg, jnp.zeros((d,), dt))
        w_server = s["w_server"] + gamma * total
        dw_tilde = s["dw_tilde"] + gamma * total[None, :]

        snap_rows = s["alpha"][perm]  # == each message's dual snapshot
        app_rows = s["applied"][perm]
        mask = (sel & app_rows)[:, None]
        alpha_applied = s["alpha_applied"].at[perm].set(
            jnp.where(mask, snap_rows, s["alpha_applied"][perm]))
        replies = dw_tilde[perm]
        reply_nnz = jnp.sum(replies != 0, axis=1)
        reply_sq = jnp.sum(replies * replies, axis=1)
        w_rows = s["w_local"][perm]
        w_local = s["w_local"].at[perm].set(
            jnp.where(sel[:, None], w_rows + replies, w_rows))
        dw_tilde = dw_tilde.at[perm].set(
            jnp.where(sel[:, None], jnp.zeros_like(replies), dw_tilde[perm]))

        # Reply-energy windows (the op sequence of _lag_window_append,
        # masked to the arrived workers).
        rows = s["ref_buf"][perm]
        lens = s["ref_len"][perm]
        full = (lens >= lag_window)[:, None]
        shifted = jnp.where(full, jnp.roll(rows, -1, axis=1), rows)
        pos = jnp.minimum(lens, lag_window - 1)
        new_rows = shifted.at[jnp.arange(K), pos].set(reply_sq)
        ref_buf = s["ref_buf"].at[perm].set(
            jnp.where(sel[:, None], new_rows, rows))
        ref_len = s["ref_len"].at[perm].set(
            jnp.where(sel, jnp.minimum(lens + 1, lag_window), lens))

        # Reply billing per rank (same arithmetic as DelayModel.p2p_time).
        if dense_reply_bytes:
            reply_bytes = jnp.full((K,), dense_reply_bytes, i64)
        else:
            reply_bytes = (reply_nnz * 8).astype(i64)
        factors = link_factors[perm]
        down_times = latency + reply_bytes * factors / bandwidth
        starts = server_time + down_times

        (key, alpha, residual, payload, applied, arrival, seq, seq_ctr,
         bytes_up, bytes_down, compute_t, comm_t) = launch(
            (s["key"], s["alpha"], s["residual"], s["payload"], s["applied"],
             s["arrival"], s["seq"], s["seq_ctr"], s["bytes_up"],
             s["bytes_down"], s["compute_t"], s["comm_t"], ref_buf, ref_len,
             w_local, need, perm, starts, reply_bytes, down_times, dur_row),
            initial=False)

        s.update(key=key, w_server=w_server, dw_tilde=dw_tilde,
                 w_local=w_local, alpha=alpha, alpha_applied=alpha_applied,
                 residual=residual, payload=payload, applied=applied,
                 ref_buf=ref_buf, ref_len=ref_len, arrival=arrival, seq=seq,
                 seq_ctr=seq_ctr, bytes_up=bytes_up, bytes_down=bytes_down,
                 compute_t=compute_t, comm_t=comm_t, sim_time=server_time)
        ys = (w_server, alpha_applied, server_time, bytes_up, bytes_down,
              compute_t, comm_t)
        return s, ys

    state, ys = jax.lax.scan(round_step, state,
                             (needs, durations[1:]), length=length)
    return state, ys


@partial(jax.jit,
         static_argnames=("loss", "num_steps", "comp", "length", "lag_window",
                          "dense_reply_bytes"))
def _lag_scan(key, X, y, norms_sq, lam, n, sigma_p, gamma, xi, durations,
              needs, up_bytes, heartbeat_bytes, latency,
              bandwidth, link_factors, *, loss, num_steps, comp, length,
              lag_window, dense_reply_bytes):
    """One LAG run = one dispatch (jit over :func:`lag_run_traced`)."""
    STATS["lag_traces"] += 1  # trace-time side effect, not per call
    return lag_run_traced(key, X, y, norms_sq, lam, n, sigma_p, gamma, xi,
                          durations, needs, up_bytes, heartbeat_bytes,
                          latency, bandwidth, link_factors, loss=loss,
                          num_steps=num_steps, comp=comp, length=length,
                          lag_window=lag_window,
                          dense_reply_bytes=dense_reply_bytes)


def lag_needs(method: MethodConfig, K: int, num_rounds: int) -> np.ndarray:
    """Per-round arrival counts of a LAG run (B-of-K + T-periodic barrier)."""
    T = method.T
    return np.asarray([K if r % T == T - 1 else min(method.B, K)
                       for r in range(num_rounds)], np.int64)


def lag_durations(method: MethodConfig, cluster: ClusterModel, *,
                  num_rounds: int, seed: int):
    """Pre-sample a LAG run's compute stream; returns (durations, delay).

    Row 0 feeds the t=0 launch wave, row 1+r feeds round r -- exactly the
    event executor's one-sample_round-per-_launch_workers consumption.
    Raises when the delay model cannot pre-sample a (round, worker) stream
    (callers normally check :func:`scan_supported` first).
    """
    delay = cluster.make_delay()
    rng = np.random.default_rng(seed)
    durations = delay.sample_stream(num_rounds + 1, method.H, rng,
                                    lockstep=False)
    if durations is None:
        raise ValueError(
            f"delay model {cluster.delay_model!r} cannot pre-sample a "
            f"(round, worker) stream; use executor='event'")
    return durations, delay


def lag_accounts(needs: np.ndarray, T: int, sim, bu, bd, ct,
                 cm) -> list[RoundAccount]:
    """RoundAccounts from one lag run's per-round scan outputs (host arrays)."""
    sim = np.asarray(sim)
    bu, bd = np.asarray(bu), np.asarray(bd)
    ct, cm = np.asarray(ct), np.asarray(cm)
    return [RoundAccount(int(needs[r]), r % T == T - 1, float(sim[r]),
                         int(bu[r]), int(bd[r]), float(ct[r]), float(cm[r]))
            for r in range(len(needs))]


def _run_lag(problem, method, cluster, *, num_outer, seed, eval_every,
             norms_sq):
    from jax.experimental import enable_x64

    K, n_k, d = problem.X.shape
    T = method.T
    R = num_outer * T
    durations, delay = lag_durations(method, cluster, num_rounds=R, seed=seed)
    needs = lag_needs(method, K, R)
    comp = compress_lib.for_method(method, d)
    dense = isinstance(comp, compress_lib.Dense)
    up_bytes = comp.wire_bytes(d)
    sigma_p = method.resolved_sigma_prime(K)
    if R == 0:
        dt = problem.X.dtype
        return ScanRun(method, [], [], None, None, jnp.zeros((d,), dt),
                       jnp.zeros((K, n_k), dt),
                       alpha_applied=jnp.zeros((K, n_k), dt))

    STATS["lag_calls"] += 1
    with enable_x64():
        state, ys = _lag_scan(
            jax.random.key(seed), problem.X, problem.y, norms_sq,
            jnp.float32(problem.lam), jnp.int32(K * n_k),
            jnp.float32(sigma_p), jnp.float32(method.gamma),
            jnp.float32(method.lag_xi),
            jnp.asarray(durations, jnp.float64),
            jnp.asarray(needs, jnp.int64),
            jnp.asarray(up_bytes, jnp.int64),
            jnp.asarray(engine.LagProtocol.HEARTBEAT_BYTES, jnp.int64),
            jnp.asarray(cluster.latency, jnp.float64),
            jnp.asarray(cluster.bandwidth, jnp.float64),
            jnp.asarray(delay.link_factors(), jnp.float64),
            loss=problem.loss, num_steps=method.H, comp=comp, length=R,
            lag_window=method.lag_window,
            dense_reply_bytes=d * 4 if dense else 0)

    ws, alpha_applied_rows, sim, bu, bd, ct, cm = ys
    rounds = lag_accounts(needs, T, sim, bu, bd, ct, cm)
    evals = _eval_indices(R, eval_every)
    idx = jnp.asarray(evals, jnp.int32)
    return ScanRun(method, rounds, evals, ws[idx], alpha_applied_rows[idx],
                   state["w_server"], state["alpha"],
                   alpha_applied=state["alpha_applied"])


# ---------------------------------------------------------------------------
# partial_work path: the per-CHUNK B-of-K event queue in-graph.
# ---------------------------------------------------------------------------


def partial_run_traced(key, X, y, norms_sq, lam, n, sigma_p, gamma, durations,
                       needs, up_bytes, latency, bandwidth, link_factors, *,
                       loss, chunk_steps, comp, length, dense_reply_bytes):
    """The whole partial_work run as a traced computation.

    The lag scan's per-worker arrival/seq carries generalize to per-CHUNK
    ``(K, C)`` state: every in-flight chunk's payload, dual snapshot, arrival
    time and sequence number live in the carry, alongside a ``harvested``
    mask marking chunks the server already folded in.  Each round:

    * the round deadline is the ``need``-th FULL arrival -- a lexicographic
      ``lax.sort`` over the final chunks' ``(arrival, seq)`` keys (without an
      elastic membership schedule every worker always has its final chunk in
      flight, so the per-round pop counts are the host-computable
      ``lag_needs`` stream and scan eligibility holds);
    * every un-harvested chunk whose key is lex-<= the deadline key is
      aggregated, in global arrival order (a flattened ``K*C`` lex sort
      driving a where-masked ``fori_loop``, so the float summation order is
      exactly the event heap's pop order -- masked-out entries select the old
      accumulator rather than adding zeros, keeping the op stream identical);
    * only the ``need`` COMPLETED workers get catch-up replies and relaunch
      (the event path's ``_server_apply_partial`` + ``_launch_chunks`` op
      sequence: per rank, reply billing then per-chunk compute/up billing,
      one PRNG split per chunk, j-major chunk-minor).

    Must be traced under ``enable_x64`` like the lag path; model math stays
    float32, so the trajectory is bit-identical to the event executor's
    (pinned by tests/test_partial_work.py).
    """
    K, n_k, d = X.shape
    dt = X.dtype
    f64 = jnp.float64
    i64 = jnp.int64
    C = len(chunk_steps)
    KC = K * C
    iota = jnp.arange(K, dtype=i64)
    kiota = jnp.arange(KC, dtype=i64)

    def launch(args, *, initial):
        """Rank-scan relaunching whole chunked passes for the first ``need``
        ranks of ``order`` (the completed workers, final-arrival order)."""
        (key, alpha, residual, payload, snaps, arrival, seq, harvested,
         seq_ctr, bytes_up, bytes_down, compute_t, comm_t, w_local, need,
         order, starts, reply_bytes, down_times, dur_wave) = args

        def do_launch(carry, xs):
            (key, alpha, residual, payload, snaps, arrival, seq, harvested,
             compute_t, comm_t, bytes_up, bytes_down) = carry
            j, k, start, rbytes, down_t = xs
            # Host accounting replica: reply billing first, then per chunk
            # compute/up billing (the event loop's float accumulation order).
            bytes_down = bytes_down + rbytes
            comm_t = comm_t + down_t
            up_t = latency + up_bytes * link_factors[k] / bandwidth
            alpha_k, res_k = alpha[k], residual[k]
            t = start
            pays, snps, arrs, seqs = [], [], [], []
            for c, h in enumerate(chunk_steps):
                key, alpha_k, res_k, _, sent = engine._local_round(
                    key, w_local, alpha_k, res_k, X[k], y[k], norms_sq[k],
                    k, lam, n, sigma_p, gamma, loss=loss, num_steps=h,
                    comp=comp)
                dur = dur_wave[c, k]
                compute_t = compute_t + dur
                comm_t = comm_t + up_t
                bytes_up = bytes_up + up_bytes
                t = t + dur
                pays.append(sent)
                snps.append(alpha_k)
                arrs.append(t + up_t)
                seqs.append(seq_ctr + j * C + c + 1)
            alpha = alpha.at[k].set(alpha_k)
            residual = residual.at[k].set(res_k)
            payload = payload.at[k].set(jnp.stack(pays))
            snaps = snaps.at[k].set(jnp.stack(snps))
            arrival = arrival.at[k].set(jnp.stack(arrs))
            seq = seq.at[k].set(jnp.stack(seqs))
            harvested = harvested.at[k].set(jnp.zeros((C,), bool))
            return (key, alpha, residual, payload, snaps, arrival, seq,
                    harvested, compute_t, comm_t, bytes_up, bytes_down), None

        def no_op(carry, xs):
            return carry, None

        def rank_body(carry, xs):
            return jax.lax.cond(xs[0] < need, do_launch, no_op, carry, xs)

        init = (key, alpha, residual, payload, snaps, arrival, seq,
                harvested, compute_t, comm_t, bytes_up, bytes_down)
        if initial:
            # No ambiguity on the first launch: every worker, worker order.
            out, _ = jax.lax.scan(do_launch, init,
                                  (iota, order, starts, reply_bytes,
                                   down_times))
        else:
            out, _ = jax.lax.scan(rank_body, init,
                                  (iota, order, starts, reply_bytes,
                                   down_times))
        (key, alpha, residual, payload, snaps, arrival, seq, harvested,
         compute_t, comm_t, bytes_up, bytes_down) = out
        return (key, alpha, residual, payload, snaps, arrival, seq,
                harvested, seq_ctr + need * C, bytes_up, bytes_down,
                compute_t, comm_t)

    # --- initial state + the t=0 launch wave ------------------------------
    zero64 = jnp.zeros((), f64)
    state = dict(
        key=key,
        w_server=jnp.zeros((d,), dt),
        dw_tilde=jnp.zeros((K, d), dt),
        w_local=jnp.zeros((K, d), dt),
        alpha=jnp.zeros((K, n_k), dt),
        alpha_applied=jnp.zeros((K, n_k), dt),
        residual=jnp.zeros((K, d), dt),
        payload=jnp.zeros((K, C, d), dt),
        snaps=jnp.zeros((K, C, n_k), dt),
        arrival=jnp.zeros((K, C), f64),
        seq=jnp.zeros((K, C), i64),
        harvested=jnp.zeros((K, C), bool),
        seq_ctr=jnp.zeros((), i64),
        bytes_up=jnp.zeros((), i64),
        bytes_down=jnp.zeros((), i64),
        compute_t=zero64,
        comm_t=zero64,
        sim_time=zero64,
    )
    (state["key"], state["alpha"], state["residual"], state["payload"],
     state["snaps"], state["arrival"], state["seq"], state["harvested"],
     state["seq_ctr"], state["bytes_up"], state["bytes_down"],
     state["compute_t"], state["comm_t"]) = launch(
        (state["key"], state["alpha"], state["residual"], state["payload"],
         state["snaps"], state["arrival"], state["seq"], state["harvested"],
         state["seq_ctr"], state["bytes_up"], state["bytes_down"],
         state["compute_t"], state["comm_t"], state["w_local"],
         jnp.asarray(K, i64), iota, jnp.zeros((K,), f64),
         jnp.zeros((K,), i64), jnp.zeros((K,), f64), durations[0]),
        initial=True)

    # --- the round loop ---------------------------------------------------

    def round_step(carry, xs):
        s = dict(carry)
        need, dur_wave = xs
        need = need.astype(i64)
        # Deadline: the need-th FULL arrival, lex (arrival, seq) -- the host
        # heap's order over final chunks (always in flight, see above).
        arr_fin = s["arrival"][:, C - 1]
        seq_fin = s["seq"][:, C - 1]
        _, _, perm = jax.lax.sort((arr_fin, seq_fin, iota), num_keys=2)
        sorted_arr = arr_fin[perm]
        sorted_seq = seq_fin[perm]
        server_time = sorted_arr[need - 1]
        cut_s = sorted_seq[need - 1]
        # Harvest: every pending chunk at or before the deadline key.
        take = ~s["harvested"] & (
            (s["arrival"] < server_time)
            | ((s["arrival"] == server_time) & (s["seq"] <= cut_s)))

        # Aggregation in global arrival order over the harvested chunks:
        # flattened lex sort, where-masked accumulation (event pop order).
        _, _, fperm = jax.lax.sort(
            (s["arrival"].reshape(KC), s["seq"].reshape(KC), kiota),
            num_keys=2)
        take_f = take.reshape(KC)
        pay_f = s["payload"].reshape(KC, d)

        def agg(j, tot):
            p = fperm[j]
            return jnp.where(take_f[p], tot + pay_f[p], tot)

        total = jax.lax.fori_loop(0, KC, agg, jnp.zeros((d,), dt))
        w_server = s["w_server"] + gamma * total
        dw_tilde = s["dw_tilde"] + gamma * total[None, :]

        # alpha_applied: each harvesting worker's LAST harvested chunk.
        any_k = jnp.any(take, axis=1)
        last = (C - 1) - jnp.argmax(take[:, ::-1], axis=1)
        snap_last = s["snaps"][jnp.arange(K), last]
        alpha_applied = jnp.where(any_k[:, None], snap_last,
                                  s["alpha_applied"])

        # Catch-up replies to the `need` COMPLETED workers only (the event
        # path's _server_apply_partial op order: replies read dw_tilde AFTER
        # this round's harvest landed).
        sel = iota < need
        replies = dw_tilde[perm]
        reply_nnz = jnp.sum(replies != 0, axis=1)
        w_rows = s["w_local"][perm]
        w_local = s["w_local"].at[perm].set(
            jnp.where(sel[:, None], w_rows + replies, w_rows))
        dw_tilde = dw_tilde.at[perm].set(
            jnp.where(sel[:, None], jnp.zeros_like(replies), dw_tilde[perm]))

        # Reply billing per rank (same arithmetic as DelayModel.p2p_time).
        if dense_reply_bytes:
            reply_bytes = jnp.full((K,), dense_reply_bytes, i64)
        else:
            reply_bytes = (reply_nnz * 8).astype(i64)
        factors = link_factors[perm]
        down_times = latency + reply_bytes * factors / bandwidth
        starts = server_time + down_times

        harvested = s["harvested"] | take
        (key, alpha, residual, payload, snaps, arrival, seq, harvested,
         seq_ctr, bytes_up, bytes_down, compute_t, comm_t) = launch(
            (s["key"], s["alpha"], s["residual"], s["payload"], s["snaps"],
             s["arrival"], s["seq"], harvested, s["seq_ctr"], s["bytes_up"],
             s["bytes_down"], s["compute_t"], s["comm_t"], w_local, need,
             perm, starts, reply_bytes, down_times, dur_wave),
            initial=False)

        s.update(key=key, w_server=w_server, dw_tilde=dw_tilde,
                 w_local=w_local, alpha=alpha, alpha_applied=alpha_applied,
                 residual=residual, payload=payload, snaps=snaps,
                 arrival=arrival, seq=seq, harvested=harvested,
                 seq_ctr=seq_ctr, bytes_up=bytes_up, bytes_down=bytes_down,
                 compute_t=compute_t, comm_t=comm_t, sim_time=server_time)
        ys = (w_server, alpha_applied, server_time, bytes_up, bytes_down,
              compute_t, comm_t, jnp.sum(take).astype(i64))
        return s, ys

    state, ys = jax.lax.scan(round_step, state,
                             (needs, durations[1:]), length=length)
    return state, ys


@partial(jax.jit,
         static_argnames=("loss", "chunk_steps", "comp", "length",
                          "dense_reply_bytes"))
def _partial_scan(key, X, y, norms_sq, lam, n, sigma_p, gamma, durations,
                  needs, up_bytes, latency, bandwidth, link_factors, *, loss,
                  chunk_steps, comp, length, dense_reply_bytes):
    """One partial_work run = one dispatch (jit over
    :func:`partial_run_traced`)."""
    STATS["partial_traces"] += 1  # trace-time side effect, not per call
    return partial_run_traced(key, X, y, norms_sq, lam, n, sigma_p, gamma,
                              durations, needs, up_bytes, latency, bandwidth,
                              link_factors, loss=loss,
                              chunk_steps=chunk_steps, comp=comp,
                              length=length,
                              dense_reply_bytes=dense_reply_bytes)


def partial_durations(method: MethodConfig, cluster: ClusterModel, *,
                      num_rounds: int, seed: int):
    """Pre-sample a partial_work run's per-chunk compute stream; returns
    ``(durations (num_rounds+1, C, K), delay)``.

    Row 0 feeds the t=0 launch wave, row 1+r feeds round r -- exactly the
    event executor's one-``sample_chunks``-per-``_launch_chunks``
    consumption (without a membership schedule every round launches, so the
    wave count is static).  Raises when the delay model cannot pre-sample
    (callers normally check :func:`scan_supported` first).
    """
    steps = engine.chunk_steps(method.H, method.n_chunks)
    delay = cluster.make_delay()
    rng = np.random.default_rng(seed)
    durations = delay.sample_chunk_stream(num_rounds + 1, steps, rng)
    if durations is None:
        raise ValueError(
            f"delay model {cluster.delay_model!r} cannot pre-sample a "
            f"(round, chunk, worker) stream; use executor='event'")
    return durations, delay


def _run_partial(problem, method, cluster, *, num_outer, seed, eval_every,
                 norms_sq):
    from jax.experimental import enable_x64

    K, n_k, d = problem.X.shape
    T = method.T
    R = num_outer * T
    if R == 0:
        dt = problem.X.dtype
        return ScanRun(method, [], [], None, None, jnp.zeros((d,), dt),
                       jnp.zeros((K, n_k), dt),
                       alpha_applied=jnp.zeros((K, n_k), dt))
    durations, delay = partial_durations(method, cluster, num_rounds=R,
                                         seed=seed)
    # Relaunch counts are the lag stream: the round deadline is the B-th
    # full arrival (K on the T-periodic barrier) and, membership-free, the
    # completed-worker count IS the deadline rank.
    needs = lag_needs(method, K, R)
    comp = compress_lib.for_method(method, d)
    dense = isinstance(comp, compress_lib.Dense)
    up_bytes = comp.wire_bytes(d)
    sigma_p = method.resolved_sigma_prime(K)

    STATS["partial_calls"] += 1
    with enable_x64():
        state, ys = _partial_scan(
            jax.random.key(seed), problem.X, problem.y, norms_sq,
            jnp.float32(problem.lam), jnp.int32(K * n_k),
            jnp.float32(sigma_p), jnp.float32(method.gamma),
            jnp.asarray(durations, jnp.float64),
            jnp.asarray(needs, jnp.int64),
            jnp.asarray(up_bytes, jnp.int64),
            jnp.asarray(cluster.latency, jnp.float64),
            jnp.asarray(cluster.bandwidth, jnp.float64),
            jnp.asarray(delay.link_factors(), jnp.float64),
            loss=problem.loss,
            chunk_steps=engine.chunk_steps(method.H, method.n_chunks),
            comp=comp, length=R, dense_reply_bytes=d * 4 if dense else 0)

    ws, alpha_applied_rows, sim, bu, bd, ct, cm, harv = ys
    sim, ct, cm = np.asarray(sim), np.asarray(ct), np.asarray(cm)
    bu, bd, harv = np.asarray(bu), np.asarray(bd), np.asarray(harv)
    rounds = [RoundAccount(int(harv[r]), r % T == T - 1, float(sim[r]),
                           int(bu[r]), int(bd[r]), float(ct[r]),
                           float(cm[r]))
              for r in range(R)]
    evals = _eval_indices(R, eval_every)
    idx = jnp.asarray(evals, jnp.int32)
    return ScanRun(method, rounds, evals, ws[idx], alpha_applied_rows[idx],
                   state["w_server"], state["alpha"],
                   alpha_applied=state["alpha_applied"])


# ---------------------------------------------------------------------------
# Divergence certificates + checkpointed lockstep runs (PR 9).
# ---------------------------------------------------------------------------


@jax.jit
def _finite_cells(ws, alphas):
    """Per-cell finiteness over stacked final iterates: (C, ...) -> (C,)."""
    fw = jnp.isfinite(ws).reshape(ws.shape[0], -1).all(axis=1)
    fa = jnp.isfinite(alphas).reshape(alphas.shape[0], -1).all(axis=1)
    return fw & fa


def finite_certificates(variants) -> np.ndarray:
    """Per-cell finite certificates over sweep results.

    ONE jitted reduction over the stacked per-cell final ``(w, alpha)``
    (the compute-and-mask idiom of :func:`lockstep_run_gap_traced`, applied
    across the cell axis): a NaN-poisoned cell only corrupts its own vmap
    lane, so the batch itself completes -- this certificate tells the serve
    layer which cells to mask out of delivery and report per-cell
    (``CellDivergenceError``) instead of failing the whole cohort.

    A deliberately SEPARATE tiny jit: folding the certificate into the
    sweep computation would change the batched jit signatures that
    :func:`repro.serve.cache.sweep_cache_key` mirrors and every trace
    counter pin in tests/test_sweep.py.
    """
    ws = jnp.stack([jnp.asarray(v.result.w) for v in variants])
    alphas = jnp.stack([jnp.asarray(v.result.alpha) for v in variants])
    return np.asarray(_finite_cells(ws, alphas))


def checkpoint_supported(method: MethodConfig, cluster: ClusterModel, *,
                         target_gap: float | None = None,
                         time_budget: float | None = None) -> tuple[bool, str]:
    """Can this run checkpoint/resume bit-identically?  (ok, why-not).

    Checkpointed runs execute as fixed-length scan SEGMENTS
    (:func:`run_lockstep_checkpointed`), so they need the lockstep scan
    path with a static round count: early stop makes the segment boundary
    data-dependent, and the non-lockstep scan protocols thread pre-sampled
    whole-run operand streams (lag durations, partial_work chunk grids)
    whose mid-run state is not a small carry.
    """
    if method.exact_dual_feedback:
        return False, ("exact_dual_feedback needs a host lstsq per round "
                       "(reference path only)")
    if target_gap is not None or time_budget is not None:
        return False, ("early stop (target_gap/time_budget) makes the "
                       "checkpoint boundary data-dependent; run without a "
                       "stop target to checkpoint")
    if method.protocol not in LOCKSTEP_PROTOCOLS:
        return False, (
            f"checkpoint segments scan from a (key, w, alpha) carry, which "
            f"only the lockstep protocols {LOCKSTEP_PROTOCOLS} expose; "
            f"{method.protocol!r} threads whole-run operand streams")
    return True, ""


def lockstep_segment_traced(key, w, alpha, X, y, norms_sq, lam, n, sigma_p,
                            gamma, *, loss, num_steps, solver, length):
    """``length`` lockstep rounds scanned FROM a given ``(key, w, alpha)``
    carry (vs :func:`lockstep_run_traced`'s zero init): the resumable unit
    of a checkpointed run.  The round body is the same shared
    ``engine._lockstep_round``, and ``lax.scan`` is sequential in the
    carry, so chaining segments is bit-identical to one whole scan."""

    def step(carry, _):
        key, w, alpha = carry
        key, w, alpha = engine._lockstep_round(
            key, w, alpha, X, y, norms_sq, lam, n, sigma_p, gamma, loss=loss,
            num_steps=num_steps, solver=solver)
        return (key, w, alpha), (w, alpha)

    (key, w, alpha), (ws, alphas) = jax.lax.scan(
        step, (key, w, alpha), None, length=length)
    return key, w, alpha, ws, alphas


@partial(jax.jit, static_argnames=("loss", "num_steps", "solver", "length"))
def _lockstep_segment_scan(key, w, alpha, X, y, norms_sq, lam, n, sigma_p,
                           gamma, *, loss, num_steps, solver, length):
    STATS["lockstep_segment_traces"] += 1  # trace-time side effect
    return lockstep_segment_traced(key, w, alpha, X, y, norms_sq, lam, n,
                                   sigma_p, gamma, loss=loss,
                                   num_steps=num_steps, solver=solver,
                                   length=length)


def checkpoint_run_id(problem, method: MethodConfig, cluster: ClusterModel,
                      *, seed: int, num_outer: int, eval_every: int) -> str:
    """Stable per-run subdirectory name: a digest of everything that shapes
    the run's trajectory.  Resuming under a different configuration would
    silently splice two different runs; the id check makes that loud."""
    sig = (dataclasses.asdict(method), dataclasses.asdict(cluster),
           tuple(problem.X.shape), str(problem.X.dtype), problem.loss,
           float(problem.lam), int(seed), int(num_outer), int(eval_every))
    return f"run_{zlib.crc32(repr(sig).encode()):08x}"


def checkpoint_manifest(checkpoint_dir, run_id: str) -> dict | None:
    """The latest durable snapshot manifest of run ``run_id``, or ``None``.

    The cluster takeover path (:mod:`repro.serve.cluster`): a surviving
    replica inspecting a dead peer's progress must learn the resume point
    WITHOUT deserializing the array payload -- it only needs to know whether
    re-running :func:`run_lockstep_checkpointed` with the same arguments
    will resume rather than restart.  Reads only the json sidecar, which
    :func:`repro.checkpoint.checkpoint.save_checkpoint` makes durable
    *before* the ``.npz`` becomes visible, so any round this returns is
    loadable.  Returns ``{"run", "round", "seed", "num_outer",
    "eval_every", "sim_time", "path"}``; ``None`` when no snapshot exists
    (takeover then restarts the run from round 0 -- still bit-identical,
    just slower)."""
    from repro.checkpoint import checkpoint as ckpt_lib

    cdir = pathlib.Path(checkpoint_dir) / run_id
    latest = ckpt_lib.latest_step(cdir)
    if latest is None:
        return None
    try:
        manifest = json.loads((cdir / f"ckpt_{latest:08d}.json").read_text())
    except (OSError, ValueError):
        return None
    extra = dict(manifest.get("extra", {}))
    extra.setdefault("run", run_id)
    extra.setdefault("round", int(manifest.get("step", latest)))
    extra["path"] = str(cdir)
    return extra


def run_lockstep_checkpointed(problem, method: MethodConfig,
                              cluster: ClusterModel, *, num_outer: int,
                              seed: int, eval_every: int, checkpoint_dir,
                              checkpoint_every: int, norms_sq=None,
                              segment_hook=None) -> ScanRun:
    """A lockstep run executed in resumable segments of ``checkpoint_every``
    rounds, serializing the scan carry after every segment.

    After each segment the carry (RNG key data, ``w``, ``alpha``) plus the
    eval-boundary snapshots gathered so far land in
    ``checkpoint_dir/<run id>/ckpt_<round>.npz``
    (:mod:`repro.checkpoint`); a killed process re-invoked with the same
    arguments resumes from the latest snapshot and executes ONLY the
    remaining segments.  Bit-identity with the unsegmented
    :func:`_run_lockstep` run holds by construction: segments chain the
    sequential scan carry exactly, host accounting is recomputed
    deterministically from ``seed``, and ALL certificate evaluation stays
    deferred to one bucketed call over the identical stacked snapshots at
    ``materialize_records`` time.

    ``segment_hook(start_round)`` is called before each segment executes --
    the serve layer wires fault injection (``kind="segment"``) through it,
    and a hook that raises kills the run AFTER the previous segment's
    checkpoint was durably written.
    """
    if checkpoint_every < 1:
        raise ValueError(
            f"checkpoint_every must be >= 1, got {checkpoint_every}")
    ok, why = checkpoint_supported(method, cluster)
    if not ok:
        raise ValueError(f"run cannot checkpoint: {why}")
    from repro.checkpoint import checkpoint as ckpt_lib

    if norms_sq is None:
        norms_sq = jnp.sum(problem.X * problem.X, axis=-1)
    K, n_k, d = problem.X.shape
    dt = problem.X.dtype
    R = num_outer
    if R == 0:
        return ScanRun(method, [], [], None, None, jnp.zeros((d,), dt),
                       jnp.zeros((K, n_k), dt))
    run_id = checkpoint_run_id(problem, method, cluster, seed=seed,
                               num_outer=R, eval_every=eval_every)
    cdir = pathlib.Path(checkpoint_dir) / run_id
    evals = _eval_indices(R, eval_every)
    rounds = lockstep_accounts(method, cluster, d, num_rounds=R, seed=seed)
    sigma_p = method.resolved_sigma_prime(K)
    solver = lockstep_solver(method)

    key = jax.random.key(seed)
    key_dt = jax.random.key_data(key).dtype
    key_shape = jax.random.key_data(key).shape
    w = jnp.zeros((d,), dt)
    alpha = jnp.zeros((K, n_k), dt)
    snap_ws: list = []  # eval-boundary snapshots gathered so far
    snap_alphas: list = []
    start = 0

    latest = ckpt_lib.latest_step(cdir)
    if latest is not None:
        if not 0 < latest <= R:
            raise ValueError(
                f"checkpoint at round {latest} is outside this run's "
                f"budget of {R} rounds ({cdir})")
        n_done = sum(1 for e in evals if e < latest)
        reference = {
            "key": np.zeros(key_shape, key_dt),
            "w": np.zeros((d,), dt),
            "alpha": np.zeros((K, n_k), dt),
            "eval_ws": np.zeros((n_done, d), dt),
            "eval_alphas": np.zeros((n_done, K, n_k), dt),
        }
        tree, extra = ckpt_lib.load_checkpoint(cdir, reference, latest)
        if extra.get("run") != run_id or extra.get("round") != latest:
            raise ValueError(
                f"checkpoint manifest under {cdir} does not match this run "
                f"(expected run={run_id!r} round={latest}, got "
                f"run={extra.get('run')!r} round={extra.get('round')!r})")
        key = jax.random.wrap_key_data(jnp.asarray(tree["key"]))
        w = jnp.asarray(tree["w"])
        alpha = jnp.asarray(tree["alpha"])
        if n_done:
            snap_ws.append(jnp.asarray(tree["eval_ws"]))
            snap_alphas.append(jnp.asarray(tree["eval_alphas"]))
        start = latest

    def stacked():
        if not snap_ws:
            return (jnp.zeros((0, d), dt), jnp.zeros((0, K, n_k), dt))
        if len(snap_ws) == 1:
            return snap_ws[0], snap_alphas[0]
        return jnp.concatenate(snap_ws), jnp.concatenate(snap_alphas)

    while start < R:
        if segment_hook is not None:
            segment_hook(start)
        length = min(checkpoint_every, R - start)
        STATS["lockstep_segment_calls"] += 1
        key, w, alpha, ws, alphas = _lockstep_segment_scan(
            key, w, alpha, problem.X, problem.y, norms_sq, problem.lam,
            K * n_k, sigma_p, method.gamma, loss=problem.loss,
            num_steps=method.H, solver=solver, length=length)
        seg_evals = [e - start for e in evals if start <= e < start + length]
        if seg_evals:
            idx = jnp.asarray(seg_evals, jnp.int32)
            snap_ws.append(ws[idx])
            snap_alphas.append(alphas[idx])
        start += length
        eval_ws, eval_alphas = stacked()
        ckpt_lib.save_checkpoint(
            cdir, start,
            {"key": jax.random.key_data(key), "w": w, "alpha": alpha,
             "eval_ws": eval_ws, "eval_alphas": eval_alphas},
            extra={"run": run_id, "round": start, "seed": int(seed),
                   "num_outer": int(R), "eval_every": int(eval_every),
                   "sim_time": rounds[start - 1].sim_time})

    eval_ws, eval_alphas = stacked()
    if not evals:
        eval_ws = eval_alphas = None
    return ScanRun(method, rounds, evals, eval_ws, eval_alphas, w, alpha)
