"""The paper's message filter F (Algorithm 2, lines 7-9) + residual feedback.

Given the accumulated primal delta ``dw`` of a worker, keep only the top
``ceil(rho * d)`` entries by magnitude:

    c_k   = (rho d)-th largest value of |dw|
    M_k   = |dw| >= c_k                       (line 8 -- note: ties may pass)
    F(dw) = dw o M_k                          (sent, O(rho d) nonzeros)
    dw   <- dw o ~M_k                         (practical residual variant, Sec. III-B2)

``topk_mask`` follows the paper's threshold definition exactly (so ties can
admit slightly more than k entries); ``topk_mask_exact`` breaks ties by index
and returns exactly k -- the Pallas kernel implements the exact variant and the
tests cross-check both against each other on tie-free inputs.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class FilterResult(NamedTuple):
    sent: jax.Array  # F(dw): dw with all but the top-k entries zeroed
    residual: jax.Array  # dw o ~M: what the worker keeps (error feedback)
    mask: jax.Array  # M_k, boolean
    threshold: jax.Array  # c_k


def num_kept(d: int, rho: float) -> int:
    """ceil(rho*d), clamped to [1, d]."""
    return max(1, min(d, int(-(-rho * d // 1))))


@partial(jax.jit, static_argnames=("k",))
def topk_mask(dw: jax.Array, k: int) -> FilterResult:
    """Paper-faithful threshold filter: M = |dw| >= c_k (ties pass)."""
    mag = jnp.abs(dw)
    c_k = jax.lax.top_k(mag, k)[0][-1]
    mask = mag >= c_k
    sent = jnp.where(mask, dw, 0.0)
    return FilterResult(sent, dw - sent, mask, c_k)


@partial(jax.jit, static_argnames=("k",))
def topk_mask_exact(dw: jax.Array, k: int) -> FilterResult:
    """Exactly-k filter (ties broken toward lower index), kernel-compatible."""
    mag = jnp.abs(dw)
    _, idx = jax.lax.top_k(mag, k)
    mask = jnp.zeros(dw.shape, bool).at[idx].set(True)
    sent = jnp.where(mask, dw, 0.0)
    c_k = jax.lax.top_k(mag, k)[0][-1]
    return FilterResult(sent, dw - sent, mask, c_k)


@partial(jax.jit, static_argnames=("k",))
def compress(dw: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """On-wire form: (values, int32 indices), each of length k.

    This is what actually crosses the network: 2k words instead of d.
    """
    _, idx = jax.lax.top_k(jnp.abs(dw), k)
    return dw[idx], idx.astype(jnp.int32)


@partial(jax.jit, static_argnames=("d",))
def decompress(values: jax.Array, idx: jax.Array, d: int) -> jax.Array:
    return jnp.zeros((d,), values.dtype).at[idx].add(values)


def message_bytes(k: int, value_bytes: int = 4, index_bytes: int = 4) -> int:
    """Bytes on the wire for one compressed message (Table I accounting)."""
    return k * (value_bytes + index_bytes)


def dense_bytes(d: int, value_bytes: int = 4) -> int:
    return d * value_bytes


@jax.jit
def nnz(x: jax.Array) -> jax.Array:
    return jnp.sum(x != 0)
