"""ACPD: straggler-agnostic server (Alg. 1) + bandwidth-efficient workers (Alg. 2).

This module runs the *faithful* algorithm: an event-driven simulation of the
parameter-server protocol, with per-worker stale models ``w_k = w^{d_k(t)}``,
group-wise B-of-K arrivals ordered by a simulated straggler clock, the
``T``-periodic full synchronization that bounds staleness (Assumption 3,
``tau <= T-1``), the top-``rho d`` message filter with residual feedback, and
the per-worker catch-up buffers ``dw_tilde_k`` on the server.

The synchronous baselines (CoCoA, CoCoA+, DisDCA) fall out of the same engine:
CoCoA+ == group protocol with B=K, rho=1, gamma=1 (then sigma' = gamma*B = K,
exactly the "adding" aggregation of Ma et al. 2015), except that they are timed
with MPI-style ``allreduce`` as in the paper's implementation, so we provide a
dedicated ``sync`` protocol for them.

All numerics run in jitted JAX; the event loop is host Python (it is control
flow over a priority queue, not tensor math).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import filter as msg_filter
from repro.core import objectives
from repro.core.sdca import solve_subproblem, solve_subproblem_all
from repro.core.simulate import ClusterModel


@dataclasses.dataclass(frozen=True)
class MethodConfig:
    """One distributed primal-dual method, in the paper's parameterization."""

    name: str
    protocol: str = "group"  # registry entry: "group", "sync", "async", "lag", ...
    B: int = 2  # group size: server proceeds once B workers arrived
    T: int = 20  # full-sync period; bounds staleness tau <= T-1
    rho: float = 1.0  # fraction of coordinates sent (1.0 = dense)
    gamma: float = 1.0  # server step size
    H: int = 1000  # local SDCA iterations per round
    sigma_prime: float | None = None  # None -> the protocol's default_sigma_prime
    use_exact_k: bool = True  # exact top-k (kernel semantics) vs >=threshold
    # Optional core.compress registry entry for the upload payload. None keeps
    # the legacy mapping (rho >= 1 -> "dense", else "topk_exact" or
    # "topk_threshold" per use_exact_k); set e.g. "topk_q8" for quantized
    # uploads without touching rho/use_exact_k.
    compressor: str | None = None
    # Alg. 2 lines 10-12 exactly: put the filtered-out mass back into the DUAL
    # via dalpha_hat = lam*n*A^+ (dw o ~M), keeping w = (1/lam n) A alpha true
    # at every iterate (the property Lemma 1 needs). Requires a least-squares
    # solve per round -- the paper itself calls it impractical and uses the
    # primal residual instead (our default, exact_dual_feedback=False).
    exact_dual_feedback: bool = False
    # LAG-style lazy aggregation (protocol="lag"): a worker skips its upload
    # when ||F(dw)||^2 < (lag_xi / lag_window) * sum of its last ``lag_window``
    # catch-up-reply squared norms -- the paper-faithful D-round window of
    # global model movement (Chen et al., arXiv:1805.09965, LAG-WK rule);
    # lag_window=1 is the legacy single-reply test (see engine.LagProtocol).
    lag_xi: float = 1.0
    lag_window: int = 10
    # CoCoA-lineage protocols (protocol="cocoa"/"cocoa_plus"): which
    # repro.core.solvers registry entry solves the local subproblem
    # ("sdca", "importance", "accelerated").  The group family always runs
    # SDCA (the paper's Alg. 2).
    local_solver: str = "sdca"
    # Adaptive group sizing (protocol="adaptive_b"): B_t = the number of
    # workers whose EWMA round latency falls at or below the
    # ``adaptive_quantile`` quantile of all workers' EWMAs (floored at
    # ``b_min``, capped at K); ``adaptive_ewma`` is the EWMA step.  ``B``
    # only seeds the first rounds, before one latency sample per worker
    # exists (see engine.AdaptiveBProtocol).
    adaptive_quantile: float = 0.5
    adaptive_ewma: float = 0.25
    b_min: int = 1
    # Chunk streaming (protocol="partial_work"): each local pass of H steps
    # is split into ``n_chunks`` pieces, streamed to the server as they
    # finish; the server harvests every chunk that arrived by its deadline
    # (the B-th FULL arrival, or a fixed ``pw_quantum`` of simulated seconds
    # when set), so stragglers contribute partial work instead of being
    # discarded (Ozfatura et al., arXiv:2004.04948).
    n_chunks: int = 1
    pw_quantum: float | None = None
    # Two-level rack-aware aggregation (protocol="hierarchical_b"): workers
    # are split into ``n_racks`` contiguous racks and a round waits for the
    # ``rack_b``-th arrival in EVERY rack before the cross-rack merge --
    # per-rack B-of-k on per-rack links (pair with the ``bandwidth_coupled``
    # delay model for slow-rack links).
    n_racks: int = 2
    rack_b: int = 1

    def resolved_sigma_prime(self, K: int) -> float:
        """sigma' when unset: delegated to the protocol registry entry.

        Each :class:`repro.core.engine.Protocol` owns its default via the
        ``default_sigma_prime`` classmethod (gamma*B for the group family,
        gamma*K for the synchronous CoCoA lineage), so new registry entries
        get a correct sigma' without this dataclass growing per-protocol
        string checks.
        """
        if self.sigma_prime is not None:
            return self.sigma_prime
        from repro.core import engine  # late import: engine imports our types

        return engine.get_protocol(self.protocol).default_sigma_prime(self, K)


def acpd_config(K: int, *, B: int | None = None, T: int = 20, rho_d: int | None = None,
                d: int | None = None, gamma: float = 0.5, H: int = 1000) -> MethodConfig:
    """Paper defaults: B=K/2, T=20, rho*d=1e3 (Sec. V-B)."""
    B = B if B is not None else max(1, K // 2)
    rho = 1.0 if (rho_d is None or d is None) else min(1.0, rho_d / d)
    return MethodConfig(name="ACPD", protocol="group", B=B, T=T, rho=rho, gamma=gamma, H=H)


@dataclasses.dataclass
class RunRecord:
    iteration: int
    sim_time: float
    gap: float
    gap_server: float
    primal: float
    dual: float
    bytes_up: int
    bytes_down: int
    compute_time: float
    comm_time: float


@dataclasses.dataclass
class RunResult:
    method: MethodConfig
    records: list[RunRecord]
    w: np.ndarray
    alpha: np.ndarray  # worker-canonical duals (may lead the server in-flight)
    alpha_applied: np.ndarray | None = None  # server-visible duals

    def time_to_gap(self, target: float) -> float | None:
        for r in self.records:
            if r.gap <= target:
                return r.sim_time
        return None

    def rounds_to_gap(self, target: float) -> int | None:
        for r in self.records:
            if r.gap <= target:
                return r.iteration
        return None

    def as_dict(self) -> dict[str, Any]:
        return {
            "method": self.method.name,
            "records": [dataclasses.asdict(r) for r in self.records],
        }


class _Message:
    """An in-flight worker->server message: F(dw_k) plus bookkeeping."""

    __slots__ = ("arrival", "worker", "payload", "alpha_snapshot", "nbytes", "seq")

    def __init__(self, arrival: float, worker: int, payload: jax.Array,
                 alpha_snapshot: jax.Array, nbytes: int, seq: int):
        self.arrival = arrival
        self.worker = worker
        self.payload = payload
        self.alpha_snapshot = alpha_snapshot
        self.nbytes = nbytes
        self.seq = seq

    def __lt__(self, other: "_Message") -> bool:
        return (self.arrival, self.seq) < (other.arrival, other.seq)


def run_method(
    problem: objectives.Problem,
    method: MethodConfig,
    cluster: ClusterModel,
    *,
    num_outer: int,
    seed: int = 0,
    eval_every: int = 1,
) -> RunResult:
    """Run a method through the pluggable protocol engine (core/engine.py).

    The engine reproduces the reference loops below bit-for-bit for the
    ``group``/``sync`` protocols (pinned by tests/test_engine.py) with far
    fewer host<->device dispatches. The one exception is the impractical
    ``exact_dual_feedback`` theory variant, whose per-round host ``lstsq``
    cannot be fused -- it stays on the reference path.
    """
    from repro.core import engine  # late import: engine imports our types

    # Validate the protocol up front: an unknown name fails here with the
    # registry listing instead of deep inside the run.
    engine.get_protocol(method.protocol)
    if method.exact_dual_feedback:
        return run_method_reference(problem, method, cluster,
                                    num_outer=num_outer, seed=seed,
                                    eval_every=eval_every)
    return engine.run_method(problem, method, cluster, num_outer=num_outer,
                             seed=seed, eval_every=eval_every)


def run_method_reference(
    problem: objectives.Problem,
    method: MethodConfig,
    cluster: ClusterModel,
    *,
    num_outer: int,
    seed: int = 0,
    eval_every: int = 1,
) -> RunResult:
    """The seed implementation: host-Python loops, one dispatch per op.

    Kept as the equivalence oracle for the engine (and for the
    ``exact_dual_feedback`` variant) -- do not optimize; its op-for-op
    ordering defines the bit-exact trajectories the engine must reproduce.
    """
    if method.protocol == "sync":
        return _run_sync(problem, method, cluster, num_outer=num_outer, seed=seed, eval_every=eval_every)
    if method.protocol == "group":
        return _run_group(problem, method, cluster, num_outer=num_outer, seed=seed, eval_every=eval_every)
    from repro.core import engine

    raise ValueError(
        f"reference implementation only covers 'group'/'sync', got "
        f"{method.protocol!r}; engine registry protocols "
        f"{engine.available_protocols()} run via repro.core.engine.run_method "
        f"/ repro.api.Session")


# ---------------------------------------------------------------------------
# Reference group-wise protocol: Algorithms 1 + 2.
# ---------------------------------------------------------------------------


def _run_group(problem, method, cluster, *, num_outer, seed, eval_every) -> RunResult:
    K, n_k, d = problem.X.shape
    n = K * n_k
    lam, loss = problem.lam, problem.loss
    gamma = method.gamma
    sigma_p = method.resolved_sigma_prime(K)
    k_keep = msg_filter.num_kept(d, method.rho)
    dense = method.rho >= 1.0
    filt = msg_filter.topk_mask_exact if method.use_exact_k else msg_filter.topk_mask

    rng = np.random.default_rng(seed)
    key = jax.random.key(seed)
    norms_sq = jnp.sum(problem.X * problem.X, axis=-1)

    # Server state (Alg. 1).
    w_server = jnp.zeros((d,), problem.X.dtype)
    dw_tilde = jnp.zeros((K, d), problem.X.dtype)  # catch-up buffer per worker

    # Worker state (Alg. 2).
    w_local = jnp.zeros((K, d), problem.X.dtype)
    alpha = jnp.zeros((K, n_k), problem.X.dtype)  # worker-canonical duals
    alpha_applied = jnp.zeros((K, n_k), problem.X.dtype)  # server-visible duals
    residual = jnp.zeros((K, d), problem.X.dtype)  # dw_k kept after filtering

    bytes_up = bytes_down = 0
    compute_time = comm_time = 0.0
    seq = 0
    queue: list[_Message] = []
    records: list[RunRecord] = []

    def _worker_round(k: int, start_time: float) -> _Message:
        """Run one full local round on worker k starting at ``start_time``."""
        nonlocal alpha, residual, bytes_up, compute_time, comm_time, key, seq
        key, sub = jax.random.split(key)
        w_eff = w_local[k] + gamma * residual[k]
        dalpha, v = solve_subproblem(
            w_eff, alpha[k], problem.X[k], problem.y[k], norms_sq[k],
            lam, n, sigma_p, sub, loss=loss, num_steps=method.H,
        )
        alpha = alpha.at[k].add(gamma * dalpha)  # line 5
        dw = residual[k] + v  # line 6
        if dense:
            sent, new_residual = dw, jnp.zeros_like(dw)
            nbytes = msg_filter.dense_bytes(d)
        else:
            res = filt(dw, k_keep)
            sent, new_residual = res.sent, res.residual  # practical variant
            nbytes = msg_filter.message_bytes(k_keep)
            if method.exact_dual_feedback:
                # Lines 10-12 exactly: unwind the unsent mass into the dual.
                # dalpha_hat = lam*n * A_[k]^+ (dw o ~M); A_[k] = X_k^T (d,n_k)
                unsent = np.asarray(new_residual, np.float64)
                A = np.asarray(problem.X[k], np.float64).T  # (d, n_k)
                dalpha_hat, *_ = np.linalg.lstsq(A, lam * n * unsent, rcond=None)
                alpha = alpha.at[k].add(-gamma * jnp.asarray(
                    dalpha_hat, problem.X.dtype))  # line 11
                new_residual = jnp.zeros_like(dw)  # line 12
        residual = residual.at[k].set(new_residual)

        duration = cluster.compute_time(k, method.H, rng)
        up_time = cluster.p2p_time(nbytes)
        compute_time += duration
        comm_time += up_time
        bytes_up += nbytes
        arrival = start_time + duration + up_time
        seq += 1
        return _Message(arrival, k, sent, jnp.asarray(alpha[k]), nbytes, seq)

    # All workers start their first round at t=0.
    for k in range(K):
        heapq.heappush(queue, _worker_round(k, 0.0))

    iteration = 0
    for outer in range(num_outer):
        for t in range(method.T):
            full_sync = t == method.T - 1
            need = K if full_sync else min(method.B, K)
            arrived: list[_Message] = [heapq.heappop(queue) for _ in range(need)]
            server_time = max(m.arrival for m in arrived)

            # Alg. 1 lines 8/10: accumulate gamma * F into every catch-up
            # buffer and into the global model.
            total = jnp.zeros((d,), problem.X.dtype)
            for m in arrived:
                total = total + m.payload
                alpha_applied = alpha_applied.at[m.worker].set(m.alpha_snapshot)
            w_server = w_server + gamma * total
            dw_tilde = dw_tilde + gamma * total[None, :]

            # Alg. 1 line 11: reply with dw_tilde_k, zero it; worker applies
            # (Alg. 2 lines 13-14) and starts its next round.
            for m in arrived:
                k = m.worker
                reply = dw_tilde[k]
                reply_nnz = int(msg_filter.nnz(reply))
                rbytes = msg_filter.message_bytes(reply_nnz) if not dense else msg_filter.dense_bytes(d)
                bytes_down += rbytes
                down_time = cluster.p2p_time(rbytes)
                comm_time += down_time
                w_local = w_local.at[k].add(reply)
                dw_tilde = dw_tilde.at[k].set(0.0)
                heapq.heappush(queue, _worker_round(k, server_time + down_time))

            iteration += 1
            if iteration % eval_every == 0:
                cert = objectives.gap_certificate(problem, alpha_applied, w=w_server)
                records.append(RunRecord(
                    iteration=iteration, sim_time=server_time,
                    gap=cert["gap"], gap_server=cert["gap_server"],
                    primal=cert["primal"], dual=cert["dual"],
                    bytes_up=bytes_up, bytes_down=bytes_down,
                    compute_time=compute_time, comm_time=comm_time,
                ))

    return RunResult(method, records, np.asarray(w_server), np.asarray(alpha),
                     alpha_applied=np.asarray(alpha_applied))


# ---------------------------------------------------------------------------
# Synchronous protocol: CoCoA / CoCoA+ / DisDCA (allreduce-timed).
# ---------------------------------------------------------------------------


def _run_sync(problem, method, cluster, *, num_outer, seed, eval_every) -> RunResult:
    K, n_k, d = problem.X.shape
    n = K * n_k
    lam, loss = problem.lam, problem.loss
    gamma = method.gamma
    sigma_p = method.resolved_sigma_prime(K)

    rng = np.random.default_rng(seed)
    key = jax.random.key(seed)
    norms_sq = jnp.sum(problem.X * problem.X, axis=-1)

    w = jnp.zeros((d,), problem.X.dtype)
    alpha = jnp.zeros((K, n_k), problem.X.dtype)

    sim_time = 0.0
    bytes_up = bytes_down = 0
    compute_time = comm_time = 0.0
    records: list[RunRecord] = []

    for it in range(1, num_outer + 1):
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, K)
        w_all = jnp.broadcast_to(w, (K, d))
        dalpha, v = solve_subproblem_all(
            w_all, alpha, problem.X, problem.y, norms_sq, lam, n, sigma_p, keys,
            loss=loss, num_steps=method.H,
        )
        alpha = alpha + gamma * dalpha
        w = w + gamma * jnp.sum(v, axis=0)

        step_compute = max(cluster.compute_time(k, method.H, rng) for k in range(K))
        step_comm = cluster.allreduce_time(d)
        sim_time += step_compute + step_comm
        compute_time += step_compute
        comm_time += step_comm
        # Ring all-reduce = reduce-scatter + all-gather, (K-1)/K * d * 4 bytes
        # per node per phase. The reduce-scatter moves worker contributions
        # toward the aggregate (upload-like), the all-gather distributes the
        # result (download-like) -- split so Table-1 byte columns compare
        # like-for-like with the group protocol's up/down accounting.
        phase = (K - 1) * d * 4
        bytes_up += phase
        bytes_down += phase

        if it % eval_every == 0:
            cert = objectives.gap_certificate(problem, alpha, w=w)
            records.append(RunRecord(
                iteration=it, sim_time=sim_time,
                gap=cert["gap"], gap_server=cert["gap_server"],
                primal=cert["primal"], dual=cert["dual"],
                bytes_up=bytes_up, bytes_down=bytes_down,
                compute_time=compute_time, comm_time=comm_time,
            ))

    return RunResult(method, records, np.asarray(w), np.asarray(alpha))
