"""One ``Compressor`` registry for both exchange paths.

Historically the repo carried two disjoint compression APIs: the paper's
message filter in :mod:`repro.core.filter` (used by the primal-dual
simulator) and ad-hoc histogram sparsification in :mod:`repro.core.exchange`
(used by the transformer train path). This module unifies them: a compressor
is a frozen, hashable config object (usable as a jit static argument) with

* ``compress(dw)``          -- the simulator form: one (d,) message, returns
  ``(sent, residual)`` with ``sent + residual == dw`` (error feedback);
* ``compress_grouped(dw)``  -- the exchange form: a (G, *shape) leaf, returns
  ``(sent, mask)`` per worker group, shard-friendly (no flatten);
* ``wire_bytes(d)``         -- bytes on the wire for one simulator message;
* ``payload_bytes(count)``  -- bytes for ``count`` kept coordinates (works on
  traced counts, used by the exchange byte metric).

Both ``MethodConfig`` (via :func:`for_method`) and ``ExchangeConfig`` (via
:func:`for_exchange`) resolve to the same registry objects, so ``bytes_up`` /
``bytes_down`` are computed one way across the simulator and the transformer
path (pinned by tests/test_compressors.py).

Registry entries:

* ``dense``          -- no filtering, 4 B/coordinate;
* ``topk_exact``     -- exactly-k top-|dw| (kernel semantics), 8 B/kept entry
  (4 B value + 4 B int32 index);
* ``topk_threshold`` -- the paper's threshold filter ``|dw| >= c_k`` (ties
  pass); grouped form uses the two-round histogram threshold;
* ``topk_q8``        -- NEW: top-k selection + 8-bit linear quantization of
  the kept values (per-message scale), 5 B/kept entry + 4 B scale. The
  quantization error stays in the residual, so error feedback makes the lossy
  payload lossless over time.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import filter as msg_filter

_NUM_BUCKETS = 64
_FLOOR = 2.0**-22


# ---------------------------------------------------------------------------
# Histogram threshold (grouped, O(n) memory) -- moved from core/exchange.py.
# ---------------------------------------------------------------------------


def _round(mag: jax.Array, hi: jax.Array, lo: jax.Array, k: jax.Array):
    """One histogram round on a flat |x|; returns (t_lo, t_hi) bracketing k."""
    hi = jnp.maximum(hi, 1e-37)
    lo = jnp.clip(lo, hi * 1e-37, hi)
    ratio = jnp.log(lo / hi) / (_NUM_BUCKETS - 1)  # negative
    # Bucket 0 holds the largest magnitudes.
    idx = jnp.where(mag >= lo, jnp.log(jnp.maximum(mag, 1e-37) / hi) / ratio, _NUM_BUCKETS)
    idx = jnp.clip(idx.astype(jnp.int32), 0, _NUM_BUCKETS)
    counts = jnp.zeros(_NUM_BUCKETS + 1, jnp.int32).at[idx].add(1)
    csum = jnp.cumsum(counts[:_NUM_BUCKETS])  # count(mag >= edge_j)
    reached = csum >= k
    j = jnp.where(jnp.any(reached), jnp.argmax(reached), _NUM_BUCKETS - 1)
    edge = lambda i: hi * jnp.exp(ratio * i.astype(jnp.float32))
    t_lo = edge(j + 1)  # lower edge of bucket j
    t_hi = jnp.where(j > 0, edge(j), jnp.inf)
    return t_lo, t_hi


def threshold_for_topk(x: jax.Array, k: jax.Array, refine: bool = True) -> jax.Array:
    """Approximate k-th-largest-|x| threshold via 1-2 histogram rounds.

    Guarantee: #{|x| >= t} >= min(k, #{|x| >= max|x|*2^-22}) and the overshoot
    is bounded by one refined-bucket's population (tested against exact top-k).
    """
    # NOTE: no reshape/flatten -- on a sharded leaf a flatten forces an
    # all-gather of the whole tensor on every device (measured: +47 s of
    # collective per step at 14B x 16 groups). All ops below are elementwise
    # or full reductions, which stay sharded.
    mag = jnp.abs(x.astype(jnp.float32))
    hi = jnp.max(mag)
    t_lo, t_hi = _round(mag, hi, hi * _FLOOR, k)
    if refine:
        t_lo, _ = _round(mag, jnp.where(jnp.isinf(t_hi), hi, t_hi), t_lo, k)
    return t_lo


def sparsify_leaf(dw: jax.Array, rho: float, refine: bool = True):
    """dw (G, *shape) -> (sent, kept_mask) with ~rho fraction kept per group.

    Shape-preserving (no flatten): see threshold_for_topk."""
    G = dw.shape[0]
    n = int(np.prod(dw.shape[1:]))
    k = jnp.int32(max(1, int(rho * n)))
    thresh = jax.vmap(lambda v: threshold_for_topk(v, k, refine))(dw)  # (G,)
    tb = thresh.reshape((G,) + (1,) * (dw.ndim - 1))
    mask = jnp.abs(dw) >= tb
    sent = jnp.where(mask, dw, 0.0)
    return sent, mask


# ---------------------------------------------------------------------------
# The registry.
# ---------------------------------------------------------------------------

_COMPRESSORS: dict[str, type["Compressor"]] = {}


def register_compressor(name: str):
    """Class decorator: make a Compressor constructible by registry name."""

    def deco(cls: type["Compressor"]) -> type["Compressor"]:
        cls.compressor_name = name
        _COMPRESSORS[name] = cls
        return cls

    return deco


def available_compressors() -> tuple[str, ...]:
    return tuple(sorted(_COMPRESSORS))


def get_compressor(name: str) -> type["Compressor"]:
    try:
        return _COMPRESSORS[name]
    except KeyError:
        raise ValueError(
            f"unknown compressor {name!r}; available: {available_compressors()}"
        ) from None


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Frozen (hashable) compression config -- see module docstring.

    ``k`` parameterizes the simulator form (one (d,) message); ``rho`` the
    grouped exchange form, where the kept count is derived per leaf.
    """

    compressor_name = "abstract"

    k: int = 0
    rho: float = 1.0
    # Second histogram round for threshold-based grouped compression;
    # ignored by compressors that don't use the histogram (dense, exact-k).
    refine: bool = True

    # -- byte accounting (ONE formula for both paths) ----------------------

    value_bytes: int = dataclasses.field(default=4, init=False)
    index_bytes: int = dataclasses.field(default=4, init=False)
    message_overhead: int = dataclasses.field(default=0, init=False)

    @property
    def entry_bytes(self) -> int:
        return self.value_bytes + self.index_bytes

    def payload_bytes(self, count):
        """Bytes for ``count`` kept coordinates (count may be traced)."""
        return count * self.entry_bytes + self.message_overhead

    def wire_bytes(self, d: int) -> int:
        """Bytes on the wire for one simulator message of a (d,) vector."""
        return int(self.payload_bytes(self.k if self.k else d))

    # -- compression -------------------------------------------------------

    def compress(self, dw: jax.Array) -> tuple[jax.Array, jax.Array]:
        """(d,) message -> (sent, residual), sent + residual == dw."""
        raise NotImplementedError

    def compress_grouped(self, dw: jax.Array) -> tuple[jax.Array, jax.Array]:
        """(G, *shape) leaf -> (sent, kept_mask) per worker group."""
        raise NotImplementedError


@register_compressor("dense")
@dataclasses.dataclass(frozen=True)
class Dense(Compressor):
    """No filtering: the whole vector crosses the wire, values only."""

    index_bytes: int = dataclasses.field(default=0, init=False)

    def wire_bytes(self, d: int) -> int:
        return int(self.payload_bytes(d))

    def compress(self, dw):
        return dw, jnp.zeros_like(dw)

    def compress_grouped(self, dw):
        return dw, jnp.ones(dw.shape, bool)


@register_compressor("topk_exact")
@dataclasses.dataclass(frozen=True)
class TopKExact(Compressor):
    """Exactly-k filter (ties broken toward lower index), kernel semantics."""

    def compress(self, dw):
        res = msg_filter.topk_mask_exact(dw, self.k)
        return res.sent, res.residual

    def compress_grouped(self, dw):
        G = dw.shape[0]
        n = int(np.prod(dw.shape[1:]))
        k = max(1, int(self.rho * n))

        def one(v):
            res = msg_filter.topk_mask_exact(v.reshape(-1), k)
            return res.sent.reshape(v.shape), res.mask.reshape(v.shape)

        # NOTE: the reshape forces a gather on sharded leaves -- exact-k is
        # for small/replicated leaves and tests; prefer topk_threshold at scale.
        return jax.vmap(one)(dw)


@register_compressor("topk_threshold")
@dataclasses.dataclass(frozen=True)
class TopKThreshold(Compressor):
    """The paper's filter: keep ``|dw| >= c_k`` (ties pass, Alg. 2 line 8).

    The simulator form computes ``c_k`` exactly via ``lax.top_k``; the grouped
    form uses the two-round histogram threshold (same semantics, approximate
    ``c_k``, shard-friendly).
    """

    def compress(self, dw):
        res = msg_filter.topk_mask(dw, self.k)
        return res.sent, res.residual

    def compress_grouped(self, dw):
        return sparsify_leaf(dw, self.rho, self.refine)


@register_compressor("topk_q8")
@dataclasses.dataclass(frozen=True)
class QuantizedTopK(Compressor):
    """Top-k selection + 8-bit linear quantization of the kept values.

    The message carries int8 values (scaled by one per-message float32) plus
    int32 indices: 5 B per kept entry + 4 B overhead, vs top-k's 8 B/entry.
    ``compress`` returns the *dequantized* payload, so the quantization error
    lands in the residual and error feedback recovers it on later rounds.
    """

    value_bytes: int = dataclasses.field(default=1, init=False)
    message_overhead: int = dataclasses.field(default=4, init=False)

    _LEVELS = 127.0

    def _quantize(self, sent, mask):
        scale = jnp.max(jnp.abs(sent)) / self._LEVELS
        scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
        q = jnp.round(sent / scale).astype(jnp.int8)
        deq = q.astype(sent.dtype) * scale
        return jnp.where(mask, deq, 0.0)

    def compress(self, dw):
        res = msg_filter.topk_mask_exact(dw, self.k)
        sent = self._quantize(res.sent, res.mask)
        return sent, dw - sent

    def compress_grouped(self, dw):
        sent, mask = sparsify_leaf(dw, self.rho, refine=self.refine)
        axes = tuple(range(1, dw.ndim))
        scale = jnp.max(jnp.abs(sent), axis=axes, keepdims=True) / self._LEVELS
        scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
        q = jnp.round(sent / scale).astype(jnp.int8)
        deq = q.astype(sent.dtype) * scale
        return jnp.where(mask, deq, 0.0), mask


# ---------------------------------------------------------------------------
# Resolution: configs -> registry objects.
# ---------------------------------------------------------------------------


def for_method(method, d: int) -> Compressor:
    """Resolve a ``MethodConfig`` to its compressor (simulator path).

    With ``method.compressor`` unset, reproduces the legacy mapping exactly:
    ``rho >= 1`` is dense, otherwise top-``ceil(rho d)`` with
    ``use_exact_k`` choosing exact-k vs threshold semantics.
    """
    rho = method.rho
    if method.compressor is None:
        if rho >= 1.0:
            return Dense(rho=rho)
        k = msg_filter.num_kept(d, rho)
        cls = TopKExact if method.use_exact_k else TopKThreshold
        return cls(k=k, rho=rho)
    cls = get_compressor(method.compressor)
    if cls is Dense:
        return Dense(rho=rho)
    return cls(k=msg_filter.num_kept(d, rho), rho=rho)


def for_exchange(cfg) -> Compressor:
    """Resolve an ``ExchangeConfig`` to its compressor (grouped path)."""
    cls = get_compressor(cfg.compressor)
    if cls is Dense or cfg.rho >= 1.0:
        return Dense(rho=cfg.rho)
    return cls(rho=cfg.rho, refine=cfg.refine)
