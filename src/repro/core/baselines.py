"""Named method presets: the paper's baselines and ablations (Table I, Fig. 3).

* CoCoA+  (Ma et al. 2015): synchronous, "adding" aggregation -> gamma=1, sigma'=K.
* CoCoA   (Jaggi et al. 2014): synchronous, "averaging" -> gamma=1/K, sigma'=1.
* DisDCA  (Yang 2013, practical variant): equivalent to CoCoA+ under the
  conditions shown in Ma et al. 2015 Sec. 4; kept as its own named config.
* ACPD              : group-wise (B of K) + top-rho*d filter (the paper).
* ACPD-B=K ablation : group-wise machinery but full barrier (isolates sparsity).
* ACPD-rho=1 ablation: group-wise, dense messages (isolates straggler-agnosticism).
"""

from __future__ import annotations

from repro.core.acpd import MethodConfig


def cocoa_plus(K: int, H: int = 1000) -> MethodConfig:
    return MethodConfig(name="CoCoA+", protocol="sync", B=K, rho=1.0, gamma=1.0,
                        sigma_prime=float(K), H=H)


def cocoa(K: int, H: int = 1000) -> MethodConfig:
    return MethodConfig(name="CoCoA", protocol="sync", B=K, rho=1.0, gamma=1.0 / K,
                        sigma_prime=1.0, H=H)


def disdca(K: int, H: int = 1000) -> MethodConfig:
    return MethodConfig(name="DisDCA", protocol="sync", B=K, rho=1.0, gamma=1.0,
                        sigma_prime=float(K), H=H)


def acpd(K: int, d: int, *, B: int | None = None, T: int = 20, rho_d: int = 1000,
         gamma: float = 0.5, H: int = 1000) -> MethodConfig:
    B = B if B is not None else max(1, K // 2)
    return MethodConfig(name="ACPD", protocol="group", B=B, T=T,
                        rho=min(1.0, rho_d / d), gamma=gamma, H=H)


def acpd_full_barrier(K: int, d: int, *, T: int = 20, rho_d: int = 1000,
                      gamma: float = 0.5, H: int = 1000) -> MethodConfig:
    """Ablation B=K: keeps sparsity, removes straggler-agnosticism."""
    return MethodConfig(name="ACPD-B=K", protocol="group", B=K, T=T,
                        rho=min(1.0, rho_d / d), gamma=gamma, H=H)


def acpd_dense(K: int, *, B: int | None = None, T: int = 20, gamma: float = 0.5,
               H: int = 1000) -> MethodConfig:
    """Ablation rho=1: keeps group-wise protocol, removes sparsity."""
    B = B if B is not None else max(1, K // 2)
    return MethodConfig(name="ACPD-rho=1", protocol="group", B=B, T=T,
                        rho=1.0, gamma=gamma, H=H)


def acpd_async(K: int, d: int, *, T: int = 20, rho_d: int = 1000,
               gamma: float = 0.5, H: int = 1000) -> MethodConfig:
    """Fully-asynchronous: B=1, per-arrival apply, no sync barrier.

    ``T`` only sets the round budget (num_outer * T rounds), not a barrier.
    sigma' is floored at 1: the paper's gamma*B rule would give gamma < 1,
    under-damping the local subproblem when every round applies one worker.
    """
    return MethodConfig(name="ACPD-async", protocol="async", B=1, T=T,
                        rho=min(1.0, rho_d / d), gamma=gamma, H=H,
                        sigma_prime=max(1.0, gamma))


def acpd_lag(K: int, d: int, *, B: int | None = None, T: int = 20,
             rho_d: int = 1000, gamma: float = 0.5, H: int = 1000,
             lag_xi: float = 1.0, lag_window: int = 10) -> MethodConfig:
    """LAG-style lazy uploads on top of the group protocol (engine.LagProtocol)."""
    B = B if B is not None else max(1, K // 2)
    return MethodConfig(name="ACPD-LAG", protocol="lag", B=B, T=T,
                        rho=min(1.0, rho_d / d), gamma=gamma, H=H,
                        lag_xi=lag_xi, lag_window=lag_window)


def cocoa_v1(K: int, H: int = 1000, local_solver: str = "sdca") -> MethodConfig:
    """CoCoA with averaging aggregation (gamma=1/K, sigma'=1) on the
    pluggable-solver ``cocoa`` protocol (engine.CocoaProtocol)."""
    return MethodConfig(name=f"CoCoA[{local_solver}]", protocol="cocoa",
                        B=K, rho=1.0, gamma=1.0 / K, H=H,
                        local_solver=local_solver)


def cocoa_plus_solver(K: int, H: int = 1000, gamma: float = 1.0,
                      local_solver: str = "sdca") -> MethodConfig:
    """CoCoA+ adding aggregation (sigma'=gamma*K) with a registry-chosen
    local solver (engine.CocoaPlusProtocol)."""
    return MethodConfig(name=f"CoCoA+[{local_solver}]", protocol="cocoa_plus",
                        B=K, rho=1.0, gamma=gamma, H=H,
                        local_solver=local_solver)


def acpd_partial_work(K: int, d: int, *, B: int | None = None, T: int = 20,
                      rho_d: int = 1000, gamma: float = 0.5, H: int = 1000,
                      n_chunks: int = 4,
                      pw_quantum: float | None = None) -> MethodConfig:
    """Straggler-UTILIZING chunk streaming (engine.PartialWorkProtocol):
    each local pass splits into ``n_chunks`` streamed partial updates, and
    the server harvests whatever chunks arrived by its B-th-full-arrival
    deadline (or every ``pw_quantum`` simulated seconds when set).

    Equal-byte-budget by construction: the per-chunk sparsity is
    ``rho_d / n_chunks`` coordinates, so one FULL pass ships exactly the
    bytes of one ``acpd()`` round -- comparisons against ``group`` isolate
    the harvest-partial-work effect from the communication budget.
    """
    B = B if B is not None else max(1, K // 2)
    return MethodConfig(name="ACPD-partial", protocol="partial_work", B=B,
                        T=T, rho=min(1.0, rho_d / (max(1, n_chunks) * d)),
                        gamma=gamma, H=H, n_chunks=n_chunks,
                        pw_quantum=pw_quantum)


def acpd_hierarchical(K: int, d: int, *, T: int = 20, rho_d: int = 1000,
                      gamma: float = 0.5, H: int = 1000, n_racks: int = 2,
                      rack_b: int = 1) -> MethodConfig:
    """Two-level rack-aware aggregation (engine.HierarchicalBProtocol):
    per-rack ``rack_b``-of-k deadlines, then one cross-rack merge.  ``B`` is
    ignored by the arrival rule (the per-rack quotas replace it) but kept at
    the group default so sigma'-resolution and spec validation see a
    consistent config."""
    return MethodConfig(name="ACPD-hier", protocol="hierarchical_b",
                        B=max(1, K // 2), T=T, rho=min(1.0, rho_d / d),
                        gamma=gamma, H=H, n_racks=n_racks, rack_b=rack_b)


def acpd_adaptive(K: int, d: int, *, T: int = 20, rho_d: int = 1000,
                  gamma: float = 0.5, H: int = 1000, quantile: float = 0.5,
                  b_min: int = 1) -> MethodConfig:
    """Adaptive group sizing: B learned from observed arrival latencies
    (engine.AdaptiveBProtocol); B seeds the pre-observation rounds only."""
    return MethodConfig(name="ACPD-adaptiveB", protocol="adaptive_b",
                        B=max(1, K // 2), T=T, rho=min(1.0, rho_d / d),
                        gamma=gamma, H=H, adaptive_quantile=quantile,
                        b_min=b_min)


ALL_PRESETS = {
    "cocoa": cocoa,
    "cocoa_plus": cocoa_plus,
    "disdca": disdca,
    "acpd": acpd,
    "acpd_full_barrier": acpd_full_barrier,
    "acpd_dense": acpd_dense,
    "acpd_async": acpd_async,
    "acpd_lag": acpd_lag,
    "acpd_partial_work": acpd_partial_work,
    "acpd_hierarchical": acpd_hierarchical,
    "cocoa_v1": cocoa_v1,
    "cocoa_plus_solver": cocoa_plus_solver,
    "acpd_adaptive": acpd_adaptive,
}
