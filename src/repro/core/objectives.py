"""Primal/dual objectives for l2-regularized empirical risk minimization.

The paper (ACPD, Huo & Huang 2019) optimizes

    P(w) = (1/n) sum_i phi_i(w^T x_i) + (lambda/2) ||w||^2          (Eq. 2)

through its Fenchel dual

    D(alpha) = (1/n) sum_i -phi_i*(-alpha_i) - (lambda/2) || (1/(lambda n)) A alpha ||^2   (Eq. 3)

with the primal-dual map  w(alpha) = (1/(lambda n)) A alpha  (Eq. 5) and the
duality gap G(alpha) = P(w(alpha)) - D(alpha) used as the convergence monitor.

Losses implemented (all 1/mu-smooth as required by Assumption 2):

* ``ridge``          phi_i(z) = (z - y_i)^2 / 2            (paper's experiments, Eq. 25)
* ``smoothed_hinge`` phi_i(z) = smoothed hinge with smoothing ``mu`` (Shalev-Shwartz & Zhang 2013)
* ``logistic``       phi_i(z) = log(1 + exp(-y_i z))

Data layout: partitions are stacked, ``X: (K, n_k, d)``, ``y: (K, n_k)``,
mirroring the paper's K workers with evenly partitioned data (n = K * n_k).
A global view is just a reshape.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

LossName = Literal["ridge", "smoothed_hinge", "logistic"]

# Smoothing constant for the smoothed hinge (gamma-bar in SSZ'13); phi is
# (1/mu)-smooth with mu == _HINGE_SMOOTHING.
_HINGE_SMOOTHING = 1.0


@dataclasses.dataclass(frozen=True)
class Problem:
    """An l2-regularized ERM instance partitioned over K workers.

    Attributes:
      X: (K, n_k, d) stacked feature partitions (rows are samples).
      y: (K, n_k) labels; +-1 for classification losses, real for ridge.
      lam: l2 regularization strength (lambda in the paper).
      loss: which phi to use.
    """

    X: jax.Array
    y: jax.Array
    lam: float
    loss: LossName = "ridge"

    @property
    def num_workers(self) -> int:
        return self.X.shape[0]

    @property
    def n_per_worker(self) -> int:
        return self.X.shape[1]

    @property
    def n(self) -> int:
        return self.X.shape[0] * self.X.shape[1]

    @property
    def d(self) -> int:
        return self.X.shape[2]

    def global_X(self) -> jax.Array:
        return self.X.reshape(self.n, self.d)

    def global_y(self) -> jax.Array:
        return self.y.reshape(self.n)


# ---------------------------------------------------------------------------
# phi and phi* for each loss.
# Conventions follow the paper: the dual objective sums -phi_i*(-alpha_i), and
# the "dual feasible direction" u_i^t satisfies -u_i^t in d phi_i(w^T x_i).
# ---------------------------------------------------------------------------


def phi(loss: LossName, z: jax.Array, y: jax.Array) -> jax.Array:
    """Pointwise loss phi_i(z) with label y_i."""
    if loss == "ridge":
        return 0.5 * (z - y) ** 2
    if loss == "smoothed_hinge":
        g = _HINGE_SMOOTHING
        m = y * z
        return jnp.where(
            m >= 1.0,
            0.0,
            jnp.where(m <= 1.0 - g, 1.0 - m - 0.5 * g, (1.0 - m) ** 2 / (2.0 * g)),
        )
    if loss == "logistic":
        # log(1 + exp(-y z)) computed stably.
        return jnp.logaddexp(0.0, -y * z)
    raise ValueError(f"unknown loss {loss!r}")


def neg_conj(loss: LossName, alpha: jax.Array, y: jax.Array) -> jax.Array:
    """-phi_i*(-alpha_i): the per-sample term of the dual objective (Eq. 3).

    For ridge (Eq. 25):          alpha*y - alpha^2/2
    For smoothed hinge:          y*alpha - (mu/2) alpha^2   on y*alpha in [0,1], -inf outside
    For logistic:                -(a log a + (1-a) log(1-a)) with a = y*alpha in (0,1)
    """
    if loss == "ridge":
        return alpha * y - 0.5 * alpha**2
    if loss == "smoothed_hinge":
        g = _HINGE_SMOOTHING
        a = y * alpha
        feasible = (a >= 0.0) & (a <= 1.0)
        val = a - 0.5 * g * a**2
        return jnp.where(feasible, val, -jnp.inf)
    if loss == "logistic":
        a = y * alpha
        eps = 1e-12
        a = jnp.clip(a, eps, 1.0 - eps)
        ent = -(a * jnp.log(a) + (1.0 - a) * jnp.log1p(-a))
        feasible = (y * alpha > 0.0) & (y * alpha < 1.0)
        return jnp.where(feasible, ent, -jnp.inf)
    raise ValueError(f"unknown loss {loss!r}")


def dual_feasible_direction(loss: LossName, z: jax.Array, y: jax.Array) -> jax.Array:
    """u_i with -u_i in d phi_i(z_i); used by the gap analysis and tests."""
    if loss == "ridge":
        return -(z - y)
    if loss == "smoothed_hinge":
        g = _HINGE_SMOOTHING
        m = y * z
        grad = jnp.where(m >= 1.0, 0.0, jnp.where(m <= 1.0 - g, -1.0, (m - 1.0) / g)) * y
        return -grad
    if loss == "logistic":
        grad = -y * jax.nn.sigmoid(-y * z)
        return -grad
    raise ValueError(f"unknown loss {loss!r}")


def smoothness_mu(loss: LossName) -> float:
    """phi is (1/mu)-smooth; returns mu (strong-convexity constant of phi*)."""
    if loss == "ridge":
        return 1.0
    if loss == "smoothed_hinge":
        return _HINGE_SMOOTHING
    if loss == "logistic":
        return 4.0
    raise ValueError(f"unknown loss {loss!r}")


# ---------------------------------------------------------------------------
# Objectives.
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("loss",))
def primal_objective(w: jax.Array, X: jax.Array, y: jax.Array, lam: float, *, loss: LossName) -> jax.Array:
    """P(w) over stacked partitions X:(K,n_k,d), y:(K,n_k)."""
    z = jnp.einsum("knd,d->kn", X, w)
    n = z.size
    return jnp.sum(phi(loss, z, y)) / n + 0.5 * lam * jnp.vdot(w, w)


@partial(jax.jit, static_argnames=("loss",))
def dual_objective(alpha: jax.Array, X: jax.Array, y: jax.Array, lam: float, *, loss: LossName) -> jax.Array:
    """D(alpha) over stacked partitions, alpha:(K,n_k)."""
    n = alpha.size
    w_alpha = primal_from_dual(alpha, X, lam)
    return jnp.sum(neg_conj(loss, alpha, y)) / n - 0.5 * lam * jnp.vdot(w_alpha, w_alpha)


@jax.jit
def primal_from_dual(alpha: jax.Array, X: jax.Array, lam: float) -> jax.Array:
    """w(alpha) = (1/(lambda n)) A alpha  (Eq. 5), A = [x_1 .. x_n] in R^{d x n}."""
    n = alpha.size
    return jnp.einsum("knd,kn->d", X, alpha) / (lam * n)


@partial(jax.jit, static_argnames=("loss",))
def duality_gap(alpha: jax.Array, X: jax.Array, y: jax.Array, lam: float, *, loss: LossName) -> jax.Array:
    """G(alpha) = P(w(alpha)) - D(alpha) >= 0; the paper's convergence monitor."""
    w_alpha = primal_from_dual(alpha, X, lam)
    return primal_objective(w_alpha, X, y, lam, loss=loss) - dual_objective(alpha, X, y, lam, loss=loss)


def gap_certificate(problem: Problem, alpha: jax.Array, w: jax.Array | None = None) -> dict[str, float]:
    """Convenience: all monitored quantities for logging/benchmarks.

    If ``w`` (e.g. the server's sparsified model) is given, also reports
    P(w_server) - D(alpha), which is what a deployed system would monitor when
    the exact primal-dual relation is broken by the practical filter variant.
    """
    X, y, lam, loss = problem.X, problem.y, problem.lam, problem.loss
    w_alpha = primal_from_dual(alpha, X, lam)
    p = primal_objective(w_alpha, X, y, lam, loss=loss)
    dv = dual_objective(alpha, X, y, lam, loss=loss)
    out = {
        "primal": float(p),
        "dual": float(dv),
        "gap": float(p - dv),
    }
    if w is not None:
        p_srv = primal_objective(w, X, y, lam, loss=loss)
        out["primal_server"] = float(p_srv)
        out["gap_server"] = float(p_srv - dv)
    return out
