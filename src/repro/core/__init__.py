"""ACPD core: the paper's contribution as a composable JAX library.

Scheduler (straggler-agnostic server), workers (bandwidth-efficient SDCA),
message filter, baselines, the straggler-clock simulator, and the beyond-paper
deep-net gradient exchange live here; substrates are sibling subpackages.
"""

from repro.core.objectives import (  # noqa: F401
    Problem,
    duality_gap,
    dual_objective,
    gap_certificate,
    primal_from_dual,
    primal_objective,
)
from repro.core.acpd import (  # noqa: F401
    MethodConfig,
    RunResult,
    run_method,
    run_method_reference,
)
from repro.core.engine import (  # noqa: F401
    Protocol,
    available_protocols,
    get_protocol,
    register_protocol,
)
from repro.core import baselines  # noqa: F401
from repro.core import filter  # noqa: F401
