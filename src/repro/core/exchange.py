"""GroupedDeltaExchange: ACPD as a gradient-exchange layer for deep nets.

This is the beyond-paper integration (DESIGN §3): each slice of the mesh's
``data`` axis is one ACPD "worker group". Per train step:

    dw_g   = residual_g + grad_g                    (error accumulation, Alg.2 l.6)
    F_g    = compress(dw_g)                         (message filter, l.7-9)
    update = gamma * sum_g p_g F_g / B              (server update, Alg.1 l.10)
    residual_g <- p_g (dw_g - F_g) + (1-p_g) dw_g   (practical variant + skipped
                                                     groups keep accumulating)

``p`` is the B-of-K participation mask: in lockstep SPMD no worker is ever
*late*, so straggler-agnosticism survives as its algorithmic content -- which
deltas are applied when, staleness bounded by the dense sync every T steps
(Alg.1 condition2), where rho is also forced to 1.

With B = K, rho = 1, gamma = 1 the update is exactly the data-parallel mean
gradient (tested), so the dense baseline is the same code path.

The compression step is a :mod:`repro.core.compress` registry entry
(``ExchangeConfig.compressor``) -- the same objects the primal-dual simulator
resolves from ``MethodConfig``, so byte accounting is computed one way on both
paths. The default ``topk_threshold`` uses a two-round histogram threshold
(O(n), vectorized over groups) -- the jnp twin of kernels/topk_filter.py; on
TPU the per-leaf filtering runs where the gradient shards live, and only the
masked sum crosses the ``data`` axis.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compress as compress_lib
from repro.core.compress import sparsify_leaf, threshold_for_topk  # noqa: F401 (re-export)

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ExchangeConfig:
    num_groups: int = 16  # K: worker groups (= data-axis slices)
    group_size: int = 8  # B: participating groups per step
    sync_period: int = 20  # T: dense full-sync every T steps
    rho: float = 1.0 / 256.0  # fraction of coordinates exchanged
    gamma: float = 0.9  # server step scale
    refine: bool = True  # second histogram round
    min_leaf_size: int = 1024  # leaves smaller than this are sent densely
    compressor: str = "topk_threshold"  # repro.core.compress registry entry

    def __post_init__(self):
        assert 1 <= self.group_size <= self.num_groups
        compress_lib.get_compressor(self.compressor)  # early validation


class ExchangeState(NamedTuple):
    residual: PyTree  # each leaf (G, *param_shape), sharded on the data axis


def dense_config(num_groups: int) -> ExchangeConfig:
    """The synchronous dense baseline (== data-parallel mean) as a config."""
    return ExchangeConfig(num_groups=num_groups, group_size=num_groups,
                          sync_period=1, rho=1.0, gamma=1.0)


def init_state(cfg: ExchangeConfig, params: PyTree) -> ExchangeState:
    res = jax.tree.map(
        lambda p: jnp.zeros((cfg.num_groups, *p.shape), jnp.float32), params)
    return ExchangeState(residual=res)


_DENSE = compress_lib.Dense()


# ---------------------------------------------------------------------------
# The exchange step.
# ---------------------------------------------------------------------------


def exchange_sequential(cfg: ExchangeConfig, grad_fn, params, grouped_batch,
                        state: ExchangeState, step: jax.Array,
                        shard_acc=None):
    """Memory-scalable ACPD round: lax.scan over the groups.

    The vmapped form materializes per-group gradients for all K groups at
    once -- K x grad memory, which at 235B x 16 groups is terabytes/device
    (measured; see EXPERIMENTS §Perf). This form computes each group's
    gradient, filters it, folds it into the running masked sum and writes the
    group's residual slice, all inside one scan step -- peak extra memory is
    ONE gradient + the accumulator, independent of K.

    grouped_batch: pytree with leading axis G on every leaf.
    Returns (update, new_state, metrics) with identical semantics to
    ``exchange`` (tested for equivalence).
    """
    G, B = cfg.num_groups, cfg.group_size
    comp = compress_lib.for_exchange(cfg)
    dense_step = jnp.mod(step, cfg.sync_period) == cfg.sync_period - 1
    p = jnp.where(dense_step, jnp.ones(G), participation(cfg, step))
    denom = jnp.maximum(jnp.sum(p), 1.0)

    def leaf_filter(dw):
        n = dw.size
        if cfg.rho >= 1.0 or n < cfg.min_leaf_size:
            return dw, jnp.ones(dw.shape, bool), jnp.float32(True)
        sent, mask = comp.compress_grouped(dw[None])
        sent, mask = sent[0], mask[0]
        sent = jnp.where(dense_step, dw, sent)
        mask = jnp.where(dense_step, jnp.ones_like(mask), mask)
        return sent, mask, dense_step.astype(jnp.float32)

    flat_res = dict(enumerate(jax.tree.leaves(state.residual)))
    treedef = jax.tree.structure(state.residual)

    def grad_flat(params_, batch_g):
        g = grad_fn(params_, batch_g)
        return dict(enumerate(jax.tree.leaves(g)))

    shard_acc = shard_acc if shard_acc is not None else (lambda d: d)
    zero_acc = shard_acc({i: jnp.zeros(v.shape[1:], jnp.float32)
                          for i, v in flat_res.items()})

    def body_flat(acc, inp):
        res_g, batch_g, g_idx = inp
        g = grad_flat(params, batch_g)
        pg = p[g_idx]
        acc_upd, acc_sent, acc_bytes = acc
        new_res, new_acc = {}, {}
        sent_count = jnp.float32(0.0)
        byte_count = jnp.float32(0.0)
        for i, dw_prev in res_g.items():
            dw = dw_prev + g[i].astype(jnp.float32)
            sent, mask, sent_dense = leaf_filter(dw)
            new_acc[i] = acc_upd[i] + pg * sent
            new_res[i] = jnp.where(pg > 0, dw - sent, dw)
            kept = jnp.sum(mask)
            sent_count += pg * kept
            byte_count += pg * jnp.where(
                sent_dense > 0, _DENSE.payload_bytes(kept),
                comp.payload_bytes(kept)).astype(jnp.float32)
        # Pin the accumulator to its sharded layout: without this the scan
        # carry (a full f32 parameter pytree) replicates on every device --
        # 59 GB at 14B, measured (§Perf).
        return (shard_acc(new_acc), acc_sent + sent_count,
                acc_bytes + byte_count), new_res

    (acc_upd, sent_total, bytes_total), new_res_flat = jax.lax.scan(
        body_flat, (zero_acc, jnp.float32(0.0), jnp.float32(0.0)),
        (flat_res, grouped_batch, jnp.arange(G)))

    update_leaves = [cfg.gamma * acc_upd[i] / denom for i in sorted(acc_upd)]
    update = jax.tree.unflatten(treedef, update_leaves)
    new_state = ExchangeState(residual=jax.tree.unflatten(
        treedef, [new_res_flat[i] for i in sorted(new_res_flat)]))
    total = float(sum(  # analysis: host-ok (static shapes, not traced values)
        np.prod(v.shape) for v in jax.tree.leaves(state.residual)))
    metrics = {
        "exchange/sent_fraction": sent_total / jnp.float32(max(total, 1.0)),
        "exchange/bytes_step": bytes_total,
        "exchange/participating": jnp.sum(p),
        "exchange/dense_step": dense_step.astype(jnp.float32),
    }
    return update, new_state, metrics


def participation(cfg: ExchangeConfig, step: jax.Array) -> jax.Array:
    """Rotating B-of-K mask (round-robin schedule), (G,) float32 in {0,1}."""
    G, B = cfg.num_groups, cfg.group_size
    g = jnp.arange(G)
    return (jnp.mod(g - step * B, G) < B).astype(jnp.float32)


def exchange(cfg: ExchangeConfig, grads_per_group: PyTree, state: ExchangeState,
             step: jax.Array) -> tuple[PyTree, ExchangeState, dict]:
    """One ACPD round over the group axis.

    grads_per_group: pytree with leading axis G on every leaf (sharded on the
    data axis). Returns (update pytree without the G axis, new state, metrics).
    """
    G, B = cfg.num_groups, cfg.group_size
    comp = compress_lib.for_exchange(cfg)
    dense_step = jnp.mod(step, cfg.sync_period) == cfg.sync_period - 1
    always_dense = cfg.rho >= 1.0 and B == G
    p = jnp.where(dense_step, jnp.ones(G), participation(cfg, step))
    denom = jnp.maximum(jnp.sum(p), 1.0)

    sent_count = jnp.float32(0.0)
    total_count = jnp.float32(0.0)
    byte_count = jnp.float32(0.0)

    def leaf_exchange(res, g):
        nonlocal sent_count, total_count, byte_count
        dw = res + g.astype(jnp.float32)  # (G, *shape)
        n = dw[0].size
        if cfg.rho >= 1.0 or n < cfg.min_leaf_size:
            sent, mask = dw, jnp.ones_like(dw, bool)
            leaf_dense = jnp.float32(1.0)
        else:
            sent_sparse, mask_sparse = comp.compress_grouped(dw)
            sent = jnp.where(dense_step, dw, sent_sparse)
            mask = jnp.where(dense_step, jnp.ones_like(dw, bool), mask_sparse)
            leaf_dense = dense_step.astype(jnp.float32)
        pb = p.reshape((G,) + (1,) * (dw.ndim - 1))
        update = cfg.gamma * jnp.sum(pb * sent, axis=0) / denom
        new_res = jnp.where(pb > 0, dw - sent, dw)
        kept = jnp.sum(jnp.where(pb > 0, mask, False), axis=tuple(range(1, dw.ndim)))
        sent_count += jnp.sum(kept)
        byte_count += jnp.sum(p * jnp.where(
            leaf_dense > 0, _DENSE.payload_bytes(kept),
            comp.payload_bytes(kept)).astype(jnp.float32))
        total_count += jnp.float32(dw.size)
        return update, new_res

    flat_res = jax.tree.leaves(state.residual)
    flat_g = jax.tree.leaves(grads_per_group)
    treedef = jax.tree.structure(state.residual)
    ups, ress = zip(*[leaf_exchange(r, g) for r, g in zip(flat_res, flat_g)])
    update = jax.tree.unflatten(treedef, ups)
    new_state = ExchangeState(residual=jax.tree.unflatten(treedef, ress))

    metrics = {
        "exchange/sent_fraction": sent_count / jnp.maximum(total_count, 1.0),
        "exchange/bytes_step": byte_count,
        "exchange/participating": jnp.sum(p),
        "exchange/dense_step": dense_step.astype(jnp.float32),
        "exchange/residual_norm": jnp.sqrt(sum(
            jnp.sum(jnp.square(r)) for r in ress)),
    }
    if always_dense:
        metrics["exchange/sent_fraction"] = jnp.float32(1.0)
    return update, new_state, metrics
