"""Event clock for the simulated distributed environment (Sec. V-B of the paper).

The paper simulates stragglers by making worker 1 take ``sigma`` times the
normal per-round compute time, and separately runs in a "real" cluster where
speeds jitter randomly. We model both:

* compute time of worker k per local round:  H * unit_time * sigma_k * J
  where J ~ LogNormal(0, jitter) (jitter=0 -> deterministic, the Sec. V-B setup).
* point-to-point message time:               latency + bytes / bandwidth
* ring all-reduce of a d-vector over K:      2 (K-1)/K * d*4 / bandwidth + 2 ceil(log2 K) * latency
  (used when timing the CoCoA+/CoCoA baselines, which the paper ran with MPI
  ``allreduce``).

All times are in arbitrary "unit" seconds; only ratios matter for the paper's
claims (speedup of ACPD over CoCoA+ at a given duality gap).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Mapping

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClusterModel:
    """Timing model for K workers + a server.

    ``delay_model`` names an entry in the :mod:`repro.core.delays` registry
    (``constant`` reproduces the historical behavior bit-for-bit);
    ``delay_params`` are that model's keyword arguments, normalized to a
    sorted tuple of ``(name, value)`` pairs so the dataclass stays hashable
    and JSON specs round-trip to equal objects.  Protocol engines call
    :meth:`make_delay` for a FRESH model per run (required for stateful
    models like ``markov``); the ``compute_time``/``p2p_time`` methods below
    delegate to one lazily-cached instance for back-compat callers (the
    reference loops in :mod:`repro.core.acpd`).
    """

    num_workers: int
    unit_time: float = 1e-5  # seconds per local SDCA iteration on a normal worker
    straggler_sigma: float = 1.0  # worker 0 is sigma x slower (paper's sigma)
    straggler_workers: tuple[int, ...] = (0,)
    jitter: float = 0.0  # lognormal sd of multiplicative compute noise
    latency: float = 1e-3  # per-message latency (seconds)
    bandwidth: float = 1.25e8  # bytes/sec (~1 Gb Ethernet, t2.medium-ish)
    delay_model: str = "constant"  # repro.core.delays registry entry
    delay_params: tuple = ()  # model kwargs as (name, value) pairs (or a dict)
    # Elastic membership schedule: ``(worker, drop_time, rejoin_time)``
    # triples in simulated seconds (``rejoin_time=None`` = never rejoins).
    # A dropped worker is masked out of aggregation and stops accruing
    # bytes/compute until its rejoin.  Only protocols declaring
    # ``supports_membership`` accept a non-empty schedule (Protocol.__init__
    # rejects it loudly otherwise).
    membership: tuple = ()

    def __post_init__(self):
        params = self.delay_params
        if isinstance(params, Mapping):
            params = params.items()
        object.__setattr__(
            self, "delay_params",
            tuple(sorted((str(k), v) for k, v in params)))
        norm = []
        for entry in self.membership:
            k, drop, rejoin = entry
            norm.append((int(k), float(drop),
                         None if rejoin is None else float(rejoin)))
        object.__setattr__(self, "membership", tuple(sorted(
            norm, key=lambda e: (e[1], e[0]))))

    def sigmas(self) -> np.ndarray:
        s = np.ones(self.num_workers)
        for k in self.straggler_workers:
            if 0 <= k < self.num_workers:
                s[k] = self.straggler_sigma
        return s

    def live_at(self, k: int, t: float) -> bool:
        """Is worker ``k`` a cluster member at simulated time ``t``?

        A worker is dead during ``[drop, rejoin)`` of any of its membership
        entries (``rejoin=None`` = forever).
        """
        for w, drop, rejoin in self.membership:
            if w == k and drop <= t and (rejoin is None or t < rejoin):
                return False
        return True

    def next_drop_after(self, k: int, t: float) -> float:
        """The first drop time of worker ``k`` strictly after ``t``
        (``inf`` when it never drops again)."""
        drops = [drop for w, drop, _ in self.membership
                 if w == k and drop > t]
        return min(drops) if drops else math.inf

    def next_rejoin_after(self, t: float) -> float:
        """The earliest rejoin time strictly after ``t`` across all workers
        (``inf`` if none) -- the starvation horizon for elastic protocols."""
        rejoins = [r for _, _, r in self.membership
                   if r is not None and r > t]
        return min(rejoins) if rejoins else math.inf

    def make_delay(self):
        """A fresh :class:`repro.core.delays.DelayModel` for one run."""
        from repro.core import delays

        return delays.get_delay(self.delay_model)(
            self, **dict(self.delay_params))

    @functools.cached_property
    def _delay(self):
        """Lazily-cached model backing the legacy method API below.

        Stateless models only: a cached stateful model (``markov``) would
        silently leak chain state across runs sharing this ClusterModel, so
        it is refused here -- callers needing one go through
        :meth:`make_delay` per run (the engine protocols do; the reference
        loops in :mod:`repro.core.acpd` support stateless models only).
        """
        model = self.make_delay()
        if model.stateful:
            raise ValueError(
                f"delay model {self.delay_model!r} is stateful; the legacy "
                f"ClusterModel.compute_time/p2p_time delegation would share "
                f"its state across runs. Use ClusterModel.make_delay() per "
                f"run (engine protocols do this automatically).")
        if model.worker_aware:
            raise ValueError(
                f"delay model {self.delay_model!r} times messages per "
                f"worker; the legacy ClusterModel.p2p_time signature cannot "
                f"carry the worker index and would silently time every "
                f"worker on the fast link. Use ClusterModel.make_delay() "
                f"(engine protocols do this automatically).")
        return model

    def compute_time(self, k: int, H: int, rng: np.random.Generator) -> float:
        return self._delay.compute_time(k, H, rng)

    def p2p_time(self, num_bytes: int) -> float:
        return self._delay.p2p_time(num_bytes)

    def allreduce_time(self, d: int, value_bytes: int = 4) -> float:
        K = self.num_workers
        if K <= 1:
            return 0.0
        ring = 2.0 * (K - 1) / K * d * value_bytes / self.bandwidth
        return ring + 2.0 * math.ceil(math.log2(K)) * self.latency


@dataclasses.dataclass
class EventClock:
    """Tracks simulated wall-clock per worker and at the server."""

    num_workers: int
    now: float = 0.0

    def __post_init__(self) -> None:
        self.worker_free_at = np.zeros(self.num_workers)

    def start_compute(self, k: int, start: float, duration: float) -> float:
        finish = max(start, self.worker_free_at[k]) + duration
        self.worker_free_at[k] = finish
        return finish

    def advance(self, t: float) -> None:
        self.now = max(self.now, t)
