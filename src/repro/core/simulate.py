"""Event clock for the simulated distributed environment (Sec. V-B of the paper).

The paper simulates stragglers by making worker 1 take ``sigma`` times the
normal per-round compute time, and separately runs in a "real" cluster where
speeds jitter randomly. We model both:

* compute time of worker k per local round:  H * unit_time * sigma_k * J
  where J ~ LogNormal(0, jitter) (jitter=0 -> deterministic, the Sec. V-B setup).
* point-to-point message time:               latency + bytes / bandwidth
* ring all-reduce of a d-vector over K:      2 (K-1)/K * d*4 / bandwidth + 2 ceil(log2 K) * latency
  (used when timing the CoCoA+/CoCoA baselines, which the paper ran with MPI
  ``allreduce``).

All times are in arbitrary "unit" seconds; only ratios matter for the paper's
claims (speedup of ACPD over CoCoA+ at a given duality gap).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClusterModel:
    """Timing model for K workers + a server."""

    num_workers: int
    unit_time: float = 1e-5  # seconds per local SDCA iteration on a normal worker
    straggler_sigma: float = 1.0  # worker 0 is sigma x slower (paper's sigma)
    straggler_workers: tuple[int, ...] = (0,)
    jitter: float = 0.0  # lognormal sd of multiplicative compute noise
    latency: float = 1e-3  # per-message latency (seconds)
    bandwidth: float = 1.25e8  # bytes/sec (~1 Gb Ethernet, t2.medium-ish)

    def sigmas(self) -> np.ndarray:
        s = np.ones(self.num_workers)
        for k in self.straggler_workers:
            if 0 <= k < self.num_workers:
                s[k] = self.straggler_sigma
        return s

    def compute_time(self, k: int, H: int, rng: np.random.Generator) -> float:
        base = H * self.unit_time * self.sigmas()[k]
        if self.jitter > 0.0:
            base *= float(rng.lognormal(0.0, self.jitter))
        return base

    def p2p_time(self, num_bytes: int) -> float:
        return self.latency + num_bytes / self.bandwidth

    def allreduce_time(self, d: int, value_bytes: int = 4) -> float:
        K = self.num_workers
        if K <= 1:
            return 0.0
        ring = 2.0 * (K - 1) / K * d * value_bytes / self.bandwidth
        return ring + 2.0 * math.ceil(math.log2(K)) * self.latency


@dataclasses.dataclass
class EventClock:
    """Tracks simulated wall-clock per worker and at the server."""

    num_workers: int
    now: float = 0.0

    def __post_init__(self) -> None:
        self.worker_free_at = np.zeros(self.num_workers)

    def start_compute(self, k: int, start: float, duration: float) -> float:
        finish = max(start, self.worker_free_at[k]) + duration
        self.worker_free_at[k] = finish
        return finish

    def advance(self, t: float) -> None:
        self.now = max(self.now, t)
