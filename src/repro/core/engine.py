"""Pluggable event-driven protocol engine for distributed primal-dual methods.

One priority-queue server loop, parameterized by a :class:`Protocol` that
supplies the three rules the paper's Algorithm 1 fixes ad hoc:

* **arrival rule**   -- how many worker messages the server waits for
  (``B`` of ``K`` for the group protocol, all ``K`` for synchronous methods,
  1 for fully-asynchronous operation);
* **aggregation rule** -- how arrived payloads enter the server state
  (catch-up buffers ``dw_tilde`` for the group family, plain allreduce-style
  summation for the CoCoA lineage);
* **reply rule**     -- what goes back to each worker and how it is timed
  and billed (p2p catch-up replies vs one ring all-reduce).

Protocols are registry entries (:func:`register_protocol`), so new server
disciplines -- e.g. LAG-style lazy aggregation (Chen et al., arXiv:1805.09965)
-- are ~50-line configs instead of forks of the loop.  Shipped entries:
``group``/``sync`` (the paper's disciplines, bit-for-bit pinned), ``async``,
``lag`` (D-window lazy uploads), ``cocoa``/``cocoa_plus`` (CoCoA lineage,
arXiv:1409.1458, pluggable :mod:`repro.core.solvers` local solver) and
``adaptive_b`` (group size learned from arrival quantiles).  Worker timing is
itself pluggable: protocols draw compute/message delays from the
:mod:`repro.core.delays` registry via ``ClusterModel.delay_model``, so every
protocol x delay x compressor scenario is one declarative spec.  The
extension walkthrough lives in ``docs/extending-protocols.md``; the contract
every subclass implements is documented on :class:`Protocol`.

Performance contract vs the reference loops in :mod:`repro.core.acpd`:

* each worker round is ONE donated, jitted dispatch (SDCA solve + dual update
  + top-k filter + residual update fused; the PRNG split happens inside);
* each server round is ONE jitted dispatch (aggregation + catch-up replies +
  reply ``nnz`` computed in-graph) followed by a single scalar pull for the
  byte accounting -- the reference does a blocking ``int(nnz(...))`` per
  message;
* duality-gap evaluation is deferred: snapshots of ``(w, alpha)`` device
  arrays are collected during simulation and evaluated afterwards (one
  ``lax.map`` dispatch by default -- NOT vmap, which would break bit-exactness;
  see ``_eval_batched`` -- or op-for-op identical to the reference with
  ``eval_mode="replay"``).

``benchmarks/bench_engine.py`` measures the resulting dispatch/wall-clock
reduction; ``tests/test_engine.py`` pins bit-for-bit equality of the
``group``/``sync`` trajectories against the reference implementation.
"""

from __future__ import annotations

import collections
import dataclasses
import math
from functools import partial
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compress as compress_lib
from repro.core import filter as msg_filter
from repro.core import objectives
from repro.core.acpd import MethodConfig, RunRecord, RunResult
from repro.core.sdca import solve_subproblem, solve_subproblem_all
from repro.core.simulate import ClusterModel

# ---------------------------------------------------------------------------
# Protocol registry.
# ---------------------------------------------------------------------------

_PROTOCOLS: dict[str, type["Protocol"]] = {}


def register_protocol(name: str):
    """Class decorator: make a Protocol constructible via ``MethodConfig.protocol``."""

    def deco(cls: type["Protocol"]) -> type["Protocol"]:
        cls.protocol_name = name
        _PROTOCOLS[name] = cls
        return cls

    return deco


def available_protocols() -> tuple[str, ...]:
    return tuple(sorted(_PROTOCOLS))


def get_protocol(name: str) -> type["Protocol"]:
    try:
        return _PROTOCOLS[name]
    except KeyError:
        raise ValueError(
            f"unknown protocol {name!r}; available: {available_protocols()}"
        ) from None


# ---------------------------------------------------------------------------
# Messages and deferred evaluation records.
# ---------------------------------------------------------------------------


class Message:
    """An in-flight worker->server message (payload stays on device)."""

    __slots__ = ("arrival", "worker", "payload", "alpha_snapshot", "nbytes",
                 "seq", "applied")

    def __init__(self, arrival: float, worker: int, payload, alpha_snapshot,
                 nbytes: int, seq: int, applied: bool = True):
        self.arrival = arrival
        self.worker = worker
        self.payload = payload
        self.alpha_snapshot = alpha_snapshot
        self.nbytes = nbytes
        self.seq = seq
        self.applied = applied  # False for LAG heartbeats (skipped uploads)

    def __lt__(self, other: "Message") -> bool:
        return (self.arrival, self.seq) < (other.arrival, other.seq)


@dataclasses.dataclass
class _Snapshot:
    """Host-side accounting + device state captured at an eval boundary."""

    iteration: int
    sim_time: float
    bytes_up: int
    bytes_down: int
    compute_time: float
    comm_time: float
    w: jax.Array
    alpha: jax.Array  # (K, n_k) server-visible (group) / canonical (sync)


# ---------------------------------------------------------------------------
# Fused jitted rounds.
# ---------------------------------------------------------------------------


def _local_round(key, w_local, alpha_k, residual_k, X_k, y_k, norms_k, k, lam,
                 n, sigma_p, gamma, *, loss, num_steps, comp):
    """Shared Alg. 2 body: solve + dual update + filter. Traced, not jitted --
    both fused worker rounds inline it so the op sequence (and therefore the
    bit-exact trajectory) is defined in exactly one place. ``comp`` is a
    frozen :mod:`repro.core.compress` registry object (static under jit)."""
    key, sub = jax.random.split(key)
    w_eff = w_local[k] + gamma * residual_k
    dalpha, v = solve_subproblem(
        w_eff, alpha_k, X_k, y_k, norms_k, lam, n, sigma_p, sub,
        loss=loss, num_steps=num_steps)
    alpha_new = alpha_k + gamma * dalpha  # Alg. 2 line 5
    dw = residual_k + v  # line 6
    sent, new_residual = comp.compress(dw)
    return key, alpha_new, new_residual, dw, sent


@partial(jax.jit, static_argnames=("loss", "num_steps", "comp"),
         donate_argnums=(0, 2, 3))
def _worker_round_fused(key, w_local, alpha_k, residual_k, X_k, y_k, norms_k,
                        k, lam, n, sigma_p, gamma, *, loss, num_steps, comp):
    """One full local round (Alg. 2) as a single dispatch.

    Returns the new global PRNG key, the worker's updated dual row and
    residual, and the compressed payload.
    """
    key, alpha_new, new_residual, _, sent = _local_round(
        key, w_local, alpha_k, residual_k, X_k, y_k, norms_k, k, lam, n,
        sigma_p, gamma, loss=loss, num_steps=num_steps, comp=comp)
    return key, alpha_new, new_residual, sent


@partial(jax.jit, static_argnames=("loss", "num_steps", "comp"),
         donate_argnums=(0, 2, 3))
def _worker_round_lag(key, w_local, alpha_k, residual_k, ref_k, X_k, y_k,
                      norms_k, k, lam, n, sigma_p, gamma, xi, *, loss,
                      num_steps, comp):
    """LAG-style lazy worker round: upload only if the delta is informative.

    The upload is skipped when ``||F(dw)||^2 < xi * ref`` where ``ref`` is the
    squared norm of the worker's last catch-up reply -- its freshest view of
    how much the global model is already moving without it (the primal-dual
    analogue of LAG's gradient-change-vs-model-movement test). Skipped mass
    stays in the residual: error feedback makes laziness lossless, only late,
    and since replies shrink as the system converges the test stays calibrated
    (all-quiet -> replies ~ 0 -> uploads resume, no starvation).
    """
    key, alpha_new, new_residual, dw, sent = _local_round(
        key, w_local, alpha_k, residual_k, X_k, y_k, norms_k, k, lam, n,
        sigma_p, gamma, loss=loss, num_steps=num_steps, comp=comp)
    send_sq = jnp.vdot(sent, sent)
    skip = send_sq < xi * ref_k
    sent = jnp.where(skip, jnp.zeros_like(sent), sent)
    new_residual = jnp.where(skip, dw, new_residual)
    return key, alpha_new, new_residual, sent, skip


# Only dw_tilde/w_local are donated: w_server and alpha_applied may be held
# by deferred eval snapshots, which donation would invalidate.
@partial(jax.jit, donate_argnums=(1, 2))
def _server_apply_fused(w_server, dw_tilde, w_local, alpha_applied, idxs,
                        payloads, snapshots, apply_mask, gamma):
    """Alg. 1 lines 8-11 for one group of arrivals, as a single dispatch.

    ``payloads``/``snapshots`` are tuples ordered by arrival (the summation
    order matters bit-for-bit); ``apply_mask`` marks real uploads (False for
    LAG heartbeats, whose zero payloads leave the sum unchanged but whose dual
    snapshots must NOT become server-visible). Reply ``nnz`` is computed
    in-graph and returned as one small vector -- the only device->host value
    the event loop needs.
    """
    total = jnp.zeros_like(w_server)
    for p in payloads:
        total = total + p
    w_server = w_server + gamma * total
    dw_tilde = dw_tilde + gamma * total[None, :]
    snap = jnp.stack(list(snapshots))
    mask = apply_mask[:, None]
    alpha_applied = alpha_applied.at[idxs].set(
        jnp.where(mask, snap, alpha_applied[idxs]))
    replies = dw_tilde[idxs]
    reply_nnz = jnp.sum(replies != 0, axis=1)
    reply_sq = jnp.sum(replies * replies, axis=1)  # LAG's laziness reference
    w_local = w_local.at[idxs].add(replies)
    dw_tilde = dw_tilde.at[idxs].set(0.0)
    return w_server, dw_tilde, w_local, alpha_applied, reply_nnz, reply_sq


# Only the key is donated: w/alpha may be held by deferred eval snapshots.
@partial(jax.jit, static_argnames=("loss", "num_steps"), donate_argnums=(0,))
def _sync_round_fused(key, w, alpha, X, y, norms_sq, lam, n, sigma_p, gamma, *,
                      loss, num_steps):
    """One lockstep CoCoA-family round (all K subproblems + aggregation)."""
    K = X.shape[0]
    key, sub = jax.random.split(key)
    keys = jax.random.split(sub, K)
    w_all = jnp.broadcast_to(w, (K, w.shape[0]))
    dalpha, v = solve_subproblem_all(
        w_all, alpha, X, y, norms_sq, lam, n, sigma_p, keys,
        loss=loss, num_steps=num_steps)
    alpha = alpha + gamma * dalpha
    w = w + gamma * jnp.sum(v, axis=0)
    return key, w, alpha


# Like _sync_round_fused but with the local solver as a static argument: the
# CoCoA lineage runs any repro.core.solvers registry entry, vmapped over the
# worker axis, in one donated dispatch.
@partial(jax.jit, static_argnames=("loss", "num_steps", "solver"),
         donate_argnums=(0,))
def _cocoa_round_fused(key, w, alpha, X, y, norms_sq, lam, n, sigma_p, gamma,
                       *, loss, num_steps, solver):
    K = X.shape[0]
    key, sub = jax.random.split(key)
    keys = jax.random.split(sub, K)
    w_all = jnp.broadcast_to(w, (K, w.shape[0]))
    fn = partial(solver, loss=loss, num_steps=num_steps)
    dalpha, v = jax.vmap(fn, in_axes=(0, 0, 0, 0, 0, None, None, None, 0))(
        w_all, alpha, X, y, norms_sq, lam, n, sigma_p, keys)
    alpha = alpha + gamma * dalpha
    w = w + gamma * jnp.sum(v, axis=0)
    return key, w, alpha


@partial(jax.jit, static_argnames=("loss",))
def _eval_batched(ws, alphas, X, y, lam, *, loss):
    """All deferred gap certificates in one dispatch.

    ``lax.map`` (not vmap): the per-snapshot computation stays unbatched, so
    each reduction sees the exact operand shapes of the reference's eager
    ``gap_certificate`` calls -- batched dot_generals reduce in a different
    order on CPU and break the last-bit equivalence contract.
    """

    def one(args):
        w, alpha = args
        w_alpha = objectives.primal_from_dual(alpha, X, lam)
        p = objectives.primal_objective(w_alpha, X, y, lam, loss=loss)
        dv = objectives.dual_objective(alpha, X, y, lam, loss=loss)
        p_srv = objectives.primal_objective(w, X, y, lam, loss=loss)
        return p, dv, p - dv, p_srv - dv

    return jax.lax.map(one, (ws, alphas))


# ---------------------------------------------------------------------------
# Protocols.
# ---------------------------------------------------------------------------


class Protocol:
    """Arrival + aggregation + reply rules driving the engine's event loop.

    A *protocol* is one server discipline: it decides how many worker
    messages a round waits for, how arrived payloads enter the server state,
    and what (and when) each worker hears back.  Subclass, decorate with
    :func:`register_protocol`, and the entry becomes constructible from any
    ``MethodConfig.protocol`` string -- inheriting engine fusion, deferred
    gap evaluation, the streaming :class:`repro.api.session.Session` loop,
    and the bit-for-bit regression harness (tests/test_engine.py) for free.
    ``docs/extending-protocols.md`` is the worked walkthrough.

    **Classmethod contract** (consulted before an instance exists):

    ``default_sigma_prime(method, K)``
        The subproblem safety parameter sigma' used when
        ``MethodConfig.sigma_prime`` is ``None``.  sigma' scales the
        quadratic penalty of the local subproblem (Eq. 7-8) and must upper
        bound the aggregation overlap: gamma * B for B-of-K group
        aggregation (the paper's rule), gamma * K for "adding" CoCoA+
        aggregation, 1 for "averaging" CoCoA aggregation.  Protocol-owned so
        registry entries supply a *correct* default instead of growing
        string checks in the config dataclass -- an unsafe sigma' diverges,
        an over-conservative one merely converges slowly.

    **Instance hooks, in the order the Session loop calls them:**

    ``num_rounds(num_outer)``
        Total server rounds for a ``num_outer`` budget (``num_outer * T``
        for the T-periodic group family, ``num_outer`` for lockstep rounds).

    ``initial_messages()``
        Launch every worker's first local round; returns the Messages that
        seed the arrival queue.  Each Message's ``arrival`` is the simulated
        time the server would receive it.

    ``arrivals_needed(round_index)``
        How many queued messages round ``round_index`` waits for -- the
        *arrival rule* (B, K, 1, or anything state-dependent; it is re-read
        every round, so adaptive disciplines just return fresh state).

    ``is_sync_round(round_index)``
        True when the round is a full-K barrier; the Session emits a
        :class:`repro.api.session.SyncEvent` after processing it.

    ``process_round(round_index, arrived)``
        The *aggregation + reply* rules: fold the arrived payloads into
        server state, bill reply bytes/time, advance ``self.sim_time``, and
        return the next wave of in-flight Messages (usually one relaunch per
        arrived worker).  Accounting invariant: ``bytes_up``/``bytes_down``/
        ``compute_time``/``comm_time`` are cumulative totals and
        ``sim_time`` is monotone.

    ``snapshot(iteration)``
        Capture (device arrays allowed, no host sync required) whatever a
        deferred duality-gap evaluation needs -- called at eval boundaries.

    ``finalize(records)``
        Fold the finished run into a :class:`RunResult`.

    Timing comes from ``self.delay`` -- a fresh
    :class:`repro.core.delays.DelayModel` per run (so stateful models like
    ``markov`` never leak across runs), resolved from
    ``ClusterModel.delay_model``.  Host randomness comes from ``self.rng``
    and device randomness from ``self.key``; both are seeded from the run's
    single ``seed`` so a (spec, seed) pair reproduces the trajectory.
    """

    protocol_name = "abstract"

    @classmethod
    def default_sigma_prime(cls, method: MethodConfig, K: int) -> float:
        """sigma' when ``MethodConfig.sigma_prime`` is unset.

        The paper's rule for the group family: gamma * B (safe for B-of-K
        aggregation). Protocol-owned so new registry entries supply their own
        value instead of growing string checks in the config dataclass.
        """
        return method.gamma * method.B

    def __init__(self, problem: objectives.Problem, method: MethodConfig,
                 cluster: ClusterModel, *, seed: int):
        self.problem = problem
        self.method = method
        self.cluster = cluster
        self.delay = cluster.make_delay()  # fresh per run; may be stateful
        self.K, self.n_k, self.d = problem.X.shape
        self.n = self.K * self.n_k
        self.sigma_p = method.resolved_sigma_prime(self.K)
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.key(seed)
        self.bytes_up = 0
        self.bytes_down = 0
        self.compute_time = 0.0
        self.comm_time = 0.0
        self.sim_time = 0.0
        self.seq = 0

    # --- hooks the engine loop calls (contract in the class docstring) ----

    def num_rounds(self, num_outer: int) -> int:
        raise NotImplementedError

    def initial_messages(self) -> Iterable[Message]:
        raise NotImplementedError

    def arrivals_needed(self, round_index: int) -> int:
        raise NotImplementedError

    def is_sync_round(self, round_index: int) -> bool:
        """True when round ``round_index`` is a full-K barrier (SyncEvent)."""
        return False

    def process_round(self, round_index: int, arrived: list[Message]) -> list[Message]:
        raise NotImplementedError

    def snapshot(self, iteration: int) -> _Snapshot:
        raise NotImplementedError

    def finalize(self, records: list[RunRecord]) -> RunResult:
        raise NotImplementedError


@register_protocol("group")
class GroupProtocol(Protocol):
    """Algorithms 1+2: straggler-agnostic B-of-K server with catch-up buffers."""

    full_sync_period: bool = True  # every T-th round is a K-barrier

    def __init__(self, problem, method, cluster, *, seed):
        super().__init__(problem, method, cluster, seed=seed)
        dt = problem.X.dtype
        self.comp = compress_lib.for_method(method, self.d)
        self.dense = isinstance(self.comp, compress_lib.Dense)
        self.up_bytes = self.comp.wire_bytes(self.d)
        self.w_server = jnp.zeros((self.d,), dt)
        self.dw_tilde = jnp.zeros((self.K, self.d), dt)
        self.w_local = jnp.zeros((self.K, self.d), dt)
        self.alpha_applied = jnp.zeros((self.K, self.n_k), dt)
        self.alpha = [jnp.zeros((self.n_k,), dt) for _ in range(self.K)]
        self.residual = [jnp.zeros((self.d,), dt) for _ in range(self.K)]
        # Per-worker constants, sliced once (the reference re-slices per round).
        self.X_k = [problem.X[k] for k in range(self.K)]
        self.y_k = [problem.y[k] for k in range(self.K)]
        norms_sq = jnp.sum(problem.X * problem.X, axis=-1)
        self.norms_k = [norms_sq[k] for k in range(self.K)]

    def num_rounds(self, num_outer: int) -> int:
        return num_outer * self.method.T

    def initial_messages(self):
        return [self._launch_worker(k, 0.0) for k in range(self.K)]

    def arrivals_needed(self, round_index: int) -> int:
        T = self.method.T
        if self.full_sync_period and round_index % T == T - 1:
            return self.K
        return min(self.method.B, self.K)

    def is_sync_round(self, round_index: int) -> bool:
        T = self.method.T
        return self.full_sync_period and round_index % T == T - 1

    def _launch_worker(self, k: int, start_time: float) -> Message:
        m = self.method
        self.key, alpha_new, residual_new, sent = _worker_round_fused(
            self.key, self.w_local, self.alpha[k], self.residual[k],
            self.X_k[k], self.y_k[k], self.norms_k[k], k, self.problem.lam,
            self.n, self.sigma_p, m.gamma, loss=self.problem.loss,
            num_steps=m.H, comp=self.comp)
        self.alpha[k] = alpha_new
        self.residual[k] = residual_new
        duration = self.delay.compute_time(k, m.H, self.rng)
        up_time = self.delay.p2p_time(self.up_bytes, k)
        self.compute_time += duration
        self.comm_time += up_time
        self.bytes_up += self.up_bytes
        self.seq += 1
        return Message(start_time + duration + up_time, k, sent, alpha_new,
                       self.up_bytes, self.seq)

    def _apply_server(self, arrived):
        """Fused aggregation + replies; returns (server_time, reply nnz)."""
        server_time = max(m.arrival for m in arrived)
        idxs = jnp.asarray([m.worker for m in arrived], jnp.int32)
        mask = jnp.asarray([m.applied for m in arrived], bool)
        (self.w_server, self.dw_tilde, self.w_local, self.alpha_applied,
         reply_nnz, reply_sq) = _server_apply_fused(
            self.w_server, self.dw_tilde, self.w_local, self.alpha_applied,
            idxs, tuple(m.payload for m in arrived),
            tuple(m.alpha_snapshot for m in arrived), mask, self.method.gamma)
        self._last_reply_sq = reply_sq  # stays on device; LAG reads slices
        # The ONE host<->device sync of the round (skipped when replies are
        # dense, whose byte count is static).
        nnz_host = None if self.dense else np.asarray(reply_nnz)
        return server_time, nnz_host

    def _account_reply(self, j, worker, server_time, nnz_host) -> float:
        """Bill the catch-up reply; returns the worker's next start time."""
        rbytes = (msg_filter.dense_bytes(self.d) if self.dense
                  else msg_filter.message_bytes(int(nnz_host[j])))
        self.bytes_down += rbytes
        down_time = self.delay.p2p_time(rbytes, worker)
        self.comm_time += down_time
        return server_time + down_time

    def process_round(self, round_index, arrived):
        server_time, nnz_host = self._apply_server(arrived)
        # Reply accounting and relaunch interleave per worker, matching the
        # reference's float accumulation order exactly (down, up, down, up).
        out = []
        for j, m in enumerate(arrived):
            start = self._account_reply(j, m.worker, server_time, nnz_host)
            out.append(self._launch_worker(m.worker, start))
        self.sim_time = server_time
        return out

    def snapshot(self, iteration):
        return _Snapshot(iteration, self.sim_time, self.bytes_up,
                         self.bytes_down, self.compute_time, self.comm_time,
                         self.w_server, self.alpha_applied)

    def finalize(self, records):
        return RunResult(self.method, records, np.asarray(self.w_server),
                         np.stack([np.asarray(a) for a in self.alpha]),
                         alpha_applied=np.asarray(self.alpha_applied))


@register_protocol("async")
class AsyncProtocol(GroupProtocol):
    """Fully-asynchronous ablation: B=1, per-worker apply, no sync barrier.

    Every arrival is applied immediately; staleness is unbounded (Assumption 3
    is intentionally violated -- this is the protocol the paper's T-periodic
    barrier exists to tame, now expressible as a config).
    """

    full_sync_period = False

    def __init__(self, problem, method, cluster, *, seed):
        if method.B != 1:
            raise ValueError(
                f"protocol 'async' is defined by B=1 (per-arrival apply); "
                f"got B={method.B}. Use protocol='group' for B-of-K "
                f"aggregation, or baselines.acpd_async() for a valid config.")
        super().__init__(problem, method, cluster, seed=seed)


@register_protocol("lag")
class LagProtocol(GroupProtocol):
    """Group protocol + LAG-style lazy uploads (arXiv:1805.09965 adapted).

    LAG's worker-side rule (LAG-WK) reuses the previous gradient -- i.e.
    uploads nothing -- when the new gradient differs from the last
    communicated one by less than a windowed average of recent global model
    movement: ``||grad change||^2 <= (xi / D) * sum_{d'=1..D}
    ||theta_{t+1-d'} - theta_{t-d'}||^2``.  Two translations to this
    delta-coded primal-dual setting:

    * the upload *is already a delta* (``F(dw)``: the change since the
      worker's last applied contribution), so "gradient unchanged -> reuse"
      becomes "delta negligible -> send nothing"; the skipped mass stays in
      the error-feedback residual, making laziness lossless, only late;
    * the worker's freshest view of global model movement is its stream of
      catch-up replies (``dw_tilde``: exactly the model change it missed),
      so the RHS window averages the squared norms of its last
      ``lag_window`` replies -- the paper's D-round window (D=10 in their
      experiments), replacing the cruder single-last-reply test this
      protocol used previously (``lag_window=1`` restores it).

    A skipping worker sends an 8-byte heartbeat instead of the payload.  The
    server treats heartbeats as arrivals (the worker is alive and gets its
    catch-up reply) but applies nothing for them.  Since replies shrink as
    the system converges, the test stays calibrated: all-quiet -> replies
    ~ 0 -> uploads resume, no starvation.
    """

    HEARTBEAT_BYTES = 8

    def __init__(self, problem, method, cluster, *, seed):
        if method.lag_window < 1:
            raise ValueError(
                f"lag_window must be >= 1, got {method.lag_window}")
        super().__init__(problem, method, cluster, seed=seed)
        # Rolling window of catch-up-reply squared norms per worker (device
        # scalars); empty window => ref 0 => the first rounds always upload.
        self._ref_hist = [
            collections.deque(maxlen=method.lag_window) for _ in range(self.K)]
        self._zero = jnp.zeros((), problem.X.dtype)

    def _ref(self, k: int):
        """Windowed mean of worker k's recent reply energy (device scalar).

        Summed afresh over the (<= lag_window) window: an incremental
        running sum in f32 accumulates catastrophic cancellation once reply
        norms decay orders of magnitude below the popped early entries.
        """
        hist = self._ref_hist[k]
        if not hist:
            return self._zero
        return jnp.sum(jnp.stack(tuple(hist))) / len(hist)

    def _launch_lag(self, k: int, start_time: float):
        """Fused round; returns (device skip flag, message-parts tuple)."""
        m = self.method
        self.key, alpha_new, residual_new, sent, skip = _worker_round_lag(
            self.key, self.w_local, self.alpha[k], self.residual[k],
            self._ref(k), self.X_k[k], self.y_k[k], self.norms_k[k], k,
            self.problem.lam, self.n, self.sigma_p, m.gamma, m.lag_xi,
            loss=self.problem.loss, num_steps=m.H, comp=self.comp)
        self.alpha[k] = alpha_new
        self.residual[k] = residual_new
        return skip, (k, start_time, sent, alpha_new)

    def _finish_launch(self, skipped: bool, parts) -> Message:
        k, start_time, sent, alpha_new = parts
        nbytes = self.HEARTBEAT_BYTES if skipped else self.up_bytes
        duration = self.delay.compute_time(k, self.method.H, self.rng)
        up_time = self.delay.p2p_time(nbytes, k)
        self.compute_time += duration
        self.comm_time += up_time
        self.bytes_up += nbytes
        self.seq += 1
        return Message(start_time + duration + up_time, k, sent, alpha_new,
                       nbytes, self.seq, applied=not skipped)

    def _relaunch_batched(self, starts):
        if not starts:
            return []
        flags, parts = zip(*[self._launch_lag(k, s) for k, s in starts])
        skipped = np.asarray(jnp.stack(flags))  # one pull for the whole group
        return [self._finish_launch(bool(s), p) for s, p in zip(skipped, parts)]

    def initial_messages(self):
        return self._relaunch_batched([(k, 0.0) for k in range(self.K)])

    def process_round(self, round_index, arrived):
        server_time, nnz_host = self._apply_server(arrived)
        starts = []
        for j, m in enumerate(arrived):
            # Slide this round's reply energy into the worker's window
            # (a device slice, no host sync; maxlen evicts the oldest).
            k = m.worker
            self._ref_hist[k].append(self._last_reply_sq[j])
            starts.append((k, self._account_reply(j, k, server_time,
                                                  nnz_host)))
        self.sim_time = server_time
        return self._relaunch_batched(starts)


@register_protocol("sync")
class SyncProtocol(Protocol):
    """CoCoA / CoCoA+ / DisDCA: lockstep rounds timed as MPI allreduce.

    The queue degenerates to K tokens popped per round; timing follows the
    reference implementation exactly (max worker compute + ring allreduce,
    bytes split evenly between the reduce-scatter and all-gather phases).
    """

    @classmethod
    def default_sigma_prime(cls, method: MethodConfig, K: int) -> float:
        # "Adding" aggregation over all K partitions (Ma et al. 2015).
        return method.gamma * K

    def __init__(self, problem, method, cluster, *, seed):
        super().__init__(problem, method, cluster, seed=seed)
        dt = problem.X.dtype
        self.w = jnp.zeros((self.d,), dt)
        self.alpha = jnp.zeros((self.K, self.n_k), dt)
        self.norms_sq = jnp.sum(problem.X * problem.X, axis=-1)

    def num_rounds(self, num_outer: int) -> int:
        return num_outer

    def is_sync_round(self, round_index: int) -> bool:
        return True  # every lockstep round is a K-barrier

    def _tokens(self):
        out = []
        for k in range(self.K):
            self.seq += 1
            out.append(Message(self.sim_time, k, None, None, 0, self.seq))
        return out

    def initial_messages(self):
        return self._tokens()

    def arrivals_needed(self, round_index: int) -> int:
        return self.K

    def _round_update(self):
        """One fused lockstep update; CoCoA-lineage subclasses override to
        swap the local solver while inheriting timing/byte accounting."""
        m = self.method
        self.key, self.w, self.alpha = _sync_round_fused(
            self.key, self.w, self.alpha, self.problem.X, self.problem.y,
            self.norms_sq, self.problem.lam, self.n, self.sigma_p, m.gamma,
            loss=self.problem.loss, num_steps=m.H)

    def process_round(self, round_index, arrived):
        m = self.method
        self._round_update()
        step_compute = max(self.delay.compute_time(k, m.H, self.rng)
                           for k in range(self.K))
        step_comm = self.delay.allreduce_time(self.d)
        self.sim_time += step_compute + step_comm
        self.compute_time += step_compute
        self.comm_time += step_comm
        phase = (self.K - 1) * self.d * 4  # ring reduce-scatter == all-gather
        self.bytes_up += phase
        self.bytes_down += phase
        return self._tokens()

    def snapshot(self, iteration):
        return _Snapshot(iteration, self.sim_time, self.bytes_up,
                         self.bytes_down, self.compute_time, self.comm_time,
                         self.w, self.alpha)

    def finalize(self, records):
        return RunResult(self.method, records, np.asarray(self.w),
                         np.asarray(self.alpha))


@register_protocol("cocoa")
class CocoaProtocol(SyncProtocol):
    """CoCoA v1 (Jaggi et al., arXiv:1409.1458): synchronous rounds,
    "averaging" aggregation, pluggable local solver.

    The CoCoA framework's point is that ANY local subproblem solver reaching
    a Theta-approximate solution plugs into the same aggregation; here the
    solver comes from the :mod:`repro.core.solvers` registry via
    ``MethodConfig.local_solver`` (``sdca`` | ``importance`` |
    ``accelerated``) instead of being hard-wired SDCA.  ``gamma`` is the
    aggregation parameter: CoCoA's averaging uses ``gamma = 1/K`` (the
    :func:`repro.core.baselines.cocoa_v1` preset), for which ``sigma' = 1``
    is the safe subproblem scaling.  Timing/byte accounting is inherited
    from the lockstep ``sync`` discipline (MPI-style ring allreduce).
    """

    @classmethod
    def default_sigma_prime(cls, method: MethodConfig, K: int) -> float:
        # "Averaging" aggregation (Jaggi et al. 2014): safe for gamma <= 1/K.
        return 1.0

    def __init__(self, problem, method, cluster, *, seed):
        # Averaging is only safe for gamma <= 1/K (sigma'=1 does not damp a
        # larger aggregate; it visibly diverges).  Only the "cocoa" entry
        # enforces this -- CocoaPlusProtocol inherits with its own sigma'.
        # An explicit MethodConfig.sigma_prime overrides at the user's risk.
        K = problem.X.shape[0]
        if (self.protocol_name == "cocoa" and method.sigma_prime is None
                and method.gamma > 1.0 / K + 1e-9):
            raise ValueError(
                f"protocol 'cocoa' uses averaging aggregation (sigma'=1), "
                f"which is only safe for gamma <= 1/K; got gamma="
                f"{method.gamma} with K={K}. Use baselines.cocoa_v1, "
                f"protocol='cocoa_plus' for adding aggregation, or set "
                f"sigma_prime explicitly.")
        super().__init__(problem, method, cluster, seed=seed)
        from repro.core import solvers as solvers_lib

        self.solver = solvers_lib.get_solver(method.local_solver)

    def _round_update(self):
        m = self.method
        self.key, self.w, self.alpha = _cocoa_round_fused(
            self.key, self.w, self.alpha, self.problem.X, self.problem.y,
            self.norms_sq, self.problem.lam, self.n, self.sigma_p, m.gamma,
            loss=self.problem.loss, num_steps=m.H, solver=self.solver)


@register_protocol("cocoa_plus")
class CocoaPlusProtocol(CocoaProtocol):
    """CoCoA+ (Ma et al. 2015): "adding" aggregation, pluggable local solver.

    Same lockstep round as :class:`CocoaProtocol` but with the adding
    aggregation's safe subproblem scaling ``sigma' = gamma * K`` (gamma = 1
    recovers the paper's CoCoA+ baseline, which the hard-wired ``sync``
    protocol pins bit-for-bit; this entry exists for the pluggable-solver
    axis).
    """

    @classmethod
    def default_sigma_prime(cls, method: MethodConfig, K: int) -> float:
        return method.gamma * K


@register_protocol("adaptive_b")
class AdaptiveBProtocol(GroupProtocol):
    """Group protocol with the group size B adapted to observed arrivals.

    The paper fixes B ahead of time, but the right B depends on delay
    behavior the operator rarely knows (how many workers are persistently
    late?).  This discipline learns it online: it keeps an EWMA of each
    worker's round latency (launch -> arrival, exactly what a real server
    observes) and waits each round for the workers in the fast
    ``adaptive_quantile`` of that latency distribution::

        B_t = clip(#{k : ewma_k <= quantile_q(ewma)}, b_min, ceil(q * K))

    The upper clip matters: ``ceil(q * K)`` is the aggregation size
    ``default_sigma_prime`` covers, and under tied latencies (a homogeneous
    cluster) the raw count alone reaches K and out-runs sigma' -- which
    diverges, not errors.  Heavy-tailed or bursty delay models (``pareto``,
    ``markov``) shrink B_t automatically while the tail is hot and relax it
    when stragglers recover; under homogeneous delays it settles at
    ``ceil(q * K)``.  The
    T-periodic full barrier is kept, so the staleness bound (Assumption 3)
    still holds.  ``MethodConfig.B`` only seeds the first rounds, before one
    latency sample per worker exists.

    This class is also the worked example of ``docs/extending-protocols.md``.
    """

    @classmethod
    def default_sigma_prime(cls, method: MethodConfig, K: int) -> float:
        # sigma' must cover the aggregation size the discipline targets:
        # about quantile * K arrivals per round (the paper's gamma * B rule
        # with the adapted B's expected value).
        target_b = max(method.b_min, math.ceil(method.adaptive_quantile * K))
        return method.gamma * target_b

    def __init__(self, problem, method, cluster, *, seed):
        if not 0.0 < method.adaptive_quantile <= 1.0:
            raise ValueError(
                f"adaptive_quantile must be in (0, 1], got "
                f"{method.adaptive_quantile}")
        if not 0.0 < method.adaptive_ewma <= 1.0:
            raise ValueError(
                f"adaptive_ewma must be in (0, 1], got {method.adaptive_ewma}")
        super().__init__(problem, method, cluster, seed=seed)
        self._latency = np.full(self.K, np.nan)  # EWMA round latency
        # The adapted B lives in [b_min, ceil(q*K)]: the upper end is the
        # aggregation size the default sigma' covers (see classmethod above).
        self._b_lo = max(1, method.b_min)
        self._b_hi = min(self.K, max(self._b_lo,
                                     math.ceil(method.adaptive_quantile
                                               * self.K)))
        self._B = int(np.clip(method.B, self._b_lo, self._b_hi))

    @property
    def current_b(self) -> int:
        """The group size the next non-barrier round will wait for."""
        return self._B

    def arrivals_needed(self, round_index: int) -> int:
        T = self.method.T
        if round_index % T == T - 1:
            return self.K  # the staleness-bounding full barrier stays
        return self._B

    def _launch_worker(self, k, start_time):
        msg = super()._launch_worker(k, start_time)
        latency = msg.arrival - start_time
        beta = self.method.adaptive_ewma
        if np.isnan(self._latency[k]):
            self._latency[k] = latency
        else:
            self._latency[k] = (1.0 - beta) * self._latency[k] + beta * latency
        if not np.isnan(self._latency).any():
            cut = np.quantile(self._latency, self.method.adaptive_quantile)
            self._B = int(np.clip(int(np.sum(self._latency <= cut)),
                                  self._b_lo, self._b_hi))
        return msg


# ---------------------------------------------------------------------------
# The engine loop.
# ---------------------------------------------------------------------------


def _materialize_records(snaps: list[_Snapshot], problem: objectives.Problem,
                         eval_mode: str) -> list[RunRecord]:
    """Turn deferred snapshots into RunRecords.

    ``batched``: one ``lax.map`` dispatch covering every gap certificate.
    ``replay``: op-for-op the reference's per-round ``gap_certificate`` calls
    (bit-identical floats by construction; used as a debugging oracle --
    ``batched`` is equally bit-exact, which tests/test_engine.py pins).
    """
    if not snaps:
        return []
    if eval_mode == "replay":
        rows = []
        for s in snaps:
            cert = objectives.gap_certificate(problem, s.alpha, w=s.w)
            rows.append((cert["primal"], cert["dual"], cert["gap"],
                         cert["gap_server"]))
    elif eval_mode == "batched":
        ws = jnp.stack([s.w for s in snaps])
        alphas = jnp.stack([s.alpha for s in snaps])
        p, dv, gap, gap_srv = _eval_batched(ws, alphas, problem.X, problem.y,
                                            problem.lam, loss=problem.loss)
        rows = list(zip(np.asarray(p, np.float64), np.asarray(dv, np.float64),
                        np.asarray(gap, np.float64),
                        np.asarray(gap_srv, np.float64)))
    else:
        raise ValueError(f"unknown eval_mode {eval_mode!r}")
    return [
        RunRecord(iteration=s.iteration, sim_time=s.sim_time,
                  gap=float(gap), gap_server=float(gap_srv), primal=float(p),
                  dual=float(dv), bytes_up=int(s.bytes_up),
                  bytes_down=int(s.bytes_down), compute_time=s.compute_time,
                  comm_time=s.comm_time)
        for s, (p, dv, gap, gap_srv) in zip(snaps, rows)
    ]


def run_method(
    problem: objectives.Problem,
    method: MethodConfig,
    cluster: ClusterModel,
    *,
    num_outer: int,
    seed: int = 0,
    eval_every: int = 1,
    eval_mode: str = "batched",
) -> RunResult:
    """Run ``method`` through the pluggable engine. Same contract as
    :func:`repro.core.acpd.run_method` (which now delegates here).

    Thin compat wrapper: the round loop lives in
    :class:`repro.api.session.Session`; this drains its event stream and
    folds it back into a :class:`RunResult` (the tests/test_engine.py
    bit-for-bit pins hold through this path).
    """
    from repro.api.session import Session  # late import: api imports engine

    session = Session(problem, method, cluster, num_outer=num_outer,
                      seed=seed, eval_every=eval_every, eval_mode=eval_mode)
    return session.run()
