"""Pluggable event-driven protocol engine for distributed primal-dual methods.

One priority-queue server loop, parameterized by a :class:`Protocol` that
supplies the three rules the paper's Algorithm 1 fixes ad hoc:

* **arrival rule**   -- how many worker messages the server waits for
  (``B`` of ``K`` for the group protocol, all ``K`` for synchronous methods,
  1 for fully-asynchronous operation);
* **aggregation rule** -- how arrived payloads enter the server state
  (catch-up buffers ``dw_tilde`` for the group family, plain allreduce-style
  summation for the CoCoA lineage);
* **reply rule**     -- what goes back to each worker and how it is timed
  and billed (p2p catch-up replies vs one ring all-reduce).

Protocols are registry entries (:func:`register_protocol`), so new server
disciplines -- e.g. LAG-style lazy aggregation (Chen et al., arXiv:1805.09965)
-- are ~50-line configs instead of forks of the loop.  Shipped entries:
``group``/``sync`` (the paper's disciplines, bit-for-bit pinned), ``async``,
``lag`` (D-window lazy uploads), ``cocoa``/``cocoa_plus`` (CoCoA lineage,
arXiv:1409.1458, pluggable :mod:`repro.core.solvers` local solver) and
``adaptive_b`` (group size learned from arrival quantiles).  Worker timing is
itself pluggable: protocols draw compute/message delays from the
:mod:`repro.core.delays` registry via ``ClusterModel.delay_model``, so every
protocol x delay x compressor scenario is one declarative spec.  The
extension walkthrough lives in ``docs/extending-protocols.md``; the contract
every subclass implements is documented on :class:`Protocol`.

Performance contract vs the reference loops in :mod:`repro.core.acpd`:

* a whole GROUP of worker rounds is ONE donated, jitted dispatch
  (:func:`_worker_rounds_fused` scans the arrived workers with the same
  unbatched per-worker ops and sequential PRNG split chain, so a B-message
  relaunch costs one dispatch instead of B);
* each server round is ONE jitted dispatch (aggregation + catch-up replies +
  reply ``nnz`` computed in-graph) followed by a single scalar pull for the
  byte accounting -- the reference does a blocking ``int(nnz(...))`` per
  message;
* host-side delay sampling is vectorized: delay models flagged
  ``vector_sampled`` draw ONE size-K numpy vector per round
  (:meth:`repro.core.delays.DelayModel.sample_round`) instead of per-message
  scalars.  The pinned trajectories (``constant`` delay, the only model the
  reference oracle covers) are unmoved; group-family trajectories under the
  stochastic vectorized models moved with the consumption change (see the
  :mod:`repro.core.delays` docstring) -- both executors stay bit-identical
  to each other;
* duality-gap evaluation is deferred: snapshots of ``(w, alpha)`` device
  arrays are collected during simulation and evaluated afterwards (one
  ``lax.map`` dispatch, padded to power-of-two snapshot buckets so sweeps
  with different round budgets reuse one compile -- NOT vmap, which would
  break bit-exactness; see ``_eval_batched``/``_eval_bucketed`` -- or
  op-for-op identical to the reference with ``eval_mode="replay"``).

This module is the per-round EVENT backend.  Runs without host-adaptive
control flow can skip per-round dispatch entirely: the scan-fused executor
(:mod:`repro.core.executor`, ``Session(executor="scan"|"auto")``) compiles
an entire run into one ``lax.scan`` and reproduces this engine bit-for-bit
(docs/performance.md).  ``benchmarks/bench_engine.py`` measures the
dispatch/wall-clock reductions of both layers; ``tests/test_engine.py`` pins
bit-for-bit equality of the ``group``/``sync`` trajectories against the
reference implementation and ``tests/test_executor.py`` pins the executors
against each other across the zoo grid.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compress as compress_lib
from repro.core import filter as msg_filter
from repro.core import objectives
from repro.core.acpd import MethodConfig, RunRecord, RunResult
from repro.core.sdca import solve_subproblem
from repro.core.simulate import ClusterModel

# ---------------------------------------------------------------------------
# Protocol registry.
# ---------------------------------------------------------------------------

_PROTOCOLS: dict[str, type["Protocol"]] = {}


def register_protocol(name: str):
    """Class decorator: make a Protocol constructible via ``MethodConfig.protocol``."""

    def deco(cls: type["Protocol"]) -> type["Protocol"]:
        cls.protocol_name = name
        _PROTOCOLS[name] = cls
        return cls

    return deco


def available_protocols() -> tuple[str, ...]:
    return tuple(sorted(_PROTOCOLS))


def get_protocol(name: str) -> type["Protocol"]:
    try:
        return _PROTOCOLS[name]
    except KeyError:
        raise ValueError(
            f"unknown protocol {name!r}; available: {available_protocols()}"
        ) from None


# ---------------------------------------------------------------------------
# Messages and deferred evaluation records.
# ---------------------------------------------------------------------------


class Message:
    """An in-flight worker->server message (payload stays on device)."""

    __slots__ = ("arrival", "worker", "payload", "alpha_snapshot", "nbytes",
                 "seq", "applied", "chunk", "final")

    def __init__(self, arrival: float, worker: int, payload, alpha_snapshot,
                 nbytes: int, seq: int, applied: bool = True,
                 chunk: int = 0, final: bool = True):
        self.arrival = arrival
        self.worker = worker
        self.payload = payload
        self.alpha_snapshot = alpha_snapshot
        self.nbytes = nbytes
        self.seq = seq
        self.applied = applied  # False for LAG heartbeats (skipped uploads)
        self.chunk = chunk  # chunk index within the sender's local pass
        self.final = final  # last chunk of the pass (non-chunked: always)

    def __lt__(self, other: "Message") -> bool:
        return (self.arrival, self.seq) < (other.arrival, other.seq)


@dataclasses.dataclass
class _Snapshot:
    """Host-side accounting + device state captured at an eval boundary."""

    iteration: int
    sim_time: float
    bytes_up: int
    bytes_down: int
    compute_time: float
    comm_time: float
    w: jax.Array
    alpha: jax.Array  # (K, n_k) server-visible (group) / canonical (sync)


# ---------------------------------------------------------------------------
# Fused jitted rounds.
# ---------------------------------------------------------------------------


def _local_round(key, w_local, alpha_k, residual_k, X_k, y_k, norms_k, k, lam,
                 n, sigma_p, gamma, *, loss, num_steps, comp):
    """Shared Alg. 2 body: solve + dual update + filter. Traced, not jitted --
    both fused worker rounds inline it so the op sequence (and therefore the
    bit-exact trajectory) is defined in exactly one place. ``comp`` is a
    frozen :mod:`repro.core.compress` registry object (static under jit)."""
    key, sub = jax.random.split(key)
    w_eff = w_local[k] + gamma * residual_k
    dalpha, v = solve_subproblem(
        w_eff, alpha_k, X_k, y_k, norms_k, lam, n, sigma_p, sub,
        loss=loss, num_steps=num_steps)
    alpha_new = alpha_k + gamma * dalpha  # Alg. 2 line 5
    dw = residual_k + v  # line 6
    sent, new_residual = comp.compress(dw)
    return key, alpha_new, new_residual, dw, sent


@partial(jax.jit, static_argnames=("loss", "num_steps", "comp"),
         donate_argnums=(0, 2, 3))
def _worker_rounds_fused(key, w_local, alpha, residual, X, y, norms_sq, idxs,
                         lam, n, sigma_p, gamma, *, loss, num_steps, comp):
    """A whole group of local rounds (Alg. 2) as ONE donated dispatch.

    ``idxs`` holds the relaunched workers in arrival order.  The body scans
    over them with the same unbatched per-worker ops (and the same
    sequential global-key split chain) the former one-dispatch-per-worker
    path used, so trajectories stay bit-identical while a B-message relaunch
    costs one dispatch instead of B.  ``alpha``/``residual`` are the stacked
    (K, n_k)/(K, d) worker states; returns them updated plus the per-message
    dual snapshots and compressed payloads, stacked in arrival order.
    """

    def body(carry, k):
        key, alpha, residual = carry
        key, alpha_k, res_k, _, sent = _local_round(
            key, w_local, alpha[k], residual[k], X[k], y[k], norms_sq[k], k,
            lam, n, sigma_p, gamma, loss=loss, num_steps=num_steps, comp=comp)
        carry = (key, alpha.at[k].set(alpha_k), residual.at[k].set(res_k))
        return carry, (alpha_k, sent)

    (key, alpha, residual), (alpha_rows, sents) = jax.lax.scan(
        body, (key, alpha, residual), idxs)
    return key, alpha, residual, alpha_rows, sents


@partial(jax.jit, static_argnames=("loss", "chunk_steps", "comp"),
         donate_argnums=(0, 2, 3))
def _worker_chunk_rounds_fused(key, w_local, alpha, residual, X, y, norms_sq,
                               idxs, lam, n, sigma_p, gamma, *, loss,
                               chunk_steps, comp):
    """A group of CHUNKED local passes (partial_work) as ONE donated dispatch.

    Each launched worker runs ``len(chunk_steps)`` sequential sub-rounds of
    the shared Alg. 2 body against its fixed ``w_local`` row (the model does
    not change mid-pass -- the server only replies at relaunch), carrying its
    dual/residual state from chunk to chunk and compressing EVERY chunk's
    delta independently (residual feedback chains through, so un-harvested
    chunk mass is never lost).  With ``chunk_steps == (H,)`` the op sequence
    -- including the one key split per worker -- degenerates to exactly
    :func:`_worker_rounds_fused`, which the n_chunks=1 bit-identity tests
    pin.  Returns per-worker per-chunk dual snapshots, payloads, and
    post-chunk residuals (``(G, C, n_k)`` / ``(G, C, d)``, arrival order).
    """

    def body(carry, k):
        key, alpha, residual = carry
        alpha_k, res_k = alpha[k], residual[k]
        snaps, sents, resids = [], [], []
        for h in chunk_steps:
            key, alpha_k, res_k, _, sent = _local_round(
                key, w_local, alpha_k, res_k, X[k], y[k], norms_sq[k], k,
                lam, n, sigma_p, gamma, loss=loss, num_steps=h, comp=comp)
            snaps.append(alpha_k)
            sents.append(sent)
            resids.append(res_k)
        carry = (key, alpha.at[k].set(alpha_k), residual.at[k].set(res_k))
        return carry, (jnp.stack(snaps), jnp.stack(sents), jnp.stack(resids))

    (key, alpha, residual), (alpha_rows, sents, resids) = jax.lax.scan(
        body, (key, alpha, residual), idxs)
    return key, alpha, residual, alpha_rows, sents, resids


# Only dw_tilde/w_local are donated: w_server and alpha_applied may be held
# by deferred eval snapshots, which donation would invalidate.
@partial(jax.jit, donate_argnums=(1, 2))
def _server_apply_partial(w_server, dw_tilde, w_local, alpha_applied,
                          snap_idxs, snapshots, payloads, reply_idxs, gamma):
    """Partial-work server round: harvest whatever chunks arrived, reply only
    to the workers being relaunched.

    ``payloads`` is every harvested chunk in arrival order (the summation
    order matters bit-for-bit); ``snap_idxs``/``snapshots`` carry ONE dual
    snapshot per harvested worker -- the host pre-selects each worker's LAST
    harvested chunk so the scatter has unique indices.  ``reply_idxs`` are
    the workers receiving a catch-up reply this round (completed workers in
    final-arrival order, then rejoining members): unlike the group fused
    apply, mid-pass stragglers get NO reply -- their ``dw_tilde`` rows keep
    accruing until their own pass completes.  With one chunk per pass the
    returned values equal :func:`_server_apply_fused` on the same arrivals.
    """
    total = jnp.zeros_like(w_server)
    for p in payloads:
        total = total + p
    w_server = w_server + gamma * total
    dw_tilde = dw_tilde + gamma * total[None, :]
    if snapshots:
        alpha_applied = alpha_applied.at[snap_idxs].set(
            jnp.stack(list(snapshots)))
    replies = dw_tilde[reply_idxs]
    reply_nnz = jnp.sum(replies != 0, axis=1)
    reply_sq = jnp.sum(replies * replies, axis=1)
    w_local = w_local.at[reply_idxs].add(replies)
    dw_tilde = dw_tilde.at[reply_idxs].set(0.0)
    return w_server, dw_tilde, w_local, alpha_applied, reply_nnz, reply_sq


def _lag_reference(ref_buf_k, ref_len_k, xi):
    """LAG's laziness reference for one worker: the windowed mean of its
    recent catch-up-reply energies, scaled by xi.  Zero-padded fixed-width
    buffer (index < len masks the live entries) so the event and scan
    executors evaluate the identical expression."""
    W = ref_buf_k.shape[0]
    live = jnp.arange(W) < ref_len_k
    total = jnp.sum(jnp.where(live, ref_buf_k, 0.0))
    return xi * total / jnp.maximum(ref_len_k, 1)


@partial(jax.jit, donate_argnums=(0, 1))
def _lag_window_append(ref_buf, ref_len, idxs, reply_sq):
    """Slide this round's reply energies into the arrived workers' windows.

    Fixed-width (K, lag_window) rolling buffers: append at ``len`` while
    filling, shift-left-and-append once full (the deque-with-maxlen
    semantics, expressed as ops both executors share).
    """
    W = ref_buf.shape[1]
    rows = ref_buf[idxs]
    lens = ref_len[idxs]
    full = (lens >= W)[:, None]
    shifted = jnp.where(full, jnp.roll(rows, -1, axis=1), rows)
    pos = jnp.minimum(lens, W - 1)
    new_rows = shifted.at[jnp.arange(idxs.shape[0]), pos].set(reply_sq)
    ref_buf = ref_buf.at[idxs].set(new_rows)
    ref_len = ref_len.at[idxs].set(jnp.minimum(lens + 1, W))
    return ref_buf, ref_len


@partial(jax.jit, static_argnames=("loss", "num_steps", "comp"),
         donate_argnums=(0, 2, 3))
def _worker_rounds_lag_fused(key, w_local, alpha, residual, ref_buf, ref_len,
                             X, y, norms_sq, idxs, lam, n, sigma_p, gamma, xi,
                             *, loss, num_steps, comp):
    """LAG-style lazy group relaunch: one dispatch for the whole group.

    Per worker, the upload is skipped when ``||F(dw)||^2 < xi * ref`` where
    ``ref`` is the windowed mean of the worker's recent catch-up-reply
    energies -- its freshest view of how much the global model is already
    moving without it (the primal-dual analogue of LAG's
    gradient-change-vs-model-movement test). Skipped mass stays in the
    residual: error feedback makes laziness lossless, only late, and since
    replies shrink as the system converges the test stays calibrated
    (all-quiet -> replies ~ 0 -> uploads resume, no starvation).
    """

    def body(carry, k):
        key, alpha, residual = carry
        ref_k = _lag_reference(ref_buf[k], ref_len[k], xi)
        key, alpha_k, res_k, dw, sent = _local_round(
            key, w_local, alpha[k], residual[k], X[k], y[k], norms_sq[k], k,
            lam, n, sigma_p, gamma, loss=loss, num_steps=num_steps, comp=comp)
        send_sq = jnp.vdot(sent, sent)
        skip = send_sq < ref_k
        sent = jnp.where(skip, jnp.zeros_like(sent), sent)
        res_k = jnp.where(skip, dw, res_k)
        carry = (key, alpha.at[k].set(alpha_k), residual.at[k].set(res_k))
        return carry, (alpha_k, sent, skip)

    (key, alpha, residual), (alpha_rows, sents, skips) = jax.lax.scan(
        body, (key, alpha, residual), idxs)
    return key, alpha, residual, alpha_rows, sents, skips


# Only dw_tilde/w_local are donated: w_server and alpha_applied may be held
# by deferred eval snapshots, which donation would invalidate.
@partial(jax.jit, donate_argnums=(1, 2))
def _server_apply_fused(w_server, dw_tilde, w_local, alpha_applied, idxs,
                        payloads, snapshots, apply_mask, gamma):
    """Alg. 1 lines 8-11 for one group of arrivals, as a single dispatch.

    ``payloads``/``snapshots`` are tuples ordered by arrival (the summation
    order matters bit-for-bit); ``apply_mask`` marks real uploads (False for
    LAG heartbeats, whose zero payloads leave the sum unchanged but whose dual
    snapshots must NOT become server-visible). Reply ``nnz`` is computed
    in-graph and returned as one small vector -- the only device->host value
    the event loop needs.
    """
    total = jnp.zeros_like(w_server)
    for p in payloads:
        total = total + p
    w_server = w_server + gamma * total
    dw_tilde = dw_tilde + gamma * total[None, :]
    snap = jnp.stack(list(snapshots))
    mask = apply_mask[:, None]
    alpha_applied = alpha_applied.at[idxs].set(
        jnp.where(mask, snap, alpha_applied[idxs]))
    replies = dw_tilde[idxs]
    reply_nnz = jnp.sum(replies != 0, axis=1)
    reply_sq = jnp.sum(replies * replies, axis=1)  # LAG's laziness reference
    w_local = w_local.at[idxs].add(replies)
    dw_tilde = dw_tilde.at[idxs].set(0.0)
    return w_server, dw_tilde, w_local, alpha_applied, reply_nnz, reply_sq


def _lockstep_local_solves(w, alpha, X, y, norms_sq, lam, n, sigma_p, keys, *,
                           loss, num_steps, solver):
    """The vmapped per-worker subproblem solves of one lockstep round.

    Shared by :func:`_lockstep_round` (full worker axis) and the
    worker-sharded executor variant
    (:func:`repro.core.executor.lockstep_run_traced_sharded`, which maps it
    over a local worker block with its slice of the key split) so the solve
    op sequence is defined in exactly one place; only the aggregation
    (plain ``sum`` vs ``sum`` + ``psum``) differs between the two callers.
    """
    K = X.shape[0]
    w_all = jnp.broadcast_to(w, (K, w.shape[0]))
    fn = partial(solver, loss=loss, num_steps=num_steps)
    return jax.vmap(fn, in_axes=(0, 0, 0, 0, 0, None, None, None, 0))(
        w_all, alpha, X, y, norms_sq, lam, n, sigma_p, keys)


def _lockstep_round(key, w, alpha, X, y, norms_sq, lam, n, sigma_p, gamma, *,
                    loss, num_steps, solver):
    """Shared lockstep round body: all K subproblems vmapped + aggregation.

    Traced, not jitted -- the per-round fused dispatches below AND the
    scan-fused whole-run executor (:mod:`repro.core.executor`) inline it, so
    the op sequence (and therefore the bit-exact trajectory) is defined in
    exactly one place.  ``solver`` is a :mod:`repro.core.solvers` entry
    (``solve_subproblem`` for the hard-wired ``sync`` discipline).
    """
    K = X.shape[0]
    key, sub = jax.random.split(key)
    keys = jax.random.split(sub, K)
    dalpha, v = _lockstep_local_solves(w, alpha, X, y, norms_sq, lam, n,
                                       sigma_p, keys, loss=loss,
                                       num_steps=num_steps, solver=solver)
    alpha = alpha + gamma * dalpha
    w = w + gamma * jnp.sum(v, axis=0)
    return key, w, alpha


# Only the key is donated: w/alpha may be held by deferred eval snapshots.
@partial(jax.jit, static_argnames=("loss", "num_steps"), donate_argnums=(0,))
def _sync_round_fused(key, w, alpha, X, y, norms_sq, lam, n, sigma_p, gamma, *,
                      loss, num_steps):
    """One lockstep CoCoA-family round (all K subproblems + aggregation)."""
    return _lockstep_round(key, w, alpha, X, y, norms_sq, lam, n, sigma_p,
                           gamma, loss=loss, num_steps=num_steps,
                           solver=solve_subproblem)


# Like _sync_round_fused but with the local solver as a static argument: the
# CoCoA lineage runs any repro.core.solvers registry entry, vmapped over the
# worker axis, in one donated dispatch.
@partial(jax.jit, static_argnames=("loss", "num_steps", "solver"),
         donate_argnums=(0,))
def _cocoa_round_fused(key, w, alpha, X, y, norms_sq, lam, n, sigma_p, gamma,
                       *, loss, num_steps, solver):
    return _lockstep_round(key, w, alpha, X, y, norms_sq, lam, n, sigma_p,
                           gamma, loss=loss, num_steps=num_steps,
                           solver=solver)


def _certificate_ops(w, alpha, X, y, lam, *, loss):
    """ONE snapshot's gap certificate: (primal, dual, gap, gap_server).

    The single definition of the certificate op sequence -- shared by the
    deferred batch evaluation below and the scan executor's in-graph
    ``target_gap`` test (:func:`repro.core.executor.lockstep_run_gap_traced`)
    so the two can never silently desynchronize; the ops mirror the
    reference's eager ``objectives.gap_certificate`` exactly (the bit-exact
    equivalence contract).
    """
    w_alpha = objectives.primal_from_dual(alpha, X, lam)
    p = objectives.primal_objective(w_alpha, X, y, lam, loss=loss)
    dv = objectives.dual_objective(alpha, X, y, lam, loss=loss)
    p_srv = objectives.primal_objective(w, X, y, lam, loss=loss)
    return p, dv, p - dv, p_srv - dv


@partial(jax.jit, static_argnames=("loss",))
def _eval_batched(ws, alphas, X, y, lam, *, loss):
    """All deferred gap certificates in one dispatch.

    ``lax.map`` (not vmap): the per-snapshot computation stays unbatched, so
    each reduction sees the exact operand shapes of the reference's eager
    ``gap_certificate`` calls -- batched dot_generals reduce in a different
    order on CPU and break the last-bit equivalence contract.
    """

    def one(args):
        w, alpha = args
        return _certificate_ops(w, alpha, X, y, lam, loss=loss)

    return jax.lax.map(one, (ws, alphas))


def _bucket_size(count: int) -> int:
    """Next power of two >= count: the static snapshot-batch sizes
    ``_eval_batched`` compiles for."""
    return 1 << max(0, count - 1).bit_length()


def _eval_bucketed(ws, alphas, X, y, lam, *, loss):
    """``_eval_batched`` padded to power-of-two snapshot counts.

    Deferred-gap evaluation used to retrace whenever the snapshot count
    changed across runs (every distinct ``num_outer`` x ``eval_every``
    combination in a sweep paid a fresh compile).  Padding the batch with
    copies of the last snapshot pins the traced shape to log-many buckets;
    ``lax.map`` evaluates rows independently, so the first ``count`` rows
    are bit-identical to the unpadded call (pinned by tests).
    """
    count = ws.shape[0]
    if count == 0:
        empty = jnp.zeros((0,), ws.dtype)
        return empty, empty, empty, empty
    pad = _bucket_size(count) - count
    if pad:
        ws = jnp.concatenate([ws, jnp.broadcast_to(ws[-1], (pad,) + ws.shape[1:])])
        alphas = jnp.concatenate(
            [alphas, jnp.broadcast_to(alphas[-1], (pad,) + alphas.shape[1:])])
    p, dv, gap, gap_srv = _eval_batched(ws, alphas, X, y, lam, loss=loss)
    return p[:count], dv[:count], gap[:count], gap_srv[:count]


# ---------------------------------------------------------------------------
# Protocols.
# ---------------------------------------------------------------------------


class Protocol:
    """Arrival + aggregation + reply rules driving the engine's event loop.

    A *protocol* is one server discipline: it decides how many worker
    messages a round waits for, how arrived payloads enter the server state,
    and what (and when) each worker hears back.  Subclass, decorate with
    :func:`register_protocol`, and the entry becomes constructible from any
    ``MethodConfig.protocol`` string -- inheriting engine fusion, deferred
    gap evaluation, the streaming :class:`repro.api.session.Session` loop,
    and the bit-for-bit regression harness (tests/test_engine.py) for free.
    ``docs/extending-protocols.md`` is the worked walkthrough.

    **Classmethod contract** (consulted before an instance exists):

    ``default_sigma_prime(method, K)``
        The subproblem safety parameter sigma' used when
        ``MethodConfig.sigma_prime`` is ``None``.  sigma' scales the
        quadratic penalty of the local subproblem (Eq. 7-8) and must upper
        bound the aggregation overlap: gamma * B for B-of-K group
        aggregation (the paper's rule), gamma * K for "adding" CoCoA+
        aggregation, 1 for "averaging" CoCoA aggregation.  Protocol-owned so
        registry entries supply a *correct* default instead of growing
        string checks in the config dataclass -- an unsafe sigma' diverges,
        an over-conservative one merely converges slowly.

    **Instance hooks, in the order the Session loop calls them:**

    ``num_rounds(num_outer)``
        Total server rounds for a ``num_outer`` budget (``num_outer * T``
        for the T-periodic group family, ``num_outer`` for lockstep rounds).

    ``initial_messages()``
        Launch every worker's first local round; returns the Messages that
        seed the arrival queue.  Each Message's ``arrival`` is the simulated
        time the server would receive it.

    ``arrivals_needed(round_index)``
        How many queued messages round ``round_index`` waits for -- the
        *arrival rule* (B, K, 1, or anything state-dependent; it is re-read
        every round, so adaptive disciplines just return fresh state).

    ``is_sync_round(round_index)``
        True when the round is a full-K barrier; the Session emits a
        :class:`repro.api.session.SyncEvent` after processing it.

    ``process_round(round_index, arrived)``
        The *aggregation + reply* rules: fold the arrived payloads into
        server state, bill reply bytes/time, advance ``self.sim_time``, and
        return the next wave of in-flight Messages (usually one relaunch per
        arrived worker).  Accounting invariant: ``bytes_up``/``bytes_down``/
        ``compute_time``/``comm_time`` are cumulative totals and
        ``sim_time`` is monotone.

    ``snapshot(iteration)``
        Capture (device arrays allowed, no host sync required) whatever a
        deferred duality-gap evaluation needs -- called at eval boundaries.

    ``finalize(records)``
        Fold the finished run into a :class:`RunResult`.

    Timing comes from ``self.delay`` -- a fresh
    :class:`repro.core.delays.DelayModel` per run (so stateful models like
    ``markov`` never leak across runs), resolved from
    ``ClusterModel.delay_model``.  Host randomness comes from ``self.rng``
    and device randomness from ``self.key``; both are seeded from the run's
    single ``seed`` so a (spec, seed) pair reproduces the trajectory.
    """

    protocol_name = "abstract"
    # True for protocols that honor ClusterModel.membership (elastic worker
    # dropout/rejoin schedules).  Protocols that do not understand
    # membership reject a non-empty schedule at construction rather than
    # silently simulating a full-strength cluster.
    supports_membership = False

    @classmethod
    def default_sigma_prime(cls, method: MethodConfig, K: int) -> float:
        """sigma' when ``MethodConfig.sigma_prime`` is unset.

        The paper's rule for the group family: gamma * B (safe for B-of-K
        aggregation). Protocol-owned so new registry entries supply their own
        value instead of growing string checks in the config dataclass.
        """
        return method.gamma * method.B

    @classmethod
    def coalesce_supported(cls, method: MethodConfig,
                           cluster: ClusterModel) -> tuple[bool, str]:
        """May runs of this protocol join a coalesced sweep batch
        (:mod:`repro.serve`)?  Returns ``(ok, reason)``.

        The base rule delegates to the executor's scan eligibility -- a run
        the scan executor can express IS expressible as one sweep cell.
        Protocols whose scan path is not the shared lockstep/lag cell
        machinery (e.g. ``partial_work``'s per-chunk carries) override this
        with an explicit refusal so the serve layer routes them to the solo
        lane instead of silently mis-batching.
        """
        from repro.core import executor  # late import: executor imports us

        return executor.scan_supported(method, cluster)

    def __init__(self, problem: objectives.Problem, method: MethodConfig,
                 cluster: ClusterModel, *, seed: int):
        if cluster.membership and not self.supports_membership:
            raise ValueError(
                f"protocol {self.protocol_name!r} does not support elastic "
                f"membership; ClusterModel.membership is non-empty. Use a "
                f"protocol declaring supports_membership (e.g. "
                f"'partial_work') or clear the membership schedule.")
        self.problem = problem
        self.method = method
        self.cluster = cluster
        self.delay = cluster.make_delay()  # fresh per run; may be stateful
        self.K, self.n_k, self.d = problem.X.shape
        self.n = self.K * self.n_k
        self.sigma_p = method.resolved_sigma_prime(self.K)
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.key(seed)
        self.bytes_up = 0
        self.bytes_down = 0
        self.compute_time = 0.0
        self.comm_time = 0.0
        self.sim_time = 0.0
        self.seq = 0

    # --- hooks the engine loop calls (contract in the class docstring) ----

    def num_rounds(self, num_outer: int) -> int:
        raise NotImplementedError

    def initial_messages(self) -> Iterable[Message]:
        raise NotImplementedError

    def arrivals_needed(self, round_index: int) -> int:
        raise NotImplementedError

    def is_sync_round(self, round_index: int) -> bool:
        """True when round ``round_index`` is a full-K barrier (SyncEvent)."""
        return False

    def process_round(self, round_index: int, arrived: list[Message]) -> list[Message]:
        raise NotImplementedError

    def snapshot(self, iteration: int) -> _Snapshot:
        raise NotImplementedError

    def finalize(self, records: list[RunRecord]) -> RunResult:
        raise NotImplementedError


@register_protocol("group")
class GroupProtocol(Protocol):
    """Algorithms 1+2: straggler-agnostic B-of-K server with catch-up buffers."""

    full_sync_period: bool = True  # every T-th round is a K-barrier

    @classmethod
    def default_sigma_prime(cls, method: MethodConfig, K: int) -> float:
        # The paper's rule: sigma' covers the B updates a round aggregates.
        return method.gamma * method.B

    @classmethod
    def coalesce_supported(cls, method: MethodConfig,
                           cluster: ClusterModel) -> tuple[bool, str]:
        # Group runs coalesce exactly when the scan executor can express
        # them as shared sweep cells (the base delegation, stated here so
        # the registry-hooks rule records the decision per family).
        return super().coalesce_supported(method, cluster)

    def __init__(self, problem, method, cluster, *, seed):
        super().__init__(problem, method, cluster, seed=seed)
        dt = problem.X.dtype
        self.comp = compress_lib.for_method(method, self.d)
        self.dense = isinstance(self.comp, compress_lib.Dense)
        self.up_bytes = self.comp.wire_bytes(self.d)
        self.w_server = jnp.zeros((self.d,), dt)
        self.dw_tilde = jnp.zeros((self.K, self.d), dt)
        self.w_local = jnp.zeros((self.K, self.d), dt)
        self.alpha_applied = jnp.zeros((self.K, self.n_k), dt)
        # Stacked worker state: the fused group relaunch updates rows
        # in-graph (the former per-worker array lists forced one dispatch
        # per relaunched worker).
        self.alpha = jnp.zeros((self.K, self.n_k), dt)
        self.residual = jnp.zeros((self.K, self.d), dt)
        self.norms_sq = jnp.sum(problem.X * problem.X, axis=-1)

    def num_rounds(self, num_outer: int) -> int:
        return num_outer * self.method.T

    def initial_messages(self):
        return self._launch_workers([(k, 0.0) for k in range(self.K)])

    def arrivals_needed(self, round_index: int) -> int:
        T = self.method.T
        if self.full_sync_period and round_index % T == T - 1:
            return self.K
        return min(self.method.B, self.K)

    def is_sync_round(self, round_index: int) -> bool:
        T = self.method.T
        return self.full_sync_period and round_index % T == T - 1

    # -- the fused group relaunch -----------------------------------------

    def _round_payloads(self, idxs):
        """Run the group's local rounds; returns stacked (alpha_rows, sents,
        skip flags or None).  Subclasses (LAG) override to add laziness."""
        (self.key, self.alpha, self.residual, alpha_rows,
         sents) = _worker_rounds_fused(
            self.key, self.w_local, self.alpha, self.residual,
            self.problem.X, self.problem.y, self.norms_sq, idxs,
            self.problem.lam, self.n, self.sigma_p, self.method.gamma,
            loss=self.problem.loss, num_steps=self.method.H, comp=self.comp)
        return alpha_rows, sents, None

    def _message_bytes(self, skipped: bool) -> int:
        return self.up_bytes

    def _launch_workers(self, starts, pre_account=None):
        """Launch local rounds for ``starts = [(worker, start_time), ...]``
        (arrival order) as ONE fused dispatch, then do the host-side
        accounting per worker.

        ``pre_account``: optional per-worker ``(rbytes, down_time)`` reply
        billing, applied immediately before each worker's own launch
        accounting -- this keeps the float accumulation order of the
        reference loops exactly (down_0, up_0, down_1, up_1, ...), which the
        bit-for-bit pins depend on.
        """
        if not starts:
            return []
        m = self.method
        # Satellite of the vectorized-delay work: per-round vector draws
        # (ONE size-K numpy draw) for models that support them, per-message
        # scalar draws (the legacy, reference-pinned order) otherwise.
        durations = (self.delay.sample_round(m.H, self.rng)
                     if self.delay.vector_sampled else None)
        idxs = jnp.asarray([k for k, _ in starts], jnp.int32)
        alpha_rows, sents, skips = self._round_payloads(idxs)
        out = []
        for j, (k, start) in enumerate(starts):
            if pre_account is not None:
                rbytes, down_time = pre_account[j]
                self.bytes_down += rbytes
                self.comm_time += down_time
            skipped = bool(skips[j]) if skips is not None else False
            nbytes = self._message_bytes(skipped)
            duration = (durations[k] if durations is not None
                        else self.delay.compute_time(k, m.H, self.rng))
            up_time = self.delay.p2p_time(nbytes, k)
            self.compute_time += duration
            self.comm_time += up_time
            self.bytes_up += nbytes
            self.seq += 1
            msg = Message(start + duration + up_time, k, sents[j],
                          alpha_rows[j], nbytes, self.seq,
                          applied=not skipped)
            self._observe_launch(k, start, msg.arrival)
            out.append(msg)
        return out

    def _observe_launch(self, k: int, start: float, arrival: float) -> None:
        """Per-launch hook (adaptive disciplines observe round latencies)."""

    def _apply_server(self, arrived):
        """Fused aggregation + replies; returns (server_time, reply nnz)."""
        server_time = max(m.arrival for m in arrived)
        idxs = jnp.asarray([m.worker for m in arrived], jnp.int32)
        mask = jnp.asarray([m.applied for m in arrived], bool)
        (self.w_server, self.dw_tilde, self.w_local, self.alpha_applied,
         reply_nnz, reply_sq) = _server_apply_fused(
            self.w_server, self.dw_tilde, self.w_local, self.alpha_applied,
            idxs, tuple(m.payload for m in arrived),
            tuple(m.alpha_snapshot for m in arrived), mask, self.method.gamma)
        self._last_reply_sq = reply_sq  # stays on device; LAG reads slices
        # The ONE host<->device sync of the round (skipped when replies are
        # dense, whose byte count is static).
        nnz_host = None if self.dense else np.asarray(reply_nnz)
        return server_time, nnz_host

    def _reply_billing(self, j, worker, nnz_host) -> tuple[int, float]:
        """(bytes, link time) of arrival ``j``'s catch-up reply."""
        rbytes = (msg_filter.dense_bytes(self.d) if self.dense
                  else msg_filter.message_bytes(int(nnz_host[j])))
        return rbytes, self.delay.p2p_time(rbytes, worker)

    def process_round(self, round_index, arrived):
        server_time, nnz_host = self._apply_server(arrived)
        # Reply billing is computed up front but ACCOUNTED inside the launch
        # loop (via pre_account), interleaved per worker exactly like the
        # reference's float accumulation order (down, up, down, up).
        starts, billing = [], []
        for j, m in enumerate(arrived):
            rbytes, down_time = self._reply_billing(j, m.worker, nnz_host)
            starts.append((m.worker, server_time + down_time))
            billing.append((rbytes, down_time))
        self.sim_time = server_time
        return self._launch_workers(starts, pre_account=billing)

    def snapshot(self, iteration):
        return _Snapshot(iteration, self.sim_time, self.bytes_up,
                         self.bytes_down, self.compute_time, self.comm_time,
                         self.w_server, self.alpha_applied)

    def finalize(self, records):
        return RunResult(self.method, records, np.asarray(self.w_server),
                         np.asarray(self.alpha),
                         alpha_applied=np.asarray(self.alpha_applied))


@register_protocol("async")
class AsyncProtocol(GroupProtocol):
    """Fully-asynchronous ablation: B=1, per-worker apply, no sync barrier.

    Every arrival is applied immediately; staleness is unbounded (Assumption 3
    is intentionally violated -- this is the protocol the paper's T-periodic
    barrier exists to tame, now expressible as a config).
    """

    full_sync_period = False

    def __init__(self, problem, method, cluster, *, seed):
        if method.B != 1:
            raise ValueError(
                f"protocol 'async' is defined by B=1 (per-arrival apply); "
                f"got B={method.B}. Use protocol='group' for B-of-K "
                f"aggregation, or baselines.acpd_async() for a valid config.")
        super().__init__(problem, method, cluster, seed=seed)


@register_protocol("lag")
class LagProtocol(GroupProtocol):
    """Group protocol + LAG-style lazy uploads (arXiv:1805.09965 adapted).

    LAG's worker-side rule (LAG-WK) reuses the previous gradient -- i.e.
    uploads nothing -- when the new gradient differs from the last
    communicated one by less than a windowed average of recent global model
    movement: ``||grad change||^2 <= (xi / D) * sum_{d'=1..D}
    ||theta_{t+1-d'} - theta_{t-d'}||^2``.  Two translations to this
    delta-coded primal-dual setting:

    * the upload *is already a delta* (``F(dw)``: the change since the
      worker's last applied contribution), so "gradient unchanged -> reuse"
      becomes "delta negligible -> send nothing"; the skipped mass stays in
      the error-feedback residual, making laziness lossless, only late;
    * the worker's freshest view of global model movement is its stream of
      catch-up replies (``dw_tilde``: exactly the model change it missed),
      so the RHS window averages the squared norms of its last
      ``lag_window`` replies -- the paper's D-round window (D=10 in their
      experiments), replacing the cruder single-last-reply test this
      protocol used previously (``lag_window=1`` restores it).

    A skipping worker sends an 8-byte heartbeat instead of the payload.  The
    server treats heartbeats as arrivals (the worker is alive and gets its
    catch-up reply) but applies nothing for them.  Since replies shrink as
    the system converges, the test stays calibrated: all-quiet -> replies
    ~ 0 -> uploads resume, no starvation.

    The reply-energy window lives in a fixed-width device buffer
    ``(K, lag_window)`` plus per-worker fill counts (see
    :func:`_lag_window_append`), summed afresh each round over the live
    entries -- an incremental running sum in f32 would accumulate
    catastrophic cancellation once reply norms decay orders of magnitude
    below the evicted early entries.  The scan executor
    (:mod:`repro.core.executor`) carries the identical buffers, so both
    executors evaluate the same laziness expression bit-for-bit.
    """

    HEARTBEAT_BYTES = 8

    def __init__(self, problem, method, cluster, *, seed):
        if method.lag_window < 1:
            raise ValueError(
                f"lag_window must be >= 1, got {method.lag_window}")
        super().__init__(problem, method, cluster, seed=seed)
        # Empty windows => ref 0 => the first rounds always upload.
        self._ref_buf = jnp.zeros((self.K, method.lag_window),
                                  problem.X.dtype)
        self._ref_len = jnp.zeros((self.K,), jnp.int32)

    def _round_payloads(self, idxs):
        (self.key, self.alpha, self.residual, alpha_rows, sents,
         skips) = _worker_rounds_lag_fused(
            self.key, self.w_local, self.alpha, self.residual, self._ref_buf,
            self._ref_len, self.problem.X, self.problem.y, self.norms_sq,
            idxs, self.problem.lam, self.n, self.sigma_p, self.method.gamma,
            self.method.lag_xi, loss=self.problem.loss,
            num_steps=self.method.H, comp=self.comp)
        return alpha_rows, sents, np.asarray(skips)  # one pull per group

    def _message_bytes(self, skipped):
        return self.HEARTBEAT_BYTES if skipped else self.up_bytes

    def process_round(self, round_index, arrived):
        server_time, nnz_host = self._apply_server(arrived)
        # Slide this round's reply energies into the arrived workers'
        # windows (one fused dispatch, no host sync).
        idxs = jnp.asarray([m.worker for m in arrived], jnp.int32)
        self._ref_buf, self._ref_len = _lag_window_append(
            self._ref_buf, self._ref_len, idxs, self._last_reply_sq)
        starts, billing = [], []
        for j, m in enumerate(arrived):
            rbytes, down_time = self._reply_billing(j, m.worker, nnz_host)
            starts.append((m.worker, server_time + down_time))
            billing.append((rbytes, down_time))
        self.sim_time = server_time
        return self._launch_workers(starts, pre_account=billing)


@register_protocol("sync")
class SyncProtocol(Protocol):
    """CoCoA / CoCoA+ / DisDCA: lockstep rounds timed as MPI allreduce.

    The queue degenerates to K tokens popped per round; timing follows the
    reference implementation exactly (max worker compute + ring allreduce,
    bytes split evenly between the reduce-scatter and all-gather phases).
    """

    @classmethod
    def default_sigma_prime(cls, method: MethodConfig, K: int) -> float:
        # "Adding" aggregation over all K partitions (Ma et al. 2015).
        return method.gamma * K

    @classmethod
    def coalesce_supported(cls, method: MethodConfig,
                           cluster: ClusterModel) -> tuple[bool, str]:
        # Lockstep rounds are the sweep machinery's native shape; defer to
        # the executor's scan eligibility for the delay-model fine print.
        return super().coalesce_supported(method, cluster)

    def __init__(self, problem, method, cluster, *, seed):
        super().__init__(problem, method, cluster, seed=seed)
        dt = problem.X.dtype
        self.w = jnp.zeros((self.d,), dt)
        self.alpha = jnp.zeros((self.K, self.n_k), dt)
        self.norms_sq = jnp.sum(problem.X * problem.X, axis=-1)

    def num_rounds(self, num_outer: int) -> int:
        return num_outer

    def is_sync_round(self, round_index: int) -> bool:
        return True  # every lockstep round is a K-barrier

    def _tokens(self):
        out = []
        for k in range(self.K):
            self.seq += 1
            out.append(Message(self.sim_time, k, None, None, 0, self.seq))
        return out

    def initial_messages(self):
        return self._tokens()

    def arrivals_needed(self, round_index: int) -> int:
        return self.K

    def _round_update(self):
        """One fused lockstep update; CoCoA-lineage subclasses override to
        swap the local solver while inheriting timing/byte accounting."""
        m = self.method
        self.key, self.w, self.alpha = _sync_round_fused(
            self.key, self.w, self.alpha, self.problem.X, self.problem.y,
            self.norms_sq, self.problem.lam, self.n, self.sigma_p, m.gamma,
            loss=self.problem.loss, num_steps=m.H)

    def process_round(self, round_index, arrived):
        m = self.method
        self._round_update()
        # One per-round vector draw (same host-RNG stream as K scalar calls
        # in worker order -- the order the pinned trajectories consumed).
        step_compute = float(np.max(self.delay.sample_round(m.H, self.rng)))
        step_comm = self.delay.allreduce_time(self.d)
        self.sim_time += step_compute + step_comm
        self.compute_time += step_compute
        self.comm_time += step_comm
        phase = (self.K - 1) * self.d * 4  # ring reduce-scatter == all-gather
        self.bytes_up += phase
        self.bytes_down += phase
        return self._tokens()

    def snapshot(self, iteration):
        return _Snapshot(iteration, self.sim_time, self.bytes_up,
                         self.bytes_down, self.compute_time, self.comm_time,
                         self.w, self.alpha)

    def finalize(self, records):
        return RunResult(self.method, records, np.asarray(self.w),
                         np.asarray(self.alpha))


@register_protocol("cocoa")
class CocoaProtocol(SyncProtocol):
    """CoCoA v1 (Jaggi et al., arXiv:1409.1458): synchronous rounds,
    "averaging" aggregation, pluggable local solver.

    The CoCoA framework's point is that ANY local subproblem solver reaching
    a Theta-approximate solution plugs into the same aggregation; here the
    solver comes from the :mod:`repro.core.solvers` registry via
    ``MethodConfig.local_solver`` (``sdca`` | ``importance`` |
    ``accelerated``) instead of being hard-wired SDCA.  ``gamma`` is the
    aggregation parameter: CoCoA's averaging uses ``gamma = 1/K`` (the
    :func:`repro.core.baselines.cocoa_v1` preset), for which ``sigma' = 1``
    is the safe subproblem scaling.  Timing/byte accounting is inherited
    from the lockstep ``sync`` discipline (MPI-style ring allreduce).
    """

    @classmethod
    def default_sigma_prime(cls, method: MethodConfig, K: int) -> float:
        # "Averaging" aggregation (Jaggi et al. 2014): safe for gamma <= 1/K.
        return 1.0

    def __init__(self, problem, method, cluster, *, seed):
        # Averaging is only safe for gamma <= 1/K (sigma'=1 does not damp a
        # larger aggregate; it visibly diverges).  Only the "cocoa" entry
        # enforces this -- CocoaPlusProtocol inherits with its own sigma'.
        # An explicit MethodConfig.sigma_prime overrides at the user's risk.
        K = problem.X.shape[0]
        if (self.protocol_name == "cocoa" and method.sigma_prime is None
                and method.gamma > 1.0 / K + 1e-9):
            raise ValueError(
                f"protocol 'cocoa' uses averaging aggregation (sigma'=1), "
                f"which is only safe for gamma <= 1/K; got gamma="
                f"{method.gamma} with K={K}. Use baselines.cocoa_v1, "
                f"protocol='cocoa_plus' for adding aggregation, or set "
                f"sigma_prime explicitly.")
        super().__init__(problem, method, cluster, seed=seed)
        from repro.core import solvers as solvers_lib

        self.solver = solvers_lib.get_solver(method.local_solver)

    def _round_update(self):
        m = self.method
        self.key, self.w, self.alpha = _cocoa_round_fused(
            self.key, self.w, self.alpha, self.problem.X, self.problem.y,
            self.norms_sq, self.problem.lam, self.n, self.sigma_p, m.gamma,
            loss=self.problem.loss, num_steps=m.H, solver=self.solver)


@register_protocol("cocoa_plus")
class CocoaPlusProtocol(CocoaProtocol):
    """CoCoA+ (Ma et al. 2015): "adding" aggregation, pluggable local solver.

    Same lockstep round as :class:`CocoaProtocol` but with the adding
    aggregation's safe subproblem scaling ``sigma' = gamma * K`` (gamma = 1
    recovers the paper's CoCoA+ baseline, which the hard-wired ``sync``
    protocol pins bit-for-bit; this entry exists for the pluggable-solver
    axis).
    """

    @classmethod
    def default_sigma_prime(cls, method: MethodConfig, K: int) -> float:
        return method.gamma * K


@register_protocol("adaptive_b")
class AdaptiveBProtocol(GroupProtocol):
    """Group protocol with the group size B adapted to observed arrivals.

    The paper fixes B ahead of time, but the right B depends on delay
    behavior the operator rarely knows (how many workers are persistently
    late?).  This discipline learns it online: it keeps an EWMA of each
    worker's round latency (launch -> arrival, exactly what a real server
    observes) and waits each round for the workers in the fast
    ``adaptive_quantile`` of that latency distribution::

        B_t = clip(#{k : ewma_k <= quantile_q(ewma)}, b_min, ceil(q * K))

    The upper clip matters: ``ceil(q * K)`` is the aggregation size
    ``default_sigma_prime`` covers, and under tied latencies (a homogeneous
    cluster) the raw count alone reaches K and out-runs sigma' -- which
    diverges, not errors.  Heavy-tailed or bursty delay models (``pareto``,
    ``markov``) shrink B_t automatically while the tail is hot and relax it
    when stragglers recover; under homogeneous delays it settles at
    ``ceil(q * K)``.  The
    T-periodic full barrier is kept, so the staleness bound (Assumption 3)
    still holds.  ``MethodConfig.B`` only seeds the first rounds, before one
    latency sample per worker exists.

    This class is also the worked example of ``docs/extending-protocols.md``.
    """

    @classmethod
    def default_sigma_prime(cls, method: MethodConfig, K: int) -> float:
        # sigma' must cover the aggregation size the discipline targets:
        # about quantile * K arrivals per round (the paper's gamma * B rule
        # with the adapted B's expected value).
        target_b = max(method.b_min, math.ceil(method.adaptive_quantile * K))
        return method.gamma * target_b

    def __init__(self, problem, method, cluster, *, seed):
        if not 0.0 < method.adaptive_quantile <= 1.0:
            raise ValueError(
                f"adaptive_quantile must be in (0, 1], got "
                f"{method.adaptive_quantile}")
        if not 0.0 < method.adaptive_ewma <= 1.0:
            raise ValueError(
                f"adaptive_ewma must be in (0, 1], got {method.adaptive_ewma}")
        super().__init__(problem, method, cluster, seed=seed)
        self._latency = np.full(self.K, np.nan)  # EWMA round latency
        # The adapted B lives in [b_min, ceil(q*K)]: the upper end is the
        # aggregation size the default sigma' covers (see classmethod above).
        self._b_lo = max(1, method.b_min)
        self._b_hi = min(self.K, max(self._b_lo,
                                     math.ceil(method.adaptive_quantile
                                               * self.K)))
        self._B = int(np.clip(method.B, self._b_lo, self._b_hi))

    @property
    def current_b(self) -> int:
        """The group size the next non-barrier round will wait for."""
        return self._B

    def arrivals_needed(self, round_index: int) -> int:
        T = self.method.T
        if round_index % T == T - 1:
            return self.K  # the staleness-bounding full barrier stays
        return self._B

    def _observe_launch(self, k, start, arrival):
        latency = arrival - start
        beta = self.method.adaptive_ewma
        if np.isnan(self._latency[k]):
            self._latency[k] = latency
        else:
            self._latency[k] = (1.0 - beta) * self._latency[k] + beta * latency
        if not np.isnan(self._latency).any():
            cut = np.quantile(self._latency, self.method.adaptive_quantile)
            self._B = int(np.clip(int(np.sum(self._latency <= cut)),
                                  self._b_lo, self._b_hi))


@register_protocol("partial_work")
class PartialWorkProtocol(GroupProtocol):
    """Straggler-UTILIZING group rounds: harvest chunk-level partial work.

    The paper's B-of-K server discards whatever stragglers computed after
    the B-th arrival; Ozfatura et al. (arXiv:2004.04948, arXiv:1808.02240)
    show that streaming chunk-level PARTIAL updates dominates discard-based
    schemes exactly in high-delay-variance regimes.  Here each local pass of
    ``H`` SDCA steps is split into ``MethodConfig.n_chunks`` chunks; the
    worker compresses and uploads EVERY chunk as it finishes (each chunk
    billed through the one compressor formula, ``wire_bytes``), and the
    server's round deadline is the ``B``-th FULL arrival (a worker's last
    chunk) -- or a fixed ``pw_quantum`` of simulated seconds when set.  The
    server folds every chunk that arrived by the deadline into the catch-up
    buffers, so a straggler at chunk 3 of 4 has contributed 3/4 of its round
    instead of nothing.  Only COMPLETED workers are replied to and
    relaunched; stragglers keep computing undisturbed (their ``dw_tilde``
    rows accrue until their own pass completes).  With ``n_chunks=1`` the
    discipline degrades bit-for-bit to ``group`` (pinned by tests).

    Elasticity: this is the protocol family honoring
    ``ClusterModel.membership`` (worker drop/rejoin schedules).  A dropping
    worker's unsent chunks are rolled back to its last sent chunk (error
    feedback keeps the mass accounted), its bytes stop accruing, and the
    B-of-K deadline shrinks with the live membership (``b_eff = min(B,
    pending full passes)``) so dropouts can never hang the barrier.  A
    rejoining worker receives a dense catch-up reply and re-enters the
    launch RNG stream at its rejoin round, deterministically.
    """

    supports_membership = True

    @classmethod
    def default_sigma_prime(cls, method: MethodConfig, K: int) -> float:
        # The group family's gamma * B, by mass conservation: a round's
        # deadline is the B-th FULL arrival, and a completing worker's
        # earlier chunks were already harvested in PRIOR rounds, so the
        # round folds B pass-equivalents of update mass in steady state --
        # straggler chunks SUBSTITUTE for the completers' already-applied
        # mass rather than adding to it.  Chunking redistributes when mass
        # lands, not how much lands per apply.  min(B, K) is what the
        # elastic ``_live_sigma`` rescaling needs: with L < B live workers
        # the deadline shrinks to the L-th full arrival.
        return method.gamma * min(method.B, K)

    @classmethod
    def coalesce_supported(cls, method: MethodConfig,
                           cluster: ClusterModel) -> tuple[bool, str]:
        return (False, "protocol 'partial_work' streams per-chunk arrivals "
                       "(per-chunk scan carries); its runs are not "
                       "expressible as shared lockstep/lag sweep cells")

    def __init__(self, problem, method, cluster, *, seed):
        if method.n_chunks < 1:
            raise ValueError(f"n_chunks must be >= 1, got {method.n_chunks}")
        if method.n_chunks > method.H:
            raise ValueError(
                f"n_chunks={method.n_chunks} exceeds H={method.H}: every "
                f"chunk needs at least one local step")
        if method.pw_quantum is not None and method.pw_quantum <= 0:
            raise ValueError(
                f"pw_quantum must be > 0 (simulated seconds per harvest "
                f"tick), got {method.pw_quantum}")
        super().__init__(problem, method, cluster, seed=seed)
        self._chunk_steps = chunk_steps(method.H, method.n_chunks)
        # Host mirror of the in-flight queue: seq -> (arrival, worker,
        # final).  arrivals_needed computes pop counts from it, so the
        # session's generic "pop N" loop never needs protocol-specific
        # peeking.
        self._pending: dict[int, tuple[float, int, bool]] = {}
        # Rejoin schedule, time-ascending; popped as the clock passes each.
        self._rejoins = sorted(
            (r, k) for k, _, r in cluster.membership if r is not None)

    # -- arrival rule ------------------------------------------------------

    def initial_messages(self):
        return self._launch_chunks(
            [(k, 0.0) for k in range(self.K)
             if self.cluster.live_at(k, 0.0)])

    def arrivals_needed(self, round_index: int) -> int:
        T = self.method.T
        if self.full_sync_period and round_index % T == T - 1:
            return len(self._pending)  # barrier: drain every in-flight chunk
        if not self._pending:
            return 0  # starved (all live workers dropped): see process_round
        if self.method.pw_quantum is not None:
            deadline = self.sim_time + self.method.pw_quantum
            return sum(1 for a, _, _ in self._pending.values()
                       if a <= deadline)
        fulls = sorted((a, s) for s, (a, _, f) in self._pending.items() if f)
        if not fulls:
            return len(self._pending)  # only orphan chunks left: drain them
        b_eff = min(self.method.B, len(fulls))  # deadline shrinks with
        cut = fulls[b_eff - 1]                  # the live membership
        return sum(1 for s, (a, _, _) in self._pending.items()
                   if (a, s) <= cut)

    # -- aggregation + reply rules -----------------------------------------

    def process_round(self, round_index, arrived):
        m = self.method
        T = m.T
        barrier = self.full_sync_period and round_index % T == T - 1
        quantum = m.pw_quantum is not None and not barrier
        for msg in arrived:
            del self._pending[msg.seq]
        if quantum:
            server_time = self.sim_time + m.pw_quantum  # fixed harvest tick
        elif arrived:
            server_time = max(msg.arrival for msg in arrived)
        elif self._rejoins:
            # Starved: every live worker dropped mid-pass. Jump the clock to
            # the next rejoin so elasticity can never hang the round loop.
            server_time = max(self.sim_time, self._rejoins[0][0])
        else:
            return []  # permanently starved; remaining rounds are no-ops
        completed = [msg.worker for msg in arrived if msg.final
                     and self.cluster.live_at(msg.worker, server_time)]
        rejoiners = [k for k in self._collect_rejoiners(server_time)
                     if self.cluster.live_at(k, server_time)
                     and k not in completed]
        reply_to = completed + rejoiners
        nnz_host = None
        if arrived or reply_to:
            last = {}  # worker -> LAST harvested chunk's dual snapshot
            for msg in arrived:
                last[msg.worker] = msg.alpha_snapshot
            (self.w_server, self.dw_tilde, self.w_local, self.alpha_applied,
             reply_nnz, reply_sq) = _server_apply_partial(
                self.w_server, self.dw_tilde, self.w_local,
                self.alpha_applied,
                jnp.asarray(list(last.keys()), jnp.int32),
                tuple(last.values()),
                tuple(msg.payload for msg in arrived),
                jnp.asarray(reply_to, jnp.int32), m.gamma)
            self._last_reply_sq = reply_sq
            if not self.dense and reply_to:
                nnz_host = np.asarray(reply_nnz)
        starts, billing = [], []
        for j, k in enumerate(reply_to):
            rbytes, down_time = self._reply_billing(j, k, nnz_host)
            starts.append((k, server_time + down_time))
            billing.append((rbytes, down_time))
        self.sim_time = server_time
        return self._launch_chunks(starts, pre_account=billing)

    def _collect_rejoiners(self, upto: float) -> list[int]:
        out = []
        while self._rejoins and self._rejoins[0][0] <= upto:
            out.append(self._rejoins.pop(0)[1])
        return out

    def _live_sigma(self) -> float:
        """sigma' for the next launch wave: membership-scaled when elastic
        (the default formula evaluated at the LIVE worker count), the run's
        resolved sigma' otherwise."""
        if self.method.sigma_prime is not None or not self.cluster.membership:
            return self.sigma_p
        live = max(1, sum(self.cluster.live_at(k, self.sim_time)
                          for k in range(self.K)))
        return self.default_sigma_prime(self.method, live)

    # -- the fused chunked launch ------------------------------------------

    def _launch_chunks(self, starts, pre_account=None):
        """Launch chunked local passes for ``starts = [(worker, start), ...]``
        as ONE fused dispatch, then account each SENT chunk host-side.

        Per-chunk durations come from ``DelayModel.sample_chunks`` (one
        chunk-major draw per wave) for ``vector_sampled`` models and from
        per-(worker, chunk) scalar draws otherwise; with one chunk both
        reduce to the group family's per-wave draw, bit-for-bit.  A chunk is
        sent only if its compute finishes strictly before the worker's next
        scheduled drop; a truncated pass rolls the worker's dual/residual
        back to its last sent chunk (durable state), so dropped bytes stop
        accruing and no update mass is silently lost.
        """
        if not starts:
            return []
        m = self.method
        C = len(self._chunk_steps)
        if self.delay.vector_sampled:
            sampled = self.delay.sample_chunks(self._chunk_steps, self.rng)
            durations = [[sampled[c][k] for c in range(C)]
                         for k, _ in starts]
        else:
            durations = [[self.delay.compute_time(k, h, self.rng)
                          for h in self._chunk_steps] for k, _ in starts]
        finishes, n_sent = [], []
        for j, (k, start) in enumerate(starts):
            drop = self.cluster.next_drop_after(k, start)
            fin, t = [], start
            for c in range(C):
                t = t + durations[j][c]
                fin.append(t)
            finishes.append(fin)
            n_sent.append(sum(1 for t in fin if t < drop))
        # Pre-capture rows for passes that will be FULLY truncated: the
        # fused call donates alpha/residual, so their pre-launch values must
        # be materialized first (rare -- only drop-before-first-chunk).
        saved = {j: (self.alpha[k], self.residual[k])
                 for j, (k, _) in enumerate(starts) if n_sent[j] == 0}
        idxs = jnp.asarray([k for k, _ in starts], jnp.int32)
        (self.key, self.alpha, self.residual, alpha_rows, sents,
         resids) = _worker_chunk_rounds_fused(
            self.key, self.w_local, self.alpha, self.residual,
            self.problem.X, self.problem.y, self.norms_sq, idxs,
            self.problem.lam, self.n, self._live_sigma(), m.gamma,
            loss=self.problem.loss, chunk_steps=self._chunk_steps,
            comp=self.comp)
        out = []
        for j, (k, start) in enumerate(starts):
            if pre_account is not None:
                rbytes, down_time = pre_account[j]
                self.bytes_down += rbytes
                self.comm_time += down_time
            for c in range(n_sent[j]):
                nbytes = self.up_bytes  # the one compressor formula, per chunk
                up_time = self.delay.p2p_time(nbytes, k)
                self.compute_time += durations[j][c]
                self.comm_time += up_time
                self.bytes_up += nbytes
                self.seq += 1
                msg = Message(finishes[j][c] + up_time, k, sents[j, c],
                              alpha_rows[j, c], nbytes, self.seq,
                              chunk=c, final=(c == C - 1))
                self._pending[self.seq] = (msg.arrival, k, msg.final)
                out.append(msg)
            if n_sent[j] < C:
                if n_sent[j] == 0:
                    row_a, row_r = saved[j]
                else:
                    row_a = alpha_rows[j, n_sent[j] - 1]
                    row_r = resids[j, n_sent[j] - 1]
                self.alpha = self.alpha.at[k].set(row_a)
                self.residual = self.residual.at[k].set(row_r)
        return out


def chunk_steps(H: int, n_chunks: int) -> tuple[int, ...]:
    """Split ``H`` local steps into ``n_chunks`` near-equal chunk sizes
    (earlier chunks take the remainder; sums to exactly ``H``)."""
    base, rem = divmod(H, n_chunks)
    return tuple(base + (1 if i < rem else 0) for i in range(n_chunks))


@register_protocol("hierarchical_b")
class HierarchicalBProtocol(GroupProtocol):
    """Two-level rack-aware aggregation: per-rack B-of-k, then cross-rack.

    Workers are split into ``MethodConfig.n_racks`` contiguous racks (worker
    ``k`` belongs to rack ``k * n_racks // K``).  A round's deadline is the
    first simulated instant at which EVERY rack has at least ``rack_b``
    arrivals in flight past its top-of-rack link -- per-rack B-of-k on
    per-rack links, then one cross-rack merge (the inherited arrival-order
    catch-up aggregation; the merge is associative so the two levels fold
    into one fused apply).  Pair with the ``bandwidth_coupled`` delay model
    (``ClusterModel.straggler_workers`` = the slow rack's members) to model
    a rack behind an oversubscribed uplink: the discipline then waits for
    ``rack_b`` arrivals from the slow rack instead of letting the fast racks
    outvote it -- per-rack representation at B-of-K cost.

    The T-periodic full barrier is kept (Assumption 3's staleness bound is
    rack-agnostic).  sigma' covers ``n_racks * rack_b`` aggregated passes.
    """

    @classmethod
    def default_sigma_prime(cls, method: MethodConfig, K: int) -> float:
        return method.gamma * max(1, method.n_racks * method.rack_b)

    @classmethod
    def coalesce_supported(cls, method: MethodConfig,
                           cluster: ClusterModel) -> tuple[bool, str]:
        return (False, "protocol 'hierarchical_b' pops rack-dependent "
                       "arrival counts (host-adaptive control flow); its "
                       "runs are not expressible as shared sweep cells")

    def __init__(self, problem, method, cluster, *, seed):
        K = problem.X.shape[0]
        if not 1 <= method.n_racks <= K:
            raise ValueError(
                f"n_racks must be in [1, K={K}], got {method.n_racks}")
        self._rack_of = [k * method.n_racks // K for k in range(K)]
        rack_sizes = [self._rack_of.count(r) for r in range(method.n_racks)]
        if not 1 <= method.rack_b <= min(rack_sizes):
            raise ValueError(
                f"rack_b must be in [1, min rack size={min(rack_sizes)}] "
                f"(racks of {rack_sizes}), got {method.rack_b}")
        super().__init__(problem, method, cluster, seed=seed)
        # One in-flight message per worker at all times (the group-family
        # relaunch invariant); recorded at launch so the arrival rule can
        # count the per-rack prefix without peeking at the session's heap.
        self._pending: dict[int, tuple[float, int, int]] = {}

    def _observe_launch(self, k, start, arrival):
        self._pending[self.seq] = (arrival, self.seq, k)

    def arrivals_needed(self, round_index: int) -> int:
        T = self.method.T
        if self.full_sync_period and round_index % T == T - 1:
            return self.K
        need = [self.method.rack_b] * self.method.n_racks
        outstanding = sum(need)
        for count, (_, _, k) in enumerate(
                sorted(self._pending.values()), start=1):
            r = self._rack_of[k]
            if need[r] > 0:
                need[r] -= 1
                outstanding -= 1
                if outstanding == 0:
                    return count
        return len(self._pending)  # unreachable under the launch invariant

    def process_round(self, round_index, arrived):
        for msg in arrived:
            del self._pending[msg.seq]
        return super().process_round(round_index, arrived)


def _materialize_records(snaps: list[_Snapshot], problem: objectives.Problem,
                         eval_mode: str) -> list[RunRecord]:
    """Turn deferred snapshots into RunRecords.

    ``batched``: one ``lax.map`` dispatch covering every gap certificate.
    ``replay``: op-for-op the reference's per-round ``gap_certificate`` calls
    (bit-identical floats by construction; used as a debugging oracle --
    ``batched`` is equally bit-exact, which tests/test_engine.py pins).
    """
    if not snaps:
        return []
    if eval_mode == "replay":
        rows = []
        for s in snaps:
            cert = objectives.gap_certificate(problem, s.alpha, w=s.w)
            rows.append((cert["primal"], cert["dual"], cert["gap"],
                         cert["gap_server"]))
    elif eval_mode == "batched":
        ws = jnp.stack([s.w for s in snaps])
        alphas = jnp.stack([s.alpha for s in snaps])
        p, dv, gap, gap_srv = _eval_bucketed(ws, alphas, problem.X, problem.y,
                                             problem.lam, loss=problem.loss)
        rows = list(zip(np.asarray(p, np.float64), np.asarray(dv, np.float64),
                        np.asarray(gap, np.float64),
                        np.asarray(gap_srv, np.float64)))
    else:
        raise ValueError(f"unknown eval_mode {eval_mode!r}")
    return [
        RunRecord(iteration=s.iteration, sim_time=s.sim_time,
                  gap=float(gap), gap_server=float(gap_srv), primal=float(p),
                  dual=float(dv), bytes_up=int(s.bytes_up),
                  bytes_down=int(s.bytes_down), compute_time=s.compute_time,
                  comm_time=s.comm_time)
        for s, (p, dv, gap, gap_srv) in zip(snaps, rows)
    ]


def run_method(
    problem: objectives.Problem,
    method: MethodConfig,
    cluster: ClusterModel,
    *,
    num_outer: int,
    seed: int = 0,
    eval_every: int = 1,
    eval_mode: str = "batched",
) -> RunResult:
    """Run ``method`` through the pluggable engine. Same contract as
    :func:`repro.core.acpd.run_method` (which now delegates here).

    Thin compat wrapper: the round loop lives in
    :class:`repro.api.session.Session`; this drains its event stream and
    folds it back into a :class:`RunResult` (the tests/test_engine.py
    bit-for-bit pins hold through this path).
    """
    from repro.api.session import Session  # late import: api imports engine

    session = Session(problem, method, cluster, num_outer=num_outer,
                      seed=seed, eval_every=eval_every, eval_mode=eval_mode)
    return session.run()
