"""Local SDCA solver for the CoCoA+-style subproblem G_k^{sigma'} (Eq. 7-8).

Each worker k holds a partition ``X_k: (n_k, d)``, ``y_k: (n_k,)`` and, per
round, runs ``H`` sequential stochastic dual coordinate-ascent steps on

    max_{dalpha}  -(1/n) sum_{i in P_k} phi_i*(-(alpha + dalpha)_i)
                  -(1/n) w_eff^T A_k dalpha
                  -(lambda sigma'/2) || (1/(lambda n)) A_k dalpha ||^2

with ``w_eff = w_k + gamma * dw_residual`` (Algorithm 2, line 4) held fixed.
The accumulated local primal delta ``v = (1/(lambda n)) A_k dalpha`` is carried
through the loop so each coordinate step sees the effective margin
``z_i = (w_eff + sigma' * v)^T x_i``.

Closed-form coordinate maximizers:

* ridge:           delta = (y_i - a_i - z_i) / (1 + q_i)
* smoothed hinge:  b* = clip((1 - y z + q_i a_y) / (g + q_i), 0, 1); delta = y (b* - a_y)
* logistic:        Newton on b = y*alpha in (0,1) (8 damped steps)

where ``a_i`` is the current dual value (alpha_i + dalpha_i),
``q_i = sigma' ||x_i||^2 / (lambda n)`` and ``g`` the hinge smoothing.

The plain (single-machine) SDCA of Shalev-Shwartz & Zhang 2013 is the special
case sigma'=1, w_eff=0-initialized global w: see ``sdca_reference`` below,
which the tests use as the convergence oracle.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.objectives import LossName, _HINGE_SMOOTHING


class LocalSolveResult(NamedTuple):
    delta_alpha: jax.Array  # (n_k,) the raw subproblem solution Delta alpha_[k]
    v: jax.Array  # (d,)  (1/(lambda n)) A_k Delta alpha_[k]


def _coordinate_delta(
    loss: LossName,
    a: jax.Array,  # current dual value alpha_i + dalpha_i
    z: jax.Array,  # effective margin (w_eff + sigma' v)^T x_i
    y: jax.Array,
    q: jax.Array,  # sigma' ||x_i||^2 / (lambda n)
) -> jax.Array:
    """Closed-form/Newton maximizer of the 1-D coordinate subproblem."""
    if loss == "ridge":
        return (y - a - z) / (1.0 + q)
    if loss == "smoothed_hinge":
        g = _HINGE_SMOOTHING
        a_y = y * a
        b = jnp.clip((1.0 - y * z + q * a_y) / (g + q), 0.0, 1.0)
        return y * (b - a_y)
    if loss == "logistic":
        eps = 1e-6
        a_y = jnp.clip(y * a, eps, 1.0 - eps)
        b = a_y
        # Damped Newton on f'(b) = log((1-b)/b) - y z - q (b - a_y).
        for _ in range(8):
            fp = jnp.log1p(-b) - jnp.log(b) - y * z - q * (b - a_y)
            fpp = -1.0 / (b * (1.0 - b)) - q
            b = jnp.clip(b - fp / fpp, eps, 1.0 - eps)
        return y * (b - a_y)
    raise ValueError(f"unknown loss {loss!r}")


@partial(jax.jit, static_argnames=("loss",))
def solve_subproblem_indices(
    w_eff: jax.Array,  # (d,)
    alpha: jax.Array,  # (n_k,) current local dual variables
    X: jax.Array,  # (n_k, d)
    y: jax.Array,  # (n_k,)
    norms_sq: jax.Array,  # (n_k,) precomputed ||x_i||^2
    lam: float,
    n_global: int,
    sigma_prime: float,
    idx: jax.Array,  # (H,) int32 coordinate visit order
    *,
    loss: LossName,
) -> LocalSolveResult:
    """H sequential SDCA steps with an explicit visit order (kernel oracle)."""

    def body(carry, i):
        dalpha, v = carry
        x_i = X[i]
        a_i = alpha[i] + dalpha[i]
        z_i = jnp.dot(w_eff, x_i) + sigma_prime * jnp.dot(v, x_i)
        q_i = sigma_prime * norms_sq[i] / (lam * n_global)
        delta = _coordinate_delta(loss, a_i, z_i, y[i], q_i)
        dalpha = dalpha.at[i].add(delta)
        v = v + (delta / (lam * n_global)) * x_i
        return (dalpha, v), None

    init = (jnp.zeros_like(alpha), jnp.zeros_like(w_eff))
    (dalpha, v), _ = jax.lax.scan(body, init, idx)
    return LocalSolveResult(dalpha, v)


@partial(jax.jit, static_argnames=("loss", "num_steps"))
def solve_subproblem(
    w_eff: jax.Array,
    alpha: jax.Array,
    X: jax.Array,
    y: jax.Array,
    norms_sq: jax.Array,
    lam: float,
    n_global: int,
    sigma_prime: float,
    key: jax.Array,
    *,
    loss: LossName,
    num_steps: int,
) -> LocalSolveResult:
    """H sequential SDCA steps with uniform sampling (Alg. 2 line 4)."""
    n_k = X.shape[0]
    # Explicit dtype: the default follows the x64 flag, and the scan-fused
    # executor traces this under enable_x64 -- int64 draws would consume the
    # PRNG differently and break executor bit-equivalence.
    idx = jax.random.randint(key, (num_steps,), 0, n_k, dtype=jnp.int32)
    return solve_subproblem_indices(
        w_eff, alpha, X, y, norms_sq, lam, n_global, sigma_prime, idx, loss=loss)


def solve_subproblem_all(w_all, alpha, X, y, norms_sq, lam, n_global, sigma_prime,
                         keys, *, loss: LossName, num_steps: int) -> LocalSolveResult:
    """vmapped over the worker axis: all K workers solve simultaneously."""
    fn = partial(solve_subproblem, loss=loss, num_steps=num_steps)
    return jax.vmap(fn, in_axes=(0, 0, 0, 0, 0, None, None, None, 0))(
        w_all, alpha, X, y, norms_sq, lam, n_global, sigma_prime, keys)


@partial(jax.jit, static_argnames=("loss", "num_epochs"))
def sdca_reference(
    X: jax.Array,  # (n, d) single-machine data
    y: jax.Array,  # (n,)
    lam: float,
    key: jax.Array,
    *,
    loss: LossName,
    num_epochs: int,
) -> tuple[jax.Array, jax.Array]:
    """Single-machine SDCA (SSZ'13) oracle: returns (alpha, w).

    This is the K=1, sigma'=1, gamma=1 case with w maintained exactly via the
    primal-dual relation; the distributed methods must converge to the same
    optimum (tests assert this).
    """
    n, d = X.shape
    norms_sq = jnp.sum(X * X, axis=-1)
    idx = jax.random.randint(key, (num_epochs * n,), 0, n, dtype=jnp.int32)

    def body(carry, i):
        alpha, w = carry
        x_i = X[i]
        z_i = jnp.dot(w, x_i)
        q_i = norms_sq[i] / (lam * n)
        delta = _coordinate_delta(loss, alpha[i], z_i, y[i], q_i)
        alpha = alpha.at[i].add(delta)
        w = w + (delta / (lam * n)) * x_i
        return (alpha, w), None

    (alpha, w), _ = jax.lax.scan(body, (jnp.zeros(n, X.dtype), jnp.zeros(d, X.dtype)), idx)
    return alpha, w
