"""Pluggable fault-injection models: the chaos axis of the serve layer.

The paper's claim is straggler *agnosticism* (arXiv:1910.04235), and the
delay registry (:mod:`repro.core.delays`) covers the slow-worker half of
that story.  This module covers the broken-worker half: a ``FaultModel`` is
a seeded, deterministic, spec-round-trippable schedule of injected failures
that the multi-tenant experiment service (:mod:`repro.serve`) consults at
every dispatch, so the recovery machinery -- quarantine-and-bisect retry,
execution deadlines, the per-key circuit breaker, divergence masking and
checkpoint/resume (:mod:`repro.serve.recovery`) -- can be exercised and
benchmarked under a *pinned* fault schedule instead of ad-hoc monkeypatching.

A fault model answers two questions:

* ``on_dispatch(kind, key, attempt)`` -- called immediately before the
  service executes work. ``kind`` is the lane (``"batch"`` for a coalesced
  cohort, ``"solo"`` for a per-request Session, ``"segment"`` for one
  checkpoint segment of a resumable run, where ``attempt`` is the 0-based
  starting round of the segment), ``key`` is a stable hashable identity for
  the work (the coalescer's batch key, or a per-request tuple), ``attempt``
  the 0-based retry count.  The model may **raise** a typed
  :class:`InjectedFault` (crash / transient error / compile failure) or
  **sleep** (slow-batch overrun); returning normally means no fault.
* ``poison_cells(n_cells, key)`` -- which cell indices of a coalesced batch
  get a NaN-poisoned operand (the service substitutes ``gamma = NaN`` for
  those cells, so divergence is *real* in the compiled run and the per-cell
  finite certificates must genuinely catch it).  Must be attempt-stable:
  the poison travels with the request, not with the retry.

Registry entries:

* ``none``               -- the default: never faults.
* ``transient_executor`` -- the first ``failures`` attempts of every batch
  raise :class:`TransientExecutorError` (transient: the service retries the
  whole cohort with exponential backoff + deterministic jitter).
* ``worker_crash``       -- a worker process dies mid-batch: the first
  ``crashes`` attempts of every batch raise :class:`WorkerCrashError`
  (transient); with ``crash_round`` set, a checkpointed solo run is killed
  at that segment boundary (persistent for that run -- the tenant resubmits
  and the run resumes from the last checkpoint, bit-identically).
* ``compile_failure``    -- every attempt of every batch raises
  :class:`CompileFailureError` (persistent: retries cannot help, so
  repeated failures on one batch key open the circuit breaker).
* ``nan_poison``         -- ``count`` deterministic cells per batch get a
  NaN gamma; the run itself succeeds and the per-cell finite certificates
  isolate exactly the poisoned tenants.
* ``slow_batch``         -- the first ``slow_attempts`` attempts of every
  batch sleep ``delay_s`` seconds before executing, tripping the service's
  execution deadline (typed ``JobTimeoutError`` + solo-lane requeue).
* ``chaos``              -- the pinned composite schedule the chaos bench
  drives: per process-order dispatch index, one deadline overrun, one
  transient fault, and one NaN-poisoned cell (stateful like ``markov``:
  build a fresh instance per service run; reproducible from ``seed`` +
  submission order alone).

Determinism: models never consult wall-clock or global RNG state -- every
decision is a pure function of ``(seed, key, attempt)`` (plus an explicit
per-instance dispatch counter for ``chaos``), with key identity reduced via
``zlib.crc32`` (Python's ``hash()`` is salted per process and would break
cross-run reproducibility).

Extending: subclass :class:`FaultModel`, decorate with
:func:`register_fault`, accept parameters as JSON-scalar keyword arguments
(they round-trip through :meth:`FaultModel.spec`).  The
``docs/fault-tolerance.md`` guide walks the registry end to end.
"""

from __future__ import annotations

import time  # analysis: host-ok (slow-batch faults sleep on the host)
import zlib

import numpy as np

# ---------------------------------------------------------------------------
# Typed injected faults.
# ---------------------------------------------------------------------------


class InjectedFault(RuntimeError):
    """Base of every injected failure; ``transient`` drives the service's
    retry-vs-quarantine classification (:mod:`repro.serve.recovery`)."""

    transient = False


class WorkerCrashError(InjectedFault):
    """A worker process died mid-batch; a relaunch can succeed (transient)."""

    transient = True


class TransientExecutorError(InjectedFault):
    """A one-off executor failure (OOM blip, preempted device); retryable."""

    transient = True


class CompileFailureError(InjectedFault):
    """Compilation of the batch's computation fails deterministically;
    retrying the same key can never help (persistent)."""

    transient = False


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

_FAULTS: dict[str, type["FaultModel"]] = {}


def register_fault(name: str):
    """Class decorator: make a FaultModel constructible by registry name."""

    def deco(cls: type["FaultModel"]) -> type["FaultModel"]:
        cls.fault_name = name
        _FAULTS[name] = cls
        return cls

    return deco


def available_faults() -> tuple[str, ...]:
    return tuple(sorted(_FAULTS))


def get_fault(name: str) -> type["FaultModel"]:
    try:
        return _FAULTS[name]
    except KeyError:
        raise ValueError(
            f"unknown fault model {name!r}; available: {available_faults()}"
        ) from None


def fault_from_spec(spec: dict) -> "FaultModel":
    """Build a model from its :meth:`FaultModel.spec` dict (JSON-safe)."""
    return get_fault(spec["fault_model"])(**spec.get("fault_params", {}))


def key_digest(key) -> int:
    """A process-stable 32-bit digest of a work identity.

    ``repr`` + crc32, NOT ``hash()``: string hashing is salted per process,
    and fault schedules must reproduce across service restarts (the
    checkpoint/resume and pinned-bench contracts)."""
    return zlib.crc32(repr(key).encode())


# ---------------------------------------------------------------------------
# Base class.
# ---------------------------------------------------------------------------


class FaultModel:
    """Deterministic injected-failure schedule; see the module docstring.

    ``stateful`` marks models carrying per-instance counters (``chaos``):
    like the ``markov`` delay model, build a FRESH instance per service run
    so schedules reproduce from ``(seed, submission order)`` alone.
    """

    fault_name = "abstract"
    stateful = False

    def __init__(self, *, seed: int = 0):
        self.seed = int(seed)

    # -- the two injection hooks ------------------------------------------

    def on_dispatch(self, kind: str, key, attempt: int) -> None:
        """Called before the service executes ``key`` (lane ``kind``) for
        the ``attempt``-th time.  Raise an :class:`InjectedFault` to fail
        the dispatch, sleep to overrun a deadline, or return for no fault."""

    def poison_cells(self, n_cells: int, key) -> tuple[int, ...]:
        """Cell indices of batch ``key`` whose gamma is replaced by NaN.
        Attempt-stable by contract (no ``attempt`` argument on purpose)."""
        return ()

    # -- spec round-trip ---------------------------------------------------

    def params(self) -> dict:
        """JSON-scalar constructor kwargs; subclasses extend."""
        return {"seed": self.seed}

    def spec(self) -> dict:
        """The JSON-safe description: ``fault_from_spec(m.spec())`` builds
        an equivalent model."""
        return {"fault_model": self.fault_name, "fault_params": self.params()}

    def _rng(self, key) -> np.random.Generator:
        return np.random.default_rng([self.seed, key_digest(key)])


@register_fault("none")
class NoFault(FaultModel):
    """The default: never injects anything."""


@register_fault("transient_executor")
class TransientExecutorFault(FaultModel):
    """First ``failures`` attempts of every batch raise a transient error."""

    def __init__(self, *, seed: int = 0, failures: int = 1):
        super().__init__(seed=seed)
        if failures < 0:
            raise ValueError(f"failures must be >= 0, got {failures}")
        self.failures = int(failures)

    def on_dispatch(self, kind, key, attempt):
        if kind == "batch" and attempt < self.failures:
            raise TransientExecutorError(
                f"injected transient executor failure "
                f"(attempt {attempt} < failures={self.failures})")

    def params(self):
        return {**super().params(), "failures": self.failures}


@register_fault("worker_crash")
class WorkerCrashFault(FaultModel):
    """A worker dies mid-batch (transient), and/or a checkpointed run is
    killed at segment boundary ``crash_round`` (resume from checkpoint)."""

    def __init__(self, *, seed: int = 0, crashes: int = 1,
                 crash_round: int | None = None):
        super().__init__(seed=seed)
        if crashes < 0:
            raise ValueError(f"crashes must be >= 0, got {crashes}")
        self.crashes = int(crashes)
        self.crash_round = None if crash_round is None else int(crash_round)

    def on_dispatch(self, kind, key, attempt):
        if kind == "batch" and attempt < self.crashes:
            raise WorkerCrashError(
                f"injected worker crash mid-batch (attempt {attempt})")
        if (kind == "segment" and self.crash_round is not None
                and attempt >= self.crash_round):
            raise WorkerCrashError(
                f"injected service kill at round {attempt} "
                f"(crash_round={self.crash_round}); resume from checkpoint")

    def params(self):
        return {**super().params(), "crashes": self.crashes,
                "crash_round": self.crash_round}


@register_fault("compile_failure")
class CompileFailureFault(FaultModel):
    """Every batch attempt fails persistently: the circuit-breaker regime."""

    def on_dispatch(self, kind, key, attempt):
        if kind == "batch":
            raise CompileFailureError(
                "injected deterministic compile failure (persistent; "
                "retries cannot help)")


@register_fault("nan_poison")
class NanPoisonFault(FaultModel):
    """``count`` deterministic cells per batch get a NaN gamma operand."""

    def __init__(self, *, seed: int = 0, count: int = 1):
        super().__init__(seed=seed)
        if count < 0:
            raise ValueError(f"count must be >= 0, got {count}")
        self.count = int(count)

    def poison_cells(self, n_cells, key):
        k = min(self.count, n_cells)
        if k == 0:
            return ()
        idx = self._rng(key).choice(n_cells, size=k, replace=False)
        return tuple(sorted(int(i) for i in idx))

    def params(self):
        return {**super().params(), "count": self.count}


@register_fault("slow_batch")
class SlowBatchFault(FaultModel):
    """First ``slow_attempts`` attempts of every batch sleep ``delay_s``
    before executing -- the deadline-overrun regime (watchdog -> typed
    ``JobTimeoutError`` -> solo-lane requeue)."""

    def __init__(self, *, seed: int = 0, delay_s: float = 0.5,
                 slow_attempts: int = 1):
        super().__init__(seed=seed)
        if delay_s < 0 or slow_attempts < 0:
            raise ValueError(
                f"need delay_s >= 0 and slow_attempts >= 0, got "
                f"{delay_s}, {slow_attempts}")
        self.delay_s = float(delay_s)
        self.slow_attempts = int(slow_attempts)

    def on_dispatch(self, kind, key, attempt):
        if kind == "batch" and attempt < self.slow_attempts:
            time.sleep(self.delay_s)

    def params(self):
        return {**super().params(), "delay_s": self.delay_s,
                "slow_attempts": self.slow_attempts}


@register_fault("chaos")
class ChaosFault(FaultModel):
    """The pinned composite schedule of the chaos bench: per batch-dispatch
    process order, dispatch 0 overruns the deadline, dispatch 1 fails
    transiently, and the first batch asked about poisoning gets ``poison``
    NaN cells.  Stateful (fresh instance per run, like ``markov``)."""

    stateful = True

    def __init__(self, *, seed: int = 0, delay_s: float = 0.3,
                 poison: int = 1):
        super().__init__(seed=seed)
        if delay_s < 0 or poison < 0:
            raise ValueError(
                f"need delay_s >= 0 and poison >= 0, got {delay_s}, {poison}")
        self.delay_s = float(delay_s)
        self.poison = int(poison)
        self._dispatches = 0
        self._poison_key = None

    def on_dispatch(self, kind, key, attempt):
        if kind != "batch":
            return
        n = self._dispatches
        self._dispatches += 1
        if n == 0:
            time.sleep(self.delay_s)  # deadline overrun
        elif n == 1:
            raise TransientExecutorError(
                "injected chaos transient fault (dispatch 1)")

    def poison_cells(self, n_cells, key):
        if self._poison_key is None:
            self._poison_key = key_digest(key)
        if key_digest(key) != self._poison_key:
            return ()
        k = min(self.poison, n_cells)
        if k == 0:
            return ()
        idx = self._rng(key).choice(n_cells, size=k, replace=False)
        return tuple(sorted(int(i) for i in idx))

    def params(self):
        return {**super().params(), "delay_s": self.delay_s,
                "poison": self.poison}
