"""Pluggable fault-injection models: the chaos axis of the serve layer.

The paper's claim is straggler *agnosticism* (arXiv:1910.04235), and the
delay registry (:mod:`repro.core.delays`) covers the slow-worker half of
that story.  This module covers the broken-worker half: a ``FaultModel`` is
a seeded, deterministic, spec-round-trippable schedule of injected failures
that the multi-tenant experiment service (:mod:`repro.serve`) consults at
every dispatch, so the recovery machinery -- quarantine-and-bisect retry,
execution deadlines, the per-key circuit breaker, divergence masking and
checkpoint/resume (:mod:`repro.serve.recovery`) -- can be exercised and
benchmarked under a *pinned* fault schedule instead of ad-hoc monkeypatching.

A fault model answers two questions:

* ``on_dispatch(kind, key, attempt)`` -- called immediately before the
  service executes work. ``kind`` is the lane (``"batch"`` for a coalesced
  cohort, ``"solo"`` for a per-request Session, ``"segment"`` for one
  checkpoint segment of a resumable run, where ``attempt`` is the 0-based
  starting round of the segment), ``key`` is a stable hashable identity for
  the work (the coalescer's batch key, or a per-request tuple), ``attempt``
  the 0-based retry count.  The model may **raise** a typed
  :class:`InjectedFault` (crash / transient error / compile failure) or
  **sleep** (slow-batch overrun); returning normally means no fault.
* ``poison_cells(n_cells, key)`` -- which cell indices of a coalesced batch
  get a NaN-poisoned operand (the service substitutes ``gamma = NaN`` for
  those cells, so divergence is *real* in the compiled run and the per-cell
  finite certificates must genuinely catch it).  Must be attempt-stable:
  the poison travels with the request, not with the retry.

Registry entries:

* ``none``               -- the default: never faults.
* ``transient_executor`` -- the first ``failures`` attempts of every batch
  raise :class:`TransientExecutorError` (transient: the service retries the
  whole cohort with exponential backoff + deterministic jitter).
* ``worker_crash``       -- a worker process dies mid-batch: the first
  ``crashes`` attempts of every batch raise :class:`WorkerCrashError`
  (transient); with ``crash_round`` set, a checkpointed solo run is killed
  at that segment boundary (persistent for that run -- the tenant resubmits
  and the run resumes from the last checkpoint, bit-identically).
* ``compile_failure``    -- every attempt of every batch raises
  :class:`CompileFailureError` (persistent: retries cannot help, so
  repeated failures on one batch key open the circuit breaker).
* ``nan_poison``         -- ``count`` deterministic cells per batch get a
  NaN gamma; the run itself succeeds and the per-cell finite certificates
  isolate exactly the poisoned tenants.
* ``slow_batch``         -- the first ``slow_attempts`` attempts of every
  batch sleep ``delay_s`` seconds before executing, tripping the service's
  execution deadline (typed ``JobTimeoutError`` + solo-lane requeue).
* ``chaos``              -- the pinned composite schedule the chaos bench
  drives: per process-order dispatch index, one deadline overrun, one
  transient fault, and one NaN-poisoned cell (stateful like ``markov``:
  build a fresh instance per service run; reproducible from ``seed`` +
  submission order alone).

**The network-fault family** (the cluster-transport seam,
:mod:`repro.serve.cluster`): replicated serving moves messages -- job
records, heartbeats, result records -- between processes through a shared
cluster directory, and these entries decide each message's fate
(:meth:`FaultModel.message_fate`) and each replica's fate
(:meth:`FaultModel.replica_fate` / :meth:`FaultModel.segment_fate`)
deterministically, so every cross-process chaos scenario replays exactly:

* ``net_drop``      -- each selected message is dropped (never written);
  senders re-send, so progress relies on at-least-once retry + idempotent
  delivery, which is exactly what the cluster tests pin.
* ``net_duplicate`` -- each selected message is delivered twice; receivers
  must dedupe (exactly-once via idempotent job keys).
* ``net_reorder``   -- each selected message is held for one transport tick,
  so the NEXT message overtakes it.
* ``net_delay``     -- each selected message is held for ``ticks`` transport
  ticks before delivery.
* ``net_partition`` -- the named replica is unreachable (reads nothing,
  its writes are dropped) for a tick window.
* ``replica_kill``  -- the named replica dies abruptly: after ``after_steps``
  scheduler steps, or mid-run at checkpoint-segment ``at_segment`` (a true
  SIGKILL in subprocess replicas; an uncatchable control-flow kill
  in-process).  Leases and heartbeats are left behind un-released -- crash
  semantics, which is the point.
* ``cluster_chaos`` -- the pinned composite the cluster bench and
  ``make cluster-smoke`` drive: one replica killed + seeded message drop.

Determinism: models never consult wall-clock or global RNG state -- every
decision is a pure function of ``(seed, key, attempt)`` (plus an explicit
per-instance dispatch counter for ``chaos``), with key identity reduced via
``zlib.crc32`` (Python's ``hash()`` is salted per process and would break
cross-run reproducibility).  Network-fault decisions are keyed on
``(seed, message kind, message key, send sequence number)`` so a re-sent
message is a NEW draw -- a dropped result is not dropped forever.

Extending: subclass :class:`FaultModel`, decorate with
:func:`register_fault`, accept parameters as JSON-scalar keyword arguments
(they round-trip through :meth:`FaultModel.spec`).  The
``docs/fault-tolerance.md`` guide walks the registry end to end.
"""

from __future__ import annotations

import time  # analysis: host-ok (slow-batch faults sleep on the host)
import zlib

import numpy as np

# ---------------------------------------------------------------------------
# Typed injected faults.
# ---------------------------------------------------------------------------


class InjectedFault(RuntimeError):
    """Base of every injected failure; ``transient`` drives the service's
    retry-vs-quarantine classification (:mod:`repro.serve.recovery`)."""

    transient = False


class WorkerCrashError(InjectedFault):
    """A worker process died mid-batch; a relaunch can succeed (transient)."""

    transient = True


class TransientExecutorError(InjectedFault):
    """A one-off executor failure (OOM blip, preempted device); retryable."""

    transient = True


class CompileFailureError(InjectedFault):
    """Compilation of the batch's computation fails deterministically;
    retrying the same key can never help (persistent)."""

    transient = False


class ReplicaKilled(BaseException):
    """The in-process analogue of SIGKILL for a cluster replica.

    Deliberately a ``BaseException``: nothing in the serve stack's typed
    recovery machinery may catch, retry, or convert it -- a killed replica
    writes no result, releases no lease, and says no goodbye, exactly like
    a process that took a real SIGKILL.  Subprocess replicas take the real
    signal instead (:mod:`repro.serve.cluster`)."""


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

_FAULTS: dict[str, type["FaultModel"]] = {}


def register_fault(name: str):
    """Class decorator: make a FaultModel constructible by registry name."""

    def deco(cls: type["FaultModel"]) -> type["FaultModel"]:
        cls.fault_name = name
        _FAULTS[name] = cls
        return cls

    return deco


def available_faults() -> tuple[str, ...]:
    return tuple(sorted(_FAULTS))


def get_fault(name: str) -> type["FaultModel"]:
    try:
        return _FAULTS[name]
    except KeyError:
        raise ValueError(
            f"unknown fault model {name!r}; available: {available_faults()}"
        ) from None


def fault_from_spec(spec: dict) -> "FaultModel":
    """Build a model from its :meth:`FaultModel.spec` dict (JSON-safe)."""
    return get_fault(spec["fault_model"])(**spec.get("fault_params", {}))


def key_digest(key) -> int:
    """A process-stable 32-bit digest of a work identity.

    ``repr`` + crc32, NOT ``hash()``: string hashing is salted per process,
    and fault schedules must reproduce across service restarts (the
    checkpoint/resume and pinned-bench contracts)."""
    return zlib.crc32(repr(key).encode())


# ---------------------------------------------------------------------------
# Base class.
# ---------------------------------------------------------------------------


class FaultModel:
    """Deterministic injected-failure schedule; see the module docstring.

    ``stateful`` marks models carrying per-instance counters (``chaos``):
    like the ``markov`` delay model, build a FRESH instance per service run
    so schedules reproduce from ``(seed, submission order)`` alone.
    """

    fault_name = "abstract"
    stateful = False

    def __init__(self, *, seed: int = 0):
        self.seed = int(seed)

    # -- the two injection hooks ------------------------------------------

    def on_dispatch(self, kind: str, key, attempt: int) -> None:
        """Called before the service executes ``key`` (lane ``kind``) for
        the ``attempt``-th time.  Raise an :class:`InjectedFault` to fail
        the dispatch, sleep to overrun a deadline, or return for no fault."""

    def poison_cells(self, n_cells: int, key) -> tuple[int, ...]:
        """Cell indices of batch ``key`` whose gamma is replaced by NaN.
        Attempt-stable by contract (no ``attempt`` argument on purpose)."""
        return ()

    # -- the cluster-transport hooks (replicated serving) ------------------

    def message_fate(self, kind: str, key, seq: int) -> tuple[int, int]:
        """``(copies, delay_ticks)`` for one cluster-transport send.

        ``kind`` is the message class (``"job"``/``"result"``/
        ``"heartbeat"``), ``key`` the message identity (job key or replica
        id), ``seq`` the sender's per-transport send counter -- so a RE-sent
        message is a fresh draw.  ``copies=0`` drops the message, ``2``
        duplicates it; ``delay_ticks > 0`` holds delivery for that many
        subsequent transport ticks (``1`` lets the next message overtake:
        reordering).  Default: deliver one copy now."""
        return (1, 0)

    def replica_fate(self, replica: str, tick: int) -> str:
        """``"ok"`` | ``"partitioned"`` | ``"killed"`` for one replica at
        one scheduler tick.  Partitioned replicas read nothing and their
        sends are dropped; killed replicas stop abruptly (no lease release,
        no final heartbeat)."""
        return "ok"

    def segment_fate(self, replica: str, start_round: int) -> bool:
        """True iff ``replica`` must die at the checkpoint segment starting
        at ``start_round`` -- the mid-run kill hook (the previous segment's
        snapshot is already durable when this fires)."""
        return False

    # -- spec round-trip ---------------------------------------------------

    def params(self) -> dict:
        """JSON-scalar constructor kwargs; subclasses extend."""
        return {"seed": self.seed}

    def spec(self) -> dict:
        """The JSON-safe description: ``fault_from_spec(m.spec())`` builds
        an equivalent model."""
        return {"fault_model": self.fault_name, "fault_params": self.params()}

    def _rng(self, key) -> np.random.Generator:
        return np.random.default_rng([self.seed, key_digest(key)])


@register_fault("none")
class NoFault(FaultModel):
    """The default: never injects anything."""


@register_fault("transient_executor")
class TransientExecutorFault(FaultModel):
    """First ``failures`` attempts of every batch raise a transient error."""

    def __init__(self, *, seed: int = 0, failures: int = 1):
        super().__init__(seed=seed)
        if failures < 0:
            raise ValueError(f"failures must be >= 0, got {failures}")
        self.failures = int(failures)

    def on_dispatch(self, kind, key, attempt):
        if kind == "batch" and attempt < self.failures:
            raise TransientExecutorError(
                f"injected transient executor failure "
                f"(attempt {attempt} < failures={self.failures})")

    def params(self):
        return {**super().params(), "failures": self.failures}


@register_fault("worker_crash")
class WorkerCrashFault(FaultModel):
    """A worker dies mid-batch (transient), and/or a checkpointed run is
    killed at segment boundary ``crash_round`` (resume from checkpoint)."""

    def __init__(self, *, seed: int = 0, crashes: int = 1,
                 crash_round: int | None = None):
        super().__init__(seed=seed)
        if crashes < 0:
            raise ValueError(f"crashes must be >= 0, got {crashes}")
        self.crashes = int(crashes)
        self.crash_round = None if crash_round is None else int(crash_round)

    def on_dispatch(self, kind, key, attempt):
        if kind == "batch" and attempt < self.crashes:
            raise WorkerCrashError(
                f"injected worker crash mid-batch (attempt {attempt})")
        if (kind == "segment" and self.crash_round is not None
                and attempt >= self.crash_round):
            raise WorkerCrashError(
                f"injected service kill at round {attempt} "
                f"(crash_round={self.crash_round}); resume from checkpoint")

    def params(self):
        return {**super().params(), "crashes": self.crashes,
                "crash_round": self.crash_round}


@register_fault("compile_failure")
class CompileFailureFault(FaultModel):
    """Every batch attempt fails persistently: the circuit-breaker regime."""

    def on_dispatch(self, kind, key, attempt):
        if kind == "batch":
            raise CompileFailureError(
                "injected deterministic compile failure (persistent; "
                "retries cannot help)")


@register_fault("nan_poison")
class NanPoisonFault(FaultModel):
    """``count`` deterministic cells per batch get a NaN gamma operand."""

    def __init__(self, *, seed: int = 0, count: int = 1):
        super().__init__(seed=seed)
        if count < 0:
            raise ValueError(f"count must be >= 0, got {count}")
        self.count = int(count)

    def poison_cells(self, n_cells, key):
        k = min(self.count, n_cells)
        if k == 0:
            return ()
        idx = self._rng(key).choice(n_cells, size=k, replace=False)
        return tuple(sorted(int(i) for i in idx))

    def params(self):
        return {**super().params(), "count": self.count}


@register_fault("slow_batch")
class SlowBatchFault(FaultModel):
    """First ``slow_attempts`` attempts of every batch sleep ``delay_s``
    before executing -- the deadline-overrun regime (watchdog -> typed
    ``JobTimeoutError`` -> solo-lane requeue)."""

    def __init__(self, *, seed: int = 0, delay_s: float = 0.5,
                 slow_attempts: int = 1):
        super().__init__(seed=seed)
        if delay_s < 0 or slow_attempts < 0:
            raise ValueError(
                f"need delay_s >= 0 and slow_attempts >= 0, got "
                f"{delay_s}, {slow_attempts}")
        self.delay_s = float(delay_s)
        self.slow_attempts = int(slow_attempts)

    def on_dispatch(self, kind, key, attempt):
        if kind == "batch" and attempt < self.slow_attempts:
            time.sleep(self.delay_s)

    def params(self):
        return {**super().params(), "delay_s": self.delay_s,
                "slow_attempts": self.slow_attempts}


@register_fault("chaos")
class ChaosFault(FaultModel):
    """The pinned composite schedule of the chaos bench: per batch-dispatch
    process order, dispatch 0 overruns the deadline, dispatch 1 fails
    transiently, and the first batch asked about poisoning gets ``poison``
    NaN cells.  Stateful (fresh instance per run, like ``markov``)."""

    stateful = True

    def __init__(self, *, seed: int = 0, delay_s: float = 0.3,
                 poison: int = 1):
        super().__init__(seed=seed)
        if delay_s < 0 or poison < 0:
            raise ValueError(
                f"need delay_s >= 0 and poison >= 0, got {delay_s}, {poison}")
        self.delay_s = float(delay_s)
        self.poison = int(poison)
        self._dispatches = 0
        self._poison_key = None

    def on_dispatch(self, kind, key, attempt):
        if kind != "batch":
            return
        n = self._dispatches
        self._dispatches += 1
        if n == 0:
            time.sleep(self.delay_s)  # deadline overrun
        elif n == 1:
            raise TransientExecutorError(
                "injected chaos transient fault (dispatch 1)")

    def poison_cells(self, n_cells, key):
        if self._poison_key is None:
            self._poison_key = key_digest(key)
        if key_digest(key) != self._poison_key:
            return ()
        k = min(self.poison, n_cells)
        if k == 0:
            return ()
        idx = self._rng(key).choice(n_cells, size=k, replace=False)
        return tuple(sorted(int(i) for i in idx))

    def params(self):
        return {**super().params(), "delay_s": self.delay_s,
                "poison": self.poison}


# ---------------------------------------------------------------------------
# The network-fault family (cluster-transport seam).
# ---------------------------------------------------------------------------

#: Message kinds the cluster transport routes; the ``kinds`` parameter of
#: the per-message entries is a comma-joined subset of these.
MESSAGE_KINDS = ("job", "result", "heartbeat")


class _PerMessageFault(FaultModel):
    """Shared machinery: select messages at ``rate`` over ``kinds``.

    Selection is a pure function of ``(seed, kind, key, seq)`` -- the send
    SEQUENCE enters the draw, so a retried message is a fresh coin flip and
    at-least-once senders always make progress eventually."""

    def __init__(self, *, seed: int = 0, rate: float = 0.5,
                 kinds: str = "job,result,heartbeat"):
        super().__init__(seed=seed)
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {rate}")
        self.rate = float(rate)
        self.kinds = str(kinds)
        parsed = tuple(k.strip() for k in self.kinds.split(",") if k.strip())
        unknown = [k for k in parsed if k not in MESSAGE_KINDS]
        if unknown:
            raise ValueError(
                f"unknown message kinds {unknown}; known: {MESSAGE_KINDS}")
        self._kinds = frozenset(parsed)

    def _selected(self, kind: str, key, seq: int) -> bool:
        if kind not in self._kinds or self.rate == 0.0:
            return False
        if self.rate >= 1.0:
            return True
        rng = np.random.default_rng(
            [self.seed, key_digest(kind), key_digest(key), int(seq)])
        return bool(rng.random() < self.rate)

    def params(self):
        return {**super().params(), "rate": self.rate, "kinds": self.kinds}


@register_fault("net_drop")
class NetDropFault(_PerMessageFault):
    """Selected messages are dropped: never written to the cluster dir.
    Progress then depends on at-least-once re-send + idempotent delivery."""

    def message_fate(self, kind, key, seq):
        return (0, 0) if self._selected(kind, key, seq) else (1, 0)


@register_fault("net_duplicate")
class NetDuplicateFault(_PerMessageFault):
    """Selected messages are delivered TWICE; receivers must dedupe
    (exactly-once delivery via idempotent job keys)."""

    def message_fate(self, kind, key, seq):
        return (2, 0) if self._selected(kind, key, seq) else (1, 0)


@register_fault("net_reorder")
class NetReorderFault(_PerMessageFault):
    """Selected messages are held one transport tick, so the next message
    overtakes them -- pairwise reordering."""

    def message_fate(self, kind, key, seq):
        return (1, 1) if self._selected(kind, key, seq) else (1, 0)


@register_fault("net_delay")
class NetDelayFault(_PerMessageFault):
    """Selected messages are held for ``ticks`` transport ticks."""

    def __init__(self, *, seed: int = 0, rate: float = 1.0, ticks: int = 2,
                 kinds: str = "job,result,heartbeat"):
        super().__init__(seed=seed, rate=rate, kinds=kinds)
        if ticks < 1:
            raise ValueError(f"ticks must be >= 1, got {ticks}")
        self.ticks = int(ticks)

    def message_fate(self, kind, key, seq):
        return ((1, self.ticks) if self._selected(kind, key, seq)
                else (1, 0))

    def params(self):
        return {**super().params(), "ticks": self.ticks}


@register_fault("net_partition")
class NetPartitionFault(FaultModel):
    """Replica ``replica`` is unreachable for scheduler ticks
    ``[start_tick, start_tick + duration)``: it reads nothing and its sends
    are dropped.  ``duration=None`` partitions it forever (the
    no-hung-handles regime: consumers must still observe bounded, typed
    outcomes)."""

    def __init__(self, *, seed: int = 0, replica: str = "",
                 start_tick: int = 0, duration: int | None = None):
        super().__init__(seed=seed)
        if not replica:
            raise ValueError("net_partition needs replica=<replica id>")
        if start_tick < 0:
            raise ValueError(f"start_tick must be >= 0, got {start_tick}")
        if duration is not None and duration < 1:
            raise ValueError(f"duration must be >= 1 or None, got {duration}")
        self.replica = str(replica)
        self.start_tick = int(start_tick)
        self.duration = None if duration is None else int(duration)

    def replica_fate(self, replica, tick):
        if replica != self.replica or tick < self.start_tick:
            return "ok"
        if self.duration is not None and tick >= self.start_tick + self.duration:
            return "ok"
        return "partitioned"

    def params(self):
        return {**super().params(), "replica": self.replica,
                "start_tick": self.start_tick, "duration": self.duration}


@register_fault("replica_kill")
class ReplicaKillFault(FaultModel):
    """Replica ``replica`` dies abruptly -- crash semantics: no lease
    release, no final heartbeat.  ``after_steps=N`` kills it at its N-th
    scheduler step; ``at_segment=R`` kills it mid-run, at the checkpoint
    segment starting at round R (the previous snapshot is already durable).
    Subprocess replicas take a real SIGKILL; in-process replicas raise the
    uncatchable :class:`ReplicaKilled`."""

    def __init__(self, *, seed: int = 0, replica: str = "",
                 after_steps: int | None = None,
                 at_segment: int | None = None):
        super().__init__(seed=seed)
        if not replica:
            raise ValueError("replica_kill needs replica=<replica id>")
        if after_steps is None and at_segment is None:
            raise ValueError(
                "replica_kill needs after_steps and/or at_segment")
        if after_steps is not None and after_steps < 0:
            raise ValueError(f"after_steps must be >= 0, got {after_steps}")
        if at_segment is not None and at_segment < 1:
            raise ValueError(
                f"at_segment must be >= 1 (segment 0's kill would precede "
                f"any checkpoint), got {at_segment}")
        self.replica = str(replica)
        self.after_steps = None if after_steps is None else int(after_steps)
        self.at_segment = None if at_segment is None else int(at_segment)

    def replica_fate(self, replica, tick):
        if (replica == self.replica and self.after_steps is not None
                and tick >= self.after_steps):
            return "killed"
        return "ok"

    def segment_fate(self, replica, start_round):
        return (replica == self.replica and self.at_segment is not None
                and start_round >= self.at_segment)

    def params(self):
        return {**super().params(), "replica": self.replica,
                "after_steps": self.after_steps,
                "at_segment": self.at_segment}


@register_fault("cluster_chaos")
class ClusterChaosFault(FaultModel):
    """The pinned composite the cluster bench and ``make cluster-smoke``
    drive: ``kill_replica`` dies (mid-segment if ``at_segment`` is set,
    else at step ``after_steps``) while every message is dropped at
    ``drop_rate``.  Deterministic: delegates to :class:`ReplicaKillFault`
    and :class:`NetDropFault` built from the same seed."""

    def __init__(self, *, seed: int = 0, kill_replica: str = "",
                 after_steps: int | None = None,
                 at_segment: int | None = None, drop_rate: float = 0.2):
        super().__init__(seed=seed)
        self._kill = ReplicaKillFault(seed=seed, replica=kill_replica,
                                      after_steps=after_steps,
                                      at_segment=at_segment)
        self._drop = NetDropFault(seed=seed, rate=drop_rate)
        self.kill_replica = self._kill.replica
        self.after_steps = self._kill.after_steps
        self.at_segment = self._kill.at_segment
        self.drop_rate = self._drop.rate

    def message_fate(self, kind, key, seq):
        return self._drop.message_fate(kind, key, seq)

    def replica_fate(self, replica, tick):
        return self._kill.replica_fate(replica, tick)

    def segment_fate(self, replica, start_round):
        return self._kill.segment_fate(replica, start_round)

    def params(self):
        return {**super().params(), "kill_replica": self.kill_replica,
                "after_steps": self.after_steps,
                "at_segment": self.at_segment, "drop_rate": self.drop_rate}
