"""Alternative local solvers the paper points to (Sec. III-B1).

The paper uses plain SDCA with uniform sampling but explicitly lists the
drop-in alternatives: Accelerated Prox-SDCA (Shalev-Shwartz & Zhang 2013/14)
and importance sampling (Zhang & Xiao 2015). Both are implemented here on the
same subproblem interface as ``sdca.solve_subproblem`` so any ACPD run can
swap them via ``MethodConfig``-level composition (see tests for the
convergence comparison).

* ``solve_subproblem_importance``: coordinates sampled with probability
  p_i proportional to (1 + sigma' ||x_i||^2 / (lam n)) -- the smoothness-
  proportional distribution -- with the update unchanged (the coordinate
  maximizer is exact, so no step-size reweighting is needed for ascent).
* ``solve_subproblem_accelerated``: outer Catalyst-style acceleration around
  the SDCA inner loop: solve a sequence of kappa-regularized subproblems at
  extrapolated points y_t = alpha_t + beta (alpha_t - alpha_{t-1}).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.objectives import LossName
from repro.core.sdca import (LocalSolveResult, solve_subproblem,
                             solve_subproblem_indices)


@partial(jax.jit, static_argnames=("loss", "num_steps"))
def solve_subproblem_importance(
    w_eff: jax.Array,
    alpha: jax.Array,
    X: jax.Array,
    y: jax.Array,
    norms_sq: jax.Array,
    lam: float,
    n_global: int,
    sigma_prime: float,
    key: jax.Array,
    *,
    loss: LossName,
    num_steps: int,
) -> LocalSolveResult:
    """SDCA with smoothness-proportional (importance) sampling."""
    q = 1.0 + sigma_prime * norms_sq / (lam * n_global)
    p = q / jnp.sum(q)
    idx = jax.random.choice(key, norms_sq.shape[0], (num_steps,), p=p)
    return solve_subproblem_indices(
        w_eff, alpha, X, y, norms_sq, lam, n_global, sigma_prime,
        idx.astype(jnp.int32), loss=loss)


@partial(jax.jit, static_argnames=("loss", "num_steps", "num_rounds"))
def solve_subproblem_accelerated(
    w_eff: jax.Array,
    alpha: jax.Array,
    X: jax.Array,
    y: jax.Array,
    norms_sq: jax.Array,
    lam: float,
    n_global: int,
    sigma_prime: float,
    key: jax.Array,
    *,
    loss: LossName,
    num_steps: int,
    num_rounds: int = 4,
    beta: float = 0.5,
) -> LocalSolveResult:
    """Catalyst-style accelerated SDCA: extrapolated restarts of the inner
    solver. Total coordinate steps = num_steps (split across rounds), so the
    comparison against plain SDCA is work-normalized."""
    n_k = X.shape[0]
    inner = max(1, num_steps // num_rounds)

    def round_body(carry, k):
        dalpha_prev, dalpha, v = carry
        # extrapolate in the dual
        momentum = beta * (dalpha - dalpha_prev)
        da_y = dalpha + momentum
        v_y = v + X.T @ momentum / (lam * n_global)
        idx = jax.random.randint(k, (inner,), 0, n_k, dtype=jnp.int32)
        res = solve_subproblem_indices(
            w_eff + sigma_prime * v_y, alpha + da_y, X, y, norms_sq, lam,
            n_global, sigma_prime, idx, loss=loss)
        return (dalpha, da_y + res.delta_alpha, v_y + res.v), None

    keys = jax.random.split(key, num_rounds)
    init = (jnp.zeros_like(alpha), jnp.zeros_like(alpha), jnp.zeros_like(w_eff))
    (_, dalpha, v), _ = jax.lax.scan(round_body, init, keys)
    return LocalSolveResult(dalpha, v)


# ---------------------------------------------------------------------------
# Local-solver registry.
#
# The CoCoA-lineage protocols in repro.core.engine (protocol="cocoa" /
# "cocoa_plus") draw their per-worker subproblem solver from here via
# ``MethodConfig.local_solver`` instead of hard-wiring SDCA, which is exactly
# the freedom the CoCoA framework (Jaggi et al., arXiv:1409.1458) advertises:
# any local solver achieving a Theta-approximate subproblem solution plugs
# into the same aggregation.  Every entry shares one signature:
#
#     solver(w_eff, alpha, X, y, norms_sq, lam, n_global, sigma_prime, key,
#            *, loss, num_steps) -> LocalSolveResult
#
# so protocols can vmap an entry across the worker axis unchanged.
# ---------------------------------------------------------------------------

_SOLVERS = {}


def register_solver(name: str):
    """Decorator (usable as a plain call too): add a local solver under
    ``name`` -- same extension pattern as the protocol/compressor/delay
    registries."""

    def deco(fn):
        _SOLVERS[name] = fn
        return fn

    return deco


register_solver("sdca")(solve_subproblem)
register_solver("importance")(solve_subproblem_importance)
register_solver("accelerated")(solve_subproblem_accelerated)


def available_solvers() -> tuple[str, ...]:
    return tuple(sorted(_SOLVERS))


def get_solver(name: str):
    """Resolve a ``MethodConfig.local_solver`` name; ValueError lists the
    registry on a miss (same error contract as protocols/compressors)."""
    try:
        return _SOLVERS[name]
    except KeyError:
        raise ValueError(
            f"unknown local solver {name!r}; available: {available_solvers()}"
        ) from None
