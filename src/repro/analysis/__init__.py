"""Static analysis for the repro engine: AST lint + trace-time contracts.

Two layers behind one CLI (``python -m repro analyze``):

* :mod:`repro.analysis.lint` -- rule registry + AST lint enforcing the
  purity / donation / mesh / version-floor invariants on source.
* :mod:`repro.analysis.contracts` -- lowers the traced entry points with
  abstract inputs and asserts the scan-fusion / no-callback / donation /
  bucket-cache contracts from the jaxpr and compiled HLO.
* :mod:`repro.analysis.findings` -- findings + the checked-in baseline
  (``ANALYSIS_BASELINE.json``) that separates accepted debt from
  regressions.

Extension guide: ``docs/static-analysis.md`` (executed by
tests/test_docs.py).
"""

from repro.analysis.findings import Baseline, Finding, sort_findings
from repro.analysis.lint import (Rule, available_rules, default_rules,
                                 get_rule, lint_paths, lint_project,
                                 lint_source, parse_project, register_rule)

__all__ = [
    "Baseline", "Finding", "Rule", "available_rules", "default_rules",
    "get_rule", "lint_paths", "lint_project", "lint_source",
    "parse_project", "register_rule", "sort_findings",
]
