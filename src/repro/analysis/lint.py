"""Project-specific AST lint: the repo's performance invariants as rules.

The engine/executor/sweep performance story rests on invariants that used to
be enforced by convention only (ROADMAP "standing constraints", docstrings,
after-the-fact runtime counters).  This module turns them into machine-checked
contracts over the source AST -- no imports, no tracing, no device:

* ``version-floor``      -- JAX-0.4.37-incompatible spellings
  (``jax.tree.flatten_with_path``, ``jax.sharding.AxisType``).
* ``mesh-via-make-mesh`` -- device meshes are built ONLY through
  :func:`repro.launch.mesh.make_mesh` (the version-safe wrapper); any direct
  ``jax.sharding.Mesh(...)`` / ``jax.make_mesh(...)`` elsewhere is an error.
* ``pallas-scalar-index``-- bare dynamic scalar indices on Pallas refs
  (``ref[k]``): 0.4.x interpret mode needs ``pl.ds(k, 1)``.
* ``traced-host-sync``   -- host synchronization (``.item()``, ``float()``
  on arrays, ``np.asarray``, ``time.*``, Python RNG) inside functions
  *reachable from traced entry points* (``jax.jit`` / ``lax.scan`` /
  ``shard_map`` / ``pallas_call`` consumers).  Host-side-by-design code is
  simply not reachable; the rest is a dispatch stall on the hot path.
* ``jit-donation``       -- a ``jax.jit`` whose wrapped function takes
  carry-style state arguments must declare ``donate_argnums`` (the engine's
  fused rounds all donate; a new hot jit that forgets doubles its HBM
  footprint silently).
* ``f64-without-x64``    -- ``jnp.float64``/``jnp.int64`` in functions with
  no ``enable_x64`` guard silently truncate to 32 bit on the default config.
* ``registry-hooks``     -- every ``@register_protocol`` / compressor /
  delay / solver entry implements the abstract hooks its base class
  declares (the Protocol hook-contract docstrings, statically enforced).
  Protocol entries must additionally state ``default_sigma_prime`` and
  ``coalesce_supported`` in their own class chain: both are concrete on
  the base, so inheriting them silently means nobody decided the new
  entry's safety parameter or its serve-batching eligibility.

Rules are registry entries (:func:`register_rule`), mirroring the protocol /
compressor / delay registries: subclass :class:`Rule`, decorate, and the rule
runs in every ``python -m repro analyze`` invocation -- the worked example
lives in ``docs/static-analysis.md`` (executed by tests/test_docs.py).

Findings are suppressed line- or scope-wise with pragmas::

    x = host_value.item()        # analysis: host-ok        (this line)
    def eval_loop(...):          # analysis: ignore[traced-host-sync]
    f64 = jnp.float64            # analysis: x64-ok

and pre-existing accepted findings live in the checked-in baseline
(``ANALYSIS_BASELINE.json``, see :mod:`repro.analysis.findings`).
"""

from __future__ import annotations

import ast
import pathlib
import re

from repro.analysis.findings import Finding, sort_findings

# ---------------------------------------------------------------------------
# Rule registry (mirrors the protocol/compressor/delay registries).
# ---------------------------------------------------------------------------

_RULES: dict[str, type["Rule"]] = {}


def register_rule(name: str):
    """Class decorator: add a :class:`Rule` to the analyzer's registry."""

    def deco(cls: type["Rule"]) -> type["Rule"]:
        cls.rule_name = name
        _RULES[name] = cls
        return cls

    return deco


def available_rules() -> tuple[str, ...]:
    return tuple(sorted(_RULES))


def get_rule(name: str) -> type["Rule"]:
    try:
        return _RULES[name]
    except KeyError:
        raise ValueError(
            f"unknown analysis rule {name!r}; available: {available_rules()}"
        ) from None


def default_rules() -> tuple[str, ...]:
    """All registered rules except ``*-example`` entries (the docs guides
    register worked examples at test time; they must not police the repo)."""
    return tuple(n for n in available_rules()
                 if not n.endswith(("-example", "_example")))


class Rule:
    """One statically checkable invariant.

    Subclass, set ``description``, implement :meth:`check`, and decorate with
    :func:`register_rule`.  ``check`` receives one parsed module plus the
    whole-project index (for cross-module rules) and returns raw findings;
    the driver applies pragma suppression and baseline matching afterwards.
    """

    rule_name = "abstract"
    description = ""

    def check(self, module: "ModuleInfo",
              project: "ProjectIndex") -> list[Finding]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Parsed-module model: pragmas, imports, scoped function table.
# ---------------------------------------------------------------------------

_PRAGMA_RE = re.compile(r"#\s*analysis:\s*([a-z0-9_\-\[\],\s*]+)")
_PRAGMA_ALIASES = {"host-ok": "traced-host-sync", "x64-ok": "f64-without-x64",
                   "fail-fast-ok": "typed-errors"}


def _parse_pragmas(lines: list[str]) -> dict[int, set[str]]:
    """line number -> suppressed rule names (``{"*"}`` suppresses all)."""
    out: dict[int, set[str]] = {}
    for i, text in enumerate(lines, start=1):
        m = _PRAGMA_RE.search(text)
        if not m:
            continue
        spec = m.group(1).strip()
        rules: set[str] = set()
        for tok in re.split(r"[\s,]+", spec):
            if not tok:
                continue
            im = re.fullmatch(r"ignore(?:\[([a-z0-9_\-,]+)\])?", tok)
            if im:
                rules |= set(im.group(1).split(",")) if im.group(1) else {"*"}
            else:
                rules.add(_PRAGMA_ALIASES.get(tok, tok))
        out[i] = rules
    return out


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


class FunctionNode:
    """One ``def`` (or traced ``lambda``) with its scope and call edges."""

    def __init__(self, module: "ModuleInfo", node, qualname: str):
        self.module = module
        self.node = node
        self.qualname = qualname
        self.edges: set["FunctionNode"] = set()
        self.partial_aliases: dict[str, str] = {}  # local name -> target

    @property
    def params(self) -> list[str]:
        a = self.node.args
        return [p.arg for p in
                list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)]

    def own_statements(self):
        """Direct AST nodes of this function, nested defs/lambdas excluded
        (they are their own FunctionNodes)."""
        skip = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
        stack = (list(self.node.body) if not isinstance(self.node, ast.Lambda)
                 else [self.node.body])
        while stack:
            n = stack.pop()
            yield n
            for child in ast.iter_child_nodes(n):
                if not isinstance(child, skip):
                    stack.append(child)


class ModuleInfo:
    """One parsed source file: AST + pragmas + import map + function table."""

    def __init__(self, path: pathlib.Path, source: str, relpath: str):
        self.path = path
        self.relpath = relpath
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        self.pragmas = _parse_pragmas(self.lines)
        self.modname = _modname_for(relpath)
        self.imports: dict[str, str] = {}
        self.functions: dict[str, FunctionNode] = {}
        self.classes: dict[str, ast.ClassDef] = {}
        self._scope_lines: dict[str, tuple[int, int]] = {}
        self._collect_imports()
        self._collect_defs()

    # -- construction ------------------------------------------------------

    def _collect_imports(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.imports[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module:
                if node.level:  # relative imports: not used in this repo
                    continue
                for a in node.names:
                    self.imports[a.asname or a.name] = (
                        f"{node.module}.{a.name}")

    def _collect_defs(self) -> None:
        def visit(node, prefix):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    q = f"{prefix}{child.name}"
                    self.functions[q] = FunctionNode(self, child, q)
                    self._scope_lines[q] = (child.lineno,
                                            child.end_lineno or child.lineno)
                    visit(child, f"{q}.")
                elif isinstance(child, ast.ClassDef):
                    q = f"{prefix}{child.name}"
                    self.classes[q] = child
                    self._scope_lines[q] = (child.lineno,
                                            child.end_lineno or child.lineno)
                    visit(child, f"{q}.")
                else:
                    visit(child, prefix)

        visit(self.tree, "")

    # -- helpers rules use -------------------------------------------------

    def canonical(self, node: ast.AST) -> str | None:
        """Alias-resolved dotted name of an expression (``jnp.float64`` ->
        ``jax.numpy.float64``), or None for non-name expressions."""
        dotted = _dotted(node)
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        head = self.imports.get(head, head)
        return f"{head}.{rest}" if rest else head

    def enclosing(self, line: int) -> str:
        """Qualname of the innermost def/class containing ``line``."""
        best, best_span = "", None
        for q, (lo, hi) in self._scope_lines.items():
            if lo <= line <= hi and (best_span is None
                                     or hi - lo <= best_span):
                best, best_span = q, hi - lo
        return best

    def snippet(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def suppressed(self, rule: str, line: int) -> bool:
        """Pragma on the line itself or on any enclosing def/class header."""
        check = [line]
        for q, (lo, hi) in self._scope_lines.items():
            if lo <= line <= hi:
                check.append(lo)
        for ln in check:
            rules = self.pragmas.get(ln)
            if rules and ("*" in rules or rule in rules):
                return True
        return False

    def finding(self, rule: str, line: int, message: str) -> Finding:
        return Finding(rule=rule, path=self.relpath, line=line,
                       message=message, context=self.enclosing(line),
                       snippet=self.snippet(line))


def _modname_for(relpath: str) -> str:
    p = pathlib.PurePosixPath(relpath)
    parts = list(p.with_suffix("").parts)
    if "src" in parts:
        parts = parts[parts.index("src") + 1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


# ---------------------------------------------------------------------------
# Project index: cross-module name resolution + traced-reachability.
# ---------------------------------------------------------------------------

# Callables whose function-valued arguments run inside a trace.
TRACE_CONSUMERS = frozenset({
    "jax.jit", "jax.vmap", "jax.pmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat", "jax.make_jaxpr", "jax.eval_shape",
    "jax.lax.scan", "jax.lax.while_loop", "jax.lax.fori_loop",
    "jax.lax.cond", "jax.lax.switch", "jax.lax.map",
    "jax.lax.associative_scan", "jax.lax.custom_root",
    "jax.experimental.shard_map.shard_map",
    "jax.experimental.pallas.pallas_call",
})

_TRACED_DECORATORS = frozenset({"jax.jit", "jax.vmap", "jax.pmap"})


class ProjectIndex:
    """All parsed modules + the traced-code call graph over them."""

    def __init__(self, modules: list[ModuleInfo]):
        self.modules = modules
        self.by_modname = {m.modname: m for m in modules}
        self._roots: set[FunctionNode] = set()
        self._build_graph()
        self._reachable = self._close_over_roots()

    # -- name resolution ---------------------------------------------------

    def resolve_function(self, module: ModuleInfo, scope: str,
                         name: str) -> FunctionNode | None:
        """Resolve a bare ``name`` referenced from ``scope`` in ``module``:
        nested defs outward, then module level, then project imports."""
        prefix = scope
        while True:
            fn = module.functions.get(f"{prefix}.{name}" if prefix else name)
            if fn is not None:
                return fn
            # Walk outward: f.g.h -> f.g -> f -> module level.
            if not prefix:
                break
            prefix = prefix.rpartition(".")[0]
        target = module.imports.get(name)
        if target:
            mod, _, attr = target.rpartition(".")
            other = self.by_modname.get(mod)
            if other and attr:
                return other.functions.get(attr)
        return None

    def resolve_call(self, module: ModuleInfo, scope: str,
                     func: ast.AST) -> FunctionNode | None:
        """Resolve a call's target FunctionNode (project functions only)."""
        if isinstance(func, ast.Name):
            # Local partial/shard_map aliases first (x = partial(f, ...)).
            fnode = module.functions.get(scope)
            while fnode is not None:
                target = fnode.partial_aliases.get(func.id)
                if target is not None:
                    return self._resolve_dotted_target(module, scope, target)
                up = fnode.qualname.rpartition(".")[0]
                fnode = module.functions.get(up) if up else None
            return self.resolve_function(module, scope, func.id)
        dotted = _dotted(func)
        if dotted is None:
            return None
        return self._resolve_dotted_target(module, scope, dotted)

    def _resolve_dotted_target(self, module: ModuleInfo, scope: str,
                               dotted: str) -> FunctionNode | None:
        if "." not in dotted:
            return self.resolve_function(module, scope, dotted)
        head, _, rest = dotted.partition(".")
        target_mod = module.imports.get(head)
        if target_mod is None:
            return None
        other = self.by_modname.get(target_mod)
        if other is None:
            # ``from repro.core import engine`` -> engine._local_round
            other = self.by_modname.get(f"{target_mod}")
        return other.functions.get(rest) if other else None

    # -- graph construction ------------------------------------------------

    def _callable_args(self, call: ast.Call):
        for arg in list(call.args) + [kw.value for kw in call.keywords]:
            if isinstance(arg, (ast.Name, ast.Attribute, ast.Lambda)):
                yield arg
            elif isinstance(arg, ast.Call):  # partial(f, ...): unwrap f
                inner = _dotted(arg.func)
                if inner and inner.split(".")[-1] == "partial" and arg.args:
                    yield arg.args[0]

    def _mark_traced_lambda(self, module: ModuleInfo, node: ast.Lambda):
        q = f"<lambda:{node.lineno}>"
        fn = FunctionNode(module, node, module.enclosing(node.lineno) or q)
        module.functions.setdefault(f"{fn.qualname}.{q}", fn)
        self._roots.add(fn)

    def _build_graph(self) -> None:
        for module in self.modules:
            # Decorator-traced roots.
            for fn in list(module.functions.values()):
                node = fn.node
                if isinstance(node, ast.Lambda):
                    continue
                for dec in node.decorator_list:
                    canon = module.canonical(dec)
                    if canon in _TRACED_DECORATORS:
                        self._roots.add(fn)
                    elif isinstance(dec, ast.Call):
                        dcanon = module.canonical(dec.func)
                        if dcanon in _TRACED_DECORATORS:
                            self._roots.add(fn)
                        elif (dcanon and dcanon.endswith("partial")
                              and dec.args
                              and module.canonical(dec.args[0])
                              in _TRACED_DECORATORS):
                            self._roots.add(fn)
            # Consumer-call roots + partial aliases + call edges.
            for fn in list(module.functions.values()):
                scope = fn.qualname
                for stmt in fn.own_statements():
                    if isinstance(stmt, ast.Assign) and isinstance(
                            stmt.value, ast.Call):
                        self._record_alias(module, fn, stmt)
                    if not isinstance(stmt, ast.Call):
                        continue
                    canon = module.canonical(stmt.func)
                    if canon in TRACE_CONSUMERS:
                        for arg in self._callable_args(stmt):
                            if isinstance(arg, ast.Lambda):
                                self._mark_traced_lambda(module, arg)
                                continue
                            target = self.resolve_call(module, scope, arg)
                            if target is not None:
                                self._roots.add(target)
                    target = self.resolve_call(module, scope, stmt.func)
                    if target is not None:
                        fn.edges.add(target)
            # Module-level consumer calls (e.g. ``f = jax.jit(g)``).
            self._module_level_roots(module)

    def _record_alias(self, module: ModuleInfo, fn: FunctionNode,
                      stmt: ast.Assign) -> None:
        """``x = partial(f, ...)`` / ``x = shard_map(f, ...)``: calling ``x``
        later must resolve (and trace-mark) ``f``."""
        call = stmt.value
        canon = module.canonical(call.func) or ""
        is_partial = canon.endswith("partial")
        if not (is_partial or canon in TRACE_CONSUMERS) or not call.args:
            return
        inner = call.args[0]
        dotted = _dotted(inner)
        if dotted is None:
            return
        for tgt in stmt.targets:
            if isinstance(tgt, ast.Name):
                fn.partial_aliases[tgt.id] = dotted
        if canon in TRACE_CONSUMERS:
            target = self.resolve_call(module, fn.qualname, inner)
            if target is not None:
                self._roots.add(target)

    def _module_level_roots(self, module: ModuleInfo) -> None:
        in_function = set()
        for fn in module.functions.values():
            if isinstance(fn.node, ast.Lambda):
                continue
            lo, hi = fn.node.lineno, fn.node.end_lineno or fn.node.lineno
            in_function.add((lo, hi))

        def inside_def(line):
            return any(lo <= line <= hi for lo, hi in in_function)

        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call) or inside_def(node.lineno):
                continue
            if module.canonical(node.func) in TRACE_CONSUMERS:
                for arg in self._callable_args(node):
                    if isinstance(arg, ast.Lambda):
                        self._mark_traced_lambda(module, arg)
                        continue
                    target = self.resolve_call(module, "", arg)
                    if target is not None:
                        self._roots.add(target)

    def _close_over_roots(self) -> set[FunctionNode]:
        seen: set[FunctionNode] = set()
        stack = list(self._roots)
        while stack:
            fn = stack.pop()
            if fn in seen:
                continue
            seen.add(fn)
            stack.extend(fn.edges)
        return seen

    def is_traced(self, fn: FunctionNode) -> bool:
        """Is ``fn`` reachable from any traced entry point?"""
        return fn in self._reachable

    def traced_functions(self, module: ModuleInfo):
        return [fn for fn in module.functions.values() if self.is_traced(fn)]


# ---------------------------------------------------------------------------
# Rules.
# ---------------------------------------------------------------------------


@register_rule("version-floor")
class VersionFloorRule(Rule):
    """JAX-0.4.37 floor: spellings that only exist from JAX 0.5."""

    description = ("flags jax.tree.flatten_with_path / jax.sharding.AxisType "
                   "and friends (ROADMAP: JAX floor is 0.4.37); use "
                   "jax.tree_util.tree_flatten_with_path and "
                   "launch/mesh.make_mesh")

    BANNED = {
        "jax.tree.flatten_with_path":
            "use jax.tree_util.tree_flatten_with_path (jax.tree spelling "
            "needs JAX >= 0.5; floor is 0.4.37)",
        "jax.tree.map_with_path":
            "use jax.tree_util.tree_map_with_path (needs JAX >= 0.5)",
        "jax.tree.leaves_with_path":
            "use jax.tree_util.tree_leaves_with_path (needs JAX >= 0.5)",
        "jax.sharding.AxisType":
            "jax.sharding.AxisType needs JAX >= 0.5; build meshes through "
            "repro.launch.mesh.make_mesh (guarded getattr)",
    }

    def check(self, module, project):
        out = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Attribute):
                continue
            canon = module.canonical(node)
            if canon in self.BANNED:
                out.append(module.finding(self.rule_name, node.lineno,
                                          self.BANNED[canon]))
        return out


@register_rule("mesh-via-make-mesh")
class MeshRule(Rule):
    """The ROADMAP mesh rule, in code: meshes only via launch/mesh."""

    description = ("flags direct jax.sharding.Mesh(...) / jax.make_mesh(...) "
                   "construction outside launch/mesh.py; route through "
                   "repro.launch.mesh.make_mesh")

    ALLOWED_IN = ("launch/mesh.py",)
    CONSTRUCTORS = {"jax.sharding.Mesh", "jax.make_mesh",
                    "jax.experimental.mesh_utils.create_device_mesh"}

    def check(self, module, project):
        if module.relpath.endswith(self.ALLOWED_IN):
            return []
        out = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            canon = module.canonical(node.func)
            if canon in self.CONSTRUCTORS:
                out.append(module.finding(
                    self.rule_name, node.lineno,
                    f"direct {canon}(...) construction; build meshes only "
                    f"through repro.launch.mesh.make_mesh (version-safe "
                    f"axis_types handling)"))
        return out


@register_rule("pallas-scalar-index")
class PallasScalarIndexRule(Rule):
    """Bare dynamic scalar indices on Pallas refs break 0.4.x interpret."""

    description = ("flags ref[k] / pl.load(ref, (k,)) with a bare dynamic "
                   "scalar index in Pallas kernels; use pl.ds(k, 1) "
                   "(JAX 0.4.x interpret-mode contract)")

    _LOAD_STORE = {"load", "store"}

    def _uses_pallas(self, module) -> bool:
        return any(v.startswith("jax.experimental.pallas")
                   for v in module.imports.values())

    def _dynamic_elements(self, module, index) -> list[ast.AST]:
        elems = index.elts if isinstance(index, ast.Tuple) else [index]
        bad = []
        for e in elems:
            if isinstance(e, (ast.Constant, ast.Slice)):
                continue
            if isinstance(e, ast.Constant) or (
                    isinstance(e, ast.UnaryOp)
                    and isinstance(e.operand, ast.Constant)):
                continue
            if isinstance(e, ast.Call):
                canon = module.canonical(e.func) or ""
                if canon.endswith((".ds", ".dslice")) or canon == "slice":
                    continue
            elif _dotted(e) == "Ellipsis" or isinstance(e, ast.Starred):
                continue
            else:
                bad.append(e)
        return bad

    def check(self, module, project):
        if not self._uses_pallas(module):
            return []
        out = []
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Subscript):
                base = node.value
                if not (isinstance(base, ast.Name)
                        and (base.id.endswith("_ref") or base.id == "ref")):
                    continue
                for e in self._dynamic_elements(module, node.slice):
                    out.append(module.finding(
                        self.rule_name, node.lineno,
                        f"bare dynamic scalar index on Pallas ref "
                        f"{base.id!r}; use pl.ds(i, 1) (bare scalars break "
                        f"0.4.x interpret mode)"))
            elif isinstance(node, ast.Call):
                canon = module.canonical(node.func) or ""
                if not (canon.startswith("jax.experimental.pallas.")
                        and canon.rsplit(".", 1)[-1] in self._LOAD_STORE):
                    continue
                if len(node.args) < 2:
                    continue
                for e in self._dynamic_elements(module, node.args[1]):
                    out.append(module.finding(
                        self.rule_name, node.lineno,
                        "bare dynamic scalar index in pl.load/pl.store; "
                        "use pl.ds(i, 1)"))
        return out


@register_rule("traced-host-sync")
class TracedHostSyncRule(Rule):
    """No host synchronization inside traced code (the PR-1/4 perf story)."""

    description = ("flags .item()/.tolist()/float()/np.asarray/time.*/Python "
                   "RNG inside functions reachable from jax.jit / lax.scan / "
                   "shard_map / pallas_call call sites; mark host-side-by-"
                   "design lines with `# analysis: host-ok`")

    _METHODS = {"item": ".item() forces a device->host sync",
                "tolist": ".tolist() forces a device->host sync",
                "block_until_ready": ".block_until_ready() stalls dispatch"}
    _NUMPY = {"numpy.asarray", "numpy.array", "numpy.ascontiguousarray",
              "numpy.copyto", "numpy.save"}
    _BUILTINS = {"float", "int", "bool"}

    def _call_finding(self, module, fn, call) -> str | None:
        func = call.func
        if isinstance(func, ast.Attribute) and func.attr in self._METHODS:
            return self._METHODS[func.attr]
        canon = module.canonical(func)
        if canon is None:
            return None
        if canon in self._NUMPY or canon.startswith("numpy.random."):
            return (f"{canon} materializes a host array inside traced code "
                    f"(use jnp, or hoist to the host side)")
        if canon.startswith("time."):
            return f"{canon}() reads the host clock inside traced code"
        if canon.startswith("random."):
            return (f"{canon}() draws host randomness inside traced code "
                    f"(use jax.random with a threaded key)")
        if canon == "jax.device_get":
            return "jax.device_get forces a device->host transfer"
        if canon in self._BUILTINS and len(call.args) == 1 and not isinstance(
                call.args[0], ast.Constant):
            return (f"{canon}() on a traced value forces concretization "
                    f"(host sync); keep it an array or hoist it")
        return None

    def check(self, module, project):
        out = []
        for fn in project.traced_functions(module):
            for stmt in fn.own_statements():
                if not isinstance(stmt, ast.Call):
                    continue
                msg = self._call_finding(module, fn, stmt)
                if msg:
                    out.append(module.finding(
                        self.rule_name, stmt.lineno,
                        f"{msg} [traced via {fn.qualname}]"))
        return out


@register_rule("jit-donation")
class JitDonationRule(Rule):
    """Hot jits with carry-style state arguments must donate them."""

    description = ("flags jax.jit over functions with carry-style parameters "
                   "(state/carry/residual/caches/...) and no donate_argnums; "
                   "un-donated carries double the buffer footprint per "
                   "dispatch")

    CARRY_PARAMS = frozenset({
        "carry", "state", "opt_state", "caches", "residual", "ref_buf",
        "w_local", "w_server", "dw_tilde", "alpha_applied",
    })
    _DONATE_KWS = {"donate_argnums", "donate_argnames"}

    def _jit_kwargs(self, call: ast.Call) -> set[str]:
        return {kw.arg for kw in call.keywords if kw.arg}

    def _check_params(self, module, params, line, what) -> Finding | None:
        hot = sorted(set(params) & self.CARRY_PARAMS)
        if not hot:
            return None
        return module.finding(
            self.rule_name, line,
            f"{what} takes carry-style argument(s) {hot} but declares no "
            f"donate_argnums/donate_argnames; donate the carry (see the "
            f"engine's fused rounds) or rename if it is not a carry")

    def _lambda_params(self, node: ast.Lambda) -> list[str]:
        a = node.args
        return [p.arg for p in
                list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)]

    def check(self, module, project):
        out = []
        for fn in module.functions.values():
            node = fn.node
            if isinstance(node, ast.Lambda):
                continue
            for dec in node.decorator_list:
                canon = module.canonical(dec)
                if canon == "jax.jit":
                    f = self._check_params(module, fn.params, dec.lineno,
                                           f"@jax.jit on {fn.qualname}")
                    if f:
                        out.append(f)
                elif isinstance(dec, ast.Call):
                    dcanon = module.canonical(dec.func) or ""
                    is_partial_jit = (
                        dcanon.endswith("partial") and dec.args
                        and module.canonical(dec.args[0]) == "jax.jit")
                    if not (is_partial_jit or dcanon == "jax.jit"):
                        continue
                    if self._jit_kwargs(dec) & self._DONATE_KWS:
                        continue
                    f = self._check_params(module, fn.params, dec.lineno,
                                           f"jit of {fn.qualname}")
                    if f:
                        out.append(f)
        # Direct jax.jit(f, ...) / jax.jit(lambda ...) call sites.
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            if module.canonical(node.func) != "jax.jit" or not node.args:
                continue
            if self._jit_kwargs(node) & self._DONATE_KWS:
                continue
            target = node.args[0]
            if isinstance(target, ast.Lambda):
                params = self._lambda_params(target)
                f = self._check_params(module, params, node.lineno,
                                       "jax.jit(lambda ...)")
            else:
                scope = module.enclosing(node.lineno)
                resolved = project.resolve_call(module, scope, target)
                if resolved is None or isinstance(resolved.node, ast.Lambda):
                    continue
                f = self._check_params(module, resolved.params, node.lineno,
                                       f"jax.jit({resolved.qualname})")
            if f:
                out.append(f)
        return out


@register_rule("f64-without-x64")
class F64Rule(Rule):
    """f64 dtypes only under an enable_x64 guard (default config truncates)."""

    description = ("flags jnp.float64/jnp.int64 in functions with no "
                   "enable_x64 guard in scope; mark call-sites guarded by "
                   "their caller with `# analysis: x64-ok`")

    F64 = {"jax.numpy.float64", "jax.numpy.int64", "jax.numpy.uint64",
           "jax.numpy.complex128"}

    def _has_x64_guard(self, module, line) -> bool:
        """Any enclosing def whose body mentions enable_x64 (with-block or
        import) guards the usage."""
        for q, (lo, hi) in module._scope_lines.items():
            if lo <= line <= hi:
                body = "\n".join(module.lines[lo - 1:hi])
                if "enable_x64" in body:
                    return True
        return False

    def check(self, module, project):
        out = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Attribute):
                continue
            canon = module.canonical(node)
            if canon not in self.F64:
                continue
            if self._has_x64_guard(module, node.lineno):
                continue
            out.append(module.finding(
                self.rule_name, node.lineno,
                f"{canon} outside an enable_x64 guard silently truncates to "
                f"32 bit under the default config; guard with "
                f"jax.experimental.enable_x64 or mark the traced callee "
                f"`# analysis: x64-ok`"))
        return out


@register_rule("registry-hooks")
class RegistryHooksRule(Rule):
    """Registered protocol/compressor/delay/solver entries implement their
    base's abstract hooks (the Protocol hook-contract docstrings)."""

    description = ("flags @register_protocol/compressor/delay classes missing "
                   "abstract hooks of their base (plus the protocol registry's "
                   "explicit extras: default_sigma_prime, coalesce_supported), "
                   "and register_solver entries off the solver signature")

    # decorator canonical name ->
    #   (base module, base class, fallback hooks, extra required hooks).
    # Extras are hooks the base implements CONCRETELY (so they cannot be
    # auto-derived from NotImplementedError bodies) but that every registered
    # entry must still state in its own chain: sigma' is the safety parameter
    # of the entry's aggregation rule, and coalesce eligibility decides
    # whether the serve layer may batch the entry's runs -- inheriting either
    # silently from Protocol means nobody decided them for the new entry.
    REGISTRIES = {
        "repro.core.engine.register_protocol":
            ("repro.core.engine", "Protocol",
             ("num_rounds", "initial_messages", "arrivals_needed",
              "process_round", "snapshot", "finalize"),
             ("default_sigma_prime", "coalesce_supported")),
        "repro.core.compress.register_compressor":
            ("repro.core.compress", "Compressor",
             ("compress", "compress_grouped"), ()),
        "repro.core.delays.register_delay":
            ("repro.core.delays", "DelayModel", ("compute_time",), ()),
    }
    SOLVER_REGISTRAR = "repro.core.solvers.register_solver"
    SOLVER_MIN_ARGS = 9  # w_eff, alpha, X, y, norms_sq, lam, n, sigma', key
    SOLVER_KWONLY = {"loss", "num_steps"}

    # -- abstract-hook extraction ------------------------------------------

    @staticmethod
    def _is_abstract(method: ast.FunctionDef) -> bool:
        body = [s for s in method.body
                if not (isinstance(s, ast.Expr)
                        and isinstance(s.value, ast.Constant))]
        return (len(body) == 1 and isinstance(body[0], ast.Raise)
                and "NotImplementedError" in ast.dump(body[0]))

    def _abstract_hooks(self, project, base_mod, base_cls, fallback):
        module = project.by_modname.get(base_mod)
        cls = module.classes.get(base_cls) if module else None
        if cls is None:
            return tuple(fallback)
        return tuple(m.name for m in cls.body
                     if isinstance(m, ast.FunctionDef)
                     and self._is_abstract(m))

    # -- class chain walking -----------------------------------------------

    def _defined_hooks(self, project, module, cls: ast.ClassDef,
                       stop_at: str) -> set[str]:
        """Concrete method names along the base chain (project files only)."""
        defined: set[str] = set()
        seen = set()
        stack = [(module, cls)]
        while stack:
            mod, node = stack.pop()
            if (mod.modname, node.name) in seen or node.name == stop_at:
                continue
            seen.add((mod.modname, node.name))
            for m in node.body:
                if isinstance(m, ast.FunctionDef) and not self._is_abstract(m):
                    defined.add(m.name)
            for base in node.bases:
                resolved = self._resolve_class(project, mod, base)
                if resolved is not None:
                    stack.append(resolved)
        return defined

    def _resolve_class(self, project, module, base):
        dotted = _dotted(base)
        if dotted is None:
            return None
        if "." not in dotted:
            if dotted in module.classes:
                return (module, module.classes[dotted])
            target = module.imports.get(dotted)
        else:
            head, _, rest = dotted.partition(".")
            target_mod = module.imports.get(head)
            target = f"{target_mod}.{rest}" if target_mod else None
        if not target:
            return None
        mod_name, _, cls_name = target.rpartition(".")
        other = project.by_modname.get(mod_name)
        if other and cls_name in other.classes:
            return (other, other.classes[cls_name])
        return None

    # -- the check ---------------------------------------------------------

    def check(self, module, project):
        out = []
        for qual, cls in module.classes.items():
            for dec in cls.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                canon = module.canonical(dec.func)
                reg = self.REGISTRIES.get(canon or "")
                if reg is None:
                    continue
                base_mod, base_cls, fallback, extra = reg
                required = self._abstract_hooks(project, base_mod, base_cls,
                                                fallback) + tuple(extra)
                defined = self._defined_hooks(project, module, cls, base_cls)
                missing = sorted(set(required) - defined)
                if missing:
                    out.append(module.finding(
                        self.rule_name, dec.lineno,
                        f"registered entry {qual!r} does not implement "
                        f"required hook(s) {missing} of {base_cls} (see the "
                        f"hook-contract docstring)"))
        out.extend(self._check_solvers(module, project))
        return out

    def _check_solvers(self, module, project):
        out = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            # register_solver("name")(fn) -- the call-registration form.
            if not (isinstance(node.func, ast.Call)
                    and module.canonical(node.func.func)
                    == self.SOLVER_REGISTRAR and node.args):
                continue
            scope = module.enclosing(node.lineno)
            fn = project.resolve_call(module, scope, node.args[0])
            if fn is None:
                continue
            a = fn.node.args
            n_pos = len(a.posonlyargs) + len(a.args)
            kwonly = {p.arg for p in a.kwonlyargs}
            if (n_pos < self.SOLVER_MIN_ARGS
                    or not self.SOLVER_KWONLY <= kwonly):
                out.append(module.finding(
                    self.rule_name, node.lineno,
                    f"solver {fn.qualname!r} does not match the local-solver "
                    f"signature (>= {self.SOLVER_MIN_ARGS} positional args + "
                    f"keyword-only {sorted(self.SOLVER_KWONLY)}; see "
                    f"repro.core.solvers)"))
        return out


@register_rule("typed-errors")
class TypedErrorsRule(Rule):
    """Serve-layer error discipline: no silent broad excepts.

    The serve layer's whole failure contract is TYPED errors delivered
    through streams and the pinned HTTP status table -- a broad
    ``except Exception`` that neither re-raises nor is explicitly marked
    swallows a failure into a hang or an untyped 500 (the PR-9 bugfixes).
    This rule flags every ``except Exception`` / ``except BaseException``
    handler under ``serve/`` whose body contains no ``raise``; handlers that
    deliberately terminate the error path (delivering it to a tenant handle,
    mapping it to a status code, poisoning streams on teardown) carry
    ``# analysis: fail-fast-ok`` with a parenthesized why.
    """

    description = ("flags except Exception/BaseException without a re-raise "
                   "under serve/; convert to a typed error or mark the "
                   "handler '# analysis: fail-fast-ok (why)'")

    BROAD = ("Exception", "BaseException")

    def check(self, module, project):
        if "serve" not in module.relpath:
            return []
        out = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.ExceptHandler) or node.type is None:
                continue
            names = []
            if isinstance(node.type, ast.Tuple):
                names = [_dotted(e) for e in node.type.elts]
            else:
                names = [_dotted(node.type)]
            if not any(n in self.BROAD for n in names if n):
                continue
            if any(isinstance(n, ast.Raise) for n in ast.walk(node)):
                continue
            out.append(module.finding(
                self.rule_name, node.lineno,
                f"broad except {', '.join(n for n in names if n)} swallows "
                f"the error; re-raise a typed serve error "
                f"(repro.serve.recovery) or mark the handler "
                f"'# analysis: fail-fast-ok (why)'"))
        return out


# ---------------------------------------------------------------------------
# Drivers.
# ---------------------------------------------------------------------------


def _iter_py_files(paths) -> list[pathlib.Path]:
    out = []
    for p in paths:
        p = pathlib.Path(p)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            out.append(p)
    return out


def parse_project(paths, *, root: pathlib.Path | None = None) -> ProjectIndex:
    """Parse every ``*.py`` under ``paths`` into a :class:`ProjectIndex`."""
    root = pathlib.Path.cwd() if root is None else pathlib.Path(root)
    modules = []
    for path in _iter_py_files(paths):
        try:
            rel = path.resolve().relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = path.as_posix()
        try:
            modules.append(ModuleInfo(path, path.read_text(), rel))
        except SyntaxError as e:
            raise SyntaxError(f"analysis cannot parse {path}: {e}") from e
    return ProjectIndex(modules)


def lint_project(project: ProjectIndex, *, rules=None) -> list[Finding]:
    """Run ``rules`` (default: every non-example registry entry) over every
    module; pragma-suppressed findings are dropped here."""
    names = default_rules() if rules is None else tuple(rules)
    instances = [get_rule(n)() for n in names]
    out = []
    for module in project.modules:
        for rule in instances:
            for f in rule.check(module, project):
                if not module.suppressed(f.rule, f.line):
                    out.append(f)
    return sort_findings(out)


def lint_paths(paths, *, root=None, rules=None) -> list[Finding]:
    """Parse + lint in one call (the CLI / CI entry)."""
    return lint_project(parse_project(paths, root=root), rules=rules)


def lint_source(source: str, *, path: str = "<snippet>",
                rules=None) -> list[Finding]:
    """Lint one in-memory snippet (the docs/test harness entry)."""
    module = ModuleInfo(pathlib.Path(path), source, path)
    return lint_project(ProjectIndex([module]), rules=rules)
