"""Trace-time contract analyzer: the dispatch invariants, read off the IR.

Layer 2 of ``python -m repro analyze`` (layer 1 is the AST lint,
:mod:`repro.analysis.lint`).  Where the lint reasons about *source*, this
module lowers the repo's key traced entry points with tiny abstract inputs
and asserts the PR-1/4/5 performance contracts from the jaxpr / compiled
HLO alone -- no timing, no runtime counters:

* ``lockstep-scan-fusion`` / ``lag-scan-fusion`` -- the whole-run executors
  (:func:`repro.core.executor.lockstep_run_traced`, ``lag_run_traced``)
  stage as exactly ONE top-level ``lax.scan`` of length R (the PR-4
  one-dispatch-per-run contract; an accidental Python-loop unroll or a
  second scan shows up here before it shows up in wall clock).
* ``lockstep-no-host-callbacks`` / ``lag-no-host-callbacks`` -- no callback
  primitive anywhere in the jaxpr and no callback custom-call in the
  compiled HLO: nothing on the scan path ever re-enters Python.
* ``engine-donation-aliasing`` -- the event engine's donated fused jits
  (``_worker_rounds_fused``, ``_server_apply_fused``, ``_lag_window_append``)
  really alias their donated operands: the lowered module carries the donor
  annotations and the compiled executable reports input-output aliasing
  (donation that silently degrades to a copy doubles HBM per dispatch).
* ``sweep-bucket-cache-sharing`` -- the PR-5 contract that grids of
  different shapes share one compile: two sweeps whose cell counts and eval
  cadences fall in the same pow2 bucket produce *identical* jit cache keys
  (same static arguments, same operand avals) for
  :func:`repro.api.sweep._sweep_scan`, checked without compiling anything.

Everything runs on abstract values (``jax.eval_shape``-sized toy shapes:
K=2 workers, n_k=3, d=4, R=3 rounds), so the whole pass is a few hundred
milliseconds of tracing on CPU.  Each check returns a
:class:`ContractResult`; the CLI fails on any ``ok=False``.
"""

from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# Results.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ContractResult:
    """One trace-time contract verdict."""

    name: str
    ok: bool
    detail: str

    def format(self) -> str:
        mark = "ok" if self.ok else "FAIL"
        return f"contract {self.name}: {mark} -- {self.detail}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# IR inspection helpers.
# ---------------------------------------------------------------------------

# Primitive names that re-enter Python from inside a trace.  Matching is by
# substring on the primitive name so new spellings (pure_callback,
# io_callback, debug_callback, python_callback, outside_call) stay covered.
_CALLBACK_TOKENS = ("callback", "outside_call", "infeed", "outfeed")


def _iter_eqns(jaxpr):
    """All equations of a (closed) jaxpr, recursing into sub-jaxprs."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            for sub in val if isinstance(val, (tuple, list)) else (val,):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    yield from _iter_eqns(sub)


def callback_primitives(jaxpr) -> list[str]:
    """Names of callback-style primitives anywhere in the jaxpr."""
    return sorted({
        e.primitive.name for e in _iter_eqns(jaxpr)
        if any(tok in e.primitive.name for tok in _CALLBACK_TOKENS)})


def top_level_scans(jaxpr) -> list[int]:
    """Lengths of the scans at the TOP level of the jaxpr (not nested)."""
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    return [int(e.params["length"]) for e in jaxpr.eqns
            if e.primitive.name == "scan"]


def hlo_callback_sites(hlo_text: str) -> list[str]:
    """Lines of a compiled HLO dump that call back into Python."""
    return [ln.strip() for ln in hlo_text.splitlines()
            if "custom-call" in ln and "callback" in ln]


def donation_evidence(lowered, compiled) -> tuple[bool, bool]:
    """(lowered module carries donor annotations, compiled executable
    reports input-output aliasing)."""
    ltxt = lowered.as_text()
    donor = ("jax.buffer_donor" in ltxt) or ("tf.aliasing_output" in ltxt)
    try:
        ctxt = compiled.as_text()
    except Exception:  # backend without HLO text dumps
        ctxt = ""
    return donor, "input_output_alias" in ctxt


# ---------------------------------------------------------------------------
# Tiny abstract problem (shared by all checks).
# ---------------------------------------------------------------------------

_K, _NK, _D, _R = 2, 3, 4, 3


def _tiny_lockstep_args():
    import jax
    import jax.numpy as jnp

    key = jax.random.key(0)
    X = jnp.zeros((_K, _NK, _D), jnp.float32)
    y = jnp.ones((_K, _NK), jnp.float32)
    norms_sq = jnp.ones((_K, _NK), jnp.float32)
    return (key, X, y, norms_sq, jnp.float32(0.1), jnp.int32(_K * _NK),
            jnp.float32(float(_K)), jnp.float32(1.0))


def _tiny_lag_args():  # analysis: x64-ok (caller wraps in enable_x64)
    import jax
    import jax.numpy as jnp

    key, X, y, norms_sq, lam, n, sigma_p, gamma = _tiny_lockstep_args()
    return (key, X, y, norms_sq, lam, n, sigma_p, gamma,
            jnp.float32(1.0),                       # xi
            jnp.ones((_R + 1, _K), jnp.float64),    # durations (t=0 + rounds)
            jnp.full((_R,), 1, jnp.int64),          # needs
            jnp.asarray(16, jnp.int64),             # up_bytes
            jnp.asarray(4, jnp.int64),              # heartbeat_bytes
            jnp.asarray(0.001, jnp.float64),        # latency
            jnp.asarray(1e6, jnp.float64),          # bandwidth
            jnp.ones((_K,), jnp.float64))           # link_factors


# ---------------------------------------------------------------------------
# The checks.
# ---------------------------------------------------------------------------


def check_lockstep_contracts() -> list[ContractResult]:
    """``lockstep_run_traced``: one scan of length R, zero host callbacks,
    both in the jaxpr and in the compiled HLO."""
    import jax

    from repro.core import solvers
    from repro.core.executor import lockstep_run_traced

    def entry(*args):
        return lockstep_run_traced(
            *args, loss="smoothed_hinge", num_steps=2,
            solver=solvers.get_solver("sdca"), length=_R)

    args = _tiny_lockstep_args()
    jaxpr = jax.make_jaxpr(entry)(*args)
    out = []

    scans = top_level_scans(jaxpr)
    out.append(ContractResult(
        "lockstep-scan-fusion", scans == [_R],
        f"top-level scans (lengths) = {scans}, want one scan of length "
        f"{_R} (whole run staged as a single scan)"))

    prims = callback_primitives(jaxpr)
    lowered = jax.jit(entry).lower(*args)
    hlo = hlo_callback_sites(lowered.compile().as_text())
    ok = not prims and not hlo
    out.append(ContractResult(
        "lockstep-no-host-callbacks", ok,
        "no callback primitives in the jaxpr and no callback custom-calls "
        "in the compiled HLO" if ok else
        f"callback primitives {prims}, HLO callback sites {hlo}"))
    return out


def check_lag_contracts() -> list[ContractResult]:
    """``lag_run_traced`` under ``enable_x64``: same two contracts (the
    in-graph event queue adds sort/cond/top_k -- none may call home)."""
    import jax
    from jax.experimental import enable_x64

    from repro.core import compress
    from repro.core.executor import lag_run_traced

    def entry(*args):
        return lag_run_traced(
            *args, loss="smoothed_hinge", num_steps=2,
            comp=compress.Dense(rho=1.0), length=_R, lag_window=2,
            dense_reply_bytes=_D * 4)

    out = []
    with enable_x64():
        args = _tiny_lag_args()
        jaxpr = jax.make_jaxpr(entry)(*args)
        scans = top_level_scans(jaxpr)
        # The staged structure is exactly: the t=0 launch wave (a rank scan
        # over the K workers) followed by ONE round scan of length R.
        out.append(ContractResult(
            "lag-scan-fusion", scans == [_K, _R],
            f"top-level scans (lengths) = {scans}, want the K={_K} initial "
            f"launch wave + one round scan of length {_R} (whole run staged "
            f"as a single round scan)"))

        prims = callback_primitives(jaxpr)
        hlo = hlo_callback_sites(jax.jit(entry).lower(*args)
                                 .compile().as_text())
    ok = not prims and not hlo
    out.append(ContractResult(
        "lag-no-host-callbacks", ok,
        "no callback primitives in the jaxpr and no callback custom-calls "
        "in the compiled HLO" if ok else
        f"callback primitives {prims}, HLO callback sites {hlo}"))
    return out


def check_engine_donation() -> list[ContractResult]:
    """The engine's donated fused jits really alias donated buffers."""
    import jax
    import jax.numpy as jnp

    from repro.core import compress, engine

    key, X, y, norms_sq, lam, n, sigma_p, gamma = _tiny_lockstep_args()
    idxs = jnp.zeros((1,), jnp.int32)
    w = jnp.zeros((_D,), jnp.float32)
    alpha = jnp.zeros((_K, _NK), jnp.float32)
    residual = jnp.zeros((_K, _D), jnp.float32)
    w_rows = jnp.zeros((_K, _D), jnp.float32)
    comp = compress.Dense(rho=1.0)

    targets = {
        "_worker_rounds_fused": lambda: engine._worker_rounds_fused.lower(
            key, w, alpha, residual, X, y, norms_sq, idxs, lam, n, sigma_p,
            gamma, loss="smoothed_hinge", num_steps=2, comp=comp),
        "_server_apply_fused": lambda: engine._server_apply_fused.lower(
            w, w_rows, w_rows, alpha, idxs, (w,), (alpha[0],),
            jnp.ones((1,), bool), gamma),
        "_lag_window_append": lambda: engine._lag_window_append.lower(
            jnp.zeros((_K, 2), jnp.float32), jnp.zeros((_K,), jnp.int32),
            idxs, jnp.ones((1,), jnp.float32)),
    }
    out = []
    for name, lower in targets.items():
        lowered = lower()
        donor, aliased = donation_evidence(lowered, lowered.compile())
        out.append(ContractResult(
            f"donation-{name}", donor and aliased,
            f"lowered donor annotation={donor}, compiled "
            f"input_output_alias={aliased} (donated carries must alias, "
            f"not copy)"))
    return out


def check_sweep_bucket_sharing() -> list[ContractResult]:
    """Two grids in the same pow2 bucket produce the SAME jit cache key.

    A ``jax.jit`` cache entry is keyed on (static arguments, operand
    avals).  ``run_sweep`` routes every grid through ``_padded_cells`` /
    ``_padded_eval_idx`` before touching ``_sweep_scan``, so the check
    builds the padded operand avals + static argument tuple for a 3-cell
    grid with 3 eval boundaries and a 4-cell grid with 4 eval boundaries
    (same buckets) and asserts they are identical -- byte-for-byte the
    same cache key, with no compile and no tracing.
    """
    import jax
    import jax.numpy as jnp

    from repro.api.sweep import _padded_cells, _padded_eval_idx

    def cache_key(num_cells, evals):
        cells = _padded_cells(list(range(num_cells)), n_shards=1)
        V = len(cells)
        eval_idx_static = _padded_eval_idx(evals)
        E = len(eval_idx_static)
        avals = tuple(
            jax.ShapeDtypeStruct(s, d) for s, d in (
                ((V,), jax.random.key(0).dtype),     # keys
                ((_K, _NK, _D), jnp.float32),        # X
                ((_K, _NK), jnp.float32),            # y
                ((_K, _NK), jnp.float32),            # norms_sq
                ((), jnp.float32), ((), jnp.int32),  # lam, n
                ((V,), jnp.float32),                 # sigma_ps
                ((V,), jnp.float32),                 # gammas
                ((E,), jnp.int32),                   # eval_idx (gather)
            ))
        statics = ("smoothed_hinge", 2, "sdca", _R, "vmap", 1)
        return (statics, tuple((a.shape, str(a.dtype)) for a in avals))

    key_a = cache_key(3, [0, 1, 2])   # 3 cells, 3 boundaries -> bucket 4, 4
    key_b = cache_key(4, [0, 1, 2, 2])  # 4 cells, 4 boundaries -> same
    ok = key_a == key_b
    return [ContractResult(
        "sweep-bucket-cache-sharing", ok,
        "3-cell/3-eval and 4-cell/4-eval grids pad to identical jit cache "
        "keys (shared compile)" if ok else
        f"cache keys differ: {key_a} vs {key_b}")]


def run_contracts(*, include_lag: bool = True) -> list[ContractResult]:
    """Run every contract check; import failures become failed results
    rather than crashes, so the CLI always reports per-contract."""
    suites = [check_lockstep_contracts, check_engine_donation,
              check_sweep_bucket_sharing]
    if include_lag:
        suites.insert(1, check_lag_contracts)
    out: list[ContractResult] = []
    for suite in suites:
        try:
            out.extend(suite())
        except Exception as e:  # pragma: no cover - environment failure
            out.append(ContractResult(suite.__name__, False,
                                      f"analyzer error: {e!r}"))
    return out
