"""Findings + the checked-in baseline: the analyzer's regression contract.

A :class:`Finding` is one rule violation at one source location.  Findings
are compared against a checked-in *baseline file* (``ANALYSIS_BASELINE.json``
at the repo root) the same way type-checker baselines work: pre-existing
accepted findings are recorded there and do not fail CI, while any finding
NOT in the baseline is a regression and exits nonzero.  Fingerprints are
content-based -- ``rule | path | enclosing-def | stripped source line`` --
so unrelated edits that shift line numbers never invalidate the baseline,
while moving a violating line to a new file or function (or editing it)
re-surfaces it for review.

Shrinking the baseline (fixing an accepted finding) never fails the check;
``stale`` entries are reported so the file can be re-generated with
``python -m repro analyze --update-baseline``.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str  # registry name of the rule that fired
    path: str  # repo-relative posix path of the file
    line: int  # 1-based line number
    message: str  # human explanation, actionable
    context: str = ""  # enclosing def/class qualname ("" at module level)
    snippet: str = ""  # the stripped source line (fingerprint component)

    @property
    def fingerprint(self) -> str:
        """Line-number-free identity used for baseline matching."""
        return "|".join((self.rule, self.path, self.context, self.snippet))

    def format(self) -> str:
        ctx = f" [{self.context}]" if self.context else ""
        return f"{self.path}:{self.line}: {self.rule}: {self.message}{ctx}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def sort_findings(findings) -> list[Finding]:
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


class Baseline:
    """The accepted-findings ledger (see module docstring)."""

    def __init__(self, fingerprints=(), *, path: pathlib.Path | None = None):
        self.fingerprints = set(fingerprints)
        self.path = path

    @classmethod
    def load(cls, path) -> "Baseline":
        path = pathlib.Path(path)
        if not path.exists():
            return cls(path=path)
        doc = json.loads(path.read_text())
        return cls((e["fingerprint"] for e in doc.get("findings", [])),
                   path=path)

    @staticmethod
    def write(path, findings) -> None:
        """Rewrite the baseline to accept exactly ``findings``."""
        findings = sort_findings(findings)
        doc = {
            "_comment": ("Accepted pre-existing findings of `python -m repro "
                         "analyze` (see docs/static-analysis.md). New "
                         "findings not listed here fail CI; regenerate with "
                         "--update-baseline after review."),
            "findings": [{"fingerprint": f.fingerprint, "rule": f.rule,
                          "path": f.path, "message": f.message}
                         for f in findings],
        }
        pathlib.Path(path).write_text(json.dumps(doc, indent=1) + "\n")

    def split(self, findings) -> tuple[list[Finding], list[Finding], set]:
        """Partition into (new, accepted) findings + stale fingerprints."""
        new, accepted, seen = [], [], set()
        for f in sort_findings(findings):
            if f.fingerprint in self.fingerprints:
                accepted.append(f)
                seen.add(f.fingerprint)
            else:
                new.append(f)
        return new, accepted, self.fingerprints - seen
