"""``python -m repro analyze``: run the lint + trace contracts against the
checked-in baseline.

Exit status is the CI contract: 0 when every finding is baseline-accepted
and every trace contract holds; 1 on any NEW finding or failed contract.
Typical loops::

    python -m repro analyze                    # full check, repo default paths
    python -m repro analyze --no-contracts     # AST lint only (fast)
    python -m repro analyze --paths src/repro/core
    python -m repro analyze --update-baseline  # accept current findings
    python -m repro analyze --json             # machine-readable report

The baseline lives at ``ANALYSIS_BASELINE.json`` (see
:mod:`repro.analysis.findings` for the fingerprint contract) and
``docs/static-analysis.md`` documents the rules, the pragmas, and how to
add a rule.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.analysis import contracts as contracts_lib
from repro.analysis import lint as lint_lib
from repro.analysis.findings import Baseline

DEFAULT_PATHS = ("src",)
BASELINE_NAME = "ANALYSIS_BASELINE.json"


def _repo_root() -> pathlib.Path:
    """The repo root: nearest ancestor of this file holding the baseline /
    Makefile, else the cwd (analyze runs from checkouts, not installs)."""
    here = pathlib.Path(__file__).resolve()
    for parent in here.parents:
        if (parent / "Makefile").exists() or (parent / BASELINE_NAME).exists():
            return parent
    return pathlib.Path.cwd()


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro analyze",
        description="project lint + trace-contract analyzer "
                    "(docs/static-analysis.md)")
    ap.add_argument("--paths", nargs="*", default=None,
                    help="files/directories to lint (default: src/)")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline file (default: <repo>/{BASELINE_NAME})")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to accept current findings")
    ap.add_argument("--no-contracts", action="store_true",
                    help="skip the trace-time contract checks (lint only)")
    ap.add_argument("--rules", nargs="*", default=None,
                    help="run only these lint rules")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit a JSON report instead of text")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    root = _repo_root()

    if args.list_rules:
        for name in lint_lib.available_rules():
            print(f"{name}: {lint_lib.get_rule(name).description}")
        return 0

    paths = [root / p for p in (args.paths or DEFAULT_PATHS)]
    findings = lint_lib.lint_paths(paths, root=root, rules=args.rules)

    baseline_path = pathlib.Path(args.baseline or root / BASELINE_NAME)
    if args.update_baseline:
        Baseline.write(baseline_path, findings)
        print(f"baseline updated: {baseline_path} "
              f"({len(findings)} accepted finding(s))")
        return 0

    baseline = Baseline.load(baseline_path)
    new, accepted, stale = baseline.split(findings)

    results = []
    if not args.no_contracts:
        results = contracts_lib.run_contracts()
    failed = [r for r in results if not r.ok]

    if args.as_json:
        print(json.dumps({
            "new": [f.as_dict() for f in new],
            "accepted": [f.as_dict() for f in accepted],
            "stale_fingerprints": sorted(stale),
            "contracts": [r.as_dict() for r in results],
        }, indent=1))
    else:
        for f in new:
            print(f.format())
        for r in results:
            print(r.format())
        summary = (f"{len(new)} new finding(s), {len(accepted)} "
                   f"baseline-accepted, {len(stale)} stale baseline "
                   f"entr(ies)")
        if results:
            summary += (f"; contracts: {len(results) - len(failed)}/"
                        f"{len(results)} ok")
        print(summary)
        if new:
            print("fix the new findings, suppress with a pragma "
                  "(# analysis: host-ok / ignore[rule]) or accept with "
                  "--update-baseline (docs/static-analysis.md)")
        if stale:
            print("stale baseline entries are fixed findings: re-run with "
                  "--update-baseline to shrink the baseline")

    return 1 if (new or failed) else 0


if __name__ == "__main__":
    sys.exit(main())
