"""``python -m repro``: the unified experiment CLI.

Subcommands:

* ``run <spec.json>``  -- execute an :class:`repro.api.ExperimentSpec` file,
  streaming session events (round/sync/eval/stop) to stdout; early stop on
  the spec's ``target_gap`` / ``time_budget``. ``--out`` writes the full
  record trajectories + provenance as JSON.
* ``spec <preset>``    -- print a preset spec (see ``repro.api.presets``) as
  JSON, ready to edit and feed back to ``run``.
* ``bench``            -- the benchmark driver; ``--quick`` and ``--only``
  are forwarded to ``benchmarks/run.py`` so both entry points share one
  driver (run from the repo root with ``PYTHONPATH=src``).
* ``analyze``          -- the static analyzer (AST lint + trace-time
  contract checks, see :mod:`repro.analysis` and docs/static-analysis.md);
  exits nonzero on findings not in ``ANALYSIS_BASELINE.json`` or on a
  failed contract.
* ``serve``            -- the persistent multi-tenant experiment service
  over HTTP (:mod:`repro.serve`, docs/serving.md): POST /submit specs,
  GET /events/<job>, GET /stats; coalesces compatible tenant requests into
  shared compiled sweep batches.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys


def _cmd_run(args) -> int:
    import jax

    from repro import api

    spec = api.ExperimentSpec.load(args.spec)
    if args.target_gap is not None:
        spec = dataclasses.replace(spec, target_gap=args.target_gap)
    if args.time_budget is not None:
        spec = dataclasses.replace(spec, time_budget=args.time_budget)
    if args.checkpoint_every is not None:
        spec = dataclasses.replace(spec, checkpoint_every=args.checkpoint_every)
    if spec.checkpoint_every is not None and args.checkpoint_dir is None:
        print("error: spec sets checkpoint_every; pass --checkpoint-dir for "
              "the snapshots", file=sys.stderr)
        return 2
    print(f"# spec {spec.name!r}: {len(spec.methods)} method(s), "
          f"problem={spec.problem.kind}, K={spec.cluster.num_workers}, "
          f"target_gap={spec.target_gap}, time_budget={spec.time_budget}"
          + (f", checkpoint_every={spec.checkpoint_every}"
             if spec.checkpoint_every is not None else ""))
    exp = api.Experiment(spec, checkpoint_dir=args.checkpoint_dir)
    results = {}
    for entry in spec.methods:
        name = entry.config.name
        print(f"== {name} (protocol={entry.config.protocol}, "
              f"num_outer={entry.num_outer}) ==")
        session = exp.session(entry)
        for ev in session:
            if isinstance(ev, api.EvalEvent):
                print(f"  eval  it={ev.iteration:5d} t={ev.sim_time:9.4f}s "
                      f"gap={ev.gap:.3e} up={ev.bytes_up / 1e6:.2f}MB "
                      f"down={ev.bytes_down / 1e6:.2f}MB")
            elif isinstance(ev, api.SyncEvent):
                if args.verbose:
                    print(f"  sync  it={ev.iteration:5d} t={ev.sim_time:9.4f}s")
            elif isinstance(ev, api.RoundEvent):
                if args.verbose:
                    print(f"  round it={ev.iteration:5d} t={ev.sim_time:9.4f}s "
                          f"arrivals={ev.arrivals}")
            elif isinstance(ev, api.StopEvent):
                print(f"  stop  reason={ev.reason} it={ev.iteration} "
                      f"t={ev.sim_time:.4f}s")
        results[name] = session.result()

    for name, res in results.items():
        last = res.records[-1]
        t = res.time_to_gap(spec.target_gap) if spec.target_gap else None
        extra = (f" time_to_gap({spec.target_gap:g})="
                 f"{t:.4f}s" if t is not None else "")
        print(f"{name:12s} rounds={last.iteration:5d} gap={last.gap:.3e}"
              f" sim_t={last.sim_time:.4f}s{extra}")

    if args.out:
        payload = {
            "spec": spec.to_dict(),
            "provenance": {"jax_version": jax.__version__,
                           "seed": spec.seed},
            "results": {name: res.as_dict() for name, res in results.items()},
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {args.out}")
    return 0


def _cmd_spec(args) -> int:
    from repro import api

    kwargs = {"quick": args.quick} if args.quick else {}
    spec = api.build_preset(args.preset, **kwargs)
    print(spec.to_json())
    return 0


def _cmd_bench(args) -> int:
    try:
        from benchmarks.run import main as bench_main
    except ImportError:
        print("error: the 'benchmarks' package is not importable; run from "
              "the repo root (python -m repro bench) with PYTHONPATH=src",
              file=sys.stderr)
        return 2
    argv = []
    if args.quick:
        argv.append("--quick")
    if args.only:
        argv.extend(["--only", args.only])
    bench_main(argv)
    return 0


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(prog="repro", description=__doc__)
    sub = parser.add_subparsers(dest="cmd", required=True)

    p_run = sub.add_parser("run", help="execute an ExperimentSpec JSON file")
    p_run.add_argument("spec", help="path to a spec JSON "
                       "(see `python -m repro spec <preset>`)")
    p_run.add_argument("--out", default=None,
                       help="write records + provenance JSON here")
    p_run.add_argument("--target-gap", type=float, default=None,
                       help="override the spec's early-stop duality gap")
    p_run.add_argument("--time-budget", type=float, default=None,
                       help="override the spec's simulated-time budget (s)")
    p_run.add_argument("--verbose", action="store_true",
                       help="also stream per-round and sync events")
    p_run.add_argument("--checkpoint-every", type=int, default=None,
                       help="snapshot the run state every N rounds "
                            "(resumable; overrides the spec's "
                            "checkpoint_every)")
    p_run.add_argument("--checkpoint-dir", default=None,
                       help="where checkpoint snapshots live; re-running "
                            "the same spec resumes from the latest one")
    p_run.set_defaults(fn=_cmd_run)

    p_spec = sub.add_parser("spec", help="print a preset spec as JSON")
    from repro.api.presets import PRESETS

    p_spec.add_argument("preset", choices=sorted(PRESETS))
    p_spec.add_argument("--quick", action="store_true",
                        help="smoke-scale variant")
    p_spec.set_defaults(fn=_cmd_spec)

    p_bench = sub.add_parser(
        "bench", help="run the benchmark driver (shared with benchmarks/run.py)")
    p_bench.add_argument("--quick", action="store_true",
                         help="smoke mode: tiny K/num_outer/H per benchmark")
    p_bench.add_argument("--only", default=None,
                         help="substring filter on benchmark module names")
    p_bench.set_defaults(fn=_cmd_bench)

    # `analyze` and `serve` own their flag surfaces; forward the raw
    # remainder so `repro analyze --update-baseline` / `repro serve --port`
    # etc. just work.
    sub.add_parser(
        "analyze", add_help=False,
        help="static analysis: project lint + trace-contract checks "
             "(docs/static-analysis.md)").set_defaults(fn=None)
    sub.add_parser(
        "serve", add_help=False,
        help="multi-tenant experiment service over HTTP "
             "(docs/serving.md)").set_defaults(fn=None)

    if argv is None:
        argv = sys.argv[1:]
    if argv and argv[0] == "analyze":
        from repro.analysis.cli import main as analyze_main

        return analyze_main(argv[1:])
    if argv and argv[0] == "serve":
        from repro.serve.http import main as serve_main

        serve_main(argv[1:])
        return 0

    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
