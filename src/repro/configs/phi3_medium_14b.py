"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352. RoPE + SwiGLU + GQA [arXiv:2404.14219].

Note: kv=10 does not divide the 16-way model axis; KV projections replicate
on the mesh (recorded by param.explain_sharding) while Q/FF/vocab shard.
"""

from repro.models.config import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="phi3-medium-14b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=10,
        head_dim=128,
        d_ff=17920,
        vocab_size=100352,
        rope_theta=10_000.0,
        layout=(LayerSpec(kind="attn", mlp="dense"),),
        param_dtype="bfloat16",
        source="arXiv:2404.14219 (Phi-3 technical report)",
    )
