"""hubert-xlarge [audio]: 48L d_model=1280 16H (MHA kv=16) d_ff=5120
vocab=504 (cluster units), encoder-only, w2v2 architecture [arXiv:2106.07447].

The conv/mel frontend is the allowed stub: batches carry precomputed frame
embeddings at d_model. Bidirectional attention (causal=False); masked-unit
prediction is proxied by CE over all frames. No autoregressive decode exists,
so decode_32k and long_500k are skipped for this arch (DESIGN §5). HuBERT's
convolutional relative positional embedding is replaced by RoPE (adaptation
note: positional scheme is orthogonal to the compute/communication profile
measured here).
"""

from repro.models.config import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="hubert-xlarge",
        family="audio",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        causal=False,
        rope_theta=10_000.0,
        layout=(LayerSpec(kind="attn", mlp="dense"),),
        frontend="audio_stub",
        param_dtype="bfloat16",
        source="arXiv:2106.07447 (HuBERT)",
    )
