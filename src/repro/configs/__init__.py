"""Architecture registry + assigned input shapes.

``get_config(arch_id)`` returns the exact assigned configuration;
``input_specs(cfg, shape, step)`` returns ShapeDtypeStruct stand-ins for every
input of the corresponding step function -- weak-type-correct, shardable, and
never allocated (the 398B configs exist only abstractly on this box).
"""

from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

from repro.models import init_caches
from repro.models.config import ModelConfig

_MODULES = {
    "pixtral-12b": "pixtral_12b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "mamba2-780m": "mamba2_780m",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "hubert-xlarge": "hubert_xlarge",
    "qwen3-14b": "qwen3_14b",
    "phi3-medium-14b": "phi3_medium_14b",
    "gemma3-27b": "gemma3_27b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
}

ARCH_IDS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.get_config()


def shape_supported(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """(supported, reason-if-not). Mirrors DESIGN §5's skip table."""
    if shape.kind == "decode" and not cfg.supports_decode():
        return False, "encoder-only: no autoregressive decode"
    if shape.name == "long_500k" and not cfg.supports_long_decode():
        return False, "pure full-attention stack: no sub-quadratic variant"
    return True, ""


def _token_batch(cfg: ModelConfig, batch: int, seq: int, with_labels: bool) -> dict:
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if cfg.frontend == "text":
        out = {"tokens": sds((batch, seq), i32)}
        if with_labels:
            out["labels"] = sds((batch, seq), i32)
    elif cfg.frontend == "vision_stub":
        p = min(cfg.num_patch_tokens, seq // 2)
        out = {
            "tokens": sds((batch, seq - p), i32),
            "patch_embeds": sds((batch, p, cfg.d_model), cfg.cdtype),
        }
        if with_labels:
            out["labels"] = sds((batch, seq - p), i32)
    elif cfg.frontend == "audio_stub":
        out = {"frame_embeds": sds((batch, seq, cfg.d_model), cfg.cdtype)}
        if with_labels:
            out["labels"] = sds((batch, seq), i32)
    else:
        raise ValueError(cfg.frontend)
    return out


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Abstract inputs for the step function selected by ``shape.kind``."""
    ok, why = shape_supported(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.arch_id} x {shape.name} unsupported: {why}")
    if shape.kind == "train":
        return {"batch": _token_batch(cfg, shape.global_batch, shape.seq_len, True)}
    if shape.kind == "prefill":
        return {"batch": _token_batch(cfg, shape.global_batch, shape.seq_len, False)}
    if shape.kind == "decode":
        B, S = shape.global_batch, shape.seq_len
        caches = jax.eval_shape(
            lambda: init_caches(cfg, B, S, cfg.cdtype))
        return {
            "token": jax.ShapeDtypeStruct((B,), jnp.int32),
            "caches": caches,
            "cache_len": jax.ShapeDtypeStruct((), jnp.int32),
        }
    raise ValueError(shape.kind)
