"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) vocab=151936,
MoE 128 experts top-8, expert d_ff=768 [hf:Qwen/Qwen3-30B-A3B]."""

from repro.models.config import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,  # kept for the assignment table; layers use d_ff_expert
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        layout=(LayerSpec(kind="attn", mlp="moe"),),
        num_experts=128,
        experts_per_token=8,
        d_ff_expert=768,
        norm_topk_probs=True,
        param_dtype="bfloat16",
        source="hf:Qwen/Qwen3-30B-A3B",
    )
