"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (kv=32, i.e. MHA) d_ff=13440
vocab=92416. Qwen1.5 architecture [hf:Qwen/CodeQwen1.5-7B]."""

from repro.models.config import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="codeqwen1.5-7b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        head_dim=128,
        d_ff=13440,
        vocab_size=92416,
        rope_theta=1_000_000.0,
        layout=(LayerSpec(kind="attn", mlp="dense"),),
        param_dtype="bfloat16",
        source="hf:Qwen/CodeQwen1.5-7B",
    )
