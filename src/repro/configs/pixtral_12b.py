"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.

Pixtral ViT frontend + Mistral-Nemo decoder [hf:mistralai/Pixtral-12B-2409].
The ViT + projector are the allowed stub: batches carry precomputed patch
embeddings (1024 per sequence by default) that a learned linear projector maps
into the decoder stream; loss is computed on text positions only.
"""

from repro.models.config import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="pixtral-12b",
        family="vlm",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        rope_theta=1_000_000.0,
        layout=(LayerSpec(kind="attn", mlp="dense"),),
        frontend="vision_stub",
        num_patch_tokens=1024,
        param_dtype="bfloat16",
        source="hf:mistralai/Pixtral-12B-2409",
    )
