"""mamba2-780m [ssm]: 48L d_model=1536 (attention-free) vocab=50280,
SSD state=128, expand=2, head_dim=64 -> 48 SSD heads [arXiv:2405.21060].

No MLP sublayer (Mamba2 blocks are mixer-only). All decode shapes including
long_500k run: state is O(1) in context.
"""

from repro.models.config import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="mamba2-780m",
        family="ssm",
        num_layers=48,
        d_model=1536,
        num_heads=1,  # unused (attention-free); keeps dataclass invariants
        num_kv_heads=1,
        d_ff=0,
        vocab_size=50280,
        layout=(LayerSpec(kind="mamba", mlp="none"),),
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        param_dtype="bfloat16",
        source="arXiv:2405.21060 (Mamba2 / SSD)",
    )
