"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16 experts top-2, Mamba:attention 7:1 [arXiv:2403.19887].

Period of 8 layers (9 periods = 72): attention at position 4, Mamba elsewhere;
MoE on odd positions (every other layer), dense MLP on even -- matching the
paper's 1-attention-in-8 and MoE-every-2 structure. The Mamba mixer uses our
SSD (mamba2-style) block with state 128 / head_dim 64; Jamba-1 ships mamba1
(d_state 16) -- SSD is the TPU-idiomatic choice and is noted as an adaptation
in DESIGN.md. long_500k is RUN: 63/72 layers are O(1)-state SSD and the 9
attention layers sequence-shard their 524k cache.
"""

from repro.models.config import LayerSpec, ModelConfig


def _layout() -> tuple[LayerSpec, ...]:
    out = []
    for pos in range(8):
        kind = "attn" if pos == 4 else "mamba"
        mlp = "moe" if pos % 2 == 1 else "dense"
        out.append(LayerSpec(kind=kind, mlp=mlp, window=None))
    return tuple(out)


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65536,
        rope_theta=10_000.0,
        layout=_layout(),
        num_experts=16,
        experts_per_token=2,
        d_ff_expert=24576,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        param_dtype="bfloat16",
        source="arXiv:2403.19887 (Jamba); 1.5-large dims per assignment",
    )
