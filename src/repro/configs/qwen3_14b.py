"""qwen3-14b [dense]: 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.

qk_norm + GQA per the Qwen3 family [hf:Qwen/Qwen3-8B]; head_dim=128 is
decoupled from d_model/num_heads as in Qwen3 model cards.
"""

from repro.models.config import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen3-14b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=17408,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        layout=(LayerSpec(kind="attn", mlp="dense"),),
        param_dtype="bfloat16",
        source="hf:Qwen/Qwen3-8B (family card; 14B dims per assignment)",
    )
