"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144; 5:1 local(sliding 1024):global interleave, 128k+ context
[hf:google/gemma-3-1b-pt family card].

62 layers = 10 full (5 local + 1 global) periods + a 2-layer remainder stage
(see ModelConfig.stages). Single rope_theta=1e6 is used for both local and
global layers (the released model uses 10k local / 1M global; the split is
orthogonal to everything measured here and is noted as an adaptation).
long_500k is RUN for this arch: local layers keep 1024-slot ring caches and
the 10+1 global layers sequence-shard their 524k cache over the mesh.
"""

from repro.models.config import LayerSpec, ModelConfig

LOCAL = LayerSpec(kind="attn", mlp="dense", window=1024)
GLOBAL = LayerSpec(kind="attn", mlp="dense", window=None)


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="gemma3-27b",
        family="dense",
        num_layers=62,
        d_model=5376,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab_size=262144,
        qk_norm=True,
        rope_theta=1_000_000.0,
        attn_logit_softcap=None,  # gemma3 dropped gemma2's softcap
        layout=(LOCAL, LOCAL, LOCAL, LOCAL, LOCAL, GLOBAL),
        param_dtype="bfloat16",
        source="hf:google/gemma-3-1b-pt (family card; 27B dims per assignment)",
    )
