"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) vocab=151936,
MoE 128 experts top-8, expert d_ff=1536 [hf:Qwen/Qwen3-30B-A3B family card]."""

from repro.models.config import LayerSpec, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen3-moe-235b-a22b",
        family="moe",
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        head_dim=128,
        d_ff=1536,  # kept for the assignment table; layers use d_ff_expert
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1_000_000.0,
        layout=(LayerSpec(kind="attn", mlp="moe"),),
        num_experts=128,
        experts_per_token=8,
        d_ff_expert=1536,
        norm_topk_probs=True,
        param_dtype="bfloat16",
        source="hf:Qwen/Qwen3-30B-A3B (family card; 235B dims per assignment)",
    )
