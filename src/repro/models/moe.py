"""Mixture-of-Experts FFN with capacity-based dispatch (qwen3/jamba style).

Routing: softmax router, top-k experts per token, optional renormalization of
the selected probabilities (qwen3's ``norm_topk_prob``). Dispatch uses the
fixed-capacity scatter/gather scheme: token-slots are ranked per expert via a
cumsum over the one-hot assignment matrix, scattered into an (E, C, D) buffer
(sharded on E over the ``model`` mesh axis), transformed by per-expert SwiGLU
weights as one grouped einsum (MXU-friendly), and gathered back weighted by
router probabilities. Tokens beyond an expert's capacity are dropped --
their combine weight is zero, matching standard TPU MoE practice.

An auxiliary load-balance loss (Switch-style) and router statistics are
returned for the training loop; the ACPD exchange composes with expert
gradients' natural sparsity (see DESIGN §Arch-applicability).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.models.config import ModelConfig
from repro.models.param import ParamSpec, constraint


def moe_spec(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.num_experts
    dt = cfg.pdtype
    return {
        "router": ParamSpec((d, e), jnp.float32, ("embed", None)),
        "gate": ParamSpec((e, d, f), dt, ("experts", "embed", "expert_ff")),
        "up": ParamSpec((e, d, f), dt, ("experts", "embed", "expert_ff")),
        "down": ParamSpec((e, f, d), dt, ("experts", "expert_ff", "embed")),
    }


def capacity(num_tokens: int, cfg: ModelConfig) -> int:  # analysis: host-ok
    # Static Python arithmetic on config values, even when called from a
    # traced layer (num_tokens comes from a shape).
    c = int(num_tokens * cfg.experts_per_token * cfg.moe_capacity_factor
            / cfg.num_experts) + 1
    # Round to a lane multiple so the (E, C, D) buffer tiles cleanly.
    return max(8, -(-c // 8) * 8)


def _num_dispatch_groups(mesh: Mesh | None, n_tokens: int) -> int:
    """Dispatch locality = batch-parallel slices (tokens never cross them).

    The batch axes come from the active sharding profile (e.g. the dp-heavy
    §Perf profile shards batch over every mesh axis)."""
    if mesh is None:
        return 1
    from repro.models.param import get_active_rules

    rules = get_active_rules()
    batch_axes = rules.get("moe_groups", rules.get("batch")) or ()
    if isinstance(batch_axes, str):
        batch_axes = (batch_axes,)
    g = 1
    for a in batch_axes:
        if a in mesh.shape:
            g *= mesh.shape[a]
    while g > 1 and n_tokens % g != 0:
        g //= 2
    return max(g, 1)


def moe(params: dict, x: jax.Array, cfg: ModelConfig,
        mesh: Mesh | None = None) -> tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (out (B, S, D), aux_loss scalar).

    Dispatch is *grouped by data shard*: each of the G data slices routes its
    own N/G tokens into a per-group (E, C_loc) buffer. The (G, E, C_loc, D)
    buffer shards as (data, model, -, -), so per-device it holds only the
    local tokens for the local experts -- a global-capacity buffer at 1M
    tokens x 128 experts would be ~5 GB/device and its rank cumsum would
    serialize across the whole batch (the dry-run caught exactly that).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    N = B * S
    G = _num_dispatch_groups(mesh, N)
    Ng = N // G
    C = capacity(Ng, cfg)
    dt = x.dtype
    xg = x.reshape(G, Ng, D)
    xg = constraint(xg, mesh, "batch", None, None)

    # bf16 x against the f32 router with f32 accumulation: avoids casting the
    # whole (Ng, D) token block to f32 just to get f32 logits.
    router_logits = jnp.einsum("gnd,de->gne", xg,
                               params["router"].astype(dt),
                               preferred_element_type=jnp.float32)  # (G, Ng, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)  # (G, Ng, K)
    if cfg.norm_topk_probs:
        top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch Transformer, eq. 4).
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    assign = jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(assign, axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # Per-group rank of each (token, choice) within its expert's capacity.
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.int32)  # (G, Ng, K, E)
    flat = onehot.reshape(G, Ng * K, E)
    ranks = jnp.cumsum(flat, axis=1) - flat  # exclusive prefix, group-local
    pos = jnp.sum(ranks * flat, axis=-1).reshape(G, Ng, K)
    keep = pos < C
    weight = jnp.where(keep, top_p, 0.0)  # dropped slots contribute nothing

    # Scatter tokens into the (G, E, C, D) dispatch buffer. One scatter per
    # routing choice k (K static, <= 8): this never materializes the
    # (Ng*K, D) token replication -- at 1M tokens x top-8 that repeat was an
    # 8 GiB/device f32 tensor in the backward pass.
    c_idx = jnp.minimum(pos, C - 1)  # (G, Ng, K)
    buf = jnp.zeros((G, E, C, D), dt)

    def scatter_group(b, xs, es, cs, kp):
        return b.at[es, cs].add(xs * kp[:, None].astype(xs.dtype))

    for kk in range(K):
        buf = jax.vmap(scatter_group)(buf, xg, top_e[..., kk], c_idx[..., kk],
                                      keep[..., kk])
    buf = constraint(buf, mesh, "batch", "experts", None, None)

    # Grouped SwiGLU over experts (single einsum each -> MXU-friendly).
    g = jnp.einsum("gecd,edf->gecf", buf, params["gate"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", buf, params["up"].astype(dt))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("gecf,efd->gecd", h, params["down"].astype(dt))
    y = constraint(y, mesh, "batch", "experts", None, None)

    # Gather back with router weights, again one (Ng, D) gather per choice.
    out = jnp.zeros((G, Ng, D), dt)

    def gather_group(ys, es, cs):
        return ys[es, cs]

    for kk in range(K):
        yk = jax.vmap(gather_group)(y, top_e[..., kk], c_idx[..., kk])
        out = out + yk * weight[..., kk, None].astype(dt)
    return out.reshape(B, S, D), aux.astype(jnp.float32)
