"""Model stack: six architecture families on one scanned-stage substrate."""

from repro.models.config import LayerSpec, ModelConfig  # noqa: F401
from repro.models.model import (  # noqa: F401
    decode_step,
    init_caches,
    model_spec,
    prefill,
    train_loss,
)
from repro.models import param  # noqa: F401
