"""Shared neural layers: norms, RoPE, SwiGLU MLP, embeddings, chunked CE."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import ParamSpec


# ---------------------------------------------------------------------------
# RMSNorm.
# ---------------------------------------------------------------------------


def rmsnorm_spec(dim: int, axis: str | None = None) -> dict:
    return {"scale": ParamSpec((dim,), jnp.float32, (axis,), init="ones")}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return y.astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embedding.
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP.
# ---------------------------------------------------------------------------


def mlp_spec(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    dt = cfg.pdtype
    return {
        "gate": ParamSpec((d, f), dt, ("embed", "ff")),
        "up": ParamSpec((d, f), dt, ("embed", "ff")),
        "down": ParamSpec((f, d), dt, ("ff", "embed")),
    }


def mlp(params: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    g = jnp.einsum("...d,df->...f", x, params["gate"].astype(dt))
    u = jnp.einsum("...d,df->...f", x, params["up"].astype(dt))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, params["down"].astype(dt))


# ---------------------------------------------------------------------------
# Embedding + LM head with sequence-chunked cross entropy.
#
# The (B, S, V) logits tensor is never materialized: the loss scans over
# sequence chunks, computing (B, C, V) logits, their logsumexp and the label
# logit per chunk. This is the difference between fitting and OOMing at
# vocab=262k, seq=4k on a 16 GB chip.
# ---------------------------------------------------------------------------


def embedding_spec(cfg: ModelConfig) -> dict:
    return {"table": ParamSpec((cfg.vocab_size, cfg.d_model), cfg.pdtype,
                               ("vocab", "embed"), scale=1.0)}


def embed(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return params["table"].astype(cfg.cdtype)[tokens]


def lm_head_spec(cfg: ModelConfig) -> dict:
    return {"out": ParamSpec((cfg.d_model, cfg.vocab_size), cfg.pdtype,
                             ("embed", "vocab"))}


def logits(params: dict, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    return jnp.einsum("...d,dv->...v", h, params["out"].astype(h.dtype)).astype(jnp.float32)


def chunked_cross_entropy(params: dict, h: jax.Array, labels: jax.Array,
                          cfg: ModelConfig, chunk: int = 512) -> jax.Array:
    """Mean NLL over (B, S) without materializing (B, S, V) logits."""
    B, S, D = h.shape
    chunk = min(chunk, S)
    n_chunks = S // chunk
    rem = S - n_chunks * chunk
    w = params["out"]

    def chunk_nll(hc: jax.Array, lc: jax.Array) -> jax.Array:
        lg = jnp.einsum("bcd,dv->bcv", hc, w.astype(hc.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        picked = jnp.take_along_axis(lg, lc[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - picked)

    if n_chunks > 0:
        hs = h[:, : n_chunks * chunk].reshape(B, n_chunks, chunk, D).swapaxes(0, 1)
        ls = labels[:, : n_chunks * chunk].reshape(B, n_chunks, chunk).swapaxes(0, 1)

        def body(tot, xs):
            hc, lc = xs
            return tot + chunk_nll(hc, lc), None

        # Remat per chunk: otherwise autodiff saves each (B, chunk, V) logits
        # block across the scan, resurrecting the full logits tensor.
        body = jax.checkpoint(body)
        total, _ = jax.lax.scan(body, jnp.float32(0.0), (hs, ls))
    else:
        total = jnp.float32(0.0)
    if rem:
        total = total + chunk_nll(h[:, n_chunks * chunk:], labels[:, n_chunks * chunk:])
    return total / (B * S)
