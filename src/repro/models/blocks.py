"""Transformer/Mamba blocks and the scanned multi-stage stack.

A *block* is one layer: pre-norm attention or SSD mixer, plus an optional
pre-norm dense-MLP or MoE sublayer (per its :class:`LayerSpec`). A *stage*
scans a stack of identical periods (see ModelConfig.stages); heterogeneous
patterns (jamba 7:1, gemma3 5:1) put the whole period inside the scan body so
the compiled HLO is O(period), not O(num_layers).

KV caches: full-attention layers keep a (B, S_max, KV, hd) buffer (sequence-
shardable); sliding-window layers keep a ring buffer of exactly ``window``
slots -- at 524k context this is the difference between 21 GB and 40 MB per
gemma3 local layer. SSD layers carry (conv, state) tuples. Caches thread
through the scan as stacked xs/ys.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import mlp, mlp_spec, rmsnorm, rmsnorm_spec
from repro.models.param import ParamSpec, constraint, stack_specs


class AttnCache(NamedTuple):
    """KV buffer. Ring-ness is static, derived from shapes: the buffer is a
    ring iff the layer has a window and S_buf == window (see _attn_decode)."""

    k: jax.Array  # (B, S_buf, KV, hd)
    v: jax.Array


# ---------------------------------------------------------------------------
# Single block.
# ---------------------------------------------------------------------------


def block_spec(cfg: ModelConfig, layer: LayerSpec) -> dict:
    spec: dict[str, Any] = {"norm1": rmsnorm_spec(cfg.d_model, "embed")}
    if layer.kind == "attn":
        spec["attn"] = attn_lib.attention_spec(cfg)
    else:
        spec["ssm"] = ssm_lib.ssm_spec(cfg)
    if layer.mlp == "dense":
        spec["norm2"] = rmsnorm_spec(cfg.d_model, "embed")
        spec["mlp"] = mlp_spec(cfg)
    elif layer.mlp == "moe":
        spec["norm2"] = rmsnorm_spec(cfg.d_model, "embed")
        spec["moe"] = moe_lib.moe_spec(cfg)
    return spec


def block_apply(
    params: dict,
    layer: LayerSpec,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    mesh: Mesh | None,
    cache: Any = None,
    cache_len: jax.Array | None = None,
    exploit_window: bool = True,
    prefill: bool = False,
) -> tuple[jax.Array, Any, jax.Array]:
    """Returns (x, new_cache, aux_loss). ``prefill=True`` returns raw caches
    (full-sequence (k, v) / SsmCache) for the caller to assemble."""
    aux = jnp.float32(0.0)
    h = rmsnorm(params["norm1"], x, cfg.rmsnorm_eps)

    if layer.kind == "attn":
        if cache is None:
            out, new_cache = attn_lib.attention(
                params["attn"], h, cfg, positions=positions, window=layer.window,
                mesh=mesh, exploit_window=exploit_window, return_kv=prefill)
        else:
            out, new_cache = _attn_decode(params["attn"], h, cfg, layer, cache,
                                          cache_len, positions, mesh)
    else:
        if cache is None:
            if prefill:
                out, new_cache = ssm_lib.ssm_forward(params["ssm"], h, cfg, mesh,
                                                     return_cache=True)
            else:
                out, new_cache = ssm_lib.ssm_forward(params["ssm"], h, cfg, mesh), None
        else:
            out, new_cache = ssm_lib.ssm_decode_step(params["ssm"], h, cache, cfg, mesh)
    x = x + out

    if layer.mlp == "dense":
        h2 = rmsnorm(params["norm2"], x, cfg.rmsnorm_eps)
        x = x + mlp(params["mlp"], h2)
    elif layer.mlp == "moe":
        h2 = rmsnorm(params["norm2"], x, cfg.rmsnorm_eps)
        out2, aux = moe_lib.moe(params["moe"], h2, cfg, mesh)
        x = x + out2
    return x, new_cache, aux


def _attn_decode(params, h, cfg, layer: LayerSpec, cache: AttnCache,
                 cache_len, positions, mesh):
    """One-token decode with either a linear or a ring KV buffer."""
    B, S, D = h.shape
    hd = cfg.resolved_head_dim
    KV, H = cfg.num_kv_heads, cfg.num_heads
    G = H // KV
    q, k, v = attn_lib._project_qkv(params, h, cfg, positions, mesh)
    S_buf = cache.k.shape[1]
    pos = cache_len - 1
    ring = layer.window is not None and S_buf == layer.window

    if ring:
        slot = jnp.mod(pos, S_buf)
        k_buf = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
        v_buf = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)
        valid = jnp.minimum(cache_len, S_buf)
        out = attn_lib.attend_cache(q, k_buf, v_buf, cfg, cache_len=valid,
                                    window=None)  # the ring IS the window
    else:
        k_buf = jax.lax.dynamic_update_slice_in_dim(cache.k, k, pos, axis=1)
        v_buf = jax.lax.dynamic_update_slice_in_dim(cache.v, v, pos, axis=1)
        out = attn_lib.attend_cache(q, k_buf, v_buf, cfg, cache_len=cache_len,
                                    window=layer.window)

    out = out.reshape(B, S, H * hd)
    out = jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(out.dtype))
    return out, AttnCache(k_buf, v_buf)


def init_layer_cache(cfg: ModelConfig, layer: LayerSpec, batch: int,
                     max_seq: int, dtype) -> Any:
    if layer.kind == "mamba":
        return ssm_lib.ssm_init_cache(cfg, batch, dtype)
    hd, KV = cfg.resolved_head_dim, cfg.num_kv_heads
    if layer.window is not None and layer.window < max_seq:
        s_buf = layer.window  # ring buffer: 524k context -> `window` slots
    else:
        s_buf = max_seq
    z = jnp.zeros((batch, s_buf, KV, hd), dtype)
    return AttnCache(z, z)


# ---------------------------------------------------------------------------
# Scanned stage stack.
# ---------------------------------------------------------------------------


def stage_spec(cfg: ModelConfig, layout: tuple[LayerSpec, ...], periods: int) -> dict:
    period = {f"pos{i}": block_spec(cfg, l) for i, l in enumerate(layout)}
    return stack_specs(period, periods)


def stage_apply(
    params: dict,
    layout: tuple[LayerSpec, ...],
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    mesh: Mesh | None,
    caches: Any = None,  # stacked over periods, or None
    cache_len: jax.Array | None = None,
    remat: bool = False,
    exploit_window: bool = True,
    prefill: bool = False,
    seq_shard: bool = False,
) -> tuple[jax.Array, Any, jax.Array]:
    """Scan the stage over its periods. Returns (x, new_caches, aux_sum).

    ``seq_shard=True`` pins the residual stream (and hence every scan-carry
    activation checkpoint) to batch x sequence sharding -- Megatron-style
    sequence parallelism. This is what makes 4k x 256 training checkpoints fit
    HBM: the per-layer saved (B_loc, S, D) buffer shrinks by the model-axis
    size, at the price of gather/scatter traffic around attention.
    """
    collect = prefill or caches is not None

    def period_body(carry, scanned):
        x, aux = carry
        if seq_shard:
            x = constraint(x, mesh, "batch", "seq", None)
        p_params, p_caches = scanned
        new_caches = {}
        for i, layer in enumerate(layout):
            c = None if p_caches is None else p_caches.get(f"pos{i}")
            x, nc, a = block_apply(
                p_params[f"pos{i}"], layer, x, cfg, positions=positions,
                mesh=mesh, cache=c, cache_len=cache_len,
                exploit_window=exploit_window, prefill=prefill)
            new_caches[f"pos{i}"] = nc
            aux = aux + a
        if seq_shard:
            x = constraint(x, mesh, "batch", "seq", None)
        return (x, aux), (new_caches if collect else None)

    body = jax.checkpoint(period_body) if remat else period_body
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.float32(0.0)), (params, caches))
    return x, new_caches, aux


def init_stage_caches(cfg: ModelConfig, layout: tuple[LayerSpec, ...],
                      periods: int, batch: int, max_seq: int, dtype) -> Any:
    def one_period():
        return {f"pos{i}": init_layer_cache(cfg, l, batch, max_seq, dtype)
                for i, l in enumerate(layout)}
    proto = one_period()
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (periods, *a.shape)).copy()
        if isinstance(a, jnp.ndarray) else a, proto)
