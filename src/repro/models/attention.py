"""GQA attention: blocked (flash-style) training/prefill path + decode path.

Memory discipline is the whole point here:

* ``attend_blocked`` never materializes the (B, H, S, S) score matrix. It
  scans over query blocks; per query block it runs an online-softmax scan
  over key/value blocks, so the live intermediate is (B, KV, G, bq, bk).
* sliding-window layers (gemma3) slice only the statically-sized
  ``window + bq`` key range per query block instead of the whole sequence --
  O(S * W) FLOPs instead of O(S^2). Controlled by ``exploit_window`` so the
  naive variant remains available as the §Perf baseline.
* the decode path attends one query over a (B, S_cache, KV, hd) cache with a
  length mask; the cache may be sequence-sharded across the mesh (the scores
  reduction then lowers to a psum, which is exactly what we want at 524k).

Everything is differentiable (training uses the same blocked path), which is
why causal skipping is done by masking rather than dynamic trip counts --
see DESIGN §Perf for the measured cost of that choice and the optimization
that recovers it.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.models.config import ModelConfig
from repro.models.flash import FlashSpec, flash_attention
from repro.models.layers import rmsnorm, rope
from repro.models.param import ParamSpec, constraint

_NEG_INF = -1e30


def attention_spec(cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    dt = cfg.pdtype
    spec = {
        "wq": ParamSpec((d, H * hd), dt, ("embed", "heads")),
        "wk": ParamSpec((d, KV * hd), dt, ("embed", "kv_heads")),
        "wv": ParamSpec((d, KV * hd), dt, ("embed", "kv_heads")),
        "wo": ParamSpec((H * hd, d), dt, ("heads", "embed")),
    }
    if cfg.qk_norm:
        spec["q_norm"] = {"scale": ParamSpec((hd,), jnp.float32, (None,), init="ones")}
        spec["k_norm"] = {"scale": ParamSpec((hd,), jnp.float32, (None,), init="ones")}
    return spec


def _project_qkv(params: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
                 mesh: Mesh | None):
    """x (B,S,D) -> q (B,S,KV,G,hd), k,v (B,S,KV,hd), RoPE'd + normed."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    G = H // KV
    dt = x.dtype

    q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(dt)).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(dt)).reshape(B, S, KV, hd)
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(dt)).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.rmsnorm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.rmsnorm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = constraint(q, mesh, "batch", None, "heads", None)
    k = constraint(k, mesh, "batch", None, "kv_heads", None)
    v = constraint(v, mesh, "batch", None, "kv_heads", None)
    q = q.reshape(B, S, KV, G, hd) * (hd**-0.5)
    return q, k, v


def _softcap(scores: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


class _Online(NamedTuple):
    m: jax.Array  # running max        (B, KV, G, bq)
    l: jax.Array  # running denominator (B, KV, G, bq)
    acc: jax.Array  # running numerator (B, KV, G, bq, hd)


def _online_step(state: _Online, scores: jax.Array, v_blk: jax.Array) -> _Online:
    """One online-softmax update. scores (B,KV,G,bq,bk), v_blk (B,KV,bk,hd)."""
    m_new = jnp.maximum(state.m, jnp.max(scores, axis=-1))
    correction = jnp.exp(state.m - m_new)
    p = jnp.exp(scores - m_new[..., None])  # (B,KV,G,bq,bk)
    l_new = state.l * correction + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bkgqc,bkch->bkgqh", p, v_blk)
    acc_new = state.acc * correction[..., None] + pv
    return _Online(m_new, l_new, acc_new)


def attend_blocked(
    q: jax.Array,  # (B, S, KV, G, hd) pre-scaled
    k: jax.Array,  # (B, S, KV, hd)
    v: jax.Array,  # (B, S, KV, hd)
    cfg: ModelConfig,
    *,
    causal: bool,
    window: int | None,
    block_q: int = 512,
    block_k: int = 512,
    exploit_window: bool = True,
) -> jax.Array:
    """Flash-style blocked attention; returns (B, S, KV, G, hd)."""
    B, S, KV, G, hd = q.shape
    bq = min(block_q, S)
    bk = min(block_k, S)
    nq = -(-S // bq)
    Sq = nq * bq
    if Sq != S:
        q = jnp.pad(q, ((0, 0), (0, Sq - S), (0, 0), (0, 0), (0, 0)))

    use_window = window is not None and exploit_window and window < S
    if use_window:
        # Each query block needs keys in [blk_start - window, blk_start + bq).
        wpad = -(-int(window) // bk) * bk  # analysis: host-ok (static config)
        Lw = wpad + bq
        k_src = jnp.pad(k, ((0, 0), (wpad, 0), (0, 0), (0, 0)))
        v_src = jnp.pad(v, ((0, 0), (wpad, 0), (0, 0), (0, 0)))
        kpos_base = jnp.arange(Lw) - wpad  # relative to block start
        nk = Lw // bk
    else:
        nk = -(-S // bk)
        Sk = nk * bk
        k_src = jnp.pad(k, ((0, 0), (0, Sk - S), (0, 0), (0, 0)))
        v_src = jnp.pad(v, ((0, 0), (0, Sk - S), (0, 0), (0, 0)))
        kpos_all = jnp.arange(Sk)

    q_blocks = q.reshape(B, nq, bq, KV, G, hd).swapaxes(0, 1)  # (nq, B, bq, KV, G, hd)

    def q_block_body(_, blk):
        qi, qb = blk  # qi scalar, qb (B, bq, KV, G, hd)
        qb = qb.transpose(0, 2, 3, 1, 4)  # (B, KV, G, bq, hd)
        qpos = qi * bq + jnp.arange(bq)

        if use_window:
            start = qi * bq  # k_src is front-padded by wpad, so this is qpos0-wpad
            kw = jax.lax.dynamic_slice_in_dim(k_src, start, Lw, axis=1)
            vw = jax.lax.dynamic_slice_in_dim(v_src, start, Lw, axis=1)
            kpos = qi * bq + kpos_base  # absolute positions of the slice
            kb_all = kw.reshape(B, nk, bk, KV, hd).swapaxes(0, 1)
            vb_all = vw.reshape(B, nk, bk, KV, hd).swapaxes(0, 1)
            kpos_blocks = kpos.reshape(nk, bk)
        else:
            kb_all = k_src.reshape(B, nk, bk, KV, hd).swapaxes(0, 1)
            vb_all = v_src.reshape(B, nk, bk, KV, hd).swapaxes(0, 1)
            kpos_blocks = kpos_all.reshape(nk, bk)

        def kv_body(state, kv):
            kb, vb, kpos_b = kv  # (B, bk, KV, hd), (B, bk, KV, hd), (bk,)
            kb = kb.transpose(0, 2, 1, 3)  # (B, KV, bk, hd)
            vb = vb.transpose(0, 2, 1, 3)
            scores = jnp.einsum("bkgqh,bkch->bkgqc", qb, kb).astype(jnp.float32)
            scores = _softcap(scores, cfg.attn_logit_softcap)
            mask = (kpos_b[None, :] >= 0) & (kpos_b[None, :] < S)
            if causal:
                mask &= kpos_b[None, :] <= qpos[:, None]
            if window is not None:
                mask &= qpos[:, None] - kpos_b[None, :] < window
            scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
            return _online_step(state, scores, vb), None

        init = _Online(
            m=jnp.full((B, KV, G, bq), _NEG_INF, jnp.float32),
            l=jnp.zeros((B, KV, G, bq), jnp.float32),
            acc=jnp.zeros((B, KV, G, bq, hd), jnp.float32),
        )
        state, _ = jax.lax.scan(kv_body, init, (kb_all, vb_all, kpos_blocks))
        out = state.acc / jnp.maximum(state.l, 1e-30)[..., None]
        return None, out.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B,bq,KV,G,hd)

    # Remat each query block: without this, autodiff saves every (bq, bk)
    # probability tile of the online-softmax scan -- the full S^2 score matrix
    # -- which is exactly what blocked attention exists to avoid. Rematting
    # recomputes the kv scan in the backward pass (one extra attention
    # forward, the same trade real flash kernels make).
    q_block_body = jax.checkpoint(q_block_body)
    _, outs = jax.lax.scan(q_block_body, None, (jnp.arange(nq), q_blocks))
    out = outs.swapaxes(0, 1).reshape(B, Sq, KV, G, hd)
    return out[:, :S]


def attend_cache(
    q: jax.Array,  # (B, 1, KV, G, hd) pre-scaled
    k_cache: jax.Array,  # (B, S_max, KV, hd) -- may be sequence-sharded
    v_cache: jax.Array,
    cfg: ModelConfig,
    *,
    cache_len: jax.Array,  # scalar: number of valid cache entries (incl. new)
    window: int | None,
) -> jax.Array:
    """Single-token decode attention over the KV cache."""
    B, S_max, KV, hd = k_cache.shape
    kpos = jnp.arange(S_max)
    mask = kpos < cache_len
    if window is not None:
        mask &= kpos >= cache_len - window
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k_cache).astype(jnp.float32)
    scores = _softcap(scores, cfg.attn_logit_softcap)
    scores = jnp.where(mask[None, None, None, None], scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(q.dtype), v_cache)
    return out.transpose(0, 3, 1, 2, 4)  # (B, 1, KV, G, hd)


def attention(
    params: dict,
    x: jax.Array,  # (B, S, D)
    cfg: ModelConfig,
    *,
    positions: jax.Array,  # (B, S) or (S,)
    window: int | None,
    mesh: Mesh | None = None,
    cache: tuple[jax.Array, jax.Array] | None = None,  # decode: (k_cache, v_cache)
    cache_len: jax.Array | None = None,
    exploit_window: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    return_kv: bool = False,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array] | None]:
    """Full attention layer. Returns (out (B,S,D), updated cache or None).

    ``return_kv=True`` (prefill) returns the raw projected (k, v) for the
    whole sequence so the caller can assemble KV cache buffers."""
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    G = H // KV
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None, :], (B, S))

    q, k, v = _project_qkv(params, x, cfg, positions, mesh)

    new_cache = None
    if cache is None:
        spec = FlashSpec(causal=cfg.causal,
                         window=window,
                         block_q=block_q, block_k=block_k,
                         softcap=cfg.attn_logit_softcap)
        if exploit_window or window is None or window >= S:
            out = flash_attention(q, k, v, spec)
        else:
            # §Perf baseline: ignore the window structurally, mask only.
            out = attend_blocked(q, k, v, cfg, causal=cfg.causal, window=window,
                                 block_q=block_q, block_k=block_k,
                                 exploit_window=False)
        if return_kv:
            new_cache = (k, v)
    else:
        assert S == 1 and cache_len is not None
        k_cache, v_cache = cache
        pos = cache_len - 1  # write slot for the new token
        if k_cache.shape[1] > 0:
            k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, pos, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, pos, axis=1)
            out = attend_cache(q, k_cache, v_cache, cfg, cache_len=cache_len,
                               window=window)
        else:  # degenerate: no cache capacity (unused)
            out = attend_cache(q, k, v, cfg, cache_len=jnp.int32(1), window=window)
        new_cache = (k_cache, v_cache)

    out = out.reshape(B, S, H * hd)
    out = jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(out.dtype))
    return out, new_cache
