"""Top-level models: causal LM, encoder, VLM/audio wrappers.

Public surface (all pure functions of (params, batch)):

* ``model_spec(cfg)``         -- parameter plan (ParamSpec pytree)
* ``train_loss(params, ...)`` -- scalar loss (chunked CE + MoE aux)
* ``prefill(params, ...)``    -- forward + assembled decode caches
* ``decode_step(params, ...)``-- one-token serve step against caches
* ``init_caches(cfg, ...)``   -- empty cache pytree for a given context size

Modality frontends (DESIGN: the one allowed stub): VLM batches carry
precomputed ``patch_embeds`` and audio batches ``frame_embeds``; a learned
linear projector stands in for the ViT/conv encoder output interface.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.models import blocks
from repro.models.blocks import AttnCache
from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import (chunked_cross_entropy, embed, embedding_spec,
                                 lm_head_spec, logits, rmsnorm, rmsnorm_spec)
from repro.models.param import ParamSpec, constraint
from repro.models.ssm import SsmCache


def model_spec(cfg: ModelConfig) -> dict:
    spec: dict[str, Any] = {}
    if cfg.frontend == "text":
        spec["embed"] = embedding_spec(cfg)
    elif cfg.frontend == "vision_stub":
        spec["embed"] = embedding_spec(cfg)  # text side of the VLM
        spec["projector"] = {
            "w": ParamSpec((cfg.d_model, cfg.d_model), cfg.pdtype, ("embed", None)),
        }
    elif cfg.frontend == "audio_stub":
        spec["projector"] = {
            "w": ParamSpec((cfg.d_model, cfg.d_model), cfg.pdtype, ("embed", None)),
        }
    for si, (layout, periods) in enumerate(cfg.stages()):
        spec[f"stage{si}"] = blocks.stage_spec(cfg, layout, periods)
    spec["final_norm"] = rmsnorm_spec(cfg.d_model, "embed")
    spec["lm_head"] = lm_head_spec(cfg)
    return spec


# ---------------------------------------------------------------------------
# Input embedding per modality.
# ---------------------------------------------------------------------------


def _input_embeds(params: dict, batch: dict, cfg: ModelConfig,
                  mesh: Mesh | None) -> jax.Array:
    if cfg.frontend == "text":
        x = embed(params["embed"], batch["tokens"], cfg)
    elif cfg.frontend == "vision_stub":
        text = embed(params["embed"], batch["tokens"], cfg)
        patches = batch["patch_embeds"].astype(cfg.cdtype)
        patches = jnp.einsum("bpd,de->bpe", patches,
                             params["projector"]["w"].astype(cfg.cdtype))
        x = jnp.concatenate([patches, text], axis=1)  # image tokens first
    elif cfg.frontend == "audio_stub":
        frames = batch["frame_embeds"].astype(cfg.cdtype)
        x = jnp.einsum("bpd,de->bpe", frames,
                       params["projector"]["w"].astype(cfg.cdtype))
    else:
        raise ValueError(cfg.frontend)
    return constraint(x, mesh, "batch", None, None)


def _forward_hidden(params, x, cfg, *, positions, mesh, caches=None,
                    cache_len=None, remat=False, exploit_window=True,
                    prefill=False, seq_shard=False):
    aux_total = jnp.float32(0.0)
    new_caches = []
    for si, (layout, periods) in enumerate(cfg.stages()):
        c = None if caches is None else caches[si]
        x, nc, aux = blocks.stage_apply(
            params[f"stage{si}"], layout, x, cfg, positions=positions, mesh=mesh,
            caches=c, cache_len=cache_len, remat=remat,
            exploit_window=exploit_window, prefill=prefill, seq_shard=seq_shard)
        new_caches.append(nc)
        aux_total = aux_total + aux
    x = rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps)
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# Training.
# ---------------------------------------------------------------------------


def train_loss(params: dict, batch: dict, cfg: ModelConfig, *,
               mesh: Mesh | None = None, remat: bool = True,
               exploit_window: bool = True, seq_shard: bool = False,
               aux_weight: float = 0.01) -> jax.Array:
    x = _input_embeds(params, batch, cfg, mesh)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :].astype(jnp.int32)
    h, _, aux = _forward_hidden(params, x, cfg, positions=positions, mesh=mesh,
                                remat=remat, exploit_window=exploit_window,
                                seq_shard=seq_shard)
    if cfg.frontend == "vision_stub":
        # Loss only on the text positions (after the patch prefix).
        P = batch["patch_embeds"].shape[1]
        h = h[:, P:]
    labels = batch["labels"]
    nll = chunked_cross_entropy(params["lm_head"], h, labels, cfg)
    return nll + aux_weight * aux


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode.
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> list:
    return [blocks.init_stage_caches(cfg, layout, periods, batch, max_seq, dtype)
            for layout, periods in cfg.stages()]


def _assemble_attn_cache(raw_kv, layer: LayerSpec, S: int, max_seq: int) -> AttnCache:
    """Stacked raw (k, v) (periods, B, S, KV, hd) -> decode buffers."""
    k, v = raw_kv
    window = layer.window
    if window is not None and window < max_seq:
        # Ring buffer: absolute position p lives in slot p % window.
        W = window
        take = min(S, W)
        kw, vw = k[..., S - take:S, :, :], v[..., S - take:S, :, :]
        slots = (jnp.arange(take) + (S - take)) % W
        shape = (*k.shape[:2], W, *k.shape[3:])
        k_buf = jnp.zeros(shape, k.dtype).at[..., slots, :, :].set(kw)
        v_buf = jnp.zeros(shape, v.dtype).at[..., slots, :, :].set(vw)
        return AttnCache(k_buf, v_buf)
    pad = max_seq - S
    k_buf = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v_buf = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return AttnCache(k_buf, v_buf)


def prefill(params: dict, batch: dict, cfg: ModelConfig, *,
            max_seq: int, mesh: Mesh | None = None,
            exploit_window: bool = True):
    """Run the prompt, return (last-position logits, caches, prompt_len)."""
    x = _input_embeds(params, batch, cfg, mesh)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :].astype(jnp.int32)
    h, raw_caches, _ = _forward_hidden(
        params, x, cfg, positions=positions, mesh=mesh, prefill=True,
        exploit_window=exploit_window)

    caches = []
    for (layout, periods), stage_raw in zip(cfg.stages(), raw_caches):
        stage_caches = {}
        for i, layer in enumerate(layout):
            raw = stage_raw[f"pos{i}"]
            if layer.kind == "attn":
                stage_caches[f"pos{i}"] = _assemble_attn_cache(raw, layer, S, max_seq)
            else:
                stage_caches[f"pos{i}"] = raw  # SsmCache already in decode form
        caches.append(stage_caches)

    last = logits(params["lm_head"], h[:, -1:], cfg)[:, 0]
    return last, caches, S


def decode_step(params: dict, token: jax.Array, caches: list,
                cache_len: jax.Array, cfg: ModelConfig, *,
                mesh: Mesh | None = None):
    """One serve step: token (B,) int32, cache_len = prompt+generated count
    (including this token). Returns (logits (B, V), new caches)."""
    if cfg.frontend == "audio_stub":
        raise ValueError("encoder-only model has no decode step")
    x = embed(params["embed"], token[:, None], cfg)
    x = constraint(x, mesh, "batch", None, None)
    positions = (cache_len - 1) * jnp.ones((x.shape[0], 1), jnp.int32)
    h, new_caches, _ = _forward_hidden(params, x, cfg, positions=positions,
                                       mesh=mesh, caches=caches,
                                       cache_len=cache_len)
    return logits(params["lm_head"], h[:, -1:], cfg)[:, 0], new_caches
