"""Mamba2 (SSD, state-space duality) layer: chunked prefill + O(1) decode.

Follows arXiv:2405.21060: per head h with scalar decay ``a_t = exp(dt_t * A_h)``
and state ``h_t = a_t h_{t-1} + (dt_t x_t) B_t^T`` (state is head_dim x N),
output ``y_t = C_t h_t + D_h x_t``, gated ``RMSNorm(y * silu(z))``, out-proj.

Training/prefill uses the *chunked* SSD form: within a chunk of length Q the
quadratic "attention" view computes intra-chunk terms,

    scores[t, s] = (C_t . B_s) * exp(L_t - L_s) * dt_s,   s <= t,
    L_t = cumsum(log a)_t  (inclusive),

and a lax.scan over chunks carries the (B, H, P, N) inter-chunk state -- so
the compiled cost is O(S Q) + O(S N P / Q), never O(S^2). Decode is the plain
one-step recurrence on (conv_state, ssm_state).

TPU adaptation notes: the chunk length is the MXU tiling knob (default 256,
lane-aligned); the scan keeps HLO size O(1) in sequence length; B/C share one
group (ngroups=1) as in the released mamba2 configs.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm
from repro.models.param import ParamSpec, constraint


class SsmCache(NamedTuple):
    conv: jax.Array  # (B, W-1, conv_channels) rolling conv input window
    state: jax.Array  # (B, H, P, N) SSD state


def _conv_channels(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def ssm_spec(cfg: ModelConfig) -> dict:
    D, DI, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    W, CC = cfg.ssm_conv_width, _conv_channels(cfg)
    dt = cfg.pdtype
    return {
        "wz": ParamSpec((D, DI), dt, ("embed", "ssm_inner")),
        "wx": ParamSpec((D, DI), dt, ("embed", "ssm_inner")),
        "wB": ParamSpec((D, N), dt, ("embed", None)),
        "wC": ParamSpec((D, N), dt, ("embed", None)),
        "wdt": ParamSpec((D, H), dt, ("embed", "ssm_heads")),
        "dt_bias": ParamSpec((H,), jnp.float32, ("ssm_heads",), init="zeros"),
        "A_log": ParamSpec((H,), jnp.float32, ("ssm_heads",), init="zeros"),
        "D_skip": ParamSpec((H,), jnp.float32, ("ssm_heads",), init="ones"),
        "conv_w": ParamSpec((W, CC), jnp.float32, (None, None), scale=0.5),
        "conv_b": ParamSpec((CC,), jnp.float32, (None,), init="zeros"),
        "norm": {"scale": ParamSpec((DI,), jnp.float32, ("ssm_inner",), init="ones")},
        "wout": ParamSpec((DI, D), dt, ("ssm_inner", "embed")),
    }


def _causal_depthwise_conv(u: jax.Array, w: jax.Array, b: jax.Array,
                           init: jax.Array | None = None) -> jax.Array:
    """u (B,S,C), w (W,C) -> causal depthwise conv; ``init`` prepends history."""
    W = w.shape[0]
    if init is None:
        up = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        up = jnp.concatenate([init.astype(u.dtype), u], axis=1)
    out = sum(up[:, i : i + u.shape[1]] * w[i][None, None, :] for i in range(W))
    return jax.nn.silu(out + b[None, None, :].astype(u.dtype))


def _project(params: dict, x: jax.Array, cfg: ModelConfig):
    """Returns z (B,S,DI), conv input u (B,S,CC), dt (B,S,H)."""
    dt_ = x.dtype
    z = jnp.einsum("bsd,de->bse", x, params["wz"].astype(dt_))
    xin = jnp.einsum("bsd,de->bse", x, params["wx"].astype(dt_))
    Bp = jnp.einsum("bsd,dn->bsn", x, params["wB"].astype(dt_))
    Cp = jnp.einsum("bsd,dn->bsn", x, params["wC"].astype(dt_))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, params["wdt"].astype(dt_))
    dt_val = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    u = jnp.concatenate([xin, Bp, Cp], axis=-1)
    return z, u, dt_val


def _split_conv(u: jax.Array, cfg: ModelConfig):
    DI, N = cfg.d_inner, cfg.ssm_state
    return u[..., :DI], u[..., DI : DI + N], u[..., DI + N :]


def ssm_forward(params: dict, x: jax.Array, cfg: ModelConfig,
                mesh: Mesh | None = None, *, return_cache: bool = False):
    """Chunked SSD over a full sequence. x (B,S,D) -> (B,S,D).

    ``return_cache=True`` (prefill) additionally returns the SsmCache (conv
    tail + final SSD state) so decoding can continue from position S."""
    B, S, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    Q = min(cfg.ssm_chunk, S)
    nc = -(-S // Q)
    Sp = nc * Q

    z, u, dt_val = _project(params, x, cfg)
    u_conv = _causal_depthwise_conv(u, params["conv_w"], params["conv_b"])
    xs, Bs, Cs = _split_conv(u_conv, cfg)
    xs = constraint(xs, mesh, "batch", None, "ssm_inner")

    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0))
        xs, Bs, Cs = jnp.pad(xs, pad), jnp.pad(Bs, pad), jnp.pad(Cs, pad)
        dt_val = jnp.pad(dt_val, pad)  # softplus(0+bias) irrelevant: masked by dt=0

    A = -jnp.exp(params["A_log"])  # (H,) negative decay rates
    xh = xs.reshape(B, nc, Q, H, P).astype(jnp.float32)
    Bc = Bs.reshape(B, nc, Q, N).astype(jnp.float32)
    Cc = Cs.reshape(B, nc, Q, N).astype(jnp.float32)
    dtc = dt_val.reshape(B, nc, Q, H)

    loga = dtc * A[None, None, None, :]  # (B,nc,Q,H) log decay per step
    L = jnp.cumsum(loga, axis=2)  # inclusive cumsum within chunk

    # Move chunk axis first for the scan.
    xh, Bc, Cc, dtc, loga, L = (jnp.moveaxis(t, 1, 0) for t in (xh, Bc, Cc, dtc, loga, L))

    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_body(h, inp):
        xq, Bq, Cq, dtq, logaq, Lq = inp  # each (B, Q, ...)
        # Intra-chunk (quadratic within the chunk only).
        cb = jnp.einsum("bqn,bsn->bqs", Cq, Bq)  # (B,Q,Q)
        # L_t - L_s <= 0 exactly on the valid (s <= t) triangle; clamping at 0
        # kills the +inf exp on the masked triangle that would otherwise leak
        # NaN through the where() in the backward pass.
        decay = jnp.exp(jnp.minimum(Lq[:, :, None, :] - Lq[:, None, :, :], 0.0))
        w = jnp.where(tri[None, :, :, None], decay, 0.0) * dtq[:, None, :, :]
        scores = cb[..., None] * w  # (B,Q,Q,H)
        y_intra = jnp.einsum("bqsh,bshp->bqhp", scores, xq)
        # Inter-chunk contribution of the carried state.
        y_inter = jnp.einsum("bqn,bqh,bhpn->bqhp", Cq, jnp.exp(Lq), h)
        # State carried to the end of the chunk.
        total = Lq[:, -1:, :]  # (B,1,H)
        w_state = jnp.exp(total - Lq) * dtq  # (B,Q,H): decay from s to chunk end
        h_new = (jnp.exp(total[:, 0])[:, :, None, None] * h
                 + jnp.einsum("bqh,bqhp,bqn->bhpn", w_state, xq, Bq))
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h_final, ys = jax.lax.scan(chunk_body, h0, (xh, Bc, Cc, dtc, loga, L))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Sp, H, P)[:, :S]
    x_skip = jnp.moveaxis(xh, 0, 1).reshape(B, Sp, H, P)[:, :S]
    y = y + params["D_skip"][None, None, :, None] * x_skip
    y = y.reshape(B, S, H * P).astype(x.dtype)

    out = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.rmsnorm_eps)
    out = jnp.einsum("bse,ed->bsd", out, params["wout"].astype(x.dtype))
    if not return_cache:
        return out
    W = cfg.ssm_conv_width
    u_raw = jnp.concatenate(
        [jnp.zeros((B, max(0, W - 1 - S), u.shape[-1]), u.dtype),
         u[:, max(0, S - (W - 1)):S]], axis=1)
    return out, SsmCache(conv=u_raw, state=h_final)


def ssm_init_cache(cfg: ModelConfig, batch: int, dtype) -> SsmCache:
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return SsmCache(
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, _conv_channels(cfg)), dtype),
        state=jnp.zeros((batch, H, P, N), jnp.float32),
    )


def ssm_decode_step(params: dict, x: jax.Array, cache: SsmCache, cfg: ModelConfig,
                    mesh: Mesh | None = None) -> tuple[jax.Array, SsmCache]:
    """One-token step. x (B,1,D) -> (y (B,1,D), new cache)."""
    B, S, D = x.shape
    assert S == 1
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

    z, u, dt_val = _project(params, x, cfg)
    u_conv = _causal_depthwise_conv(u, params["conv_w"], params["conv_b"],
                                    init=cache.conv)
    new_conv = jnp.concatenate([cache.conv[:, 1:], u.astype(cache.conv.dtype)], axis=1)
    xs, Bs, Cs = _split_conv(u_conv, cfg)

    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt_val[:, 0] * A[None, :])  # (B,H)
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    Bv = Bs[:, 0].astype(jnp.float32)  # (B,N)
    Cv = Cs[:, 0].astype(jnp.float32)

    inc = jnp.einsum("bh,bhp,bn->bhpn", dt_val[:, 0], xh, Bv)
    h_new = a[:, :, None, None] * cache.state + inc
    y = jnp.einsum("bn,bhpn->bhp", Cv, h_new)
    y = y + params["D_skip"][None, :, None] * xh
    y = y.reshape(B, 1, H * P).astype(x.dtype)

    out = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.rmsnorm_eps)
    out = jnp.einsum("bse,ed->bsd", out, params["wout"].astype(x.dtype))
    return out, SsmCache(conv=new_conv, state=h_new)
