"""Model configuration: one dataclass covering all six architecture families.

A model is a sequence of *stages*; each stage is a scanned stack of identical
*periods*; a period is a tuple of :class:`LayerSpec`s. This factorization lets
heterogeneous stacks (Jamba's 1:7 attention:Mamba interleave, Gemma3's 5:1
local:global pattern) compile as O(1)-size HLO while keeping exact layer
counts (remainder layers become a second stage).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

Family = Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]
LayerKind = Literal["attn", "mamba"]
MlpKind = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: LayerKind = "attn"
    mlp: MlpKind = "dense"
    window: int | None = None  # sliding-window size; None = full attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None  # None -> d_model // num_heads

    # Attention details.
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    causal: bool = True  # False for encoder-only (hubert)
    attn_logit_softcap: float | None = None

    # Layer pattern. Default: homogeneous attention stack.
    layout: tuple[LayerSpec, ...] = (LayerSpec(),)

    # MoE.
    num_experts: int = 0
    experts_per_token: int = 0
    d_ff_expert: int = 0
    moe_capacity_factor: float = 1.25
    norm_topk_probs: bool = True  # qwen3-style renormalization

    # SSM (Mamba2 / SSD).
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # Modality frontend (see DESIGN: the one allowed stub).
    frontend: Literal["text", "audio_stub", "vision_stub"] = "text"
    num_patch_tokens: int = 1024  # VLM: patch embeddings per sequence

    # Numerics.
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    rmsnorm_eps: float = 1e-6

    # Citation for the assignment table.
    source: str = ""

    def __post_init__(self):
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, "GQA grouping"

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def stages(self) -> list[tuple[tuple[LayerSpec, ...], int]]:
        """[(period_layout, num_periods), ...] covering exactly num_layers."""
        period = len(self.layout)
        full, rem = divmod(self.num_layers, period)
        out: list[tuple[tuple[LayerSpec, ...], int]] = []
        if full:
            out.append((self.layout, full))
        if rem:
            out.append((self.layout[:rem], 1))
        return out

    def has_attention(self) -> bool:
        return any(l.kind == "attn" for l in self.layout)

    def max_window(self) -> int | None:
        """None if any attention layer is full/global (unbounded context cost)."""
        windows = [l.window for l in self.layout if l.kind == "attn"]
        if not windows:
            return 0  # attention-free
        if any(w is None for w in windows):
            return None
        return max(windows)  # all-local

    def supports_long_decode(self) -> bool:
        """True if decode cost/memory is sub-linear in context (SSM/hybrid with
        bounded-window attention handled via sequence-sharded cache)."""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid":
            return True  # few attention layers; cache sequence-sharded
        return self.max_window() is not None or any(
            l.kind == "attn" and l.window is not None for l in self.layout
        )

    def supports_decode(self) -> bool:
        return self.causal  # encoder-only models have no autoregressive decode

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 periods, d_model<=256, <=4 experts."""
        period = len(self.layout)
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4)
        # Largest divisor of num_heads not exceeding the original KV count
        # (keeps the GQA grouping valid after reduction).
        kv_target = min(self.num_kv_heads, num_heads)
        num_kv = max(d for d in range(1, num_heads + 1)
                     if num_heads % d == 0 and d <= kv_target)
        layout = tuple(
            dataclasses.replace(l, window=min(l.window, 64) if l.window else l.window)
            for l in self.layout
        )
        return dataclasses.replace(
            self,
            num_layers=min(self.num_layers, 2 * period),
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=64 if self.head_dim else None,
            d_ff=min(self.d_ff, 512),
            d_ff_expert=min(self.d_ff_expert, 128) if self.d_ff_expert else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.experts_per_token else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            num_patch_tokens=16 if self.frontend == "vision_stub" else self.num_patch_tokens,
            layout=layout,
            param_dtype="float32",
            compute_dtype="float32",
        )
