"""Blocked attention with a flash-style custom VJP.

Forward: online-softmax over (bq, bk) tiles (never materializes S^2 scores),
saving only (q, k, v, out, lse) -- O(S) residuals.
Backward: the textbook FlashAttention-2 recomputation: per (q-block, kv-block)
pair rebuild the probability tile from lse, form ds = p * (dp - delta), and
accumulate dq per q-block / dk, dv across q-blocks in the scan carry.

Compared to autodiff through the online-softmax scan this removes the
O(S^2 / chip) saved probability tiles (the 2.5 GiB x n_blocks buffers the
dry-run exposed) at the cost of one extra attention forward in the backward
pass -- the same trade the CUDA/Pallas flash kernels make.

Sliding windows reuse the statically-sized (window + bq) key slice per query
block, so windowed layers cost O(S * W) in both passes.

GQA layout throughout: q (B, S, KV, G, hd) pre-scaled; k, v (B, S, KV, hd).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


class FlashSpec(NamedTuple):
    causal: bool
    window: int | None
    block_q: int
    block_k: int
    softcap: float | None


def _mask(spec: FlashSpec, qpos, kpos, S):
    m = (kpos[None, :] >= 0) & (kpos[None, :] < S)
    if spec.causal:
        m &= kpos[None, :] <= qpos[:, None]
    if spec.window is not None:
        m &= qpos[:, None] - kpos[None, :] < spec.window
    return m  # (bq, bk)


def _scores(spec: FlashSpec, qb, kb):  # (B,KV,G,bq,hd) x (B,KV,bk,hd)
    s = jnp.einsum("bkgqh,bkch->bkgqc", qb, kb).astype(jnp.float32)
    if spec.softcap is not None:
        s = spec.softcap * jnp.tanh(s / spec.softcap)
    return s


def _dscores(spec: FlashSpec, s_capped, ds):
    """Chain rule through the optional softcap (s_capped = cap*tanh(s/cap))."""
    if spec.softcap is None:
        return ds
    return ds * (1.0 - jnp.square(s_capped / spec.softcap))


def _layout(spec: FlashSpec, S: int):
    bq = min(spec.block_q, S)
    nq = -(-S // bq)
    use_window = spec.window is not None and spec.window < S
    if use_window:
        bk = min(spec.block_k, S)
        wpad = -(-int(spec.window) // bk) * bk  # analysis: host-ok (static)
        Lw = wpad + bq
        nk = Lw // bk
        return bq, nq, bk, nk, wpad, Lw, True
    bk = min(spec.block_k, S)
    nk = -(-S // bk)
    return bq, nq, bk, nk, 0, nk * bk, False


def _pad_q(q, nq, bq):
    B, S = q.shape[0], q.shape[1]
    Sq = nq * bq
    if Sq != S:
        q = jnp.pad(q, ((0, 0), (0, Sq - S)) + ((0, 0),) * (q.ndim - 2))
    return q


def _kv_source(k, v, spec: FlashSpec, S, Sq, wpad, Lk, windowed):
    """Padded key/value streams. Windowed: front-pad by wpad and back-pad to
    Sq so the last query block's (window + bq) slice stays in bounds."""
    if windowed:
        pad = ((0, 0), (wpad, Sq - S), (0, 0), (0, 0))
    else:
        pad = ((0, 0), (0, Lk - S), (0, 0), (0, 0))
    return jnp.pad(k, pad), jnp.pad(v, pad)


def _fwd_impl(q, k, v, spec: FlashSpec):
    B, S, KV, G, hd = q.shape
    bq, nq, bk, nk, wpad, Lk, windowed = _layout(spec, S)
    qp = _pad_q(q, nq, bq)
    k_src, v_src = _kv_source(k, v, spec, S, nq * bq, wpad, Lk, windowed)
    q_blocks = qp.reshape(B, nq, bq, KV, G, hd).swapaxes(0, 1)

    def q_block(_, blk):
        qi, qb = blk
        qb = qb.transpose(0, 2, 3, 1, 4)  # (B,KV,G,bq,hd)
        qpos = qi * bq + jnp.arange(bq)
        if windowed:
            start = qi * bq
            kw = jax.lax.dynamic_slice_in_dim(k_src, start, Lk, axis=1)
            vw = jax.lax.dynamic_slice_in_dim(v_src, start, Lk, axis=1)
            kpos = qi * bq + (jnp.arange(Lk) - wpad)
        else:
            kw, vw = k_src, v_src
            kpos = jnp.arange(Lk)
        kb_all = kw.reshape(B, nk, bk, KV, hd).swapaxes(0, 1)
        vb_all = vw.reshape(B, nk, bk, KV, hd).swapaxes(0, 1)
        kpos_b = kpos.reshape(nk, bk)

        def kv_body(state, kv):
            m_run, l_run, acc = state
            kb, vb, kp = kv
            kb = kb.transpose(0, 2, 1, 3)
            vb = vb.transpose(0, 2, 1, 3)
            s = _scores(spec, qb, kb)
            s = jnp.where(_mask(spec, qpos, kp, S)[None, None, None], s, _NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            corr = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bkch->bkgqh", p, vb)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, KV, G, bq), _NEG_INF, jnp.float32),
                jnp.zeros((B, KV, G, bq), jnp.float32),
                jnp.zeros((B, KV, G, bq, hd), jnp.float32))
        (m_f, l_f, acc), _ = jax.lax.scan(kv_body, init, (kb_all, vb_all, kpos_b))
        l_safe = jnp.maximum(l_f, 1e-30)
        out = (acc / l_safe[..., None]).astype(q.dtype)
        lse = m_f + jnp.log(l_safe)
        return None, (out.transpose(0, 3, 1, 2, 4), lse)

    _, (outs, lses) = jax.lax.scan(q_block, None, (jnp.arange(nq), q_blocks))
    out = outs.swapaxes(0, 1).reshape(B, nq * bq, KV, G, hd)[:, :S]
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, KV, G, nq * bq)[..., :S]
    return out, lse


def _bwd_impl(q, k, v, out, lse, dout, spec: FlashSpec):
    B, S, KV, G, hd = q.shape
    bq, nq, bk, nk, wpad, Lk, windowed = _layout(spec, S)
    qp = _pad_q(q, nq, bq)
    outp = _pad_q(out, nq, bq)
    doutp = _pad_q(dout, nq, bq)
    Sq = nq * bq
    if Sq != S:
        lse = jnp.pad(lse, ((0, 0), (0, 0), (0, 0), (0, Sq - S)), constant_values=1.0)
    k_src, v_src = _kv_source(k, v, spec, S, Sq, wpad, Lk, windowed)

    # delta_i = sum_h dout_i * out_i  (FlashAttention-2, eq. for dS).
    delta = jnp.einsum("bskgh,bskgh->bkgs", doutp.astype(jnp.float32),
                       outp.astype(jnp.float32))
    delta = delta.reshape(B, KV, G, nq, bq).transpose(3, 0, 1, 2, 4)
    lse_b = lse.reshape(B, KV, G, nq, bq).transpose(3, 0, 1, 2, 4)
    q_blocks = qp.reshape(B, nq, bq, KV, G, hd).swapaxes(0, 1)
    do_blocks = doutp.reshape(B, nq, bq, KV, G, hd).swapaxes(0, 1)

    dk0 = jnp.zeros((B, k_src.shape[1], KV, hd), jnp.float32)
    dv0 = jnp.zeros_like(dk0)

    def q_block(carry, blk):
        dk_acc, dv_acc = carry
        qi, qb, dob, dlt, lseb = blk
        qb = qb.transpose(0, 2, 3, 1, 4)  # (B,KV,G,bq,hd)
        dob = dob.transpose(0, 2, 3, 1, 4).astype(jnp.float32)
        qpos = qi * bq + jnp.arange(bq)
        if windowed:
            start = qi * bq
            kw = jax.lax.dynamic_slice_in_dim(k_src, start, Lk, axis=1)
            vw = jax.lax.dynamic_slice_in_dim(v_src, start, Lk, axis=1)
            kpos = qi * bq + (jnp.arange(Lk) - wpad)
        else:
            kw, vw = k_src, v_src
            kpos = jnp.arange(Lk)
        kb_all = kw.reshape(B, nk, bk, KV, hd).swapaxes(0, 1)
        vb_all = vw.reshape(B, nk, bk, KV, hd).swapaxes(0, 1)
        kpos_b = kpos.reshape(nk, bk)

        def kv_body(dq_acc, kv):
            kb, vb, kp, j = kv
            kbt = kb.transpose(0, 2, 1, 3)
            vbt = vb.transpose(0, 2, 1, 3)
            s = _scores(spec, qb, kbt)
            msk = _mask(spec, qpos, kp, S)[None, None, None]
            s = jnp.where(msk, s, _NEG_INF)
            p = jnp.exp(s - lseb[..., None])  # (B,KV,G,bq,bk)
            dp = jnp.einsum("bkgqh,bkch->bkgqc", dob, vbt.astype(jnp.float32))
            ds = p * (dp - dlt[..., None])
            ds = _dscores(spec, s, ds)
            ds = jnp.where(msk, ds, 0.0)
            dq_blk = jnp.einsum("bkgqc,bkch->bkgqh", ds,
                                kbt.astype(jnp.float32))
            dk_blk = jnp.einsum("bkgqc,bkgqh->bkch", ds, qb.astype(jnp.float32))
            dv_blk = jnp.einsum("bkgqc,bkgqh->bkch", p, dob)
            return dq_acc + dq_blk, (dk_blk.transpose(0, 2, 1, 3),
                                     dv_blk.transpose(0, 2, 1, 3))

        dq0 = jnp.zeros((B, KV, G, bq, hd), jnp.float32)
        dq_blk, (dk_blks, dv_blks) = jax.lax.scan(
            kv_body, dq0, (kb_all, vb_all, kpos_b, jnp.arange(nk)))
        dk_full = dk_blks.swapaxes(0, 1).reshape(B, nk * bk, KV, hd)
        dv_full = dv_blks.swapaxes(0, 1).reshape(B, nk * bk, KV, hd)
        if windowed:
            # Scatter-accumulate this q-block's (Lk,) key-range grads back
            # into the padded buffer at its window offset.
            start = qi * bq
            dk_acc = jax.lax.dynamic_update_slice_in_dim(
                dk_acc, jax.lax.dynamic_slice_in_dim(dk_acc, start, Lk, 1)
                + dk_full, start, axis=1)
            dv_acc = jax.lax.dynamic_update_slice_in_dim(
                dv_acc, jax.lax.dynamic_slice_in_dim(dv_acc, start, Lk, 1)
                + dv_full, start, axis=1)
        else:
            dk_acc = dk_acc + dk_full
            dv_acc = dv_acc + dv_full
        return (dk_acc, dv_acc), dq_blk.transpose(0, 3, 1, 2, 4)

    (dk_acc, dv_acc), dq_blocks = jax.lax.scan(
        q_block, (dk0, dv0), (jnp.arange(nq), q_blocks, do_blocks, delta, lse_b))
    dq = dq_blocks.swapaxes(0, 1).reshape(B, Sq, KV, G, hd)[:, :S]
    if windowed:
        dk = dk_acc[:, wpad : wpad + S]
        dv = dv_acc[:, wpad : wpad + S]
    else:
        dk = dk_acc[:, :S]
        dv = dv_acc[:, :S]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention(q, k, v, spec: FlashSpec):
    """q (B,S,KV,G,hd) pre-scaled; k, v (B,S,KV,hd) -> (B,S,KV,G,hd)."""
    out, _ = _fwd_impl(q, k, v, spec)
    return out


def _flash_fwd(q, k, v, spec):
    out, lse = _fwd_impl(q, k, v, spec)
    return out, (q, k, v, out, lse)


def _flash_bwd(spec, res, dout):
    q, k, v, out, lse = res
    return _bwd_impl(q, k, v, out, lse, dout, spec)


flash_attention.defvjp(_flash_fwd, _flash_bwd)
