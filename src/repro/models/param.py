"""Parameter plans: shapes + logical axes declared separately from values.

Every module declares its parameters as a pytree of :class:`ParamSpec`
(shape, dtype, logical axis names, initializer). The plan can then be

* ``materialize``d into real arrays (training / smoke tests),
* turned into ``abstract`` ShapeDtypeStructs (multi-pod dry-run -- no bytes
  are ever allocated for the 398B configs), and
* resolved into ``NamedSharding``s through a logical-axis -> mesh-axis rule
  table (the MaxText-style indirection that keeps model code mesh-agnostic).

Sharding safety: jax 0.8 rejects uneven shardings, so a logical axis is only
mapped onto a mesh axis when the dimension divides the axis size; otherwise it
silently replicates (recorded by ``explain_sharding`` for DESIGN notes).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# Default logical-axis -> mesh-axis rules for the production mesh.
# "batch"-like axes go to data parallel dims; big weight dims go to "model".
DEFAULT_RULES: dict[str, str | tuple[str, ...] | None] = {
    "batch": ("pod", "data"),
    "seq": "model",  # sequence-parallel residual stream (activations)
    "seq_shard": "model",  # sequence-sharded KV caches (decode)
    "vocab": "model",
    "ff": "model",
    "heads": "model",
    "kv_heads": "model",
    "experts": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
}


# Active rule table. Sharding *profiles* (launch/steps.py) swap this during
# tracing via rule_scope(); model code always consults the active table, so
# the same model definition lowers under tensor-parallel or pure-DP layouts.
_ACTIVE_RULES: list[dict] = [DEFAULT_RULES]


def get_active_rules() -> dict:
    return _ACTIVE_RULES[-1]


class rule_scope:
    """Context manager: override the logical-axis rules while tracing."""

    def __init__(self, rules: dict | None):
        self.rules = DEFAULT_RULES if rules is None else rules

    def __enter__(self):
        _ACTIVE_RULES.append(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _ACTIVE_RULES.pop()
        return False


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    dtype: Any
    axes: tuple[str | None, ...]  # logical axis name per dim (None = anonymous)
    init: str = "normal"  # "normal" | "zeros" | "ones" | "scaled"
    scale: float | None = None  # stddev override; None -> 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def materialize(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        fan_in = self.shape[0] if len(self.shape) > 1 else max(self.shape[-1], 1)
        std = self.scale if self.scale is not None else 1.0 / math.sqrt(fan_in)
        return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(self.dtype)


def stack_specs(spec: PyTree, num: int) -> PyTree:
    """Prepend a scanned ``layers`` axis of size ``num`` to every leaf."""
    def f(s: ParamSpec) -> ParamSpec:
        return ParamSpec((num, *s.shape), s.dtype, ("layers", *s.axes), s.init, s.scale)
    return jax.tree.map(f, spec, is_leaf=lambda x: isinstance(x, ParamSpec))


def tree_abstract(spec: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.abstract(), spec,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def tree_materialize(spec: PyTree, key: jax.Array) -> PyTree:
    leaves, treedef = jax.tree.flatten(spec, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [s.materialize(k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def _resolve_axis(name: str | None, dim: int, mesh: Mesh,
                  rules: dict[str, Any]) -> str | tuple[str, ...] | None:
    if name is None:
        return None
    target = rules.get(name)
    if target is None:
        return None
    axes = (target,) if isinstance(target, str) else tuple(target)
    axes = tuple(a for a in axes if a in mesh.shape)
    if not axes:
        return None
    total = int(np.prod([mesh.shape[a] for a in axes]))
    if dim % total != 0:
        return None  # uneven -> replicate (jax 0.8 requires divisibility)
    return axes if len(axes) > 1 else axes[0]


def spec_to_pspec(s: ParamSpec, mesh: Mesh, rules: dict[str, Any] | None = None) -> P:
    rules = DEFAULT_RULES if rules is None else rules
    return P(*(_resolve_axis(a, dim, mesh, rules) for a, dim in zip(s.axes, s.shape)))


def tree_pspecs(spec: PyTree, mesh: Mesh, rules: dict[str, Any] | None = None) -> PyTree:
    return jax.tree.map(lambda s: spec_to_pspec(s, mesh, rules), spec,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def tree_shardings(spec: PyTree, mesh: Mesh, rules: dict[str, Any] | None = None) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, spec_to_pspec(s, mesh, rules)),
                        spec, is_leaf=lambda x: isinstance(x, ParamSpec))


def explain_sharding(spec: PyTree, mesh: Mesh, rules: dict[str, Any] | None = None) -> list[str]:
    """Human-readable list of which params replicated due to indivisibility."""
    out: list[str] = []
    # tree_util spelling: jax.tree.flatten_with_path needs JAX >= 0.5.
    flat, _ = jax.tree_util.tree_flatten_with_path(
        spec, is_leaf=lambda x: isinstance(x, ParamSpec))
    rules = DEFAULT_RULES if rules is None else rules
    for path, s in flat:
        for a, dim in zip(s.axes, s.shape):
            if a is not None and rules.get(a) is not None:
                if _resolve_axis(a, dim, mesh, rules) is None:
                    out.append(f"{jax.tree_util.keystr(path)}: axis {a!r} dim {dim} "
                               f"not divisible -> replicated")
    return out


def num_params(spec: PyTree) -> int:
    leaves = jax.tree.leaves(spec, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(int(np.prod(s.shape)) for s in leaves)


def constraint(x: jax.Array, mesh: Mesh | None, *axes: str | tuple[str, ...] | None,
               rules: dict[str, Any] | None = None) -> jax.Array:
    """with_sharding_constraint on *logical* axis names.

    Names are translated through the rule table (e.g. "batch" ->
    ("pod", "data"), "heads" -> "model"); names not in the table are taken as
    literal mesh axes. Axes absent from the mesh and indivisible dims resolve
    to None (so the same model code runs on a 1-device CPU mesh), and an
    unsharded name NEVER forces replication of a dim some other pass sharded
    -- we only constrain dims we positively resolve.
    """
    if mesh is None:
        return x
    rules = get_active_rules() if rules is None else rules
    resolved = []
    any_set = False
    for dim, a in zip(x.shape, axes):
        if a is None:
            resolved.append(None)
            continue
        target = rules.get(a, a) if isinstance(a, str) else a
        if target is None:
            resolved.append(None)
            continue
        cand = (target,) if isinstance(target, str) else tuple(target)
        cand = tuple(c for c in cand if c in mesh.shape)
        total = (int(np.prod([mesh.shape[c] for c in cand]))  # analysis: host-ok
                 if cand else 0)
        if cand and total and dim % total == 0:
            resolved.append(cand if len(cand) > 1 else cand[0])
            any_set = True
        else:
            resolved.append(None)
    if not any_set:
        return x  # nothing resolvable: don't force full replication
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*resolved)))
