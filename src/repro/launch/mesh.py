"""Production meshes. Functions, not module constants: importing this module
never touches jax device state (the dry-run sets XLA_FLAGS before any init).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 v5e pod (256 chips); multi_pod adds the 2-pod axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh() -> Mesh:
    """Whatever this host has (1 CPU device here): for smoke tests/examples."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_divisor(mesh: Mesh) -> int:
    out = 1
    for a in data_axes(mesh):
        out *= mesh.shape[a]
    return out
