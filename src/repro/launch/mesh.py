"""Production meshes. Functions, not module constants: importing this module
never touches jax device state (the dry-run sets XLA_FLAGS before any init).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def _axis_type_kwargs(num_axes: int) -> dict:
    """``axis_types=`` kwarg when this JAX has it, empty dict otherwise.

    ``jax.sharding.AxisType`` only exists from JAX 0.5; on 0.4.x every mesh
    axis is implicitly Auto, so omitting the kwarg is semantically identical.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * num_axes}


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Version-safe ``jax.make_mesh`` with all axes Auto-typed."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 v5e pod (256 chips); multi_pod adds the 2-pod axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever this host has (1 CPU device here): for smoke tests/examples."""
    n = len(jax.devices())
    return make_mesh((1, n), ("data", "model"))


def make_sweep_mesh(n_shards: int, axis: str) -> Mesh:
    """1-D mesh over the first ``n_shards`` local devices for the sharded
    sweep runner (:mod:`repro.api.sweep`): ``axis`` is ``"cells"`` or
    ``"workers"``.  ``n_shards`` must not exceed the local device count
    (callers size it via :func:`repro.api.sweep.resolve_shard`, which picks
    the largest power of two that fits)."""
    return make_mesh((n_shards,), (axis,))


def device_summary() -> dict:
    """This host's accelerator inventory as a plain dict -- surfaced by the
    experiment service's ``GET /stats`` endpoint and stamped into bench
    provenance, so serve-side numbers always say what hardware (and how many
    sweep shards) produced them."""
    devs = jax.devices()
    return {
        "platform": devs[0].platform if devs else "none",
        "device_count": len(devs),
        "sweep_shards": _pow2_floor(len(devs)),
    }


def _pow2_floor(n: int) -> int:
    return 1 << (max(1, n).bit_length() - 1)


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_divisor(mesh: Mesh) -> int:
    out = 1
    for a in data_axes(mesh):
        out *= mesh.shape[a]
    return out
