"""Analytic per-device HBM traffic model (the roofline memory term).

Neither source of byte counts in the compiled artifact is usable for HBM
traffic on the target hardware: XLA:CPU's ``cost_analysis()['bytes accessed']``
is fusion-blind (counts every logical operand) and undercounts loops, while
summing streamed operands x trip counts overcounts tiles that stay VMEM-
resident across inner loops. So the memory term is modeled analytically --
exactly how published rooflines derive it -- from the same configuration the
compiled program implements, with the component inventory below. Weights and
state sizes agree with the artifact's memory_analysis() argument sizes (the
dry-run records both so the cross-check is visible).

Per train step and device (bf16 weights/activations, f32 moments):
  weights      3 reads of the gathered per-layer weights (fwd, remat, bwd)
               + grad write/read + f32 moment read/write pairs + param rw
  activations  scan checkpoints w+r; per-layer tensor ios (qkv/mlp/ssd/moe);
               flash K/V streaming (window-aware) fwd + 2x bwd;
               chunked-CE logits w+r x fwd+bwd
  exchange     residual read/write + filtered update (when ACPD is on)
Decode: weights read once, KV/SSM cache read (+1 slot write), activations ~0.
Prefill: weights once, activations fwd-only, cache write once.
"""

from __future__ import annotations

import math

import numpy as np

from repro.configs import InputShape
from repro.launch.mesh import batch_divisor
from repro.models.config import LayerSpec, ModelConfig


def _mesh_sizes(mesh_shape: dict) -> tuple[int, int, int]:
    model = mesh_shape.get("model", 1)
    data = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    return model, data, model * data


def _layer_params(cfg: ModelConfig, layer: LayerSpec) -> float:
    D, hd = cfg.d_model, cfg.resolved_head_dim
    p = 2 * D  # norms
    if layer.kind == "attn":
        p += D * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
    else:
        DI, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        p += D * (2 * DI + 2 * N + H) + DI * D + DI + 3 * H
    if layer.mlp == "dense":
        p += 3 * D * cfg.d_ff
    elif layer.mlp == "moe":
        p += D * cfg.num_experts + 3 * cfg.num_experts * D * cfg.d_ff_expert
    return float(p)


def hbm_bytes(cfg: ModelConfig, shape: InputShape, mesh_shape: dict,
              *, exchange: bool = False) -> float:
    """Modeled HBM bytes per device per step."""
    model_n, data_n, dev_n = _mesh_sizes(mesh_shape)
    B, S = shape.global_batch, shape.seq_len
    D, hd = cfg.d_model, cfg.resolved_head_dim
    KV = cfg.num_kv_heads
    bf, f32 = 2, 4

    layers = [(l, periods) for layout, periods in cfg.stages() for l in layout]
    total_params = sum(_layer_params(cfg, l) * p for l, p in layers)
    embed_params = cfg.vocab_size * D * (1 if cfg.frontend == "audio_stub" else 2)
    total_params += embed_params

    if shape.kind == "train":
        b_loc = B // data_n if B % data_n == 0 else B
        t_loc = b_loc * S
        s_loc = S // model_n if S % model_n == 0 else S  # seq-sharded stream

        # Weights: gathered per layer (sharded over model only once gathered
        # from FSDP), 3 passes; grads + moments + params f32 at 1/dev_n.
        w_gathered = total_params / model_n * bf * 3
        w_opt = total_params / dev_n * (f32 * 2 * 2 + f32 * 2 + bf * 2)

        # Activations.
        n_ckpt = sum(periods for _, periods in cfg.stages())
        a_ckpt = n_ckpt * b_loc * s_loc * D * bf * 2
        per_layer_io = 0.0
        for l, p in layers:
            io = t_loc * D * 4  # residual in/out x2 sublayers
            if l.kind == "attn":
                io += t_loc * hd * (cfg.num_heads * 2 + KV * 2)
                Lk = min(l.window or S, S) + 512 if l.window else S
                nq = -(-S // 512)
                io += b_loc * nq * min(Lk, S) * KV * hd * 2  # K+V stream
            else:
                io += t_loc * (2 * cfg.d_inner + 2 * cfg.ssm_state
                               + cfg.ssm_heads) * 2
                io += b_loc * (S / max(cfg.ssm_chunk, 1)) * cfg.ssm_heads \
                    * cfg.ssm_head_dim * cfg.ssm_state * 2  # chunk states
            if l.mlp == "dense":
                io += t_loc * cfg.d_ff / model_n * 3 * 2
            elif l.mlp == "moe":
                cap = cfg.experts_per_token * cfg.moe_capacity_factor
                io += t_loc * cap * D / model_n * 2 * 2  # dispatch+combine
                io += t_loc * cap * cfg.d_ff_expert / model_n * 3 * 2
            per_layer_io += io * p * bf
        act = (a_ckpt + per_layer_io) * 3  # fwd + remat + bwd passes
        ce = t_loc * (cfg.vocab_size / model_n) * f32 * 2 * 3 / 8  # 1/8: chunks live briefly; logits w+r per pass
        exch_b = total_params / dev_n * f32 * 4 if exchange else 0.0
        return w_gathered + w_opt + act + ce + exch_b

    if shape.kind == "prefill":
        b_loc = B // data_n if B % data_n == 0 else B
        t_loc = b_loc * S
        w = total_params / model_n * bf
        act = 0.0
        cache = 0.0
        for l, p in layers:
            io = t_loc * D * 4
            if l.kind == "attn":
                io += t_loc * hd * (cfg.num_heads * 2 + KV * 2)
                Lk = min(l.window or S, S) + 512 if l.window else S
                nq = -(-S // 512)
                io += b_loc * nq * min(Lk, S) * KV * hd * 2
                cache += b_loc * min(l.window or S, S) * KV * hd * bf
            else:
                io += t_loc * (2 * cfg.d_inner + 2 * cfg.ssm_state
                               + cfg.ssm_heads) * 2
                cache += b_loc * cfg.ssm_heads * cfg.ssm_head_dim \
                    * cfg.ssm_state * f32
            if l.mlp == "dense":
                io += t_loc * cfg.d_ff / model_n * 3 * 2
            elif l.mlp == "moe":
                cap = cfg.experts_per_token * cfg.moe_capacity_factor
                io += t_loc * cap * (D * 2 + cfg.d_ff_expert * 3) / model_n * 2
            act += io * p * bf
        ce = b_loc * (cfg.vocab_size / model_n) * f32 * 2
        return w + act + cache / dev_n * 0 + cache + ce

    # decode: weights once + cache traffic dominate.
    b_loc = B // data_n if B % data_n == 0 else B
    w = total_params / (model_n * (data_n if cfg.num_experts and
                                   cfg.d_ff_expert % data_n == 0 else 1)) * bf
    cache = 0.0
    for l, p in layers:
        if l.kind == "attn":
            s_buf = min(l.window or S, S)
            # B=1 long-context caches shard over every mesh axis.
            shard = dev_n if B == 1 else model_n
            cache += p * b_loc * (s_buf / shard if s_buf % shard == 0
                                  else s_buf) * KV * hd * bf * 2
        else:
            cache += p * b_loc * cfg.ssm_heads * cfg.ssm_head_dim \
                * cfg.ssm_state * f32 * 2 / (model_n if cfg.ssm_heads
                                             % model_n == 0 else 1)
    return w + cache


def memory_seconds(cfg: ModelConfig, shape: InputShape, mesh_shape: dict,
                   hbm_bw: float = 819e9, *, exchange: bool = False) -> float:
    return hbm_bytes(cfg, shape, mesh_shape, exchange=exchange) / hbm_bw
