"""Multi-pod dry-run: prove every (architecture x input-shape x mesh) lowers,
compiles, and fits -- and extract the roofline terms from the compiled
artifact. The os.environ lines below MUST stay the first statements executed
(jax locks the device count at first init), hence no __future__ import here.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
  ... --exchange acpd            # ACPD GroupedDeltaExchange instead of plain DP
  ... --out experiments/dryrun   # one JSON artifact per combo

Artifacts feed EXPERIMENTS.md §Dry-run/§Roofline via benchmarks/roofline.py.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, input_specs, shape_supported
from repro.core import exchange as exch_lib
from repro.launch import hlo_analysis
from repro.launch.flops import model_flops
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (TrainSetup, build_prefill_step, build_serve_step,
                                build_train_step)
from repro.optim.optimizers import OptimizerConfig


def run_one(arch: str, shape_name: str, mesh_kind: str, exchange: str,
            out_dir: pathlib.Path | None, block_q: int | None = None,
            tag: str = "", profile: str = "tp",
            exploit_window: bool = True, acpd_groups: int | None = None,
            acpd_vmap: bool = False) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_supported(cfg, shape)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "exchange": exchange, "tag": tag, "profile": profile}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    import numpy as np

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    num_devices = int(np.prod(list(mesh.shape.values())))

    t0 = time.time()
    if shape.kind == "train":
        if acpd_groups is not None:
            n_groups = acpd_groups
        elif profile in ("dp", "ep"):
            n_groups = num_devices  # every chip is an ACPD worker group
        else:
            n_groups = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        exch = None if exchange == "plain" else exch_lib.ExchangeConfig(
            num_groups=n_groups, group_size=max(1, n_groups // 2),
            sync_period=20, rho=1.0 / 256.0, gamma=0.9)
        setup = TrainSetup(cfg=cfg, optimizer=OptimizerConfig(),
                           exchange=exch, profile=profile,
                           exploit_window=exploit_window,
                           sequential_exchange=not acpd_vmap)
        jitted, _, abstract = build_train_step(setup, mesh, shape)
    elif shape.kind == "prefill":
        jitted, _, abstract = build_prefill_step(cfg, mesh, shape)
    else:
        jitted, _, abstract = build_serve_step(cfg, mesh, shape)

    with mesh:
        lowered = jitted.lower(*abstract)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    mf = model_flops(cfg, shape)
    scan_lengths = {periods for _, periods in cfg.stages() if periods > 1}
    roof = hlo_analysis.analyze(compiled, model_flops_global=mf,
                                num_devices=num_devices,
                                scan_lengths=scan_lengths)
    # Memory term from the analytic HBM model (see launch/analytic.py).
    from repro.launch.analytic import hbm_bytes
    roof.hbm_bytes_per_device = hbm_bytes(
        cfg, shape, dict(mesh.shape), exchange=exchange == "acpd")
    roof.memory_s = roof.hbm_bytes_per_device / hlo_analysis.HBM_BW
    terms = {"compute": roof.compute_s, "memory": roof.memory_s,
             "collective": roof.collective_s}
    roof.dominant = max(terms, key=terms.get)
    rec.update(
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        num_devices=num_devices,
        roofline=roof.as_dict(),
        model_flops_global=mf,
    )
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        suffix = f"-{tag}" if tag else ""
        fn = out_dir / f"{arch}__{shape_name}__{mesh_kind}__{exchange}{suffix}.json"
        fn.write_text(json.dumps(rec, indent=1))
    return rec


def _summ(rec: dict) -> str:
    if rec["status"] != "ok":
        return (f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:6s} "
                f"SKIP ({rec.get('reason', rec.get('error', '?'))[:60]})")
    r = rec["roofline"]
    mem = r["memory_stats"]
    per_dev_gb = mem.get("footprint_adjusted_bytes",
                         mem.get("footprint_bytes", 0)) / 2**30
    return (f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:6s} "
            f"{rec['exchange']:5s} mem/dev={per_dev_gb:6.2f}GiB "
            f"C={r['compute_s']*1e3:9.3f}ms M={r['memory_s']*1e3:9.3f}ms "
            f"X={r['collective_s']*1e3:9.3f}ms dom={r['dominant']:10s} "
            f"useful={r['useful_ratio'] if r['useful_ratio'] is None else round(r['useful_ratio'], 3)} "
            f"compile={rec['compile_s']:.1f}s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--exchange", default="plain", choices=["plain", "acpd"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--profile", default="tp", choices=["tp", "dp", "ep"])
    ap.add_argument("--no-exploit-window", action="store_true")
    ap.add_argument("--acpd-groups", type=int, default=None)
    ap.add_argument("--acpd-vmap", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    out_dir = pathlib.Path(args.out)

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                suffix = f"-{args.tag}" if args.tag else ""
                fn = out_dir / f"{arch}__{shape}__{mesh_kind}__{args.exchange}{suffix}.json"
                if args.skip_existing and fn.exists():
                    print(f"{arch:24s} {shape:12s} {mesh_kind:6s} cached")
                    continue
                try:
                    rec = run_one(arch, shape, mesh_kind, args.exchange, out_dir,
                                  tag=args.tag, profile=args.profile,
                                  exploit_window=not args.no_exploit_window,
                                  acpd_groups=args.acpd_groups,
                                  acpd_vmap=args.acpd_vmap)
                except Exception as e:  # a failure here is a bug in our system
                    failures += 1
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "exchange": args.exchange, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                    out_dir.mkdir(parents=True, exist_ok=True)
                    fn.write_text(json.dumps(rec, indent=1))
                print(_summ(rec), flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run failures")


if __name__ == "__main__":
    main()
