"""MODEL_FLOPS: the 6*N*D (train) / 2*N*D (inference) convention.

N = *active* parameters per token: all params except the input embedding
table, with MoE expert weights scaled by experts_per_token/num_experts
(6*N_active*D for MoE, per the roofline spec). Attention's O(S) per-token
score/AV FLOPs are intentionally *not* included -- the useful-compute ratio
MODEL_FLOPS/HLO_FLOPs therefore reads below 1 for long-context shapes, and
the gap quantifies attention + remat + padding overhead (discussed per-entry
in EXPERIMENTS.md).
"""

from __future__ import annotations

import numpy as np
import jax

from repro.configs import InputShape
from repro.models import model_spec
from repro.models.config import ModelConfig
from repro.models.param import ParamSpec


def active_params(cfg: ModelConfig) -> float:
    spec = model_spec(cfg)
    # jax.tree.flatten_with_path only exists from JAX 0.5; tree_util spelling
    # works on 0.4.x too.
    flat, _ = jax.tree_util.tree_flatten_with_path(
        spec, is_leaf=lambda x: isinstance(x, ParamSpec))
    total = 0.0
    moe_scale = (cfg.experts_per_token / cfg.num_experts) if cfg.num_experts else 1.0
    for path, s in flat:
        keys = [getattr(p, "key", str(p)) for p in path]
        n = float(np.prod(s.shape))
        if keys[:2] == ["embed", "table"]:
            continue  # input lookup is a gather, not FLOPs
        if "moe" in keys and keys[-1] in ("gate", "up", "down"):
            n *= moe_scale
        total += n
    return total


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    n_active = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    if shape.kind == "decode":
        return 2.0 * n_active * shape.global_batch  # one token per sequence
    raise ValueError(shape.kind)
