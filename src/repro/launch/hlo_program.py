"""Loop-aware analysis of compiled HLO text.

XLA's ``cost_analysis()`` counts every while-loop body ONCE -- useless for
scan-over-layers programs where >95% of the work lives inside loops. This
module re-derives the roofline quantities from the optimized HLO itself:

  1. split the module into computations and build the call graph
     (while body= / fusion calls= / to_apply= / conditional branches),
  2. read each while op's trip count from ``backend_config
     {"known_trip_count": {"n": ...}}`` (emitted by XLA for scans),
  3. propagate execution multiplicities from ENTRY through the graph,
  4. FLOPs: every ``dot`` contributes 2 * prod(result dims) * prod(lhs
     contracting dims), times its computation's multiplicity,
  5. collective wire bytes: ring-model bytes (see hlo_analysis) times
     multiplicity,
  6. HBM bytes: streamed operand+result bytes of dots, gathers, scatters and
     dynamic-update-slices times multiplicity -- an upper estimate that
     ignores fusion reuse of operands already in registers/VMEM (documented;
     elementwise traffic is fused into these in practice).

Elementwise FLOPs are ignored (matmuls dominate the compute term by >10x for
every shape here); the SSD layer's einsums all lower to dots, so SSM archs
are covered too.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_OP_DEF = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s+([\w\-]+)\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
# Per-field callee references. Values are either %name or {%a, %b}.
_FIELD_REFS = {
    "body": re.compile(r"\bbody=(?:\{([^}]*)\}|%([\w.\-]+))"),
    "condition": re.compile(r"\bcondition=(?:\{([^}]*)\}|%([\w.\-]+))"),
    "calls": re.compile(r"\bcalls=(?:\{([^}]*)\}|%([\w.\-]+))"),
    "to_apply": re.compile(r"\bto_apply=(?:\{([^}]*)\}|%([\w.\-]+))"),
    "true_computation": re.compile(r"\btrue_computation=(?:\{([^}]*)\}|%([\w.\-]+))"),
    "false_computation": re.compile(r"\bfalse_computation=(?:\{([^}]*)\}|%([\w.\-]+))"),
    "branch_computations": re.compile(r"\bbranch_computations=\{([^}]*)\}"),
}
_OPERANDS = re.compile(r"\(((?:%[\w.\-]+(?:,\s*)?)+)\)?")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_PARAM_DEF = re.compile(r"^\s+%([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s+parameter\(")


def _shape_dims(shape_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE.finditer(shape_str):
        if m.group(1) in _DTYPE_BYTES:
            dims = [int(d) for d in m.group(2).split(",") if d]
            out.append((m.group(1), dims))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    shape: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]


def parse_computations(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if line and not line[0].isspace():
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1), [])
                comps[cur.name] = cur
            continue
        if cur is None:
            continue
        m = _OP_DEF.match(line)
        if m:
            cur.ops.append(Op(m.group(1), m.group(3), m.group(2), line))
    return comps


def _find_entry(text: str, comps: dict[str, Computation]) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    return next(reversed(comps))


def multiplicities(text: str, comps: dict[str, Computation]) -> dict[str, float]:
    entry = _find_entry(text, comps)
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # Build edges: (caller, callee, factor). Only a while's *body* gets the
    # trip-count factor; its condition and all other call kinds get 1.
    edges: dict[str, list[tuple[str, float]]] = defaultdict(list)
    for cname, comp in comps.items():
        for op in comp.ops:
            trip = 1.0
            if op.kind == "while":
                tm = _TRIP.search(op.line)
                trip = float(tm.group(1)) if tm else 1.0
            for field, rx in _FIELD_REFS.items():
                for m in rx.finditer(op.line):
                    blob = next(g for g in m.groups() if g is not None)
                    for callee in re.findall(r"%?([\w.\-]+)", blob):
                        if callee in comps:
                            factor = trip if field == "body" else 1.0
                            edges[cname].append((callee, factor))
    # Propagate in topological-ish order (HLO computations are listed callees
    # first; iterate to fixpoint for safety -- the graph is a DAG).
    for _ in range(len(comps)):
        changed = False
        new = defaultdict(float)
        new[entry] = 1.0
        for caller, outs in edges.items():
            for callee, f in outs:
                new[callee] += mult[caller] * f
        new[entry] = 1.0
        for k in set(new) | set(mult):
            if abs(new[k] - mult[k]) > 1e-9:
                changed = True
        mult = new
        if not changed:
            break
    return dict(mult)


@dataclasses.dataclass
class ProgramCosts:
    dot_flops: float  # loop-aware matmul FLOPs (per device)
    hbm_bytes: float  # loop-aware streamed-bytes upper estimate (per device)
    wire_bytes: float  # loop-aware collective on-wire bytes (per device)
    collective_counts: dict[str, float]  # dynamic (trip-weighted) counts
    dot_count: int
    while_trips: list[int]
    # XLA:CPU reduces bf16 tensors in f32; the TPU lowering keeps bf16 on the
    # wire. This halves every f32 collective as the hardware-faithful volume.
    wire_bytes_bf16: float = 0.0


_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_MEM_KINDS = {"dot", "gather", "scatter", "dynamic-update-slice", "convolution"}


def _collective_wire(op: Op) -> float:
    b = _shape_bytes(op.shape)
    gm = _GROUPS_RE.search(op.line)
    if gm:
        n = len(gm.group(1).split(","))
    else:
        gi = _GROUPS_IOTA_RE.search(op.line)
        n = int(gi.group(2)) if gi else 16
    n = max(n, 1)
    kind = op.kind.replace("-start", "")
    if kind == "all-reduce":
        return 2.0 * b * (n - 1) / n
    if kind == "collective-permute":
        return float(b)
    if kind == "reduce-scatter":
        return b * (n - 1)
    return b * (n - 1) / n  # all-gather, all-to-all


def analyze_program(text: str) -> ProgramCosts:
    comps = parse_computations(text)
    mult = multiplicities(text, comps)

    # name -> shape string (for dot operand lookup), per computation + params.
    flops = 0.0
    hbm = 0.0
    wire = 0.0
    wire_bf16 = 0.0
    coll_counts: dict[str, float] = defaultdict(float)
    dot_count = 0
    trips = []

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        shapes = {op.name: op.shape for op in comp.ops}
        # parameters defined with explicit shapes too (matched by _OP_DEF).
        for op in comp.ops:
            kind = op.kind
            if kind == "while":
                tm = _TRIP.search(op.line)
                if tm:
                    trips.append(int(tm.group(1)))
            base = kind.replace("-start", "").replace("-done", "")
            if base in _COLL_KINDS:
                if kind.endswith("-done"):
                    continue
                w = m * _collective_wire(op)
                wire += w
                wire_bf16 += w * (0.5 if "f32[" in op.shape else 1.0)
                coll_counts[base] += m
                hbm += m * 2 * _shape_bytes(op.shape)
                continue
            if base not in _MEM_KINDS:
                continue
            out_bytes = _shape_bytes(op.shape)
            args = re.search(r"\b" + re.escape(kind) + r"\(([^)]*)\)", op.line)
            arg_names = re.findall(r"%([\w.\-]+)", args.group(1)) if args else []
            in_bytes = sum(_shape_bytes(shapes.get(a, "")) for a in arg_names)
            hbm += m * (out_bytes + in_bytes)
            if base == "dot":
                cm = _CONTRACT.search(op.line)
                if not cm or not arg_names:
                    continue
                lhs_shape = shapes.get(arg_names[0], "")
                dims = _shape_dims(lhs_shape)
                if not dims:
                    continue
                lhs_dims = dims[0][1]
                contract = 1
                for ci in (int(c) for c in cm.group(1).split(",") if c):
                    if ci < len(lhs_dims):
                        contract *= lhs_dims[ci]
                out_elems = 1
                for _, od in _shape_dims(op.shape):
                    for d in od:
                        out_elems *= d
                    break
                flops += m * 2.0 * out_elems * contract
                dot_count += 1

    return ProgramCosts(flops, hbm, wire, dict(coll_counts), dot_count, trips,
                        wire_bytes_bf16=wire_bf16)
