"""Batched serving driver: prefill a prompt batch, then decode N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --reduced \
      --batch 4 --prompt-len 48 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import make_token_dataset
from repro.models import decode_step, model_spec, prefill
from repro.models.param import tree_materialize


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.supports_decode():
        raise SystemExit(f"{cfg.arch_id} is encoder-only: no decode")

    params = tree_materialize(model_spec(cfg), jax.random.key(args.seed))
    stream = make_token_dataset(args.batch * args.prompt_len, cfg.vocab_size,
                                args.seed)
    prompts = jnp.asarray(stream.reshape(args.batch, args.prompt_len))
    max_seq = args.prompt_len + args.gen

    batch = {"tokens": prompts}
    if cfg.frontend == "vision_stub":
        p = min(cfg.num_patch_tokens, args.prompt_len // 2)
        rng = np.random.default_rng(args.seed)
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, p, cfg.d_model)).astype(np.float32))

    t0 = time.time()
    logits, caches, plen = prefill(params, batch, cfg, max_seq=max_seq)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if cfg.frontend == "vision_stub":
        plen = plen  # patches + text both occupy cache slots
    out = [tok]
    t1 = time.time()
    # The decode loop rebinds ``caches`` every step: donate it so each step
    # updates the KV buffers in place instead of allocating a second copy
    # (found by `repro analyze`, rule jit-donation).
    step_fn = jax.jit(
        lambda params, tok, caches, pos: decode_step(params, tok, caches,
                                                     pos, cfg),
        donate_argnums=(2,))
    for i in range(args.gen - 1):
        logits, caches = step_fn(params, tok, caches, jnp.int32(plen + 1 + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    gen = np.stack([np.asarray(t) for t in out], axis=1)
    dt_prefill, dt_decode = t1 - t0, time.time() - t1
    print(f"prefill {args.batch}x{plen} in {dt_prefill:.2f}s; "
          f"decoded {args.gen - 1} steps in {dt_decode:.2f}s "
          f"({dt_decode / max(args.gen - 1, 1) * 1e3:.0f} ms/tok)")
    print("generated token ids (batch 0):", gen[0].tolist())


if __name__ == "__main__":
    main()
