"""End-to-end training driver.

Runs real steps on whatever devices exist (the host mesh here; the production
mesh on a pod), with the ACPD exchange or the plain synchronous baseline:

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --reduced \
      --steps 50 --batch 8 --seq 128 --exchange acpd

Checkpoints (params + opt + exchange residuals + data cursor) every
--ckpt-every steps; resumes with --resume.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import InputShape, get_config
from repro.core import exchange as exch_lib
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import batch_divisor, make_host_mesh, make_production_mesh
from repro.launch.steps import TrainSetup, build_train_step
from repro.models import model_spec
from repro.models.param import tree_materialize
from repro.optim.optimizers import OptimizerConfig, init_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant of the architecture")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--exchange", default="acpd",
                    choices=["acpd", "dense", "plain"])
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=2)
    ap.add_argument("--sync-period", type=int, default=10)
    ap.add_argument("--rho", type=float, default=1 / 64)
    ap.add_argument("--gamma", type=float, default=0.9)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd"])
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_production_mesh() if args.production_mesh else make_host_mesh()
    shape = InputShape("cli", args.seq, args.batch, "train")

    if args.exchange == "plain":
        exch = None
    elif args.exchange == "dense":
        exch = exch_lib.dense_config(args.groups)
    else:
        exch = exch_lib.ExchangeConfig(
            num_groups=args.groups, group_size=args.group_size,
            sync_period=args.sync_period, rho=args.rho, gamma=args.gamma)
    opt_cfg = OptimizerConfig(name=args.optimizer, learning_rate=args.lr,
                              warmup_steps=min(20, args.steps // 5 + 1),
                              total_steps=args.steps)
    setup = TrainSetup(cfg=cfg, optimizer=opt_cfg, exchange=exch,
                       seq_shard=False, zero1=False, fsdp=False)

    jitted, shardings, _ = build_train_step(setup, mesh, shape)

    key = jax.random.key(args.seed)
    params = tree_materialize(model_spec(cfg), key)
    opt_state = init_state(opt_cfg, params)
    exch_state = exch_lib.init_state(exch, params) if exch is not None else None
    pipe = TokenPipeline(cfg, args.batch, args.seq, mesh=None, seed=args.seed)

    start = 0
    if args.resume and args.ckpt_dir:
        tree = {"params": params, "opt": opt_state, "exch": exch_state}
        tree, extra = load_checkpoint(args.ckpt_dir, tree)
        params, opt_state, exch_state = tree["params"], tree["opt"], tree["exch"]
        pipe.load_state_dict(extra["pipeline"])
        start = int(extra["step"])
        print(f"resumed from step {start}")

    with mesh:
        t0 = time.time()
        for step in range(start, args.steps):
            batch = pipe.next_batch()
            params, opt_state, exch_state, metrics = jitted(
                params, opt_state, exch_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                sent = m.get("exchange/sent_fraction")
                print(f"step {step:5d} loss={m['loss']:.4f} "
                      f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e}"
                      + (f" sent={sent:.4f}" if sent is not None else ""),
                      flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, step + 1,
                                {"params": params, "opt": opt_state,
                                 "exch": exch_state},
                                extra={"step": step + 1,
                                       "pipeline": pipe.state_dict()})
        dt = time.time() - t0
        print(f"done: {args.steps - start} steps in {dt:.1f}s "
              f"({dt / max(args.steps - start, 1):.2f}s/step)")


if __name__ == "__main__":
    main()
