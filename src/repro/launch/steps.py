"""Jitted step functions + their sharding trees for a given (config, mesh).

``build_train_step``: data-parallel training over the mesh's data axes with
either the plain synchronous exchange (mean gradient -- the CoCoA+-analogue
baseline) or the ACPD GroupedDeltaExchange (B-of-K participation + top-rho
sparsification + error feedback), then AdamW/SGD.

``build_prefill_step`` / ``build_serve_step``: batched serving; decode caches
are sequence-sharded over the mesh (and over *all* axes when batch=1, which is
what makes the 524k-context single-sequence shape fit).

Everything returns (jitted_fn, input_shardings, abstract_inputs) so the
multi-pod dry-run can ``.lower(...)`` without allocating anything.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import InputShape
from repro.core import exchange as exch_lib
from repro.launch.mesh import batch_divisor, data_axes
from repro.models import model_spec, train_loss, decode_step
from repro.models.config import ModelConfig
from repro.models.model import prefill as model_prefill
from repro.models.param import tree_abstract, tree_pspecs
from repro.optim.optimizers import OptimizerConfig, OptState, apply_update, init_state

PyTree = Any

# Weight-sharding rule tables (see models.param.DEFAULT_RULES):
# * "tp" training profile: tensor-parallel weights over the model axis + FSDP
#   over data ("embed" dims); XLA inserts the per-layer gathers inside the
#   scan. Without FSDP, 235B/398B configs cannot hold even bf16 weights.
# * "dp" training profile (§Perf): NO tensor parallelism -- the batch shards
#   over every mesh axis (256-way on one pod) and weights FSDP-shard over
#   (data, model) combined. Per-layer TP activation all-reduces disappear;
#   the only collectives are FSDP weight gathers + the gradient reduction.
# * serving keeps weights resident (no per-layer gathers); the big-MoE
#   configs instead shard the expert ff dim over the data axis, which turns
#   into a cheap per-MoE-layer psum at decode.
from repro.models.param import DEFAULT_RULES, rule_scope

TRAIN_RULES = {**DEFAULT_RULES, "embed": "data"}
DP_RULES = {
    "batch": ("pod", "data", "model"),
    "seq": None,
    "seq_shard": None,
    "embed": ("data", "model"),  # FSDP over the whole pod
    "vocab": None, "ff": None, "heads": None, "kv_heads": None,
    "experts": None, "ssm_inner": None, "ssm_heads": None, "expert_ff": None,
}
# "ep" (§Perf, MoE archs): tokens shard over every axis like dp, but expert
# weights STAY model-sharded (full-expert FSDP gathers are what made dp lose
# on the 235B: 2.4 GB/layer of expert weights re-gathered 3x per step).
# Dispatch groups remain the data slices; the token->expert movement across
# the model axis lowers to an all-to-all-shaped exchange of (C, D) slots.
EP_RULES = {
    "batch": ("pod", "data", "model"),
    "moe_groups": ("pod", "data"),
    "seq": None,
    "seq_shard": None,
    "embed": "data",  # FSDP for the non-expert weights
    "vocab": None, "ff": None, "heads": None, "kv_heads": None,
    "experts": "model", "ssm_inner": None, "ssm_heads": None,
    "expert_ff": None,
}
SERVE_RULES = {**DEFAULT_RULES, "expert_ff": "data"}
PROFILE_RULES = {"tp": TRAIN_RULES, "dp": DP_RULES, "ep": EP_RULES}


@dataclasses.dataclass(frozen=True)
class TrainSetup:
    cfg: ModelConfig
    optimizer: OptimizerConfig
    exchange: exch_lib.ExchangeConfig | None  # None -> plain mean-grad DP
    remat: bool = True
    exploit_window: bool = True
    seq_shard: bool = True  # sequence-parallel activations (memory fit)
    zero1: bool = True  # shard optimizer moments over the data axis too
    fsdp: bool = True  # shard weights over the data axis too (memory fit)
    profile: str = "tp"  # "tp" | "dp" | "ep" (see the rule tables above)
    # scan the exchange over groups (one gradient live at a time) instead of
    # vmapping all K group-gradients -- mandatory at >10B params (§Perf).
    sequential_exchange: bool = True


def _sharding(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def _batch_pspec(cfg: ModelConfig, mesh: Mesh, batch: dict) -> dict:
    """Shard every batch leaf's leading (batch) dim over the data axes."""
    axes = data_axes(mesh)
    div = batch_divisor(mesh)

    def leaf(x):
        b = x.shape[0]
        lead = axes if (axes and b % div == 0) else None
        return P(lead, *([None] * (x.ndim - 1)))

    return jax.tree.map(leaf, batch)


# ---------------------------------------------------------------------------
# Training.
# ---------------------------------------------------------------------------


def build_train_step(setup: TrainSetup, mesh: Mesh, shape: InputShape):
    cfg = setup.cfg
    spec = model_spec(cfg)
    if setup.profile in ("dp", "ep"):
        rules = PROFILE_RULES[setup.profile]
        daxes = tuple(mesh.shape.keys())  # batch (and groups) over every axis
        seq_shard = False  # B_loc is tiny; no need to split the sequence
        total = int(np.prod(list(mesh.shape.values())))
        if shape.global_batch % total != 0:
            raise ValueError(
                f"profile {setup.profile!r} shards the batch over all "
                f"{total} devices; global_batch={shape.global_batch} is not "
                f"divisible (use the tp profile on this mesh)")
    else:
        rules = TRAIN_RULES if setup.fsdp else DEFAULT_RULES
        daxes = data_axes(mesh)
        seq_shard = setup.seq_shard
    param_ps = tree_pspecs(spec, mesh, rules)
    abstract_params = tree_abstract(spec)

    from repro.configs import input_specs  # avoid cycle at module import
    abstract_batch = input_specs(cfg, shape)["batch"]
    div = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1

    def _leaf_ps(x):
        lead = daxes if (daxes and x.shape[0] % div == 0) else None
        return P(lead, *([None] * (x.ndim - 1)))

    batch_ps = jax.tree.map(_leaf_ps, abstract_batch)

    def _uses(ps_entries, axis: str) -> bool:
        for e in ps_entries:
            if e == axis or (isinstance(e, tuple) and axis in e):
                return True
        return False

    def zero1_ps(ps: P, leaf) -> P:
        """ZeRO-1: additionally shard optimizer moments over the data axis on
        the first dim that is unsharded and divisible (no-op when FSDP already
        spent the data axis on this tensor)."""
        if not setup.zero1 or not daxes:
            return ps
        entries = list(ps) + [None] * (len(leaf.shape) - len(ps))
        if any(_uses(entries, a) for a in daxes):
            return ps
        dsize = int(np.prod([mesh.shape[a] for a in daxes]))
        for i, (e, dim) in enumerate(zip(entries, leaf.shape)):
            if e is None and dim % dsize == 0:
                entries[i] = daxes if len(daxes) > 1 else daxes[0]
                return P(*entries)
        return ps

    moment_ps = jax.tree.map(zero1_ps, param_ps, abstract_params)
    opt_ps = OptState(step=P(), mu=moment_ps,
                      nu=moment_ps if setup.optimizer.name == "adamw" else None)
    abstract_opt = jax.eval_shape(
        lambda p: init_state(setup.optimizer, p), abstract_params)

    def _g_axes(G: int):
        """Largest subset of the data axes whose size divides G (G=2
        pod-as-worker groups shard over 'pod' alone)."""
        for cand in (daxes, ("pod",), ("data",), ()):
            cand = tuple(a for a in cand if a in mesh.shape)
            if cand and G % int(np.prod([mesh.shape[a] for a in cand])) == 0:
                return cand
        return None

    def residual_ps(ps: P, G: int) -> P:
        """Residuals (G, *shape): G shards over (a divisible subset of) the
        data axes; inner dims keep their param sharding minus those axes."""
        gax = _g_axes(G)
        used = gax or ()
        def strip(e):
            if e is None:
                return None
            t = (e,) if isinstance(e, str) else tuple(e)
            t = tuple(a for a in t if a not in used)
            return t[0] if len(t) == 1 else (t if t else None)
        inner = [strip(e) for e in ps]
        return P(gax if gax else None, *inner)

    exch = setup.exchange
    if exch is not None:
        exch_ps = exch_lib.ExchangeState(
            residual=jax.tree.map(lambda ps: residual_ps(ps, exch.num_groups),
                                  param_ps))
        abstract_exch = jax.eval_shape(
            lambda p: exch_lib.init_state(exch, p), abstract_params)
    else:
        exch_ps, abstract_exch = None, None

    def loss_fn(params, batch):
        with rule_scope(rules):
            return train_loss(params, batch, cfg, mesh=mesh, remat=setup.remat,
                              exploit_window=setup.exploit_window,
                              seq_shard=seq_shard)

    def grads_per_group(params, batch, groups: int):
        def regroup(x):
            g = x.reshape(groups, x.shape[0] // groups, *x.shape[1:])
            return jax.lax.with_sharding_constraint(
                g, _sharding(mesh, daxes if daxes else None))
        grouped = jax.tree.map(regroup, batch)
        return jax.vmap(jax.grad(loss_fn), in_axes=(None, 0))(params, grouped)

    def constrain_update(u):
        """ZeRO-1: pin the update to the moments' data-sharded layout so the
        gradient reduction lowers to reduce-scatter (not all-reduce) and the
        optimizer math runs on 1/|data| of each tensor."""
        if not setup.zero1:
            return u
        return jax.tree.map(
            lambda g, ps: jax.lax.with_sharding_constraint(
                g, NamedSharding(mesh, ps)), u, moment_ps)

    def step_fn(params, opt_state, exch_state, batch):
        metrics = {}
        if exch is None:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            update, new_exch = constrain_update(grads), exch_state
        else:
            loss = loss_fn(params, batch)  # monitored value
            if setup.sequential_exchange:
                grouped = jax.tree.map(
                    lambda x: x.reshape(exch.num_groups,
                                        x.shape[0] // exch.num_groups,
                                        *x.shape[1:]), batch)
                flat_mps = jax.tree.leaves(moment_ps)

                def shard_acc(d):
                    return {i: jax.lax.with_sharding_constraint(
                        v, NamedSharding(mesh, flat_mps[i]))
                        for i, v in d.items()}

                update, new_exch, em = exch_lib.exchange_sequential(
                    exch, jax.grad(loss_fn), params, grouped, exch_state,
                    opt_state.step, shard_acc=shard_acc)
            else:
                g = grads_per_group(params, batch, exch.num_groups)
                update, new_exch, em = exch_lib.exchange(
                    exch, g, exch_state, opt_state.step)
            metrics.update(em)
        new_params, new_opt, om = apply_update(
            setup.optimizer, params, update, opt_state)
        metrics.update(om)
        metrics["loss"] = loss
        return new_params, new_opt, new_exch, metrics

    in_shardings = (
        jax.tree.map(lambda ps: NamedSharding(mesh, ps), param_ps),
        jax.tree.map(lambda ps: NamedSharding(mesh, ps), opt_ps,
                     is_leaf=lambda x: isinstance(x, P)),
        jax.tree.map(lambda ps: NamedSharding(mesh, ps), exch_ps,
                     is_leaf=lambda x: isinstance(x, P)) if exch_ps is not None else None,
        jax.tree.map(lambda ps: NamedSharding(mesh, ps), batch_ps,
                     is_leaf=lambda x: isinstance(x, P)),
    )
    out_shardings = (in_shardings[0], in_shardings[1], in_shardings[2],
                     NamedSharding(mesh, P()))

    jitted = jax.jit(step_fn, in_shardings=in_shardings,
                     out_shardings=out_shardings, donate_argnums=(0, 1, 2))
    abstract = (abstract_params, abstract_opt, abstract_exch, abstract_batch)
    return jitted, in_shardings, abstract


# ---------------------------------------------------------------------------
# Serving.
# ---------------------------------------------------------------------------


def _cache_pspecs(cfg: ModelConfig, mesh: Mesh, batch_size: int, max_seq: int):
    """PartitionSpec tree mirroring models.init_caches structurally."""
    from repro.models import blocks as blocks_lib
    from repro.models.blocks import AttnCache
    from repro.models.ssm import SsmCache

    daxes = data_axes(mesh)
    div = batch_divisor(mesh)
    batch_ok = bool(daxes) and batch_size % div == 0
    b_ax = daxes if batch_ok else None
    # When the batch can't shard (B=1 long-context), spread the sequence over
    # every mesh axis; otherwise over the model axis only.
    seq_axes_pref = ("model",) if batch_ok else tuple(mesh.shape.keys())

    def seq_ax(s_buf: int) -> tuple[str, ...] | None:
        total = int(np.prod([mesh.shape[a] for a in seq_axes_pref]))
        if s_buf % total == 0:
            return seq_axes_pref
        if s_buf % mesh.shape["model"] == 0:
            return ("model",)
        return None

    def div_ax(dim: int, ax: str = "model"):
        return (ax,) if dim % mesh.shape[ax] == 0 else None

    stages = []
    for layout, periods in cfg.stages():
        stage = {}
        for i, layer in enumerate(layout):
            if layer.kind == "attn":
                if layer.window is not None and layer.window < max_seq:
                    s_buf = layer.window
                else:
                    s_buf = max_seq
                kv_spec = P(None, b_ax, seq_ax(s_buf), None, None)
                stage[f"pos{i}"] = AttnCache(kv_spec, kv_spec)
            else:
                cc = cfg.d_inner + 2 * cfg.ssm_state
                stage[f"pos{i}"] = SsmCache(
                    conv=P(None, b_ax, None, div_ax(cc)),
                    state=P(None, b_ax, div_ax(cfg.ssm_heads), None, None),
                )
        stages.append(stage)
    return stages


def build_serve_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape):
    """One-token decode against a seq_len-sized cache (decode shapes)."""
    from repro.configs import input_specs

    spec = model_spec(cfg)
    param_ps = tree_pspecs(spec, mesh, SERVE_RULES)
    abstract_params = tree_abstract(spec)
    specs = input_specs(cfg, shape)
    B, S = shape.global_batch, shape.seq_len
    daxes = data_axes(mesh)
    batch_ok = bool(daxes) and B % batch_divisor(mesh) == 0

    cache_ps = _cache_pspecs(cfg, mesh, B, S)
    token_ps = P(daxes if batch_ok else None)

    def serve_fn(params, token, caches, cache_len):
        logits, new_caches = decode_step(params, token, caches, cache_len, cfg,
                                         mesh=mesh)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, new_caches

    ns = lambda tree: jax.tree.map(lambda ps: NamedSharding(mesh, ps), tree,
                                   is_leaf=lambda x: isinstance(x, P))
    in_shardings = (ns(param_ps), NamedSharding(mesh, token_ps), ns(cache_ps),
                    NamedSharding(mesh, P()))
    out_shardings = (NamedSharding(mesh, token_ps), ns(cache_ps))
    jitted = jax.jit(serve_fn, in_shardings=in_shardings,
                     out_shardings=out_shardings, donate_argnums=(2,))
    abstract = (abstract_params, specs["token"], specs["caches"],
                specs["cache_len"])
    return jitted, in_shardings, abstract


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape):
    """Prompt processing: forward + cache assembly (prefill shapes)."""
    from repro.configs import input_specs

    spec = model_spec(cfg)
    param_ps = tree_pspecs(spec, mesh, SERVE_RULES)
    abstract_params = tree_abstract(spec)
    abstract_batch = input_specs(cfg, shape)["batch"]
    batch_ps = _batch_pspec(cfg, mesh, abstract_batch)
    B, S = shape.global_batch, shape.seq_len
    cache_ps = _cache_pspecs(cfg, mesh, B, S)
    daxes = data_axes(mesh)
    batch_ok = bool(daxes) and B % batch_divisor(mesh) == 0

    def prefill_fn(params, batch):
        last, caches, _ = model_prefill(params, batch, cfg, max_seq=S, mesh=mesh)
        return last, caches

    ns = lambda tree: jax.tree.map(lambda ps: NamedSharding(mesh, ps), tree,
                                   is_leaf=lambda x: isinstance(x, P))
    in_shardings = (ns(param_ps), ns(batch_ps))
    vocab_ax = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
    out_shardings = (NamedSharding(mesh, P(daxes if batch_ok else None, vocab_ax)),
                     ns(cache_ps))
    jitted = jax.jit(prefill_fn, in_shardings=in_shardings,
                     out_shardings=out_shardings)
    abstract = (abstract_params, abstract_batch)
    return jitted, in_shardings, abstract
