"""Roofline terms from a compiled XLA executable (no hardware required).

Sources (DESIGN §Roofline):
* ``compiled.cost_analysis()`` -> per-partition HLO FLOPs and bytes accessed.
* ``compiled.memory_analysis()`` -> per-device argument/output/temp bytes.
* ``compiled.as_text()`` (post-SPMD optimized HLO) -> the collective schedule:
  every all-reduce / all-gather / reduce-scatter / all-to-all /
  collective-permute with its result shape and replica-group size.

Hardware constants: TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

On-wire byte accounting per op (ring algorithms, n = replica-group size):
  all-reduce       2 * bytes * (n-1)/n
  all-gather       bytes_out * (n-1)/n
  reduce-scatter   bytes_in  * (n-1)/n   (we see the *result* shape = 1/n of in)
  all-to-all       bytes * (n-1)/n
  collective-permute  bytes (single hop)
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"(\w[\w.-]*)\s*=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\](?:\{[^}]*\})?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict[str, int]
    result_bytes: dict[str, int]  # sum of per-device result-shape bytes
    wire_bytes: float  # ring-model on-wire bytes per device
    ops: list[dict]

    def as_dict(self) -> dict[str, Any]:
        return {"counts": self.counts, "result_bytes": self.result_bytes,
                "wire_bytes": self.wire_bytes}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    rbytes: dict[str, int] = {}
    wire = 0.0
    ops: list[dict] = []
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        name, shape_str, kind = m.group(1), m.group(2), m.group(3)
        # async pairs: count the -start, skip the -done (same tensor).
        if f"{kind}-done" in line:
            continue
        if name in seen_done:
            continue
        seen_done.add(name)
        b = _shape_bytes(shape_str)
        gm = _GROUPS_RE.search(line)
        if gm:
            n = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            n = int(gi.group(2)) if gi else 16
        n = max(n, 1)
        if kind == "all-reduce":
            w = 2.0 * b * (n - 1) / n
        elif kind == "collective-permute":
            w = float(b)
        elif kind == "all-gather":
            w = b * (n - 1) / n
        elif kind == "reduce-scatter":
            # result shape is the scatter output (1/n of the input).
            w = b * (n - 1)
        else:  # all-to-all
            w = b * (n - 1) / n
        counts[kind] = counts.get(kind, 0) + 1
        rbytes[kind] = rbytes.get(kind, 0) + b
        wire += w
        ops.append({"kind": kind, "bytes": b, "group": n, "wire": w})
    return CollectiveStats(counts, rbytes, wire, ops)


_CONVERT_RE = re.compile(r"= f32\[([\d,]+)\]\S* convert\(%\S+\)")


def cpu_upcast_bytes(hlo_text: str, scan_lengths: set[int]) -> int:
    """Bytes of bf16->f32 weight upcasts hoisted out of scan loops.

    The CPU backend has no native bf16 matmul, so XLA upconverts bf16 weights
    to f32 and hoists the convert of the *whole stacked* (num_periods, ...)
    tensor out of the while loop. A TPU's MXU consumes bf16 directly, so these
    buffers do not exist on the target hardware; we report them separately and
    subtract them from the adjusted footprint. Heuristic: f32 converts whose
    leading dim equals a scan length and that are >= 64 MiB.
    """
    total = 0
    for m in _CONVERT_RE.finditer(hlo_text):
        dims = [int(d) for d in m.group(1).split(",") if d]
        if not dims or dims[0] not in scan_lengths:
            continue
        n = 1
        for d in dims:
            n *= d
        if n * 4 >= 64 * 2**20:
            total += n * 4
    return total


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    memory_stats: dict[str, int]
    collectives: dict[str, Any]
    model_flops: float | None = None
    useful_ratio: float | None = None
    # scan-once raw values from cost_analysis, kept for reference:
    scan_once_flops: float | None = None
    scan_once_bytes: float | None = None
    loop_multiplier: float | None = None

    def as_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def analyze(compiled, *, model_flops_global: float | None = None,
            num_devices: int | None = None,
            scan_lengths: set[int] | None = None) -> Roofline:
    """Loop-aware roofline terms from the compiled artifact.

    cost_analysis() counts while bodies once; the compute and collective terms
    therefore come from hlo_program (dot FLOPs / ring bytes x trip counts).
    The HBM term scales cost_analysis' scan-once byte count by the same
    multiplicity ratio (per-layer byte traffic is uniform across the scanned
    layers, so the ratio transfer is exact for the dominant contributors).
    """
    from repro.launch.hlo_program import analyze_program

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # JAX 0.4.x: one dict per device
        ca = ca[0] if ca else {}
    so_flops = float(ca.get("flops", 0.0))
    so_bytes = float(ca.get("bytes accessed", 0.0))
    hlo_text = compiled.as_text()
    prog = analyze_program(hlo_text)
    flops = max(prog.dot_flops, so_flops)
    loop_mult = flops / so_flops if so_flops > 0 else 1.0
    # Placeholder; callers (dryrun) override with the analytic model -- see
    # launch/analytic.py for why neither artifact byte count works.
    hbm = so_bytes * max(loop_mult, 1.0)
    ma = compiled.memory_analysis()
    mem_stats = {}
    if ma is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem_stats[f] = int(getattr(ma, f, 0))
        # True per-device footprint: donated outputs alias their arguments.
        mem_stats["footprint_bytes"] = (
            mem_stats["argument_size_in_bytes"]
            + mem_stats["temp_size_in_bytes"]
            + mem_stats["output_size_in_bytes"]
            - mem_stats["alias_size_in_bytes"])
        if scan_lengths:
            up = cpu_upcast_bytes(hlo_text, scan_lengths)
            mem_stats["cpu_upcast_bytes"] = up
            non_temp = (mem_stats["argument_size_in_bytes"]
                        + mem_stats["output_size_in_bytes"]
                        - mem_stats["alias_size_in_bytes"])
            # Upcasts live in temp; never subtract below the non-temp part.
            mem_stats["footprint_adjusted_bytes"] = non_temp + max(
                mem_stats["temp_size_in_bytes"] - up, 0)
    colls = parse_collectives(hlo_text)
    # Loop-aware collective volume from the program graph (parse_collectives'
    # static schedule is kept inside the record for the §Dry-run listing).
    # The roofline term uses the bf16-adjusted volume: XLA:CPU upcasts bf16
    # reductions to f32 on the wire; the TPU lowering does not.
    colls.wire_bytes = prog.wire_bytes_bf16
    colls.result_bytes["raw_f32_wire"] = int(prog.wire_bytes)
    colls.counts = {k: int(v) for k, v in prog.collective_counts.items()}

    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = colls.wire_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)

    model_flops_dev = None
    ratio = None
    if model_flops_global is not None and num_devices:
        model_flops_dev = model_flops_global / num_devices
        ratio = model_flops_dev / flops if flops else None
    return Roofline(flops, hbm, colls.wire_bytes, compute_s, memory_s,
                    collective_s, dominant, mem_stats, colls.as_dict(),
                    model_flops_dev, ratio,
                    scan_once_flops=so_flops, scan_once_bytes=so_bytes,
                    loop_multiplier=loop_mult)
