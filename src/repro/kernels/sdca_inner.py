"""Pallas TPU kernel for the worker's SDCA inner loop (Algorithm 2, line 4).

Runs H sequential ridge-SDCA coordinate steps on one worker partition with the
whole working set resident in VMEM:

    state: dalpha (n_k,), v (d,)            [kept in the loop carry]
    step : i = idx[h]
           z     = (w_eff + sigma' v) . x_i
           delta = (y_i - a_i - z) / (1 + sigma' ||x_i||^2 / (lambda n))
           dalpha[i] += delta ;  v += delta/(lambda n) * x_i

The loop is *inherently sequential* (each step reads the v written by the
previous one), so there is no MXU mapping -- this is a VPU/latency kernel. The
TPU adaptation vs. a CPU/GPU implementation is residency: the (n_k, d) data
tile, w_eff and the evolving v never leave VMEM during the H steps, so HBM
traffic is one read of the partition + O(n_k + d) instead of H * O(d).

Grid = workers (one program per partition, matching the paper's K workers);
the coordinate visit order is supplied via scalar prefetch so the index stream
is available in SMEM before the program body runs.

Capacity contract: n_k * d * 4B + 2*d*4B must fit VMEM (~16 MB/core), i.e.
n_k * d <~ 4M. ``ops.sdca_epoch`` falls back to the jnp path beyond that.
Ridge only (the paper's experiments); other losses use the jnp path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sdca_kernel(idx_row,  # (H,) int32 visit order for this worker (SMEM-read)
                 w_ref,  # (1, d) VMEM
                 alpha_ref,  # (1, n_k) VMEM
                 x_ref,  # (1, n_k, d) VMEM
                 y_ref,  # (1, n_k) VMEM
                 norms_ref,  # (1, n_k) VMEM
                 scal_ref,  # SMEM: [lam_n, sigma_prime]
                 dalpha_ref,  # out (1, n_k)
                 v_ref,  # out (1, d)
                 ):
    h_steps = idx_row.shape[0]
    lam_n = scal_ref[0]
    sigma_p = scal_ref[1]

    w_eff = w_ref[0, :]
    alpha = alpha_ref[0, :]
    y = y_ref[0, :]
    norms = norms_ref[0, :]

    def body(h, carry):
        dalpha, v = carry
        i = idx_row[h]
        # All-slice index tuple: a bare scalar 0 here breaks the JAX 0.4.x
        # interpret-mode discharge rule (int has no .shape).
        x_i = pl.load(x_ref, (pl.ds(0, 1), pl.ds(i, 1), slice(None)))[0, 0]  # (d,)
        a_i = alpha[i] + dalpha[i]
        z_i = jnp.dot(w_eff, x_i) + sigma_p * jnp.dot(v, x_i)
        q_i = sigma_p * norms[i] / lam_n
        delta = (y[i] - a_i - z_i) / (1.0 + q_i)
        dalpha = dalpha.at[i].add(delta)
        v = v + (delta / lam_n) * x_i
        return dalpha, v

    dalpha0 = jnp.zeros(alpha.shape, alpha.dtype)
    v0 = jnp.zeros(w_eff.shape, w_eff.dtype)
    dalpha, v = jax.lax.fori_loop(0, h_steps, body, (dalpha0, v0))
    dalpha_ref[0, :] = dalpha
    v_ref[0, :] = v


@functools.partial(jax.jit, static_argnames=("interpret",))
def sdca_inner_pallas(
    w_eff: jax.Array,  # (K, d)
    alpha: jax.Array,  # (K, n_k)
    X: jax.Array,  # (K, n_k, d)
    y: jax.Array,  # (K, n_k)
    norms_sq: jax.Array,  # (K, n_k)
    lam: float,
    n_global: int,
    sigma_prime: float,
    idx: jax.Array,  # (K, H) int32 visit order per worker
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """All-K-workers SDCA epoch; returns (dalpha (K,n_k), v (K,d))."""
    K, n_k, d = X.shape
    H = idx.shape[1]
    scal = jnp.array([lam * n_global, sigma_prime], jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(K,),
        in_specs=[
            pl.BlockSpec((1, d), lambda k, idx: (k, 0)),
            pl.BlockSpec((1, n_k), lambda k, idx: (k, 0)),
            pl.BlockSpec((1, n_k, d), lambda k, idx: (k, 0, 0)),
            pl.BlockSpec((1, n_k), lambda k, idx: (k, 0)),
            pl.BlockSpec((1, n_k), lambda k, idx: (k, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, n_k), lambda k, idx: (k, 0)),
            pl.BlockSpec((1, d), lambda k, idx: (k, 0)),
        ],
    )

    def kernel(idx_ref, w_ref, alpha_ref, x3_ref, y_ref, norms_ref, scal_ref,
               dalpha_ref, v_ref):
        k = pl.program_id(0)
        _sdca_kernel(idx_ref[k], w_ref, alpha_ref, x3_ref, y_ref, norms_ref,
                     scal_ref, dalpha_ref, v_ref)

    dalpha, v = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((K, n_k), X.dtype),
            jax.ShapeDtypeStruct((K, d), X.dtype),
        ],
        interpret=interpret,
    )(idx, w_eff, alpha, X, y, norms_sq, scal)
    return dalpha, v
