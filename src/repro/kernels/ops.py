"""Public jit'd wrappers around the Pallas kernels with automatic fallback.

On this container (CPU) the kernels execute in interpret mode; on a real TPU
set ``REPRO_PALLAS_INTERPRET=0`` (or rely on backend autodetection) to compile
them. The wrappers also enforce each kernel's capacity contract and fall back
to the pure-jnp oracle when it is not met, so callers never need to care.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.sdca_inner import sdca_inner_pallas
from repro.kernels.topk_filter import topk_filter_pallas

# VMEM capacity contract for the SDCA kernel: partition + vectors in f32.
_SDCA_VMEM_BUDGET = 4_000_000  # elements (~16 MB f32)


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def topk_filter(dw: jax.Array, k: int, *, use_kernel: bool = True,
                interpret: bool | None = None):
    """Message filter F: returns (sent, residual, mask). See Algorithm 2."""
    if not use_kernel:
        return ref.topk_filter_ref(dw, k)
    interpret = _interpret_default() if interpret is None else interpret
    return topk_filter_pallas(dw, k, interpret=interpret)


def sdca_epoch(w_eff, alpha, X, y, norms_sq, lam, n_global, sigma_prime, idx,
               *, loss: str = "ridge", use_kernel: bool = True,
               interpret: bool | None = None):
    """All-workers SDCA epoch: (dalpha (K,n_k), v (K,d)).

    Kernel path requires ridge loss and the VMEM capacity contract; anything
    else silently uses the jnp oracle (identical semantics).
    """
    K, n_k, d = X.shape
    fits = (n_k * d + 2 * d + 3 * n_k) <= _SDCA_VMEM_BUDGET
    if not use_kernel or loss != "ridge" or not fits:
        return ref.sdca_inner_ref(w_eff, alpha, X, y, norms_sq, lam, n_global,
                                  sigma_prime, idx)
    interpret = _interpret_default() if interpret is None else interpret
    return sdca_inner_pallas(w_eff, alpha, X, y, norms_sq, lam, n_global,
                             sigma_prime, idx, interpret=interpret)
