"""Pallas TPU kernel for the ACPD message filter (Algorithm 2, lines 7-9).

Selects the top ``k = ceil(rho d)`` entries of ``|dw|`` and splits ``dw`` into
(sent, residual). A full sort is O(d log d) and hostile to the TPU's tiled
memory system; instead we use the classic *histogram select*:

  1. ``histogram_kernel``: one sequential-grid pass over (8,128) VMEM tiles,
     accumulating ``counts[j] = #{ |x| >= edges[j] }`` for a geometric ladder of
     NUM_BUCKETS edges. The grid on TPU is sequential, so the counts block can
     be revisited and accumulated without atomics.
  2. a tiny on-device reduction picks the bucket band [t_lo, t_hi) that brackets
     the k-th magnitude; one refinement round re-histograms inside the band,
     giving an effective resolution of NUM_BUCKETS^2 (~4096 edges).
  3. ``emit_kernel``: second pass; keeps everything ``>= t_hi`` outright and
     admits band elements in index order until the remaining quota is used,
     carrying the running band-count in an SMEM scratch cell across the
     sequential grid.

Contract (see ops.topk_filter): exactly ``min(k, #{|x| >= t_floor})`` entries
are kept, every kept magnitude is >= t_lo, every dropped magnitude is < t_hi,
and ``sent + residual == dw`` *exactly* (bitwise) -- the conservation property
that error feedback relies on. On tie-free inputs whose k-th magnitude falls
strictly inside one refined bucket, the result equals exact top-k.

The GPU analogue in gradient-compression systems samples + sorts on CUDA
cores; the TPU adaptation replaces that with two streaming VPU passes whose
working set is one (8,128) tile in VMEM -- HBM traffic is exactly 2 reads +
1 write of dw, the roofline floor for this op.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NUM_BUCKETS = 64
LANE = 128
SUBLANE = 8
TILE = SUBLANE * LANE  # elements per grid step
# Dynamic range covered by the ladder, relative to max|x|. Entries smaller than
# max|x| * FLOOR are never selected (they are numerically irrelevant to the
# update and stay in the residual, which error feedback preserves).
FLOOR = 2.0**-22


def _bucket_edges(hi: jax.Array, lo: jax.Array) -> jax.Array:
    """Geometric ladder of NUM_BUCKETS edges descending from hi to lo."""
    hi = jnp.maximum(hi, 1e-37)
    lo = jnp.maximum(lo, hi * 1e-37)
    t = jnp.arange(NUM_BUCKETS, dtype=jnp.float32) / (NUM_BUCKETS - 1)
    return jnp.exp(jnp.log(hi) * (1.0 - t) + jnp.log(lo) * t)


def _histogram_kernel(x_ref, edges_ref, counts_ref):
    """counts[j] += #{ |tile| >= edges[j] } ; counts block is revisited."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    mag = jnp.abs(x_ref[...].astype(jnp.float32))  # (SUBLANE, LANE)
    edges = edges_ref[...]  # (1, NUM_BUCKETS)
    # (NUM_BUCKETS, SUBLANE*LANE) comparison, reduced over elements.
    ge = mag.reshape(1, -1) >= edges.reshape(NUM_BUCKETS, 1)
    counts_ref[...] += jnp.sum(ge, axis=1, dtype=jnp.int32).reshape(1, NUM_BUCKETS)


def _emit_kernel(x_ref, thresh_ref, sent_ref, resid_ref, mask_ref, band_used_ref):
    """Split tile into (sent, residual) given [t_lo, t_hi) + band quota.

    thresh_ref (SMEM): [t_lo, t_hi, quota]. band_used_ref (SMEM scratch):
    running count of admitted band elements across the sequential grid.
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        band_used_ref[0] = 0

    x = x_ref[...]
    mag = jnp.abs(x.astype(jnp.float32))
    t_lo = thresh_ref[0]
    t_hi = thresh_ref[1]
    quota = thresh_ref[2].astype(jnp.int32)

    strong = mag >= t_hi
    band = (mag >= t_lo) & (mag < t_hi)

    # Admit band elements in index order while quota lasts. The tile is a
    # contiguous row-major chunk, so flattening preserves index order.
    band_flat = band.reshape(-1)
    prefix_excl = jnp.cumsum(band_flat.astype(jnp.int32)) - band_flat.astype(jnp.int32)
    already = band_used_ref[0]
    admit = band_flat & (already + prefix_excl < quota)
    band_used_ref[0] = already + jnp.sum(band_flat.astype(jnp.int32))

    keep = strong | admit.reshape(strong.shape)
    sent_ref[...] = jnp.where(keep, x, jnp.zeros_like(x))
    resid_ref[...] = jnp.where(keep, jnp.zeros_like(x), x)
    mask_ref[...] = keep


def _pad_to_tiles(x: jax.Array) -> tuple[jax.Array, int]:
    d = x.shape[0]
    n_tiles = -(-d // TILE)
    pad = n_tiles * TILE - d
    xp = jnp.pad(x, (0, pad))
    return xp.reshape(n_tiles * SUBLANE, LANE), n_tiles


def _histogram(x2d: jax.Array, edges: jax.Array, n_tiles: int, interpret: bool) -> jax.Array:
    counts = pl.pallas_call(
        _histogram_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0)),
            pl.BlockSpec((1, NUM_BUCKETS), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, NUM_BUCKETS), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, NUM_BUCKETS), jnp.int32),
        interpret=interpret,
    )(x2d, edges.reshape(1, NUM_BUCKETS))
    return counts[0]


def _select_band(counts: jax.Array, edges: jax.Array, k: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Pick [t_lo, t_hi) bracketing the k-th magnitude from ladder counts.

    counts is monotone nondecreasing along descending edges. t_lo = first edge
    with count >= k (or the last edge if none), t_hi = previous edge
    (or +inf if even the largest edge already admits >= k).
    """
    reached = counts >= k
    j = jnp.argmax(reached)  # first True; 0 if none True (handled below)
    any_reached = jnp.any(reached)
    j = jnp.where(any_reached, j, NUM_BUCKETS - 1)
    t_lo = edges[j]
    t_hi = jnp.where(j > 0, edges[jnp.maximum(j - 1, 0)], jnp.inf)
    count_hi = jnp.where(j > 0, counts[jnp.maximum(j - 1, 0)], 0)
    return t_lo, t_hi, count_hi


@functools.partial(jax.jit, static_argnames=("k", "interpret", "refine"))
def topk_filter_pallas(dw: jax.Array, k: int, *, interpret: bool = True,
                       refine: bool = True):
    """Kernel-backed message filter. Returns (sent, residual, mask).

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container); on a real TPU pass interpret=False.
    """
    d = dw.shape[0]
    x2d, n_tiles = _pad_to_tiles(dw)

    mag_max = jnp.max(jnp.abs(dw)).astype(jnp.float32)
    edges = _bucket_edges(mag_max, mag_max * FLOOR)
    counts = _histogram(x2d, edges, n_tiles, interpret)
    t_lo, t_hi, count_hi = _select_band(counts, edges, k)

    if refine:
        # Second round inside [t_lo, t_hi): need (k - count_hi) more entries.
        edges2 = _bucket_edges(jnp.minimum(t_hi, mag_max), t_lo)
        counts2 = _histogram(x2d, edges2, n_tiles, interpret)
        # counts2 counts >= each refined edge; the elements >= t_hi are
        # included in every refined count, so subtract count_hi implicitly by
        # searching for (k) again on the refined ladder.
        t_lo, t_hi, count_hi = _select_band(counts2, edges2, k)

    quota = jnp.maximum(k - count_hi, 0).astype(jnp.float32)
    thresh = jnp.stack([t_lo, jnp.where(jnp.isinf(t_hi), jnp.float32(3.4e38), t_hi), quota])

    sent2d, resid2d, mask2d = pl.pallas_call(
        _emit_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0)),
            pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0)),
            pl.BlockSpec((SUBLANE, LANE), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x2d.shape, dw.dtype),
            jax.ShapeDtypeStruct(x2d.shape, dw.dtype),
            jax.ShapeDtypeStruct(x2d.shape, jnp.bool_),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(x2d, thresh)

    flat = lambda a: a.reshape(-1)[:d]
    return flat(sent2d), flat(resid2d), flat(mask2d)
