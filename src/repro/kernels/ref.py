"""Pure-jnp oracles for the Pallas kernels (the ground truth in tests)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sdca import solve_subproblem_indices


@functools.partial(jax.jit, static_argnames=("k",))
def topk_filter_ref(dw: jax.Array, k: int):
    """Exact top-k split (ties toward lower index): (sent, residual, mask)."""
    mag = jnp.abs(dw)
    _, idx = jax.lax.top_k(mag, k)
    mask = jnp.zeros(dw.shape, bool).at[idx].set(True)
    sent = jnp.where(mask, dw, jnp.zeros_like(dw))
    return sent, dw - sent, mask


def sdca_inner_ref(w_eff, alpha, X, y, norms_sq, lam, n_global, sigma_prime, idx):
    """vmapped-over-workers ridge SDCA epoch with explicit visit order."""
    fn = functools.partial(solve_subproblem_indices, loss="ridge")
    dalpha, v = jax.vmap(fn, in_axes=(0, 0, 0, 0, 0, None, None, None, 0))(
        w_eff, alpha, X, y, norms_sq, lam, n_global, sigma_prime, idx)
    return dalpha, v
