"""Pallas TPU flash-attention forward kernel (GQA, causal).

The serving/prefill hot-spot of the framework. Grid is
(batch, kv_head, q_group, q_block, kv_block) with the kv_block axis innermost
and sequential: the (bq, hd) output tile plus the online-softmax running
statistics (m, l) live in VMEM scratch across kv steps, and only the final
normalized tile is written back -- HBM traffic is one read of Q + nq reads of
K/V tiles + one write of O, the flash roofline.

GQA without replication: the K/V BlockSpec index maps ignore the q_group axis,
so all G query groups of one KV head stream the same K/V tiles (no jnp.repeat
materialization).

Causality is handled two ways: fully-masked kv blocks are skipped via
``@pl.when`` (on real hardware this prunes ~half the MXU work; the jnp path
can't skip without breaking differentiability -- this asymmetry is the reason
the kernel exists), and the diagonal block applies the elementwise mask.

Training and sliding-window layers use the jnp custom-VJP path
(models/flash.py); this kernel covers the fwd-only inference path and is
validated against that implementation in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_fwd_kernel(q_ref,  # (1, 1, 1, bq, hd)
                      k_ref,  # (1, 1, bk, hd)
                      v_ref,  # (1, 1, bk, hd)
                      o_ref,  # (1, 1, 1, bq, hd)
                      m_scr,  # VMEM (bq,)
                      l_scr,  # VMEM (bq,)
                      acc_scr,  # VMEM (bq, hd)
                      *, causal: bool, sm_scale: float, bq: int, bk: int,
                      nk: int, seq_len: int):
    qi = pl.program_id(3)
    kj = pl.program_id(4)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Skip blocks strictly above the diagonal (causal).
    run = (not causal) or (kj * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0, 0, 0].astype(jnp.float32) * sm_scale  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = q @ k.T  # (bq, bk)
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < seq_len
        if causal:
            mask &= kpos <= qpos
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v
        m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention_fwd_pallas(
    q: jax.Array,  # (B, S, KV, G, hd) -- NOT pre-scaled
    k: jax.Array,  # (B, S, KV, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Returns (B, S, KV, G, hd). Pads S to block multiples internally."""
    B, S, KV, G, hd = q.shape
    bq = min(block_q, max(8, S))
    bk = min(block_k, max(8, S))
    nq = -(-S // bq)
    nk = -(-S // bk)
    Sq, Sk = nq * bq, nk * bk
    sm_scale = hd**-0.5

    qt = jnp.pad(q, ((0, 0), (0, Sq - S), (0, 0), (0, 0), (0, 0)))
    qt = qt.transpose(0, 2, 3, 1, 4)  # (B, KV, G, Sq, hd)
    kt = jnp.pad(k, ((0, 0), (0, Sk - S), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v, ((0, 0), (0, Sk - S), (0, 0), (0, 0))).transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_fwd_kernel, causal=causal,
                               sm_scale=sm_scale, bq=bq, bk=bk, nk=nk,
                               seq_len=S)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, G, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, bq, hd),
                         lambda b, h, g, i, j: (b, h, g, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, g, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, g, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, bq, hd),
                               lambda b, h, g, i, j: (b, h, g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 3, 1, 2, 4)[:, :S]
