"""Problem registry: named, JSON-parameterizable dataset builders.

An :class:`ExperimentSpec` references a problem by registry entry name plus a
flat params dict, so a spec file fully determines the dataset (the container
has no network access -- every entry is a deterministic synthetic generator,
see :mod:`repro.data.synthetic`).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

from repro.core.objectives import Problem
from repro.data.synthetic import LinearDatasetSpec, make_linear_problem

_PROBLEMS: dict[str, Callable[..., Problem]] = {}


def register_problem(name: str):
    """Decorator: register a keyword-only problem builder under ``name``."""

    def deco(fn: Callable[..., Problem]) -> Callable[..., Problem]:
        _PROBLEMS[name] = fn
        return fn

    return deco


def available_problems() -> tuple[str, ...]:
    return tuple(sorted(_PROBLEMS))


@dataclasses.dataclass(frozen=True)
class ProblemSpec:
    """A registry entry name + its keyword parameters (JSON-round-trippable)."""

    kind: str
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def build(self) -> Problem:
        try:
            fn = _PROBLEMS[self.kind]
        except KeyError:
            raise ValueError(
                f"unknown problem {self.kind!r}; available: "
                f"{available_problems()}") from None
        return fn(**self.params)

    def to_dict(self) -> dict[str, Any]:
        return {"kind": self.kind, "params": dict(self.params)}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ProblemSpec":
        return cls(kind=d["kind"], params=dict(d.get("params", {})))


def build_problem(spec: ProblemSpec) -> Problem:
    return spec.build()


@register_problem("linear_synthetic")
def linear_synthetic(*, num_workers: int = 4, n_per_worker: int = 512,
                     d: int = 8192, nnz_per_row: int = 64,
                     label_noise: float = 0.05, task: str = "classification",
                     seed: int = 0, lam: float = 1e-4,
                     loss: str = "ridge") -> Problem:
    """The generic K-partitioned sparse linear problem (Assumption 1 data)."""
    spec = LinearDatasetSpec(num_workers=num_workers, n_per_worker=n_per_worker,
                             d=d, nnz_per_row=nnz_per_row,
                             label_noise=label_noise, task=task, seed=seed)
    return make_linear_problem(spec, lam=lam, loss=loss)


@register_problem("rcv1_like")
def rcv1_like(*, K: int = 4, seed: int = 7, d: int = 2048,
              n_per_worker: int = 192, nnz_per_row: int = 24,
              lam: float = 1e-3, loss: str = "ridge") -> Problem:
    """Scaled-down stand-in for the paper's RCV1 split (benchmark default)."""
    return linear_synthetic(num_workers=K, n_per_worker=n_per_worker, d=d,
                            nnz_per_row=nnz_per_row, seed=seed, lam=lam,
                            loss=loss)
