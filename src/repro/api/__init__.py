"""The public API surface: declarative specs + streaming sessions.

Everything a caller needs lives here:

* :class:`ExperimentSpec` / :class:`MethodEntry` / :class:`ProblemSpec` --
  declarative, JSON-round-trippable experiment descriptions;
* :class:`Session` / :class:`Experiment` and the typed event stream
  (:class:`RoundEvent`, :class:`EvalEvent`, :class:`SyncEvent`,
  :class:`StopEvent`) -- streaming execution with early stop, on either
  execution backend (``executor="auto"|"event"|"scan"`` -- the scan-fused
  whole-run executor is bit-identical to the event loop, see
  docs/performance.md);
* :func:`run_sweep` / :func:`sweep_spec` -- whole delay x seed x gamma
  grids of any scan-capable method (lockstep AND ``lag``) as ONE compiled
  computation, optionally sharded over the local device mesh
  (``shard="auto"|"none"|"cells"|"workers"``; :func:`run_lockstep_sweep`
  is the lockstep-only compat wrapper); :func:`run_sweep_cells` runs an
  EXPLICIT list of :class:`SweepCellSpec` cells through the same compiled
  callables -- the entry point the multi-tenant service layer
  (:mod:`repro.serve`) batches coalesced tenant requests through;
* the :mod:`repro.core.compress` ``Compressor`` registry (re-exported) --
  the shared payload-compression extension point for both the simulator and
  the transformer exchange path;
* the :mod:`repro.core.delays` ``DelayModel`` registry (re-exported) -- the
  pluggable worker-delay axis (``ClusterModel.delay_model``);
* preset spec builders for the paper's figures plus the straggler-zoo
  family (:mod:`repro.api.presets`).

CLI: ``python -m repro run spec.json`` / ``python -m repro spec <preset>`` /
``python -m repro bench [--quick] [--only ...]``.

Legacy one-shot entry points (``repro.core.acpd.run_method``,
``repro.core.engine.run_method``) remain as thin wrappers that drain a
Session and fold the events into a ``RunResult``.
"""

from repro.api.presets import PRESETS, build_preset  # noqa: F401
from repro.api.problems import (  # noqa: F401
    ProblemSpec,
    available_problems,
    build_problem,
    register_problem,
)
from repro.api.session import (  # noqa: F401
    EvalEvent,
    Experiment,
    RoundEvent,
    Session,
    SessionEvent,
    StopEvent,
    SyncEvent,
)
from repro.api.spec import ExperimentSpec, MethodEntry  # noqa: F401
from repro.api.sweep import (  # noqa: F401
    ShardPlan,
    SweepCellSpec,
    SweepVariant,
    resolve_shard,
    run_lockstep_sweep,
    run_sweep,
    run_sweep_cells,
    sweep_spec,
    sweep_supported,
)
from repro.core.compress import (  # noqa: F401
    Compressor,
    available_compressors,
    get_compressor,
    register_compressor,
)
from repro.core.delays import (  # noqa: F401
    DelayModel,
    available_delays,
    get_delay,
    register_delay,
)
from repro.core.solvers import (  # noqa: F401
    available_solvers,
    get_solver,
    register_solver,
)

__all__ = [
    "Compressor",
    "DelayModel",
    "EvalEvent",
    "Experiment",
    "ExperimentSpec",
    "MethodEntry",
    "PRESETS",
    "ProblemSpec",
    "RoundEvent",
    "Session",
    "SessionEvent",
    "ShardPlan",
    "StopEvent",
    "SweepCellSpec",
    "SweepVariant",
    "SyncEvent",
    "available_compressors",
    "available_delays",
    "available_problems",
    "available_solvers",
    "build_preset",
    "build_problem",
    "get_compressor",
    "get_delay",
    "get_solver",
    "register_compressor",
    "register_delay",
    "register_solver",
    "resolve_shard",
    "run_lockstep_sweep",
    "run_sweep",
    "run_sweep_cells",
    "sweep_spec",
    "sweep_supported",
]
