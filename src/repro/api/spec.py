"""Declarative, JSON-round-trippable experiment specs.

An :class:`ExperimentSpec` is the serializable description of one complete
experiment: a problem registry entry, a :class:`ClusterModel`, a list of
methods (each a :class:`MethodConfig` plus its round budget), the eval/stop
policy and the seed. ``to_json``/``from_json`` round-trip losslessly
(``spec == ExperimentSpec.from_json(spec.to_json())``), so benchmarks,
examples, the ``python -m repro`` CLI and future live-serving hooks all share
one entry point -- see :class:`repro.api.session.Session` for execution.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping

from repro.core.acpd import MethodConfig
from repro.core.simulate import ClusterModel
from repro.api.problems import ProblemSpec


def _cluster_to_dict(c: ClusterModel) -> dict[str, Any]:
    d = dataclasses.asdict(c)
    d["straggler_workers"] = list(c.straggler_workers)
    # Normalized (name, value) pairs -> a plain JSON object; ClusterModel's
    # __post_init__ re-normalizes on the way back in.
    d["delay_params"] = dict(c.delay_params)
    # (worker, drop, rejoin) triples -> JSON [worker, drop, rejoin-or-null].
    d["membership"] = [list(e) for e in c.membership]
    return d


def _cluster_from_dict(d: Mapping[str, Any]) -> ClusterModel:
    kw = dict(d)
    if "straggler_workers" in kw:
        kw["straggler_workers"] = tuple(kw["straggler_workers"])
    if "membership" in kw:
        kw["membership"] = tuple(tuple(e) for e in kw["membership"])
    return ClusterModel(**kw)


def _method_from_dict(d: Mapping[str, Any]) -> MethodConfig:
    return MethodConfig(**dict(d))


@dataclasses.dataclass(frozen=True)
class MethodEntry:
    """One method inside a spec: the config plus its outer-round budget."""

    config: MethodConfig
    num_outer: int

    def to_dict(self) -> dict[str, Any]:
        return {"config": dataclasses.asdict(self.config),
                "num_outer": self.num_outer}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "MethodEntry":
        return cls(config=_method_from_dict(d["config"]),
                   num_outer=int(d["num_outer"]))


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """The single declarative description of an experiment run.

    ``target_gap`` / ``time_budget`` are the early-stop policy: a session
    streaming this spec stops once the duality gap reaches ``target_gap``
    (evaluated every ``eval_every`` rounds) or the simulated clock passes
    ``time_budget`` seconds, whichever comes first.

    ``executor`` picks the execution backend per method run: ``"auto"``
    (default) compiles whole runs to one ``lax.scan`` when the protocol and
    stop policy allow it and falls back to the event queue otherwise;
    ``"event"`` / ``"scan"`` force a backend (see docs/performance.md).
    Both backends produce bit-identical results, so the field is a pure
    speed axis and old spec JSONs (without it) keep their meaning.

    ``shard`` picks how batched sweep executions
    (:func:`repro.api.sweep.run_sweep` / :func:`repro.api.sweep.sweep_spec`)
    partition work over the local device mesh: ``"auto"`` (default) shards
    the sweep-cell axis over all local devices when more than one exists and
    degrades to the single-device vmap path otherwise; ``"none"`` forces the
    unsharded path; ``"cells"`` / ``"workers"`` force an axis (see
    docs/performance.md).  Like ``executor``, a pure speed axis: old spec
    JSONs keep their meaning, and single-``Session`` runs ignore it.
    """

    name: str
    problem: ProblemSpec
    cluster: ClusterModel
    methods: tuple[MethodEntry, ...]
    eval_every: int = 1
    seed: int = 0
    target_gap: float | None = None
    time_budget: float | None = None
    executor: str = "auto"
    shard: str = "auto"
    # Checkpoint cadence (rounds): with it set, sessions for this spec run
    # as resumable scan segments and snapshot the carry every N rounds
    # (``repro.core.executor.run_lockstep_checkpointed``); the snapshot
    # location is execution state, not spec state, so it travels separately
    # (``Experiment(spec, checkpoint_dir=...)`` / the service's
    # ``checkpoint_dir``).  ``None`` (the default -- old spec JSONs keep
    # their meaning) never checkpoints.
    checkpoint_every: int | None = None

    def __post_init__(self):
        object.__setattr__(self, "methods", tuple(self.methods))

    def method_named(self, name: str) -> MethodEntry:
        for entry in self.methods:
            if entry.config.name == name:
                return entry
        raise KeyError(f"no method named {name!r} in spec {self.name!r}")

    # -- validation --------------------------------------------------------

    def validate(self) -> "ExperimentSpec":
        """Resolve every registry name and structural invariant WITHOUT
        building the dataset or compiling anything; returns ``self``.

        Raises ``ValueError`` naming the bad entry AND the full list of
        known entries (problem kinds, protocols, compressors, delay models,
        local solvers) so a caller -- in particular the serve layer's
        admission gate (:class:`repro.serve.ExperimentService`), where a
        queued bad spec must never reach a batch and poison its cohort --
        can reject at enqueue time with an actionable message.  ``Session``
        construction performs the same resolution; this front-loads it for
        specs that are queued before they run.
        """
        import inspect

        from repro.api import problems as problems_lib
        from repro.core import compress as compress_lib
        from repro.core import delays as delays_lib
        from repro.core import engine as engine_lib
        from repro.core import solvers as solvers_lib

        errors: list[str] = []
        builder = problems_lib._PROBLEMS.get(self.problem.kind)
        if builder is None:
            errors.append(
                f"unknown problem {self.problem.kind!r}; available: "
                f"{problems_lib.available_problems()}")
        else:
            params = inspect.signature(builder).parameters
            unknown = sorted(set(self.problem.params) - set(params))
            if unknown:
                errors.append(
                    f"problem {self.problem.kind!r} got unknown params "
                    f"{unknown}; accepted: {sorted(params)}")
        try:
            delays_lib.get_delay(self.cluster.delay_model)
        except ValueError as e:
            errors.append(str(e))
        if not self.methods:
            errors.append("spec declares no methods")
        names = [m.config.name for m in self.methods]
        if len(set(names)) != len(names):
            errors.append(f"duplicate method names in spec: {names}")
        for entry in self.methods:
            cfg = entry.config
            where = f"method {cfg.name!r}"
            if cfg.protocol not in engine_lib.available_protocols():
                errors.append(
                    f"{where}: unknown protocol {cfg.protocol!r}; "
                    f"available: {engine_lib.available_protocols()}")
            if cfg.compressor is not None:
                try:
                    compress_lib.get_compressor(cfg.compressor)
                except ValueError as e:
                    errors.append(f"{where}: {e}")
            try:
                solvers_lib.get_solver(cfg.local_solver)
            except ValueError as e:
                errors.append(f"{where}: {e}")
            if entry.num_outer <= 0:
                errors.append(f"{where}: num_outer must be >= 1, got "
                              f"{entry.num_outer}")
            if not 1 <= cfg.B <= self.cluster.num_workers:
                errors.append(
                    f"{where}: B={cfg.B} outside [1, K={self.cluster.num_workers}]")
            if cfg.n_chunks < 1:
                errors.append(f"{where}: n_chunks must be >= 1, got "
                              f"{cfg.n_chunks}")
            elif cfg.n_chunks > cfg.H:
                errors.append(
                    f"{where}: n_chunks={cfg.n_chunks} exceeds H={cfg.H}: "
                    f"every chunk needs at least one local step")
            if cfg.pw_quantum is not None and cfg.pw_quantum <= 0:
                errors.append(f"{where}: pw_quantum must be > 0, got "
                              f"{cfg.pw_quantum}")
            K = self.cluster.num_workers
            if cfg.protocol == "hierarchical_b":
                if not 1 <= cfg.n_racks <= K:
                    errors.append(f"{where}: n_racks={cfg.n_racks} outside "
                                  f"[1, K={K}]")
                else:
                    sizes = [sum(1 for k in range(K)
                                 if k * cfg.n_racks // K == r)
                             for r in range(cfg.n_racks)]
                    if not 1 <= cfg.rack_b <= min(sizes):
                        errors.append(
                            f"{where}: rack_b={cfg.rack_b} outside "
                            f"[1, min rack size={min(sizes)}] (racks of "
                            f"{sizes})")
            if self.cluster.membership:
                try:
                    proto_cls = engine_lib.get_protocol(cfg.protocol)
                except ValueError:
                    proto_cls = None  # unknown protocol: reported above
                if proto_cls is not None and not getattr(
                        proto_cls, "supports_membership", False):
                    errors.append(
                        f"{where}: protocol {cfg.protocol!r} does not "
                        f"support the cluster's elastic membership schedule "
                        f"(supporting protocols declare supports_membership)")
        for entry in self.cluster.membership:
            k, drop, rejoin = entry
            if not 0 <= k < self.cluster.num_workers:
                errors.append(
                    f"membership entry {list(entry)}: worker {k} outside "
                    f"[0, K={self.cluster.num_workers})")
            if drop < 0:
                errors.append(f"membership entry {list(entry)}: drop time "
                              f"must be >= 0")
            if rejoin is not None and rejoin <= drop:
                errors.append(
                    f"membership entry {list(entry)}: rejoin time must be "
                    f"> drop time (use null for never-rejoins)")
        if self.eval_every <= 0:
            errors.append(f"eval_every must be >= 1, got {self.eval_every}")
        if self.checkpoint_every is not None:
            from repro.core import executor as executor_lib

            if self.checkpoint_every < 1:
                errors.append(f"checkpoint_every must be >= 1, got "
                              f"{self.checkpoint_every}")
            for entry in self.methods:
                ok, why = executor_lib.checkpoint_supported(
                    entry.config, self.cluster, target_gap=self.target_gap,
                    time_budget=self.time_budget)
                if not ok:
                    errors.append(
                        f"method {entry.config.name!r}: {why}")
        if self.executor not in ("auto", "event", "scan"):
            errors.append(f"unknown executor {self.executor!r}; expected "
                          f"'auto', 'event' or 'scan'")
        from repro.api.sweep import SHARD_MODES
        if self.shard not in SHARD_MODES:
            errors.append(f"unknown shard mode {self.shard!r}; expected one "
                          f"of {SHARD_MODES}")
        if errors:
            raise ValueError(
                f"invalid spec {self.name!r}: " + "; ".join(errors))
        return self

    # -- serialization -----------------------------------------------------

    def to_dict(self) -> dict[str, Any]:
        return {
            "name": self.name,
            "problem": self.problem.to_dict(),
            "cluster": _cluster_to_dict(self.cluster),
            "methods": [m.to_dict() for m in self.methods],
            "eval_every": self.eval_every,
            "seed": self.seed,
            "target_gap": self.target_gap,
            "time_budget": self.time_budget,
            "executor": self.executor,
            "shard": self.shard,
            "checkpoint_every": self.checkpoint_every,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExperimentSpec":
        return cls(
            name=d["name"],
            problem=ProblemSpec.from_dict(d["problem"]),
            cluster=_cluster_from_dict(d["cluster"]),
            methods=tuple(MethodEntry.from_dict(m) for m in d["methods"]),
            eval_every=int(d.get("eval_every", 1)),
            seed=int(d.get("seed", 0)),
            target_gap=d.get("target_gap"),
            time_budget=d.get("time_budget"),
            executor=d.get("executor", "auto"),
            shard=d.get("shard", "auto"),
            checkpoint_every=d.get("checkpoint_every"),
        )

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(text))

    @classmethod
    def load(cls, path) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
