"""Streaming run sessions: the engine's round loop as a typed event stream.

A :class:`Session` replaces one-shot execution. It owns the canonical
priority-queue event loop (moved here from ``core/engine.py``) and yields
typed events as the simulation advances:

* :class:`RoundEvent` -- one server round applied: live sim-clock and
  byte/time accounting;
* :class:`SyncEvent`  -- the round was a full-K barrier (the T-periodic sync
  for the group family, every round for the CoCoA lineage);
* :class:`EvalEvent`  -- a duality-gap certificate (streamed per eval
  boundary in ``eval_mode="stream"``, or emitted in one deferred batch after
  the loop in the bit-exact ``"batched"``/``"replay"`` modes);
* :class:`StopEvent`  -- why the session ended (``completed``,
  ``target_gap``, or ``time_budget``).

Early stop: ``target_gap`` stops once the streamed gap reaches the target
(forces ``eval_mode="stream"``); ``time_budget`` stops once the simulated
clock passes the budget. ``engine.run_method`` / ``acpd.run_method`` are thin
compat wrappers that drain the stream and fold it back into a ``RunResult``
-- the tests/test_engine.py bit-for-bit pins hold through them.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Iterator

from repro.core import engine, objectives
from repro.core import compress as compress_lib
from repro.core import executor as executor_lib
from repro.core import solvers as solvers_lib
from repro.core.acpd import MethodConfig, RunRecord, RunResult
from repro.core.simulate import ClusterModel

# ---------------------------------------------------------------------------
# Events.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RoundEvent:
    """One server round applied; accounting totals as of this round."""

    iteration: int
    sim_time: float
    arrivals: int
    bytes_up: int
    bytes_down: int
    compute_time: float
    comm_time: float


@dataclasses.dataclass(frozen=True)
class SyncEvent:
    """The round just applied was a full-K barrier."""

    iteration: int
    sim_time: float


@dataclasses.dataclass(frozen=True)
class EvalEvent:
    """A duality-gap certificate at an eval boundary (mirrors RunRecord)."""

    iteration: int
    sim_time: float
    gap: float
    gap_server: float
    primal: float
    dual: float
    bytes_up: int
    bytes_down: int
    compute_time: float
    comm_time: float

    def to_record(self) -> RunRecord:
        return RunRecord(**dataclasses.asdict(self))


@dataclasses.dataclass(frozen=True)
class StopEvent:
    """The session ended: ``completed`` | ``target_gap`` | ``time_budget``."""

    reason: str
    iteration: int
    sim_time: float


SessionEvent = RoundEvent | SyncEvent | EvalEvent | StopEvent


# ---------------------------------------------------------------------------
# The session.
# ---------------------------------------------------------------------------


class Session:
    """A streaming run of one method through the protocol engine.

    Iterate :meth:`events` (or the session itself) for live consumption, or
    call :meth:`run` to drain and get the folded :class:`RunResult`.

    ``eval_mode``:

    * ``"batched"`` (default) -- gap certificates deferred to one ``lax.map``
      dispatch after the loop; ``EvalEvent``\\ s arrive at the end.
      Bit-exact with the reference loops (pinned).
    * ``"replay"``  -- deferred, op-for-op eager certificates (debug oracle).
    * ``"stream"``  -- certificates computed at each eval boundary and
      streamed live; required for (and implied by) ``target_gap`` early stop.

    ``executor``:

    * ``"auto"`` (default) -- the scan-fused whole-run backend
      (:mod:`repro.core.executor`) whenever the run qualifies (lockstep
      protocols always, including ``target_gap`` early stop -- whose
      certificate moves in-graph -- up to
      ``executor.GAP_SCAN_AUTO_MAX_ROUNDS`` budgeted rounds; ``lag`` when
      the delay stream is pre-sampleable and not early-stopped;
      ``time_budget`` always events), the event queue otherwise.  Both
      backends produce bit-identical ``RunResult`` streams, so "auto" is a
      pure speed axis.
    * ``"event"`` -- force the per-round priority-queue loop.
    * ``"scan"``  -- force whole-run compilation; raises ``ValueError`` with
      the reason when the run cannot scan (docs/performance.md has the
      support matrix).
    """

    def __init__(self, problem: objectives.Problem, method: MethodConfig,
                 cluster: ClusterModel, *, num_outer: int, seed: int = 0,
                 eval_every: int = 1, eval_mode: str = "batched",
                 target_gap: float | None = None,
                 time_budget: float | None = None,
                 executor: str = "auto",
                 checkpoint_dir=None, checkpoint_every: int | None = None,
                 _segment_hook=None):
        if (checkpoint_every is None) != (checkpoint_dir is None):
            raise ValueError("checkpoint_dir and checkpoint_every come "
                             "together: set both or neither")
        if checkpoint_every is not None:
            if checkpoint_every < 1:
                raise ValueError(
                    f"checkpoint_every must be >= 1, got {checkpoint_every}")
            ok, why = executor_lib.checkpoint_supported(
                method, cluster, target_gap=target_gap,
                time_budget=time_budget)
            if not ok:
                raise ValueError(f"run cannot checkpoint: {why}")
            executor = "scan"  # segments are a scan-backend construct
        if target_gap is not None:
            eval_mode = "stream"  # gap early-stop needs live certificates
        if eval_mode not in ("batched", "replay", "stream"):
            raise ValueError(f"unknown eval_mode {eval_mode!r}")
        if executor not in ("auto", "event", "scan"):
            raise ValueError(f"unknown executor {executor!r}; expected "
                             f"'auto', 'event' or 'scan'")
        # Resolve names the run might otherwise never (or only late) check:
        # the sync protocols ignore the compressor at run time and only the
        # CoCoA lineage resolves the local solver.  Protocol and delay-model
        # names are covered by the construction below itself (Protocol
        # __init__ calls cluster.make_delay()), all with the same
        # registry-listing ValueError.
        if method.compressor is not None:
            compress_lib.get_compressor(method.compressor)
        solvers_lib.get_solver(method.local_solver)
        # The protocol instance is constructed for BOTH executors: its
        # __init__ carries the per-protocol validation (cocoa's gamma bound,
        # lag_window >= 1, async's B=1) and the event loop's state; the scan
        # backend re-derives its own state from the same (spec, seed).
        self.proto = engine.get_protocol(method.protocol)(
            problem, method, cluster, seed=seed)
        ok, why = executor_lib.scan_supported(
            method, cluster, eval_mode=eval_mode, target_gap=target_gap,
            time_budget=time_budget)
        if executor == "scan" and not ok:
            raise ValueError(f"executor='scan' cannot run this spec: {why}")
        # auto + target_gap: the gap scan computes (masked) rounds to the
        # end of the budget, so past GAP_SCAN_AUTO_MAX_ROUNDS the event
        # loop's stop-at-the-hit wins; executor="scan" still forces it.
        auto_ok = ok and not (
            target_gap is not None
            and num_outer > executor_lib.GAP_SCAN_AUTO_MAX_ROUNDS)
        self.executor = "scan" if (executor == "scan"
                                   or (executor == "auto" and auto_ok)) \
            else "event"
        self.problem = problem
        self.method = method
        self.cluster = cluster
        self.seed = seed
        self.num_outer = num_outer
        self.eval_every = eval_every
        self.eval_mode = eval_mode
        self.target_gap = target_gap
        self.time_budget = time_budget
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self._segment_hook = _segment_hook
        self._result: RunResult | None = None
        self._events: Iterator[SessionEvent] | None = None

    # -- streaming ---------------------------------------------------------

    def events(self) -> Iterator[SessionEvent]:
        """The event stream. Single-use; created lazily on first call."""
        if self._events is None:
            self._events = self._generate()
        return self._events

    def __iter__(self) -> Iterator[SessionEvent]:
        return self.events()

    def run(self) -> RunResult:
        """Drain the stream and return the folded RunResult."""
        for _ in self.events():
            pass
        return self.result()

    def result(self) -> RunResult:
        if self._result is None:
            raise RuntimeError("session not finished; drain events() or call "
                               "run() first")
        return self._result

    # -- the canonical loop ------------------------------------------------

    def _eval_stream(self, snap) -> EvalEvent:
        cert = objectives.gap_certificate(self.problem, snap.alpha, w=snap.w)
        return EvalEvent(
            iteration=snap.iteration, sim_time=snap.sim_time,
            gap=cert["gap"], gap_server=cert["gap_server"],
            primal=cert["primal"], dual=cert["dual"],
            bytes_up=snap.bytes_up, bytes_down=snap.bytes_down,
            compute_time=snap.compute_time, comm_time=snap.comm_time)

    def _generate(self) -> Iterator[SessionEvent]:
        if self.executor == "scan":
            yield from self._generate_scan()
            return
        proto = self.proto
        queue: list[engine.Message] = []
        for msg in proto.initial_messages():
            heapq.heappush(queue, msg)

        snaps = []  # deferred-eval snapshots ("batched"/"replay")
        records: list[RunRecord] = []  # streamed records ("stream")
        streaming = self.eval_mode == "stream"
        iteration = 0
        reason = "completed"

        for r in range(proto.num_rounds(self.num_outer)):
            need = proto.arrivals_needed(r)
            arrived = [heapq.heappop(queue) for _ in range(need)]
            for msg in proto.process_round(r, arrived):
                heapq.heappush(queue, msg)
            iteration += 1

            yield RoundEvent(
                iteration=iteration, sim_time=proto.sim_time,
                arrivals=len(arrived), bytes_up=proto.bytes_up,
                bytes_down=proto.bytes_down, compute_time=proto.compute_time,
                comm_time=proto.comm_time)
            if proto.is_sync_round(r):
                yield SyncEvent(iteration=iteration, sim_time=proto.sim_time)

            evaluated = iteration % self.eval_every == 0
            if evaluated:
                snap = proto.snapshot(iteration)
                if streaming:
                    ev = self._eval_stream(snap)
                    records.append(ev.to_record())
                    yield ev
                    if (self.target_gap is not None
                            and ev.gap <= self.target_gap):
                        reason = "target_gap"
                        break
                else:
                    snaps.append(snap)

            if (self.time_budget is not None
                    and proto.sim_time >= self.time_budget):
                reason = "time_budget"
                if not evaluated:
                    # Terminal certificate so the result reflects the state
                    # at the stop point.
                    snap = proto.snapshot(iteration)
                    if streaming:
                        ev = self._eval_stream(snap)
                        records.append(ev.to_record())
                        yield ev
                    else:
                        snaps.append(snap)
                break

        if not streaming:
            records = engine._materialize_records(snaps, self.problem,
                                                  self.eval_mode)
            for rec in records:
                yield EvalEvent(**dataclasses.asdict(rec))
        self._result = proto.finalize(records)
        yield StopEvent(reason=reason, iteration=iteration,
                        sim_time=proto.sim_time)

    def _generate_scan(self) -> Iterator[SessionEvent]:
        """The scan backend's stream: the run executes as one compiled
        computation up front, then the identical event sequence is replayed
        from its per-round accounting.

        In ``eval_mode="stream"`` (a ``target_gap`` run: the certificates
        were computed in-graph) the replay interleaves ``EvalEvent``\\ s at
        their boundaries, exactly like the live event loop; deferred modes
        keep the emit-evals-at-the-end contract."""
        if self.checkpoint_every is not None:
            run = executor_lib.run_lockstep_checkpointed(
                self.problem, self.method, self.cluster,
                num_outer=self.num_outer, seed=self.seed,
                eval_every=self.eval_every,
                checkpoint_dir=self.checkpoint_dir,
                checkpoint_every=self.checkpoint_every,
                norms_sq=self.proto.norms_sq,
                segment_hook=self._segment_hook)
        else:
            run = executor_lib.run_scan(self.problem, self.method,
                                        self.cluster,
                                        num_outer=self.num_outer,
                                        seed=self.seed,
                                        eval_every=self.eval_every,
                                        norms_sq=self.proto.norms_sq,
                                        target_gap=self.target_gap)
        records = run.materialize_records(self.problem, self.eval_mode)
        streaming = self.eval_mode == "stream"
        rec_iter = iter(records)
        iteration = 0
        for acct in run.rounds:
            iteration += 1
            yield RoundEvent(
                iteration=iteration, sim_time=acct.sim_time,
                arrivals=acct.arrivals, bytes_up=acct.bytes_up,
                bytes_down=acct.bytes_down, compute_time=acct.compute_time,
                comm_time=acct.comm_time)
            if acct.is_sync:
                yield SyncEvent(iteration=iteration, sim_time=acct.sim_time)
            if streaming and iteration % self.eval_every == 0:
                yield EvalEvent(**dataclasses.asdict(next(rec_iter)))
        if not streaming:
            for rec in records:
                yield EvalEvent(**dataclasses.asdict(rec))
        self._result = run.finalize(records)
        yield StopEvent(reason=run.stop_reason, iteration=iteration,
                        sim_time=run.rounds[-1].sim_time if run.rounds
                        else 0.0)


# ---------------------------------------------------------------------------
# Spec-level execution.
# ---------------------------------------------------------------------------


class Experiment:
    """An :class:`ExperimentSpec` bound to its built problem.

    Builds the dataset once; hands out one :class:`Session` per method entry.
    """

    def __init__(self, spec, *, checkpoint_dir=None):
        if spec.checkpoint_every is not None and checkpoint_dir is None:
            raise ValueError(
                "spec sets checkpoint_every: pass checkpoint_dir to "
                "Experiment (where should the snapshots live?)")
        self.spec = spec
        self.problem = spec.problem.build()
        self.cluster = spec.cluster
        self.checkpoint_dir = checkpoint_dir

    def session(self, entry, *, eval_mode: str | None = None,
                executor: str | None = None, _segment_hook=None) -> Session:
        spec = self.spec
        if entry.config.exact_dual_feedback:
            raise ValueError(
                "exact_dual_feedback runs on the reference path (host lstsq "
                "per round, unfusable) and cannot stream; use "
                "repro.core.acpd.run_method")
        if eval_mode is None:
            eval_mode = "stream" if spec.target_gap is not None else "batched"
        ckpt_every = spec.checkpoint_every
        return Session(self.problem, entry.config, self.cluster,
                       num_outer=entry.num_outer, seed=spec.seed,
                       eval_every=spec.eval_every, eval_mode=eval_mode,
                       target_gap=spec.target_gap,
                       time_budget=spec.time_budget,
                       executor=spec.executor if executor is None
                       else executor,
                       checkpoint_dir=(self.checkpoint_dir
                                       if ckpt_every is not None else None),
                       checkpoint_every=ckpt_every,
                       _segment_hook=_segment_hook)

    def run_entry(self, entry) -> RunResult:
        if entry.config.exact_dual_feedback:
            from repro.core.acpd import run_method

            return run_method(self.problem, entry.config, self.cluster,
                              num_outer=entry.num_outer, seed=self.spec.seed,
                              eval_every=self.spec.eval_every)
        return self.session(entry).run()

    def run(self) -> dict[str, RunResult]:
        """Run every method entry; keyed by ``MethodConfig.name``."""
        return {entry.config.name: self.run_entry(entry)
                for entry in self.spec.methods}
