"""Named ExperimentSpec builders: the paper's figures/tables as specs.

One builder per benchmark family; ``benchmarks/bench_*.py`` and the
``python -m repro spec <preset>`` CLI both draw from here, so a figure run is
fully described by one JSON document (``spec.to_json()``).

``quick=True`` is the smoke scale used by ``make check`` /
``benchmarks/run.py --quick``.
"""

from __future__ import annotations

import dataclasses

from repro.core import baselines
from repro.core.simulate import ClusterModel
from repro.api.problems import ProblemSpec
from repro.api.spec import ExperimentSpec, MethodEntry


def rcv1_spec(K: int = 4, seed: int = 7, d: int = 2048,
              n_per_worker: int = 192) -> ProblemSpec:
    """The benchmarks' RCV1-like problem as a registry reference."""
    return ProblemSpec("rcv1_like", {"K": K, "seed": seed, "d": d,
                                     "n_per_worker": n_per_worker})


def cluster_model(K: int, sigma: float = 1.0, jitter: float = 0.0,
                  delay: str = "constant",
                  delay_params: dict | None = None) -> ClusterModel:
    return ClusterModel(num_workers=K, straggler_sigma=sigma, jitter=jitter,
                        delay_model=delay,
                        delay_params=tuple((delay_params or {}).items()))


def fig3(sigma: float = 10.0, quick: bool = False,
         target_gap: float | None = None) -> ExperimentSpec:
    """Fig. 3 convergence: CoCoA+ vs ACPD vs the B=K / rho=1 ablations."""
    K = 4
    d = 512 if quick else 2048
    H = 64 if quick else 256
    methods = (
        MethodEntry(baselines.cocoa_plus(K, H=H), 10 if quick else 60),
        MethodEntry(baselines.acpd(K, d, B=2, T=10, rho_d=64, gamma=0.5, H=H),
                    3 if quick else 12),
        MethodEntry(baselines.acpd_full_barrier(K, d, T=10, rho_d=64,
                                                gamma=0.5, H=H),
                    2 if quick else 8),
        MethodEntry(baselines.acpd_dense(K, B=2, T=10, gamma=0.5, H=H),
                    2 if quick else 8),
    )
    return ExperimentSpec(
        name=f"fig3-convergence-sigma{int(sigma)}{'-quick' if quick else ''}",
        problem=rcv1_spec(K=K, d=d), cluster=cluster_model(K, sigma=sigma),
        methods=methods, eval_every=2, seed=0, target_gap=target_gap)


def fig4a(quick: bool = False) -> ExperimentSpec:
    """Fig. 4a: the sparsity constant rho swept as one spec (one ACPD entry
    per rho*d, distinguished by method name)."""
    K = 4
    d = 512 if quick else 2048
    H = 64 if quick else 256
    outer = 2 if quick else 8
    methods = []
    for rho_d in ((8, 128) if quick else (8, 32, 128, 512, 2048)):
        m = baselines.acpd(K, d, B=2, T=10, rho_d=rho_d, gamma=0.5, H=H)
        methods.append(MethodEntry(
            dataclasses.replace(m, name=f"ACPD-rho_d{rho_d}"), outer))
    return ExperimentSpec(
        name=f"fig4a-rho{'-quick' if quick else ''}",
        problem=rcv1_spec(K=K, d=d), cluster=cluster_model(K),
        methods=tuple(methods), eval_every=2, seed=0)


def fig4b(K: int, quick: bool = False) -> ExperimentSpec:
    """Fig. 4b worker scaling at one K: all four registry protocols."""
    d = 1024 if quick else 8192
    H = 64 if quick else 256
    methods = (
        MethodEntry(baselines.acpd(K, d, B=max(1, K // 2), T=10, rho_d=128,
                                   gamma=0.5, H=H), 2 if quick else 8),
        MethodEntry(baselines.cocoa_plus(K, H=H), 10 if quick else 60),
        MethodEntry(baselines.acpd_async(K, d, T=10, rho_d=128, gamma=0.5,
                                         H=H), 4 if quick else 16),
        MethodEntry(baselines.acpd_lag(K, d, B=max(1, K // 2), T=10,
                                       rho_d=128, gamma=0.5, H=H),
                    2 if quick else 8),
    )
    return ExperimentSpec(
        name=f"fig4b-scaling-K{K}{'-quick' if quick else ''}",
        problem=rcv1_spec(K=K, d=d, n_per_worker=64 if quick else 128,
                          seed=7 + K),
        cluster=cluster_model(K, sigma=1.0), methods=methods, eval_every=2,
        seed=0)


def fig5(quick: bool = False) -> ExperimentSpec:
    """Fig. 5 'real environment' proxy: lognormal jitter on every worker."""
    K, d = (4, 1024) if quick else (8, 4096)
    H = 64 if quick else 256
    methods = (
        MethodEntry(baselines.acpd(K, d, B=K // 2, T=10, rho_d=64, gamma=0.5,
                                   H=H), 2 if quick else 8),
        MethodEntry(baselines.cocoa_plus(K, H=H), 10 if quick else 60),
    )
    return ExperimentSpec(
        name=f"fig5-realenv{'-quick' if quick else ''}",
        problem=rcv1_spec(K=K, d=d, n_per_worker=96, seed=31),
        cluster=cluster_model(K, sigma=1.0, jitter=0.6), methods=methods,
        eval_every=2, seed=0)


def table1(quick: bool = False) -> ExperimentSpec:
    """Table I bytes-per-round accounting runs."""
    K = 4
    d = 512 if quick else 2048
    H = 64 if quick else 256
    methods = (
        MethodEntry(baselines.cocoa_plus(K, H=H), 5 if quick else 20),
        MethodEntry(baselines.acpd(K, d, rho_d=64, H=H), 1 if quick else 2),
        MethodEntry(baselines.acpd_dense(K, H=H), 1 if quick else 2),
    )
    return ExperimentSpec(
        name=f"table1-bytes{'-quick' if quick else ''}",
        problem=rcv1_spec(K=K, d=d), cluster=cluster_model(K),
        methods=methods, eval_every=5, seed=0)


def quickstart(quick: bool = False,
               target_gap: float | None = 1e-3) -> ExperimentSpec:
    """The examples/quickstart.py comparison as a spec (with early stop)."""
    K = 4
    d = 1024 if quick else 4096
    H = 128 if quick else 512
    methods = (
        MethodEntry(baselines.cocoa_plus(K, H=H), 10 if quick else 40),
        MethodEntry(baselines.acpd(K, d, B=2, T=10, rho_d=128, gamma=0.5,
                                   H=H), 3 if quick else 8),
    )
    return ExperimentSpec(
        name=f"quickstart{'-quick' if quick else ''}",
        problem=ProblemSpec("linear_synthetic",
                            {"num_workers": K, "n_per_worker": 256, "d": d,
                             "nnz_per_row": 32, "seed": 0, "lam": 1e-3,
                             "loss": "ridge"}),
        cluster=ClusterModel(num_workers=K, straggler_sigma=5.0),
        methods=methods, eval_every=4, seed=0, target_gap=target_gap)


# -- the straggler-zoo preset family ----------------------------------------
#
# One spec per delay model, each running the full protocol zoo against it:
# the "straggler-agnostic" claim as a stress grid instead of a single
# hard-coded delay shape.  benchmarks/bench_straggler_zoo.py sweeps the whole
# family into a protocol x delay JSON grid.

ZOO_DELAYS: dict[str, dict] = {
    "constant": {},
    "shifted_exponential": {"tail_mean": 1.0},
    "pareto": {"shape": 1.8, "scale": 0.5},
    "markov": {"p_slow": 0.1, "p_recover": 0.25, "slow_factor": 8.0},
    "bandwidth_coupled": {"link_slowdown": 20.0},
}


def straggler_zoo(delay: str = "pareto", quick: bool = False,
                  target_gap: float | None = None) -> ExperimentSpec:
    """Protocol zoo vs one delay model: every server discipline in the
    registry against the named straggler behavior.

    ``bandwidth_coupled`` zeroes the compute slowdown (the straggler is a
    slow LINK, so the payload-byte coupling with the compressor is the only
    handicap); every other model keeps the paper's sigma=5 compute straggler.
    """
    if delay not in ZOO_DELAYS:
        raise ValueError(
            f"unknown zoo delay {delay!r}; available: {tuple(sorted(ZOO_DELAYS))}")
    K = 4
    d = 512 if quick else 2048
    H = 64 if quick else 256
    sigma = 1.0 if delay == "bandwidth_coupled" else 5.0
    methods = (
        MethodEntry(baselines.cocoa_plus(K, H=H), 10 if quick else 60),
        MethodEntry(baselines.acpd(K, d, B=2, T=10, rho_d=64, gamma=0.5, H=H),
                    3 if quick else 12),
        MethodEntry(baselines.acpd_adaptive(K, d, T=10, rho_d=64, gamma=0.5,
                                            H=H, quantile=0.5),
                    3 if quick else 12),
        MethodEntry(baselines.acpd_lag(K, d, B=2, T=10, rho_d=64, gamma=0.5,
                                       H=H), 3 if quick else 12),
        # Equal byte budget with the acpd() row by construction: n_chunks
        # chunks of rho_d/n_chunks coordinates each per full pass.
        MethodEntry(baselines.acpd_partial_work(K, d, B=2, T=10, rho_d=64,
                                                gamma=0.5, H=H, n_chunks=4),
                    3 if quick else 12),
        MethodEntry(baselines.acpd_hierarchical(K, d, T=10, rho_d=64,
                                                gamma=0.5, H=H, n_racks=2,
                                                rack_b=1),
                    3 if quick else 12),
        MethodEntry(baselines.acpd_async(K, d, T=10, rho_d=64, gamma=0.5,
                                         H=H), 10 if quick else 40),
        MethodEntry(baselines.cocoa_v1(K, H=H), 10 if quick else 60),
        MethodEntry(baselines.cocoa_plus_solver(K, H=H,
                                                local_solver="accelerated"),
                    10 if quick else 60),
    )
    return ExperimentSpec(
        name=f"zoo-{delay}{'-quick' if quick else ''}",
        problem=rcv1_spec(K=K, d=d),
        cluster=cluster_model(K, sigma=sigma, delay=delay,
                              delay_params=ZOO_DELAYS[delay]),
        methods=methods, eval_every=2, seed=0, target_gap=target_gap)


PRESETS = {
    "fig3": fig3,
    "fig4a": fig4a,
    "fig5": fig5,
    "table1": table1,
    "quickstart": quickstart,
}
# fig4b takes a required K; expose the paper's K values as named presets.
for _K in (2, 4, 8):
    PRESETS[f"fig4b-K{_K}"] = (lambda K: lambda quick=False: fig4b(K, quick))(_K)
# The straggler-zoo family: one preset per registered zoo delay model.
for _delay in sorted(ZOO_DELAYS):
    PRESETS[f"zoo-{_delay}"] = (
        lambda dl: lambda quick=False, target_gap=None: straggler_zoo(
            dl, quick=quick, target_gap=target_gap))(_delay)


def build_preset(name: str, **kwargs) -> ExperimentSpec:
    try:
        fn = PRESETS[name]
    except KeyError:
        raise ValueError(
            f"unknown preset {name!r}; available: {tuple(sorted(PRESETS))}"
        ) from None
    return fn(**kwargs)
