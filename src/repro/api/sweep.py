"""Batched sweep runner: many independent lockstep runs, ONE compiled call.

Bench grids sweep seeds, server step sizes (gamma) and sparsity levels over
the *same spec shape* -- identical dataset, protocol, round budget.  Running
them as separate sessions pays one compile + one dispatch chain per cell.
This module batches every variant of a lockstep run (``sync`` / ``cocoa`` /
``cocoa_plus``) into a single compiled computation built on
:func:`repro.core.executor.lockstep_run_traced`:

* ``batch="vmap"`` (default) -- variants are vmapped: one XLA computation
  whose inner ops are batched across the sweep axis.  Fastest, but batched
  reductions reorder floats, so trajectories are NOT bit-identical to
  single-run executions (they are still deterministic for a fixed sweep).
* ``batch="map"``  -- variants run through ``lax.map``: still one compile
  and one dispatch for the whole sweep, but each variant keeps the
  unbatched op shapes -- bit-identical to ``Session(executor="scan")`` (and
  therefore to the event engine), pinned by tests/test_executor.py.

Timing/byte accounting is host-side per seed
(:func:`repro.core.executor.lockstep_accounts` -- gamma does not move the
simulated clock, so variants sharing a seed share the accounting), and the
deferred gap certificates of ALL variants evaluate in one bucketed
``lax.map`` dispatch.

The group-family protocols (data-dependent arrival control flow) cannot
batch this way; sweep them with one :class:`repro.api.Session` per cell.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, executor, objectives
from repro.core.acpd import MethodConfig, RunRecord, RunResult
from repro.core.simulate import ClusterModel


@dataclasses.dataclass(frozen=True)
class SweepVariant:
    """One cell of the sweep: the varied parameters plus its RunResult."""

    seed: int
    gamma: float
    result: RunResult


@partial(jax.jit,
         static_argnames=("loss", "num_steps", "solver", "length", "batch"))
def _sweep_scan(keys, X, y, norms_sq, lam, n, sigma_ps, gammas, *, loss,
                num_steps, solver, length, batch):
    """All sweep variants in one compiled computation."""
    executor.STATS["sweep_traces"] += 1  # trace-time side effect
    run = partial(executor.lockstep_run_traced, loss=loss,
                  num_steps=num_steps, solver=solver, length=length)
    if batch == "vmap":
        return jax.vmap(
            lambda key, sp, g: run(key, X, y, norms_sq, lam, n, sp, g)
        )(keys, sigma_ps, gammas)
    return jax.lax.map(
        lambda args: run(args[0], X, y, norms_sq, lam, n, args[1], args[2]),
        (keys, sigma_ps, gammas))


def run_lockstep_sweep(
    problem: objectives.Problem,
    method: MethodConfig,
    cluster: ClusterModel,
    *,
    num_outer: int,
    seeds=(0,),
    gammas=None,
    eval_every: int = 1,
    batch: str = "vmap",
) -> list[SweepVariant]:
    """Run the cross product ``seeds x gammas`` of a lockstep method as one
    compiled computation; returns one :class:`SweepVariant` per cell.

    ``gammas=None`` keeps the method's own gamma (a pure seed sweep).  When
    a gamma variant is swept and ``method.sigma_prime`` is unset, each
    variant gets its protocol's safe default sigma' for THAT gamma (the same
    resolution a single run would do).
    """
    if method.protocol not in executor.LOCKSTEP_PROTOCOLS:
        raise ValueError(
            f"sweep batching needs a lockstep protocol "
            f"{executor.LOCKSTEP_PROTOCOLS}, got {method.protocol!r}; run "
            f"group-family methods one Session per cell")
    if batch not in ("vmap", "map"):
        raise ValueError(f"unknown batch mode {batch!r}; 'vmap' or 'map'")
    if num_outer <= 0:
        raise ValueError(f"num_outer must be >= 1, got {num_outer}")
    gammas = [method.gamma] if gammas is None else list(gammas)
    seeds = list(seeds)
    K, n_k, d = problem.X.shape

    cells = [(s, g) for s in seeds for g in gammas]
    methods = [dataclasses.replace(method, gamma=g) for _, g in cells]
    sigma_ps = np.asarray([m.resolved_sigma_prime(K) for m in methods])
    keys = jax.vmap(jax.random.key)(jnp.asarray([s for s, _ in cells]))
    norms_sq = jnp.sum(problem.X * problem.X, axis=-1)

    executor.STATS["sweep_calls"] += 1
    w, alpha, ws, alphas = _sweep_scan(
        keys, problem.X, problem.y, norms_sq, problem.lam, K * n_k,
        jnp.asarray(sigma_ps, problem.X.dtype),
        jnp.asarray([g for _, g in cells], problem.X.dtype),
        loss=problem.loss, num_steps=method.H,
        solver=executor.lockstep_solver(method), length=num_outer,
        batch=batch)

    # Gamma does not move the simulated clock: accounting is per seed.
    accounts = {s: executor.lockstep_accounts(method, cluster, d,
                                              num_rounds=num_outer, seed=s)
                for s in seeds}
    evals = executor._eval_indices(num_outer, eval_every)
    # Every variant's certificates in one bucketed lax.map dispatch: rows
    # stay unbatched, so per-variant values match single-run evaluation.
    # (eval_every > num_outer => no boundaries => empty records, like a
    # Session with the same parameters.)
    V, S = len(cells), len(evals)
    idx = jnp.asarray(evals, jnp.int32)
    ws_eval = ws[:, idx].reshape((V * S, d))
    alphas_eval = alphas[:, idx].reshape((V * S, K, n_k))
    p, dv, gap, gap_srv = engine._eval_bucketed(
        ws_eval, alphas_eval, problem.X, problem.y, problem.lam,
        loss=problem.loss)
    p = np.asarray(p, np.float64).reshape(V, S)
    dv = np.asarray(dv, np.float64).reshape(V, S)
    gap = np.asarray(gap, np.float64).reshape(V, S)
    gap_srv = np.asarray(gap_srv, np.float64).reshape(V, S)

    out = []
    for v, ((seed, gamma), m) in enumerate(zip(cells, methods)):
        rounds = accounts[seed]
        records = [
            RunRecord(iteration=r + 1, sim_time=rounds[r].sim_time,
                      gap=float(gap[v, i]), gap_server=float(gap_srv[v, i]),
                      primal=float(p[v, i]), dual=float(dv[v, i]),
                      bytes_up=rounds[r].bytes_up,
                      bytes_down=rounds[r].bytes_down,
                      compute_time=rounds[r].compute_time,
                      comm_time=rounds[r].comm_time)
            for i, r in enumerate(evals)
        ]
        out.append(SweepVariant(seed, gamma, RunResult(
            m, records, np.asarray(w[v]), np.asarray(alpha[v]))))
    return out


def sweep_spec(spec, method_name: str, *, seeds=None, gammas=None,
               batch: str = "vmap") -> list[SweepVariant]:
    """Spec-level convenience: sweep one method entry of an
    :class:`repro.api.ExperimentSpec` (its eval cadence, its problem, its
    seed -- ``seeds`` defaults to ``(spec.seed,)`` so the no-axes call
    reproduces exactly the run the spec declares)."""
    if spec.target_gap is not None or spec.time_budget is not None:
        raise ValueError(
            "sweep batching compiles whole runs and cannot early-stop; "
            "this spec sets target_gap/time_budget -- run it per-cell via "
            "Experiment/Session instead")
    entry = spec.method_named(method_name)
    problem = spec.problem.build()
    return run_lockstep_sweep(problem, entry.config, spec.cluster,
                              num_outer=entry.num_outer,
                              seeds=(spec.seed,) if seeds is None else seeds,
                              gammas=gammas, eval_every=spec.eval_every,
                              batch=batch)
