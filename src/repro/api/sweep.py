"""Batched sweep runner: many independent runs, ONE compiled call.

Bench grids sweep delay models, seeds, server step sizes (gamma) and
sparsity levels over the *same spec shape* -- identical dataset, protocol,
round budget.  Running them as separate sessions pays one compile + one
dispatch chain per cell.  :func:`run_sweep` batches every scan-capable run
(the lockstep protocols ``sync`` / ``cocoa`` / ``cocoa_plus`` AND ``lag``)
into a single compiled computation built on the traced run bodies of
:mod:`repro.core.executor` (:func:`~repro.core.executor.lockstep_run_traced`
/ :func:`~repro.core.executor.lag_run_traced`):

* ``batch="vmap"`` (default) -- variants are vmapped: one XLA computation
  whose inner ops are batched across the sweep axis.  Fastest, but batched
  reductions reorder floats, so trajectories are NOT bit-identical to
  single-run executions (they are still deterministic for a fixed sweep).
* ``batch="map"``  -- variants run through ``lax.map``: still one compile
  and one dispatch for the whole sweep, but each variant keeps the
  unbatched op shapes -- bit-identical to ``Session(executor="scan")`` (and
  therefore to the event engine), pinned by tests/test_sweep.py.

The *delay axis rides along for free*: lockstep timing is host-side
accounting (gamma and the delay model never move the compiled computation),
and the lag executor's in-graph event queue consumes pre-sampled duration
streams and link factors as traced operands -- so a whole
delay x seed x gamma grid of one protocol is ONE compiled call.  Different
grid shapes reuse one compile: the cell axis AND the static eval-boundary
axis are padded to power-of-two buckets (trailing duplicates, the
``engine._eval_bucketed`` trick), so repeated calls with different
(n_delays, n_seeds, n_gammas) grids or eval cadences retrace at most
log-many times per axis.

Sharding (``shard=``): the batched axes can be partitioned over the local
device mesh (:func:`repro.launch.mesh.make_mesh` + ``shard_map``):

* ``"auto"`` (default) -- shard the cell axis over all local devices when
  more than one exists; degrade to the single-device path otherwise (the
  1-device behavior is bit-identical to ``shard="none"``).
* ``"none"``  -- force the unsharded vmap/map path.
* ``"cells"`` -- partition the sweep-cell axis: cells are independent, so
  there is no cross-shard communication at all and per-cell results are
  bit-identical to the unsharded path (each shard runs the same per-cell
  ops on its block).
* ``"workers"`` -- lockstep only: partition the worker axis of the
  per-round inner computation (each shard solves its local subproblems,
  one ``psum`` per round reduces the aggregate; see
  :func:`repro.core.executor.lockstep_run_traced_sharded`).  For large-K
  cells; deterministic but NOT bit-identical (the reduction re-associates,
  like ``batch="vmap"``).

Timing/byte accounting stays host-side for lockstep
(:func:`repro.core.executor.lockstep_accounts` -- per (delay, seed), since
gamma does not move the simulated clock) and comes back as per-round scan
outputs for lag; the deferred gap certificates of ALL variants evaluate in
one bucketed ``lax.map`` dispatch.

The group-family protocols (data-dependent arrival control flow) cannot
batch this way; sweep them with one :class:`repro.api.Session` per cell.
:func:`run_lockstep_sweep` remains as the lockstep-only compat wrapper.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import compress as compress_lib
from repro.core import engine, executor, objectives
from repro.core.acpd import MethodConfig, RunRecord, RunResult
from repro.core.simulate import ClusterModel
from repro.launch import mesh as mesh_lib

SHARD_MODES = ("auto", "none", "cells", "workers")


@dataclasses.dataclass(frozen=True)
class SweepVariant:
    """One cell of the sweep: the varied parameters plus its RunResult.

    ``rounds`` carries the cell's full per-round host accounting
    (:class:`repro.core.executor.RoundAccount` tuples) so a consumer can
    replay the cell's complete Session event stream -- the serve layer's
    stream demultiplexer (:mod:`repro.serve.streams`) depends on it.  It is
    set by :func:`run_sweep_cells` (and the lag path generally); the
    lockstep cross-product sweep leaves it ``None`` -- that path dedups
    trajectories across the delay axis and only needs eval-boundary
    records.
    """

    seed: int
    gamma: float
    result: RunResult
    delay: str = "constant"  # the cell's delay-model registry entry
    rounds: tuple | None = None  # per-round RoundAccounts (cell sweeps)


@dataclasses.dataclass(frozen=True)
class SweepCellSpec:
    """One EXPLICIT sweep cell: its full per-cell parameterization.

    :func:`run_sweep` generates the cross product of its axes internally;
    :func:`run_sweep_cells` instead takes a flat list of these -- the serve
    layer's coalescer (:mod:`repro.serve.coalesce`) builds one per tenant
    request, so heterogeneous tenant grids batch into one compiled call
    with no cross-product waste.  ``gamma=None`` keeps the method's own
    gamma; ``sigma_prime=None`` resolves the protocol default for the
    cell's gamma (exactly what a solo run would do).  The ``cluster`` is
    fully per-cell: lockstep timing is host-side accounting, and the lag
    executor consumes pre-sampled per-cell delay streams as traced
    operands, so cells of different delay models / latencies / bandwidths
    share one computation.
    """

    cluster: ClusterModel
    seed: int
    gamma: float | None = None
    sigma_prime: float | None = None


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """A resolved ``shard=`` request: which axis, over how many devices."""

    mode: str  # "none" | "cells" | "workers"
    n_shards: int  # 1 iff mode == "none"


def _pow2_floor(n: int) -> int:
    return 1 << (max(1, n).bit_length() - 1)


def resolve_shard(shard: str, *, protocol: str, num_workers: int,
                  n_devices: int | None = None) -> ShardPlan:
    """Resolve a ``shard=`` request against this host's devices.

    ``auto`` picks ``cells`` whenever more than one device exists (cells are
    embarrassingly parallel and stay bit-identical) and degrades to ``none``
    on a single device.  ``cells`` degrades to ``none`` on one device too.
    ``workers`` needs a lockstep protocol (the lag event queue is
    sequential in arrivals and cannot split its worker axis) and a worker
    count divisible by the shard count; it degrades to ``none`` when no
    usable split exists.  Mesh sizes are the largest power of two that fits
    so cell-axis pow2 padding always divides evenly.
    """
    if shard not in SHARD_MODES:
        raise ValueError(f"unknown shard mode {shard!r}; expected one of "
                         f"{SHARD_MODES}")
    if n_devices is None:
        n_devices = len(jax.devices())
    pow2 = _pow2_floor(n_devices)
    if shard == "workers":
        if protocol not in executor.LOCKSTEP_PROTOCOLS:
            raise ValueError(
                f"shard='workers' partitions the lockstep worker axis; "
                f"protocol {protocol!r} cannot (lag's in-graph event queue "
                f"is sequential in arrival order). Use shard='cells'.")
        s = pow2
        while s > 1 and num_workers % s:
            s //= 2
        return ShardPlan("workers", s) if s > 1 else ShardPlan("none", 1)
    if shard == "none" or pow2 == 1:
        return ShardPlan("none", 1)
    return ShardPlan("cells", pow2)  # "auto" and "cells"


def sweep_supported(method: MethodConfig,
                    cluster: ClusterModel) -> tuple[bool, str]:
    """Can (method, cluster) batch into :func:`run_sweep`?  (ok, why-not).

    Strictly narrower than ``executor.scan_supported``: ``partial_work``
    scans solo (per-chunk carries are per-run state) but does not batch
    into shared sweep cells."""
    if method.protocol not in executor.SWEEP_PROTOCOLS:
        return False, (
            f"protocol {method.protocol!r} does not batch into shared sweep "
            f"cells (sweep-batchable: {executor.SWEEP_PROTOCOLS}); run it "
            f"one Session per cell")
    return executor.scan_supported(method, cluster)


# ---------------------------------------------------------------------------
# The compiled sweep computations.
# ---------------------------------------------------------------------------


@partial(jax.jit,
         static_argnames=("loss", "num_steps", "solver", "length",
                          "batch", "n_shards"))
def _sweep_scan(keys, X, y, norms_sq, lam, n, sigma_ps, gammas, eval_idx, *,
                loss, num_steps, solver, length, batch, n_shards):
    """All lockstep sweep variants in one compiled computation.

    ``eval_idx`` (a traced int32 vector, pow2-padded so eval cadences share
    compiles) gathers the eval-boundary snapshots in-graph, so only
    O(cells x boundaries) state leaves the device instead of the full
    O(cells x rounds) trail.  ``n_shards > 1`` partitions the cell axis over
    the local mesh via ``shard_map`` -- cells are independent, so each shard
    runs the identical per-cell ops on its block (no collectives; per-cell
    results are bit-identical to the unsharded path) with donated carries
    inside its scan.
    """
    executor.STATS["sweep_traces"] += 1  # trace-time side effect
    run = partial(executor.lockstep_run_traced, loss=loss,
                  num_steps=num_steps, solver=solver, length=length)

    def one(key, X, y, norms_sq, lam, n, sp, g, idx):
        w, alpha, ws, alphas = run(key, X, y, norms_sq, lam, n, sp, g)
        return w, alpha, ws[idx], alphas[idx]

    def block(keys, X, y, norms_sq, lam, n, sigma_ps, gammas, idx):
        if batch == "vmap":
            return jax.vmap(
                lambda key, sp, g: one(key, X, y, norms_sq, lam, n, sp, g,
                                       idx)
            )(keys, sigma_ps, gammas)
        return jax.lax.map(
            lambda a: one(a[0], X, y, norms_sq, lam, n, a[1], a[2], idx),
            (keys, sigma_ps, gammas))

    if n_shards == 1:
        return block(keys, X, y, norms_sq, lam, n, sigma_ps, gammas,
                     eval_idx)
    mesh = mesh_lib.make_sweep_mesh(n_shards, "cells")
    fn = shard_map(block, mesh=mesh,
                   in_specs=(P("cells"), P(), P(), P(), P(), P(),
                             P("cells"), P("cells"), P()),
                   out_specs=(P("cells"),) * 4, check_rep=False)
    return fn(keys, X, y, norms_sq, lam, n, sigma_ps, gammas, eval_idx)


@partial(jax.jit,
         static_argnames=("loss", "num_steps", "solver", "length",
                          "batch", "n_shards", "num_workers"))
def _sweep_scan_workers(keys, X, y, norms_sq, lam, n, sigma_ps, gammas,
                        eval_idx, *, loss, num_steps, solver, length, batch,
                        n_shards, num_workers):
    """Lockstep sweep with the WORKER axis sharded over the mesh.

    Every device sees every cell but only its block of the K workers; each
    round's aggregate is one cross-shard ``psum``
    (:func:`repro.core.executor.lockstep_run_traced_sharded`).  A perf mode
    for large-K cells -- deterministic, not bit-identical (the reduction
    re-associates).
    """
    executor.STATS["sweep_traces"] += 1  # trace-time side effect
    mesh = mesh_lib.make_sweep_mesh(n_shards, "workers")

    def block(keys, X, y, norms_sq, lam, n, sigma_ps, gammas, idx):
        run = partial(executor.lockstep_run_traced_sharded, loss=loss,
                      num_steps=num_steps, solver=solver, length=length,
                      axis="workers", num_workers=num_workers)

        def one(key, sp, g):
            w, alpha, ws, alphas = run(key, X, y, norms_sq, lam, n, sp, g)
            return w, alpha, ws[idx], alphas[idx]

        if batch == "vmap":
            return jax.vmap(one)(keys, sigma_ps, gammas)
        return jax.lax.map(lambda a: one(*a), (keys, sigma_ps, gammas))

    fn = shard_map(block, mesh=mesh,
                   in_specs=(P(), P("workers"), P("workers"), P("workers"),
                             P(), P(), P(), P(), P()),
                   out_specs=(P(), P(None, "workers"), P(),
                              P(None, None, "workers")),
                   check_rep=False)
    return fn(keys, X, y, norms_sq, lam, n, sigma_ps, gammas, eval_idx)


@partial(jax.jit,
         static_argnames=("loss", "num_steps", "comp", "length", "lag_window",
                          "dense_reply_bytes", "batch", "n_shards"))
def _lag_sweep_scan(keys, X, y, norms_sq, lam, n, sigma_ps, gammas, xi,
                    durations, needs, up_bytes, heartbeat_bytes, latencies,
                    bandwidths, link_factors, eval_idx, *, loss, num_steps,
                    comp, length, lag_window, dense_reply_bytes, batch,
                    n_shards):
    """All LAG sweep variants in one compiled computation.

    The per-cell operands carry the whole delay axis: pre-sampled duration
    streams (f64, one per (delay, seed)), per-worker link factors and
    latency/bandwidth scalars -- so cells of DIFFERENT delay models batch
    into the same computation.  Must be called under ``enable_x64`` (the
    in-graph event-queue timing is f64, like the single-run path).
    """
    executor.STATS["sweep_lag_traces"] += 1  # trace-time side effect

    def one(shared, key, sp, g, dur, lat, bw, lf):
        (X, y, norms_sq, lam, n, xi, needs, up_bytes, heartbeat_bytes,
         idx) = shared
        state, ys = executor.lag_run_traced(
            key, X, y, norms_sq, lam, n, sp, g, xi, dur, needs, up_bytes,
            heartbeat_bytes, lat, bw, lf, loss=loss, num_steps=num_steps,
            comp=comp, length=length, lag_window=lag_window,
            dense_reply_bytes=dense_reply_bytes)
        ws, app_rows, sim, bu, bd, ct, cm = ys
        return (state["w_server"], state["alpha"], state["alpha_applied"],
                ws[idx], app_rows[idx], sim, bu, bd, ct, cm)

    def block(keys, X, y, norms_sq, lam, n, sigma_ps, gammas, xi, durations,
              needs, up_bytes, heartbeat_bytes, latencies, bandwidths,
              link_factors, idx):
        shared = (X, y, norms_sq, lam, n, xi, needs, up_bytes,
                  heartbeat_bytes, idx)
        if batch == "vmap":
            return jax.vmap(partial(one, shared))(
                keys, sigma_ps, gammas, durations, latencies, bandwidths,
                link_factors)
        return jax.lax.map(lambda a: one(shared, *a),
                           (keys, sigma_ps, gammas, durations, latencies,
                            bandwidths, link_factors))

    args = (keys, X, y, norms_sq, lam, n, sigma_ps, gammas, xi, durations,
            needs, up_bytes, heartbeat_bytes, latencies, bandwidths,
            link_factors, eval_idx)
    if n_shards == 1:
        return block(*args)
    mesh = mesh_lib.make_sweep_mesh(n_shards, "cells")
    cell = P("cells")
    fn = shard_map(block, mesh=mesh,
                   in_specs=(cell, P(), P(), P(), P(), P(), cell, cell, P(),
                             cell, P(), P(), P(), cell, cell, cell, P()),
                   out_specs=(cell,) * 10, check_rep=False)
    return fn(*args)


# ---------------------------------------------------------------------------
# The sweep drivers.
# ---------------------------------------------------------------------------


def _delay_variants(cluster: ClusterModel, delays):
    """Normalize the delay axis to [(name, ClusterModel), ...].

    ``delays=None`` keeps the spec's own cluster (a pure seed/gamma sweep);
    entries may be registry names (default parameters) or ``(name, params)``
    pairs.
    """
    if delays is None:
        return [(cluster.delay_model, cluster)]
    out = []
    for entry in delays:
        if isinstance(entry, str):
            name, params = entry, None
        else:
            name, params = entry
        if params is None:
            params = (dict(cluster.delay_params)
                      if name == cluster.delay_model else {})
        out.append((name, dataclasses.replace(
            cluster, delay_model=name, delay_params=tuple(params.items()))))
    return out


def _padded_cells(cells, n_shards):
    """Pad the cell list to the pow2 bucket (>= shard count) by repeating
    the last cell; padded rows compute real (discarded) work, so grids of
    different shapes share one compile without poisoning any live cell."""
    V = len(cells)
    V_pad = max(engine._bucket_size(V), n_shards)
    return cells + [cells[-1]] * (V_pad - V)


def _padded_eval_idx(evals) -> tuple:
    """The static eval-boundary tuple, padded to its pow2 bucket (last
    index repeated) so sweeps differing only in eval cadence share compiles
    the same way the cell axis does; callers slice the duplicate snapshot
    rows off before evaluation."""
    if not evals:
        return ()
    pad = engine._bucket_size(len(evals)) - len(evals)
    return tuple(evals) + (evals[-1],) * pad


def run_sweep(
    problem: objectives.Problem,
    method: MethodConfig,
    cluster: ClusterModel,
    *,
    num_outer: int,
    seeds=(0,),
    gammas=None,
    delays=None,
    eval_every: int = 1,
    batch: str = "vmap",
    shard: str = "auto",
) -> list[SweepVariant]:
    """Run the cross product ``delays x seeds x gammas`` of a scan-capable
    method as one compiled computation; returns one :class:`SweepVariant`
    per cell (delay-major, then seed, then gamma).

    ``gammas=None`` keeps the method's own gamma; when a gamma variant is
    swept and ``method.sigma_prime`` is unset, each variant gets its
    protocol's safe default sigma' for THAT gamma (the same resolution a
    single run would do).  ``delays=None`` keeps the cluster's own delay
    model; otherwise entries are delay-registry names or ``(name, params)``
    pairs.  ``shard`` partitions the batched axes over the local device mesh
    (see the module docstring; ``"auto"`` degrades gracefully to the
    unsharded path on one device).

    Contract: under ``batch="map"`` with an unsharded or cells-sharded
    plan, every cell is bit-identical to the corresponding
    ``Session(executor="scan")`` run -- and therefore to the event engine
    (pinned by tests/test_sweep.py).
    """
    if method.protocol not in executor.SWEEP_PROTOCOLS:
        raise ValueError(
            f"sweep batching needs a sweep-batchable (shared-cell "
            f"scan-capable) protocol {executor.SWEEP_PROTOCOLS}, got "
            f"{method.protocol!r}; run other protocols one Session per "
            f"cell")
    if batch not in ("vmap", "map"):
        raise ValueError(f"unknown batch mode {batch!r}; 'vmap' or 'map'")
    if num_outer <= 0:
        raise ValueError(f"num_outer must be >= 1, got {num_outer}")
    gammas = [method.gamma] if gammas is None else list(gammas)
    seeds = list(seeds)
    if not seeds or not gammas:
        raise ValueError(
            f"the sweep grid is empty: got {len(seeds)} seeds x "
            f"{len(gammas)} gammas (each axis needs at least one value)")
    variants = _delay_variants(cluster, delays)
    if not variants:
        raise ValueError("delays=() declares an empty delay axis; pass "
                         "None to keep the cluster's own delay model")
    plan = resolve_shard(shard, protocol=method.protocol,
                         num_workers=problem.X.shape[0])
    if method.protocol == "lag":
        return _run_lag_sweep(problem, method, variants, num_outer=num_outer,
                              seeds=seeds, gammas=gammas,
                              eval_every=eval_every, batch=batch, plan=plan)
    return _run_lockstep_sweep(problem, method, variants,
                               num_outer=num_outer, seeds=seeds,
                               gammas=gammas, eval_every=eval_every,
                               batch=batch, plan=plan)


def _variant_records(rounds, evals, gap, gap_srv, p, dv, v):
    return [
        RunRecord(iteration=r + 1, sim_time=rounds[r].sim_time,
                  gap=float(gap[v, i]), gap_server=float(gap_srv[v, i]),
                  primal=float(p[v, i]), dual=float(dv[v, i]),
                  bytes_up=rounds[r].bytes_up,
                  bytes_down=rounds[r].bytes_down,
                  compute_time=rounds[r].compute_time,
                  comm_time=rounds[r].comm_time)
        for i, r in enumerate(evals)
    ]


def _eval_grid(ws_eval, alphas_eval, problem, V, S):
    """Every variant's certificates in one bucketed lax.map dispatch: rows
    stay unbatched, so per-variant values match single-run evaluation.

    Snapshots are gathered to host first: a cells-sharded sweep leaves them
    distributed, and evaluating through the sharded layout would let GSPMD
    re-partition the certificate reductions (breaking the bit-identity of
    the certificates, though not of the trajectories).
    """
    K, n_k, d = problem.X.shape
    p, dv, gap, gap_srv = engine._eval_bucketed(
        np.asarray(ws_eval).reshape(V * S, d),
        np.asarray(alphas_eval).reshape(V * S, K, n_k),
        problem.X, problem.y, problem.lam, loss=problem.loss)
    return tuple(np.asarray(a, np.float64).reshape(V, S)
                 for a in (p, dv, gap, gap_srv))


def _run_lockstep_sweep(problem, method, variants, *, num_outer, seeds,
                        gammas, eval_every, batch, plan):
    K, n_k, d = problem.X.shape
    # Trajectories depend only on (seed, gamma): the delay axis is pure
    # host-side accounting for lockstep runs, so compute each unique
    # trajectory once and reuse it across delay variants.
    cells = [(s, g) for s in seeds for g in gammas]
    methods = {g: dataclasses.replace(method, gamma=g) for g in gammas}
    padded = _padded_cells(cells, plan.n_shards)
    sigma_ps = np.asarray([methods[g].resolved_sigma_prime(K)
                           for _, g in padded])
    keys = jax.vmap(jax.random.key)(jnp.asarray([s for s, _ in padded]))
    norms_sq = jnp.sum(problem.X * problem.X, axis=-1)
    evals = executor._eval_indices(num_outer, eval_every)

    executor.STATS["sweep_calls"] += 1
    runner = _sweep_scan if plan.mode != "workers" else partial(
        _sweep_scan_workers, num_workers=K)
    w, alpha, ws_eval, alphas_eval = runner(
        keys, problem.X, problem.y, norms_sq, problem.lam, K * n_k,
        jnp.asarray(sigma_ps, problem.X.dtype),
        jnp.asarray([g for _, g in padded], problem.X.dtype),
        jnp.asarray(_padded_eval_idx(evals), jnp.int32),
        loss=problem.loss, num_steps=method.H,
        solver=executor.lockstep_solver(method), length=num_outer,
        batch=batch, n_shards=plan.n_shards if plan.mode != "none" else 1)

    V, S = len(cells), len(evals)
    p, dv, gap, gap_srv = _eval_grid(ws_eval[:V, :S], alphas_eval[:V, :S],
                                     problem, V, S)
    # Gamma does not move the simulated clock: accounting is per
    # (delay variant, seed).
    out = []
    for name, cl in variants:
        accounts = {s: executor.lockstep_accounts(
            method, cl, d, num_rounds=num_outer, seed=s) for s in seeds}
        for v, (seed, gamma) in enumerate(cells):
            records = _variant_records(accounts[seed], evals, gap, gap_srv,
                                       p, dv, v)
            out.append(SweepVariant(seed, gamma, RunResult(
                methods[gamma], records, np.asarray(w[v]),
                np.asarray(alpha[v])), delay=name))
    return out


def _run_lag_sweep(problem, method, variants, *, num_outer, seeds, gammas,
                   eval_every, batch, plan):
    # Cell order: delay-major, then seed, then gamma (matches the returned
    # variant order).  The cell-level core below keys duration streams by
    # the (hashable) ClusterModel itself, NOT the delay name: two entries
    # of the same model with different params must not share a stream.
    cells = [SweepCellSpec(cl, s, g, method.sigma_prime)
             for _, cl in variants for s in seeds for g in gammas]
    return _lag_cells(problem, method, cells, num_outer=num_outer,
                      eval_every=eval_every, batch=batch, plan=plan)


def _lag_cells(problem, method, cells, *, num_outer, eval_every, batch,
               plan):
    from jax.experimental import enable_x64

    K, n_k, d = problem.X.shape
    T = method.T
    R = num_outer * T
    comp = compress_lib.for_method(method, d)
    dense = isinstance(comp, compress_lib.Dense)
    up_bytes = comp.wire_bytes(d)
    needs = executor.lag_needs(method, K, R)
    mcfgs = [dataclasses.replace(method, gamma=c.gamma,
                                 sigma_prime=c.sigma_prime) for c in cells]

    for c in cells:
        ok, why = executor.scan_supported(method, c.cluster)
        if not ok:
            raise ValueError(
                f"delay model {c.cluster.delay_model!r} cannot batch into a "
                f"lag sweep: {why}; run it per-cell via "
                f"Session(executor='event')")

    # Durations are per (cluster, seed) -- the same host-RNG stream a single
    # run would consume -- so gamma variants of one (cluster, seed) share.
    padded = _padded_cells(list(cells), plan.n_shards)
    dur_cache: dict = {}
    link_cache: dict = {}
    for c in padded:
        if (c.cluster, c.seed) not in dur_cache:
            durations, delay = executor.lag_durations(
                method, c.cluster, num_rounds=R, seed=c.seed)
            dur_cache[(c.cluster, c.seed)] = durations
            link_cache[c.cluster] = delay.link_factors()
    durations = np.stack([dur_cache[(c.cluster, c.seed)] for c in padded])
    link_factors = np.stack([link_cache[c.cluster] for c in padded])
    lats = np.asarray([c.cluster.latency for c in padded])
    bws = np.asarray([c.cluster.bandwidth for c in padded])
    sigma_ps = np.asarray([dataclasses.replace(
        method, gamma=c.gamma,
        sigma_prime=c.sigma_prime).resolved_sigma_prime(K) for c in padded])
    keys = jax.vmap(jax.random.key)(
        jnp.asarray([c.seed for c in padded]))
    norms_sq = jnp.sum(problem.X * problem.X, axis=-1)
    evals = executor._eval_indices(R, eval_every)

    executor.STATS["sweep_lag_calls"] += 1
    with enable_x64():
        (w, alpha, alpha_applied, ws_eval, app_eval, sim, bu, bd, ct,
         cm) = _lag_sweep_scan(
            keys, problem.X, problem.y, norms_sq, jnp.float32(problem.lam),
            jnp.int32(K * n_k), jnp.asarray(sigma_ps, jnp.float32),
            jnp.asarray([c.gamma for c in padded], jnp.float32),
            jnp.float32(method.lag_xi),
            jnp.asarray(durations, jnp.float64),
            jnp.asarray(needs, jnp.int64),
            jnp.asarray(up_bytes, jnp.int64),
            jnp.asarray(engine.LagProtocol.HEARTBEAT_BYTES, jnp.int64),
            jnp.asarray(lats, jnp.float64),
            jnp.asarray(bws, jnp.float64),
            jnp.asarray(link_factors, jnp.float64),
            jnp.asarray(_padded_eval_idx(evals), jnp.int32),
            loss=problem.loss, num_steps=method.H, comp=comp, length=R,
            lag_window=method.lag_window,
            dense_reply_bytes=d * 4 if dense else 0, batch=batch,
            n_shards=plan.n_shards if plan.mode == "cells" else 1)

    V, S = len(cells), len(evals)
    p, dv, gap, gap_srv = _eval_grid(ws_eval[:V, :S], app_eval[:V, :S],
                                     problem, V, S)
    sim, bu, bd, ct, cm = (np.asarray(a) for a in (sim, bu, bd, ct, cm))
    out = []
    for v, c in enumerate(cells):
        rounds = executor.lag_accounts(needs, T, sim[v], bu[v], bd[v],
                                       ct[v], cm[v])
        records = _variant_records(rounds, evals, gap, gap_srv, p, dv, v)
        out.append(SweepVariant(c.seed, c.gamma, RunResult(
            mcfgs[v], records, np.asarray(w[v]), np.asarray(alpha[v]),
            alpha_applied=np.asarray(alpha_applied[v])),
            delay=c.cluster.delay_model, rounds=tuple(rounds)))
    return out


def _lockstep_cells(problem, method, cells, *, num_outer, eval_every, batch,
                    plan):
    K, n_k, d = problem.X.shape
    mcfgs = [dataclasses.replace(method, gamma=c.gamma,
                                 sigma_prime=c.sigma_prime) for c in cells]
    padded = _padded_cells(list(cells), plan.n_shards)
    sigma_ps = np.asarray([dataclasses.replace(
        method, gamma=c.gamma,
        sigma_prime=c.sigma_prime).resolved_sigma_prime(K) for c in padded])
    keys = jax.vmap(jax.random.key)(jnp.asarray([c.seed for c in padded]))
    norms_sq = jnp.sum(problem.X * problem.X, axis=-1)
    evals = executor._eval_indices(num_outer, eval_every)

    executor.STATS["sweep_calls"] += 1
    runner = _sweep_scan if plan.mode != "workers" else partial(
        _sweep_scan_workers, num_workers=K)
    w, alpha, ws_eval, alphas_eval = runner(
        keys, problem.X, problem.y, norms_sq, problem.lam, K * n_k,
        jnp.asarray(sigma_ps, problem.X.dtype),
        jnp.asarray([c.gamma for c in padded], problem.X.dtype),
        jnp.asarray(_padded_eval_idx(evals), jnp.int32),
        loss=problem.loss, num_steps=method.H,
        solver=executor.lockstep_solver(method), length=num_outer,
        batch=batch, n_shards=plan.n_shards if plan.mode != "none" else 1)

    V, S = len(cells), len(evals)
    p, dv, gap, gap_srv = _eval_grid(ws_eval[:V, :S], alphas_eval[:V, :S],
                                     problem, V, S)
    out = []
    for v, c in enumerate(cells):
        rounds = executor.lockstep_accounts(mcfgs[v], c.cluster, d,
                                            num_rounds=num_outer,
                                            seed=c.seed)
        records = _variant_records(rounds, evals, gap, gap_srv, p, dv, v)
        out.append(SweepVariant(c.seed, c.gamma, RunResult(
            mcfgs[v], records, np.asarray(w[v]), np.asarray(alpha[v])),
            delay=c.cluster.delay_model, rounds=tuple(rounds)))
    return out


def run_sweep_cells(
    problem: objectives.Problem,
    method: MethodConfig,
    cells,
    *,
    num_outer: int,
    eval_every: int = 1,
    batch: str = "vmap",
    shard: str = "auto",
) -> list[SweepVariant]:
    """Run an EXPLICIT list of sweep cells as one compiled computation.

    Where :func:`run_sweep` runs the full ``delays x seeds x gammas`` cross
    product, this takes a flat list of :class:`SweepCellSpec` (or
    ``(cluster, seed, gamma)`` tuples) and runs exactly those cells -- the
    entry point the multi-tenant serve layer (:mod:`repro.serve`) batches
    coalesced requests through, since different tenants rarely ask for a
    rectangular grid.  ``method`` is the shared template: everything that
    is static to the compiled computation (protocol, H, T, B, rho,
    compressor, solver, lag window) comes from it, while each cell's
    ``gamma`` / ``sigma_prime`` / ``cluster`` / ``seed`` override per cell.

    Same compiled callables, same pow2 cell/eval bucketing, and same
    bit-identity contract as :func:`run_sweep`: under ``batch="map"`` with
    an unsharded or cells-sharded plan every cell is bit-identical to the
    corresponding solo ``Session(executor="scan")`` run (pinned by
    tests/test_serve.py).  Every returned variant carries its full
    per-round accounting (``SweepVariant.rounds``) so callers can replay
    the cell's complete Round/Sync/Eval/Stop event stream.
    """
    if method.protocol not in executor.SWEEP_PROTOCOLS:
        raise ValueError(
            f"sweep batching needs a sweep-batchable (shared-cell "
            f"scan-capable) protocol {executor.SWEEP_PROTOCOLS}, got "
            f"{method.protocol!r}; run other protocols one Session per "
            f"cell")
    if batch not in ("vmap", "map"):
        raise ValueError(f"unknown batch mode {batch!r}; 'vmap' or 'map'")
    if num_outer <= 0:
        raise ValueError(f"num_outer must be >= 1, got {num_outer}")
    cells = [c if isinstance(c, SweepCellSpec) else SweepCellSpec(*c)
             for c in cells]
    if not cells:
        raise ValueError("cells is empty: pass at least one SweepCellSpec")
    cells = [dataclasses.replace(c, gamma=method.gamma)
             if c.gamma is None else c for c in cells]
    K = problem.X.shape[0]
    for c in cells:
        if c.cluster.num_workers != K:
            raise ValueError(
                f"cell cluster has num_workers={c.cluster.num_workers} but "
                f"the problem is partitioned over K={K} workers")
    plan = resolve_shard(shard, protocol=method.protocol, num_workers=K)
    core = _lag_cells if method.protocol == "lag" else _lockstep_cells
    return core(problem, method, cells, num_outer=num_outer,
                eval_every=eval_every, batch=batch, plan=plan)


# ---------------------------------------------------------------------------
# Compat + spec-level entry points.
# ---------------------------------------------------------------------------


def run_lockstep_sweep(
    problem: objectives.Problem,
    method: MethodConfig,
    cluster: ClusterModel,
    *,
    num_outer: int,
    seeds=(0,),
    gammas=None,
    eval_every: int = 1,
    batch: str = "vmap",
    shard: str = "none",
) -> list[SweepVariant]:
    """Lockstep-only compat wrapper over :func:`run_sweep` (PR-4 surface;
    unsharded by default).  New code should call :func:`run_sweep`."""
    if method.protocol not in executor.LOCKSTEP_PROTOCOLS:
        raise ValueError(
            f"sweep batching needs a lockstep protocol "
            f"{executor.LOCKSTEP_PROTOCOLS}, got {method.protocol!r}; use "
            f"run_sweep for lag, or one Session per cell for the group "
            f"family")
    return run_sweep(problem, method, cluster, num_outer=num_outer,
                     seeds=seeds, gammas=gammas, eval_every=eval_every,
                     batch=batch, shard=shard)


def sweep_spec(spec, method_name: str, *, seeds=None, gammas=None,
               delays=None, batch: str = "vmap",
               shard: str | None = None) -> list[SweepVariant]:
    """Spec-level convenience: sweep one method entry of an
    :class:`repro.api.ExperimentSpec` (its eval cadence, its problem, its
    seed -- ``seeds`` defaults to ``(spec.seed,)`` so the no-axes call
    reproduces exactly the run the spec declares).  ``shard`` defaults to
    the spec's own ``shard`` field."""
    if spec.target_gap is not None or spec.time_budget is not None:
        raise ValueError(
            "sweep batching compiles whole runs and cannot early-stop; "
            "this spec sets target_gap/time_budget -- run it per-cell via "
            "Experiment/Session instead")
    entry = spec.method_named(method_name)
    problem = spec.problem.build()
    return run_sweep(problem, entry.config, spec.cluster,
                     num_outer=entry.num_outer,
                     seeds=(spec.seed,) if seeds is None else seeds,
                     gammas=gammas, delays=delays,
                     eval_every=spec.eval_every, batch=batch,
                     shard=spec.shard if shard is None else shard)
