"""Pytree checkpointing: npz payload + json manifest, atomic, step-indexed.

No orbax in this container, so this is a small self-contained implementation:
every leaf is saved by its tree path; restore rebuilds against a reference
pytree (shape/dtype-checked) so sharding/placement is re-applied by the
caller. Atomicity via write-to-tmp + rename.
"""

from __future__ import annotations

import json
import pathlib
import re
import tempfile
from typing import Any

import jax
import numpy as np

PyTree = Any

_SAFE = re.compile(r"[^A-Za-z0-9_.-]+")


def _key(path) -> str:
    return _SAFE.sub("_", jax.tree_util.keystr(path))


def save_checkpoint(directory: str | pathlib.Path, step: int, tree: PyTree,
                    extra: dict | None = None) -> pathlib.Path:
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    payload = {_key(p): np.asarray(v) for p, v in flat}
    manifest = {
        "step": int(step),
        "keys": sorted(payload),
        "extra": extra or {},
    }
    final = directory / f"ckpt_{step:08d}.npz"
    with tempfile.NamedTemporaryFile(dir=directory, suffix=".tmp", delete=False) as f:
        np.savez(f, **payload)
        tmp = pathlib.Path(f.name)
    # Manifest first, then payload: a concurrent reader (cluster takeover
    # scans peers' checkpoint dirs) that can see the .npz must also see a
    # complete .json.  Both renames are atomic within the directory.
    with tempfile.NamedTemporaryFile("w", dir=directory, suffix=".tmp",
                                     delete=False) as f:
        f.write(json.dumps(manifest))
        tmp_json = pathlib.Path(f.name)
    tmp_json.rename(directory / f"ckpt_{step:08d}.json")
    tmp.rename(final)
    return final


def latest_step(directory: str | pathlib.Path) -> int | None:
    directory = pathlib.Path(directory)
    steps = [int(p.stem.split("_")[1]) for p in directory.glob("ckpt_*.npz")]
    return max(steps) if steps else None


def load_checkpoint(directory: str | pathlib.Path, reference: PyTree,
                    step: int | None = None) -> tuple[PyTree, dict]:
    """Restore into the structure of ``reference``; returns (tree, extra)."""
    directory = pathlib.Path(directory)
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    data = np.load(directory / f"ckpt_{step:08d}.npz")
    manifest = json.loads((directory / f"ckpt_{step:08d}.json").read_text())
    flat, treedef = jax.tree_util.tree_flatten_with_path(reference)
    leaves = []
    for p, ref in flat:
        k = _key(p)
        if k not in data:
            raise KeyError(f"checkpoint missing leaf {k}")
        arr = data[k]
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {np.shape(ref)}")
        leaves.append(arr.astype(np.asarray(ref).dtype) if hasattr(ref, "dtype") else arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest.get("extra", {})
