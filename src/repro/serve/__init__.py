"""The persistent multi-tenant experiment service (ROADMAP open item 1).

Long-lived serving over the batched sweep substrate: tenants submit
:class:`~repro.api.ExperimentSpec` requests (in-process or over HTTP --
``python -m repro serve``); the service validates at admission, coalesces
compatible requests into shared :func:`repro.api.run_sweep_cells` batches
(per-tenant round-robin fairness, bounded queue depth with typed
backpressure), keeps the jit compile cache warm across tenants with hit/miss
accounting, and streams each tenant's typed Round/Sync/Eval/Stop events back
bit-identical to a solo :class:`~repro.api.Session` run.

The serve layer is also **self-healing** (PR 9): injected or real failures
are retried with backoff when transient, quarantined by cohort bisection
when persistent, bounded by watchdog deadlines and a per-key circuit
breaker, and divergent (non-finite) cells are masked out of delivery
per-cell -- while checkpointed runs survive a service kill and resume
bit-identically.  Fault schedules come from the :mod:`repro.core.faults`
registry; the knobs live in :class:`~repro.serve.recovery.RecoveryPolicy`.

The serve layer also scales PAST one process (PR 10): N replicas coordinate
through a shared **cluster directory** -- mutually-exclusive lease files own
jobs, heartbeats detect dead replicas, and survivors take over a dead
owner's lease and resume its checkpointed run bit-identically
(:mod:`repro.serve.cluster` + :mod:`repro.serve.leases`; spawn replicas with
``python -m repro serve --replica-of <cluster-dir>``).  Cross-process chaos
replays exactly through the seeded network-fault family in
:mod:`repro.core.faults` (drop/duplicate/reorder/delay/partition/kill).

Layout: :mod:`~repro.serve.service` (admission + dispatch + recovery),
:mod:`~repro.serve.coalesce` (batch keys + fairness policy),
:mod:`~repro.serve.streams` (per-tenant demux/replay),
:mod:`~repro.serve.recovery` (typed errors, backoff, breaker, watchdog),
:mod:`~repro.serve.cache` (compile-cache mirror + TTL/LRU result cache),
:mod:`~repro.serve.clock` (the injectable clock every timing decision
reads), :mod:`~repro.serve.leases` (filesystem leases + heartbeats),
:mod:`~repro.serve.cluster` (replicas, transport, client, takeover),
:mod:`~repro.serve.http` (stdlib HTTP front end + replica CLI).
docs/serving.md and docs/fault-tolerance.md are the executed guides.
"""

from repro.serve.cache import (  # noqa: F401
    CompileCache,
    TTLCache,
    result_cache_key,
    sweep_cache_key,
)
from repro.serve.clock import SYSTEM_CLOCK, Clock, ManualClock  # noqa: F401
from repro.serve.cluster import (  # noqa: F401
    ClusterClient,
    ClusterJobError,
    ClusterReplica,
    ClusterTransport,
    ClusterUnavailableError,
    job_key,
    run_cluster,
)
from repro.serve.leases import LeaseManager  # noqa: F401
from repro.serve.coalesce import (  # noqa: F401
    CoalescePolicy,
    batch_key,
    form_batch,
)
from repro.serve.http import (  # noqa: F401
    event_from_dict,
    event_to_dict,
    serve_http,
)
from repro.serve.recovery import (  # noqa: F401
    CellDivergenceError,
    CircuitBreaker,
    CircuitOpenError,
    JobTimeoutError,
    RecoveryPolicy,
    ServiceStoppedError,
)
from repro.serve.service import (  # noqa: F401
    BackpressureError,
    ExperimentService,
    SpecValidationError,
)
from repro.serve.streams import JobHandle, replay_events  # noqa: F401

__all__ = [
    "BackpressureError",
    "CellDivergenceError",
    "CircuitBreaker",
    "CircuitOpenError",
    "Clock",
    "ClusterClient",
    "ClusterJobError",
    "ClusterReplica",
    "ClusterTransport",
    "ClusterUnavailableError",
    "CoalescePolicy",
    "CompileCache",
    "ExperimentService",
    "JobHandle",
    "JobTimeoutError",
    "LeaseManager",
    "ManualClock",
    "RecoveryPolicy",
    "SYSTEM_CLOCK",
    "ServiceStoppedError",
    "SpecValidationError",
    "TTLCache",
    "batch_key",
    "event_from_dict",
    "event_to_dict",
    "form_batch",
    "job_key",
    "replay_events",
    "result_cache_key",
    "run_cluster",
    "serve_http",
    "sweep_cache_key",
]
