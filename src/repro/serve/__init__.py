"""The persistent multi-tenant experiment service (ROADMAP open item 1).

Long-lived serving over the batched sweep substrate: tenants submit
:class:`~repro.api.ExperimentSpec` requests (in-process or over HTTP --
``python -m repro serve``); the service validates at admission, coalesces
compatible requests into shared :func:`repro.api.run_sweep_cells` batches
(per-tenant round-robin fairness, bounded queue depth with typed
backpressure), keeps the jit compile cache warm across tenants with hit/miss
accounting, and streams each tenant's typed Round/Sync/Eval/Stop events back
bit-identical to a solo :class:`~repro.api.Session` run.

The serve layer is also **self-healing** (PR 9): injected or real failures
are retried with backoff when transient, quarantined by cohort bisection
when persistent, bounded by watchdog deadlines and a per-key circuit
breaker, and divergent (non-finite) cells are masked out of delivery
per-cell -- while checkpointed runs survive a service kill and resume
bit-identically.  Fault schedules come from the :mod:`repro.core.faults`
registry; the knobs live in :class:`~repro.serve.recovery.RecoveryPolicy`.

Layout: :mod:`~repro.serve.service` (admission + dispatch + recovery),
:mod:`~repro.serve.coalesce` (batch keys + fairness policy),
:mod:`~repro.serve.streams` (per-tenant demux/replay),
:mod:`~repro.serve.recovery` (typed errors, backoff, breaker, watchdog),
:mod:`~repro.serve.cache` (compile-cache key mirror + counters),
:mod:`~repro.serve.http` (stdlib HTTP front end).  docs/serving.md and
docs/fault-tolerance.md are the executed guides.
"""

from repro.serve.cache import CompileCache, sweep_cache_key  # noqa: F401
from repro.serve.coalesce import (  # noqa: F401
    CoalescePolicy,
    batch_key,
    form_batch,
)
from repro.serve.http import event_to_dict, serve_http  # noqa: F401
from repro.serve.recovery import (  # noqa: F401
    CellDivergenceError,
    CircuitBreaker,
    CircuitOpenError,
    JobTimeoutError,
    RecoveryPolicy,
    ServiceStoppedError,
)
from repro.serve.service import (  # noqa: F401
    BackpressureError,
    ExperimentService,
    SpecValidationError,
)
from repro.serve.streams import JobHandle, replay_events  # noqa: F401

__all__ = [
    "BackpressureError",
    "CellDivergenceError",
    "CircuitBreaker",
    "CircuitOpenError",
    "CoalescePolicy",
    "CompileCache",
    "ExperimentService",
    "JobHandle",
    "JobTimeoutError",
    "RecoveryPolicy",
    "ServiceStoppedError",
    "SpecValidationError",
    "batch_key",
    "event_to_dict",
    "form_batch",
    "replay_events",
    "serve_http",
    "sweep_cache_key",
]
