"""Thin stdlib HTTP front end over :class:`~repro.serve.ExperimentService`.

``python -m repro serve [--host H] [--port P] [...policy knobs]`` binds a
``ThreadingHTTPServer``; the protocol is deliberately minimal JSON:

* ``POST /submit``  body ``{"tenant": str, "spec": <ExperimentSpec dict>,
  "method": str?}`` -> ``200 {"job_id": ...}``;
  ``400`` on validation errors (full registry listings in ``error``),
  ``429`` on per-tenant backpressure.
* ``GET /events/<job_id>`` -> blocks until the job finishes, returns
  ``{"events": [{"type": "round"|"sync"|"eval"|"stop", ...}, ...]}`` -- the
  tenant's full typed stream in order.
* ``GET /stats``  -> the service counters: coalesce factor, compile-cache
  hits/misses, retry/bisect/breaker accounting, per-tenant in-flight depth,
  device inventory.
* ``GET /health`` -> liveness: dispatcher thread state, queue depths, the
  full per-batch-key circuit-breaker state table, and -- on a cluster
  replica -- membership, lease table, and heartbeat ages (``503`` when the
  service is dead).  docs/serving.md documents the JSON shape.

``python -m repro serve --replica-of <cluster-dir>`` runs a **cluster
replica** instead of binding HTTP: the process joins the shared-directory
serve cluster of :mod:`repro.serve.cluster` and executes jobs from its
``jobs/`` queue under lease ownership (docs/fault-tolerance.md).

**Error contract** (the ``ERROR_STATUS`` table): every failed request gets a
structured JSON body ``{"error_type": <class name>, "message": str,
"job_id": str?}`` with a PINNED status code per typed error --
``SpecValidationError`` 400, ``BackpressureError`` 429,
``CellDivergenceError`` 422 (the request's own cell diverged),
``JobTimeoutError`` 504, ``CircuitOpenError``/``ServiceStoppedError`` 503 --
and only genuinely unclassified failures fall back to a 500.  A legacy
``error`` key mirrors ``message`` for older clients.

This is a control-plane front end for the in-process service, not a
load-bearing web server: auth, TLS and horizontal scale-out sit outside the
repo's scope (ROADMAP open item 2 covers multi-host).
"""

from __future__ import annotations

import dataclasses
import json
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.api.session import EvalEvent, RoundEvent, StopEvent, SyncEvent
from repro.serve.recovery import (
    CellDivergenceError,
    CircuitOpenError,
    JobTimeoutError,
    ServiceStoppedError,
)
from repro.serve.service import (
    BackpressureError,
    ExperimentService,
    SpecValidationError,
)

_EVENT_TYPES = {RoundEvent: "round", SyncEvent: "sync", EvalEvent: "eval",
                StopEvent: "stop"}

#: Typed error -> pinned HTTP status.  Most-derived match wins (the list is
#: scanned in order); anything unlisted is a 500.
ERROR_STATUS: tuple[tuple[type, int], ...] = (
    (SpecValidationError, 400),
    (BackpressureError, 429),
    (CellDivergenceError, 422),
    (JobTimeoutError, 504),
    (CircuitOpenError, 503),
    (ServiceStoppedError, 503),
)


def error_body(error: BaseException, *, job_id: str | None = None) -> tuple:
    """(status, payload) for one typed error: the structured contract plus
    the legacy ``error`` key."""
    status = 500
    for cls, code in ERROR_STATUS:
        if isinstance(error, cls):
            status = code
            break
    payload = {"error_type": type(error).__name__, "message": str(error),
               "error": str(error)}
    if job_id is not None:
        payload["job_id"] = job_id
    return status, payload


def event_to_dict(event) -> dict:
    """One typed event as a JSON-able dict (``type`` tag + its fields)."""
    return {"type": _EVENT_TYPES[type(event)], **dataclasses.asdict(event)}


_EVENT_CLASSES = {name: cls for cls, name in _EVENT_TYPES.items()}


def event_from_dict(d: dict):
    """Inverse of :func:`event_to_dict` -- EXACT, not approximate: every
    event field is a JSON scalar and Python float repr round-trips, so
    ``event_from_dict(json.loads(json.dumps(event_to_dict(e)))) == e``.
    The cluster transport leans on this for bit-identical cross-process
    result delivery."""
    d = dict(d)
    return _EVENT_CLASSES[d.pop("type")](**d)


def make_handler(service: ExperimentService):
    class Handler(BaseHTTPRequestHandler):
        def log_message(self, fmt, *args):  # quiet by default
            pass

        def _reply(self, code: int, payload: dict) -> None:
            body = json.dumps(payload).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def _reply_error(self, error: BaseException,
                         job_id: str | None = None) -> None:
            status, payload = error_body(error, job_id=job_id)
            self._reply(status, payload)

        def do_POST(self):  # noqa: N802 (stdlib handler naming)
            if self.path != "/submit":
                return self._reply(404, {"error": f"no route {self.path}"})
            length = int(self.headers.get("Content-Length", 0))
            try:
                req = json.loads(self.rfile.read(length) or b"{}")
                tenant = req["tenant"]
                spec_dict = req["spec"]
            except (KeyError, ValueError) as e:
                return self._reply(
                    400, {"error": f"body must be JSON with 'tenant' and "
                                   f"'spec': {e}",
                          "error_type": "BadRequest",
                          "message": f"body must be JSON with 'tenant' and "
                                     f"'spec': {e}"})
            try:
                handle = service.submit_json(tenant, json.dumps(spec_dict),
                                             method=req.get("method"))
            except (SpecValidationError, BackpressureError,
                    ServiceStoppedError) as e:
                return self._reply_error(e)
            self._reply(200, {"job_id": handle.job_id,
                              "tenant": handle.tenant})

        def do_GET(self):  # noqa: N802
            if self.path == "/stats":
                return self._reply(200, service.stats())
            if self.path == "/health":
                health = service.health()
                return self._reply(
                    200 if health["status"] == "ok" else 503, health)
            if self.path.startswith("/events/"):
                job_id = self.path[len("/events/"):]
                try:
                    handle = service.job(job_id)
                except KeyError as e:
                    return self._reply(404, {"error": str(e)})
                try:
                    events = [event_to_dict(e) for e in handle.events()]
                except Exception as e:  # analysis: fail-fast-ok (mapped to the pinned typed-error status table)
                    return self._reply_error(e, job_id=job_id)
                return self._reply(200, {"job_id": job_id, "events": events})
            self._reply(404, {"error": f"no route {self.path}"})

    return Handler


def serve_http(service: ExperimentService, host: str = "127.0.0.1",
               port: int = 8008) -> ThreadingHTTPServer:
    """Bind (but do not run) the HTTP server; caller owns ``serve_forever``.

    Returning the bound server lets tests pick ``port=0`` and read the real
    port back before starting the loop in a thread."""
    return ThreadingHTTPServer((host, port), make_handler(service))


def main(argv: list[str] | None = None) -> None:
    """CLI entry point: ``python -m repro serve``."""
    import argparse

    from repro.core.faults import fault_from_spec
    from repro.serve.coalesce import CoalescePolicy
    from repro.serve.recovery import RecoveryPolicy

    ap = argparse.ArgumentParser(
        prog="repro serve",
        description="persistent multi-tenant experiment service (HTTP)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8008)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait", type=float, default=0.05,
                    help="seconds a non-full batch waits before closing")
    ap.add_argument("--max-tenant-depth", type=int, default=8)
    ap.add_argument("--batch", default="map", choices=("map", "vmap"),
                    help="map = bit-identical to solo Sessions (default); "
                         "vmap = faster, float-reassociated")
    ap.add_argument("--shard", default="auto",
                    choices=("auto", "none", "cells", "workers"))
    ap.add_argument("--batch-deadline", type=float, default=None,
                    help="seconds one batch dispatch may run before the "
                         "watchdog requeues it solo (default: no deadline)")
    ap.add_argument("--solo-deadline", type=float, default=None,
                    help="seconds one solo run may take before failing with "
                         "JobTimeoutError (default: no deadline)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for checkpoint/resume snapshots of "
                         "specs with checkpoint_every")
    ap.add_argument("--fault-model", default=None,
                    help="inject a repro.core.faults registry entry "
                         "(chaos testing)")
    ap.add_argument("--fault-params", default="{}",
                    help="JSON kwargs for --fault-model")
    ap.add_argument("--replica-of", default=None, metavar="CLUSTER_DIR",
                    help="run as one replica of the shared-directory serve "
                         "cluster at CLUSTER_DIR instead of binding HTTP "
                         "(see docs/fault-tolerance.md, 'Replicated "
                         "serving')")
    ap.add_argument("--replica-id", default=None,
                    help="this replica's id in the cluster (default: "
                         "replica-<pid>)")
    ap.add_argument("--step-interval", type=float, default=0.2,
                    help="seconds between replica scheduler ticks "
                         "(--replica-of mode)")
    ap.add_argument("--lease-ttl", type=float, default=10.0,
                    help="seconds without a heartbeat before a replica is "
                         "presumed dead and its leases become stealable")
    args = ap.parse_args(argv)

    fault = None
    if args.fault_model is not None:
        fault = fault_from_spec({"fault_model": args.fault_model,
                                 "fault_params": json.loads(args.fault_params)})

    if args.replica_of is not None:
        # Replica mode: join the filesystem cluster and serve jobs from its
        # shared directory.  Faults apply at the cluster seam, and a
        # replica_kill schedule takes a REAL self-SIGKILL here -- the
        # subprocess analogue of the in-process ReplicaKilled.
        import os as _os

        from repro.serve.cluster import ClusterReplica

        replica_id = args.replica_id or f"replica-{_os.getpid()}"
        replica = ClusterReplica(
            args.replica_of, replica_id, fault=fault,
            lease_ttl_s=args.lease_ttl, subprocess_kill=True,
            service_kwargs=dict(
                policy=CoalescePolicy(
                    max_batch=args.max_batch, max_wait_s=args.max_wait,
                    max_tenant_depth=args.max_tenant_depth, batch=args.batch,
                    shard=args.shard),
                recovery=RecoveryPolicy(
                    batch_deadline_s=args.batch_deadline,
                    solo_deadline_s=args.solo_deadline)))
        print(f"cluster replica {replica_id} serving {args.replica_of} "
              f"(lease ttl {args.lease_ttl:g}s, "
              f"tick every {args.step_interval:g}s)", flush=True)
        try:
            replica.run_forever(interval_s=args.step_interval)
        except KeyboardInterrupt:
            pass
        return

    service = ExperimentService(
        CoalescePolicy(
            max_batch=args.max_batch, max_wait_s=args.max_wait,
            max_tenant_depth=args.max_tenant_depth, batch=args.batch,
            shard=args.shard),
        recovery=RecoveryPolicy(batch_deadline_s=args.batch_deadline,
                                solo_deadline_s=args.solo_deadline),
        fault=fault, checkpoint_dir=args.checkpoint_dir).start()
    server = serve_http(service, args.host, args.port)
    host, port = server.server_address[:2]
    print(f"experiment service listening on http://{host}:{port} "
          f"(POST /submit, GET /events/<job>, GET /stats, GET /health)")
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.shutdown()
        service.stop()


if __name__ == "__main__":
    main()
