"""Thin stdlib HTTP front end over :class:`~repro.serve.ExperimentService`.

``python -m repro serve [--host H] [--port P] [...policy knobs]`` binds a
``ThreadingHTTPServer``; the protocol is deliberately minimal JSON:

* ``POST /submit``  body ``{"tenant": str, "spec": <ExperimentSpec dict>,
  "method": str?}`` -> ``200 {"job_id": ...}``;
  ``400`` on validation errors (full registry listings in ``error``),
  ``429`` on per-tenant backpressure.
* ``GET /events/<job_id>`` -> blocks until the job finishes, returns
  ``{"events": [{"type": "round"|"sync"|"eval"|"stop", ...}, ...]}`` -- the
  tenant's full typed stream in order (``500`` carries the job's error).
* ``GET /stats`` -> the service counters: coalesce factor, compile-cache
  hits/misses, per-tenant in-flight depth, device inventory.

This is a control-plane front end for the in-process service, not a
load-bearing web server: auth, TLS and horizontal scale-out sit outside the
repo's scope (ROADMAP open item 2 covers multi-host).
"""

from __future__ import annotations

import dataclasses
import json
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.api.session import EvalEvent, RoundEvent, StopEvent, SyncEvent
from repro.serve.service import (
    BackpressureError,
    ExperimentService,
    SpecValidationError,
)

_EVENT_TYPES = {RoundEvent: "round", SyncEvent: "sync", EvalEvent: "eval",
                StopEvent: "stop"}


def event_to_dict(event) -> dict:
    """One typed event as a JSON-able dict (``type`` tag + its fields)."""
    return {"type": _EVENT_TYPES[type(event)], **dataclasses.asdict(event)}


def make_handler(service: ExperimentService):
    class Handler(BaseHTTPRequestHandler):
        def log_message(self, fmt, *args):  # quiet by default
            pass

        def _reply(self, code: int, payload: dict) -> None:
            body = json.dumps(payload).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_POST(self):  # noqa: N802 (stdlib handler naming)
            if self.path != "/submit":
                return self._reply(404, {"error": f"no route {self.path}"})
            length = int(self.headers.get("Content-Length", 0))
            try:
                req = json.loads(self.rfile.read(length) or b"{}")
                tenant = req["tenant"]
                spec_dict = req["spec"]
            except (KeyError, ValueError) as e:
                return self._reply(
                    400, {"error": f"body must be JSON with 'tenant' and "
                                   f"'spec': {e}"})
            try:
                handle = service.submit_json(tenant, json.dumps(spec_dict),
                                             method=req.get("method"))
            except SpecValidationError as e:
                return self._reply(400, {"error": str(e)})
            except BackpressureError as e:
                return self._reply(429, {"error": str(e)})
            self._reply(200, {"job_id": handle.job_id,
                              "tenant": handle.tenant})

        def do_GET(self):  # noqa: N802
            if self.path == "/stats":
                return self._reply(200, service.stats())
            if self.path.startswith("/events/"):
                job_id = self.path[len("/events/"):]
                try:
                    handle = service.job(job_id)
                except KeyError as e:
                    return self._reply(404, {"error": str(e)})
                try:
                    events = [event_to_dict(e) for e in handle.events()]
                except Exception as e:  # noqa: BLE001 -- job failure -> 500
                    return self._reply(500, {"error": repr(e)})
                return self._reply(200, {"job_id": job_id, "events": events})
            self._reply(404, {"error": f"no route {self.path}"})

    return Handler


def serve_http(service: ExperimentService, host: str = "127.0.0.1",
               port: int = 8008) -> ThreadingHTTPServer:
    """Bind (but do not run) the HTTP server; caller owns ``serve_forever``.

    Returning the bound server lets tests pick ``port=0`` and read the real
    port back before starting the loop in a thread."""
    return ThreadingHTTPServer((host, port), make_handler(service))


def main(argv: list[str] | None = None) -> None:
    """CLI entry point: ``python -m repro serve``."""
    import argparse

    from repro.serve.coalesce import CoalescePolicy

    ap = argparse.ArgumentParser(
        prog="repro serve",
        description="persistent multi-tenant experiment service (HTTP)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8008)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait", type=float, default=0.05,
                    help="seconds a non-full batch waits before closing")
    ap.add_argument("--max-tenant-depth", type=int, default=8)
    ap.add_argument("--batch", default="map", choices=("map", "vmap"),
                    help="map = bit-identical to solo Sessions (default); "
                         "vmap = faster, float-reassociated")
    ap.add_argument("--shard", default="auto",
                    choices=("auto", "none", "cells", "workers"))
    args = ap.parse_args(argv)

    service = ExperimentService(CoalescePolicy(
        max_batch=args.max_batch, max_wait_s=args.max_wait,
        max_tenant_depth=args.max_tenant_depth, batch=args.batch,
        shard=args.shard)).start()
    server = serve_http(service, args.host, args.port)
    host, port = server.server_address[:2]
    print(f"experiment service listening on http://{host}:{port} "
          f"(POST /submit, GET /events/<job>, GET /stats)")
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.shutdown()
        service.stop()


if __name__ == "__main__":
    main()
