"""Filesystem leases + heartbeats: who owns which job, and who is alive.

The replicated serve cluster (:mod:`repro.serve.cluster`) coordinates
through a shared **cluster directory** -- the same idiom as the shareable
``checkpoint_dir``: no broker process, no sockets between replicas, just
atomic filesystem operations every POSIX rename/link gives us.  This module
is the coordination substrate; the cluster layer builds job routing and
takeover on top of it.

Three primitives, three guarantees:

* **Lease acquisition is mutually exclusive.**  A lease is claimed by
  writing a tmp file with the FULL lease record and then ``os.link``-ing it
  to ``leases/<job>.json``.  ``link`` fails with ``EEXIST`` if the name is
  taken -- unlike ``rename``, which would silently replace the current
  owner (last-writer-wins is exactly the wrong semantics for ownership).
  Exactly one of N concurrent claimants wins, and the winner's record is
  complete the instant the name exists (no torn reads).

* **Heartbeats are atomic snapshots.**  Each replica periodically renames a
  tmp file over ``replicas/<replica>.json`` carrying its own
  ``clock.time()``; readers age that stamp against THEIR clock.  In-process
  test clusters share one :class:`~repro.serve.clock.ManualClock` (ages are
  exact and sleep-free); cross-process clusters use the system clock, whose
  epoch is comparable between processes on one host.  A replica whose
  heartbeat is older than ``lease_ttl_s`` is presumed dead.

* **Takeover is raced through a rename.**  To steal a dead owner's lease, a
  claimant atomically renames the lease file to a private claim name --
  only one concurrent claimant's rename succeeds (the loser gets ENOENT) --
  and then re-acquires with the dead owner's ``epoch + 1``.  The epoch is
  the fencing token: a resurrected owner still holding epoch ``e`` fails
  its :meth:`LeaseManager.still_owner` check against the epoch-``e+1``
  lease and must discard its work instead of double-delivering.
"""

from __future__ import annotations

import json
import os
import pathlib
import re
import tempfile

from repro.serve.clock import SYSTEM_CLOCK, Clock

_SAFE = re.compile(r"[^A-Za-z0-9_.-]+")


def _fname(key: str) -> str:
    return _SAFE.sub("_", str(key))


def _atomic_write(path: pathlib.Path, payload: dict) -> None:
    """Full-content atomic replace: readers see old or new, never torn."""
    with tempfile.NamedTemporaryFile("w", dir=path.parent, suffix=".tmp",
                                     delete=False) as f:
        f.write(json.dumps(payload))
        tmp = pathlib.Path(f.name)
    os.replace(tmp, path)


def _read_json(path: pathlib.Path) -> dict | None:
    """None on missing; raises on torn content (atomic writes make torn
    reads a bug, not a race)."""
    try:
        text = path.read_text()
    except FileNotFoundError:
        return None
    return json.loads(text)


class LeaseManager:
    """One replica's view of the shared lease/heartbeat state.

    ``lease_ttl_s`` is both the heartbeat staleness threshold and therefore
    the failure-detection latency: a replica that has not heartbeat for
    ``lease_ttl_s`` seconds is presumed dead and its leases become
    stealable.  ``clock`` is injectable so every timing behavior here is
    testable with a :class:`~repro.serve.clock.ManualClock`.
    """

    def __init__(self, cluster_dir, replica_id: str, *,
                 clock: Clock | None = None, lease_ttl_s: float = 10.0):
        if lease_ttl_s <= 0:
            raise ValueError(f"lease_ttl_s must be positive, got {lease_ttl_s}")
        self.cluster_dir = pathlib.Path(cluster_dir)
        self.replica_id = str(replica_id)
        self.clock = clock or SYSTEM_CLOCK
        self.lease_ttl_s = float(lease_ttl_s)
        self._replicas = self.cluster_dir / "replicas"
        self._leases = self.cluster_dir / "leases"
        for d in (self._replicas, self._leases):
            d.mkdir(parents=True, exist_ok=True)
        self._beats = 0

    # -- heartbeats --------------------------------------------------------

    def heartbeat(self) -> None:
        """Publish this replica's liveness stamp (atomic replace)."""
        self._beats += 1
        _atomic_write(self._replicas / f"{_fname(self.replica_id)}.json",
                      {"replica": self.replica_id,
                       "time": self.clock.time(), "seq": self._beats})

    def retire(self) -> None:
        """Graceful shutdown: withdraw the heartbeat so peers stop counting
        this replica as a member (a CRASHED replica never calls this --
        that is the whole point of staleness detection)."""
        try:
            os.unlink(self._replicas / f"{_fname(self.replica_id)}.json")
        except FileNotFoundError:
            pass

    def membership(self) -> dict:
        """Every replica that ever heartbeat: ``{replica: {"age_s", "alive",
        "seq"}}``, aged against THIS replica's clock."""
        now = self.clock.time()
        out = {}
        for path in sorted(self._replicas.glob("*.json")):
            beat = _read_json(path)
            if beat is None:  # unlinked between glob and read
                continue
            age = max(0.0, now - beat["time"])
            out[beat["replica"]] = {
                "age_s": round(age, 6),
                "alive": age < self.lease_ttl_s,
                "seq": beat["seq"],
            }
        return out

    def alive(self, replica: str) -> bool:
        beat = _read_json(self._replicas / f"{_fname(replica)}.json")
        if beat is None:
            return False
        return max(0.0, self.clock.time() - beat["time"]) < self.lease_ttl_s

    # -- leases ------------------------------------------------------------

    def _lease_path(self, job_key: str) -> pathlib.Path:
        return self._leases / f"{_fname(job_key)}.json"

    def try_acquire(self, job_key: str, *, epoch: int = 0) -> dict | None:
        """Claim ``job_key`` at ``epoch``; the full lease record on the win,
        ``None`` if any owner (any epoch) already holds the name."""
        record = {"job": str(job_key), "owner": self.replica_id,
                  "epoch": int(epoch), "time": self.clock.time()}
        path = self._lease_path(job_key)
        with tempfile.NamedTemporaryFile("w", dir=self._leases,
                                         suffix=".tmp", delete=False) as f:
            f.write(json.dumps(record))
            tmp = pathlib.Path(f.name)
        try:
            os.link(tmp, path)  # atomic: EEXIST iff someone owns the job
        except FileExistsError:
            return None
        finally:
            os.unlink(tmp)
        return record

    def read_lease(self, job_key: str) -> dict | None:
        return _read_json(self._lease_path(job_key))

    def still_owner(self, job_key: str, epoch: int) -> bool:
        """The fencing check: does this replica still hold ``job_key`` at
        ``epoch``?  A replica that was presumed dead and superseded sees
        ``False`` (higher epoch or different owner) and must DISCARD its
        late work rather than deliver it."""
        lease = self.read_lease(job_key)
        return (lease is not None and lease["owner"] == self.replica_id
                and lease["epoch"] == int(epoch))

    def release(self, job_key: str, epoch: int) -> bool:
        """Release a lease this replica holds at ``epoch``; True if
        released.  Never touches a lease someone else won in the meantime."""
        if not self.still_owner(job_key, epoch):
            return False
        try:
            os.unlink(self._lease_path(job_key))
        except FileNotFoundError:
            pass
        return True

    def expired(self, lease: dict) -> bool:
        """Is this lease's owner presumed dead (heartbeat stale/missing)?
        Self-owned leases are never expired -- a replica trusts its own
        liveness."""
        return lease["owner"] != self.replica_id and not self.alive(lease["owner"])

    def try_takeover(self, job_key: str) -> dict | None:
        """Steal ``job_key`` from a presumed-dead owner; the new lease
        record (epoch bumped) on the win, ``None`` otherwise.

        The steal itself is raced through an atomic rename of the lease
        file to a claimant-private name: of N concurrent claimants exactly
        one rename succeeds, the losers get ENOENT and report ``None`` --
        so mutual exclusion holds even during takeover.
        """
        lease = self.read_lease(job_key)
        if lease is None or not self.expired(lease):
            return None
        path = self._lease_path(job_key)
        claim = self._leases / f"{path.name}.claim.{_fname(self.replica_id)}"
        try:
            os.replace(path, claim)  # atomic: one claimant wins the steal
        except FileNotFoundError:
            return None  # another claimant already renamed it away
        try:
            stolen = _read_json(claim)
            if stolen is not None and stolen["epoch"] != lease["epoch"]:
                # The file we renamed was a NEWER lease than the stale one
                # we decided to steal (the old owner was superseded between
                # our read and our rename).  Put it back and stand down.
                os.replace(claim, path)
                return None
            return self.try_acquire(job_key, epoch=lease["epoch"] + 1)
        finally:
            try:
                os.unlink(claim)
            except FileNotFoundError:
                pass

    def lease_table(self) -> dict:
        """Every live lease file: ``{job: {"owner", "epoch", "age_s",
        "owner_alive"}}`` -- the ``GET /health`` view."""
        now = self.clock.time()
        membership = self.membership()
        out = {}
        for path in sorted(self._leases.glob("*.json")):
            lease = _read_json(path)
            if lease is None:
                continue
            out[lease["job"]] = {
                "owner": lease["owner"],
                "epoch": lease["epoch"],
                "age_s": round(max(0.0, now - lease["time"]), 6),
                "owner_alive": membership.get(lease["owner"],
                                              {"alive": False})["alive"],
            }
        return out
