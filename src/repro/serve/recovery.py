"""Recovery machinery for the experiment service: typed errors, retry
backoff, execution deadlines, and the per-key circuit breaker.

The service (:mod:`repro.serve.service`) composes these primitives into its
self-healing dispatch path:

* transient faults (``exc.transient`` is true -- see
  :mod:`repro.core.faults`) are retried with exponential backoff and
  deterministic jitter, up to ``RecoveryPolicy.max_attempts``;
* persistent batch failures are *quarantined by bisection*: the cohort is
  split in half and each half retried independently (depth bounded by
  ``max_bisect_depth``), so only the poison request fails;
* every batch/solo execution can carry a deadline
  (``batch_deadline_s`` / ``solo_deadline_s``); an overrun becomes a typed
  :class:`JobTimeoutError` (batched work is then requeued on the solo
  lane) instead of a hang;
* repeated failures on one ``batch_key`` open a :class:`CircuitBreaker`
  for that key (fast-fail with :class:`CircuitOpenError`), with a
  half-open probe after ``breaker_cooldown_s``.

Everything here is deterministic given the policy seed and the sequence of
calls -- jitter comes from ``numpy`` generators keyed on
``(seed, key digest, attempt)``, never from global RNG state or wall-clock.
The one time-dependent component (breaker cooldown) reads an injectable
:class:`repro.serve.clock.Clock`, so cooldown behavior is testable with a
``ManualClock`` instead of real sleeps.
"""

from __future__ import annotations

import dataclasses
import threading

import numpy as np

from ..core.faults import key_digest
from .clock import SYSTEM_CLOCK, Clock

# ---------------------------------------------------------------------------
# Typed errors.  HTTP status mapping lives in serve/http.py.
# ---------------------------------------------------------------------------


class JobTimeoutError(RuntimeError):
    """A batch or solo execution overran its deadline; the watchdog
    abandoned it (late results are discarded, never delivered)."""


class CellDivergenceError(RuntimeError):
    """This request's cell produced non-finite iterates; it was masked out
    of the coalesced delivery (healthy cohort members were unaffected)."""


class CircuitOpenError(RuntimeError):
    """The circuit breaker for this request's batch key is open after
    repeated failures; fast-failed without dispatching."""


class ServiceStoppedError(RuntimeError):
    """The service (or its dispatcher thread) went away before this job
    finished; the stream was terminated by the teardown poison-pill."""


def is_transient(exc: BaseException) -> bool:
    """Retry classification: injected faults carry a ``transient`` class
    attribute (:mod:`repro.core.faults`); everything else is persistent."""
    return bool(getattr(exc, "transient", False))


# ---------------------------------------------------------------------------
# Policy + deterministic backoff.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Knobs for the service's self-healing dispatch path.

    ``max_attempts`` counts dispatches of the same cohort (1 = no retry);
    ``backoff_*`` shape the inter-retry sleep
    ``base * factor**attempt * (1 + U(-jitter, jitter))``;
    ``max_bisect_depth`` bounds quarantine recursion (a cohort of 2**d
    splits to singletons at depth d); ``*_deadline_s`` of ``None`` disables
    the watchdog for that lane; the breaker opens after
    ``breaker_threshold`` consecutive failures of one batch key and
    half-opens after ``breaker_cooldown_s``.
    """

    max_attempts: int = 3
    backoff_base_s: float = 0.05
    backoff_factor: float = 2.0
    backoff_jitter: float = 0.25
    max_bisect_depth: int = 3
    batch_deadline_s: float | None = None
    solo_deadline_s: float | None = None
    breaker_threshold: int = 3
    breaker_cooldown_s: float = 30.0
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.max_bisect_depth < 0:
            raise ValueError(
                f"max_bisect_depth must be >= 0, got {self.max_bisect_depth}")
        if not 0.0 <= self.backoff_jitter < 1.0:
            raise ValueError(
                f"backoff_jitter must be in [0, 1), got {self.backoff_jitter}")
        if self.breaker_threshold < 1:
            raise ValueError(
                f"breaker_threshold must be >= 1, got {self.breaker_threshold}")


def backoff_delay(policy: RecoveryPolicy, attempt: int, key) -> float:
    """Deterministic jittered exponential backoff before retry ``attempt``
    (1-based: the sleep before the second dispatch is ``attempt=1``)."""
    base = policy.backoff_base_s * policy.backoff_factor ** (attempt - 1)
    if policy.backoff_jitter <= 0.0:
        return base
    rng = np.random.default_rng([policy.seed, key_digest(key), attempt])
    u = float(rng.uniform(-policy.backoff_jitter, policy.backoff_jitter))
    return base * (1.0 + u)


# ---------------------------------------------------------------------------
# Circuit breaker (per batch key).
# ---------------------------------------------------------------------------


class CircuitBreaker:
    """Thread-safe closed -> open -> half-open breaker, keyed by batch key.

    ``allow(key)`` gates a dispatch: closed keys always pass; open keys
    fast-fail until ``cooldown_s`` has elapsed, then exactly one caller is
    admitted as the half-open probe (concurrent callers keep fast-failing
    until the probe resolves).  ``record_success`` closes the key;
    ``record_failure`` re-opens a half-open key immediately, or opens a
    closed key once it accumulates ``threshold`` consecutive failures.
    """

    def __init__(self, threshold: int, cooldown_s: float,
                 clock: Clock | None = None):
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self.clock = clock or SYSTEM_CLOCK
        self._lock = threading.Lock()
        # key -> [state, consecutive_failures, opened_at]
        self._keys: dict = {}

    def allow(self, key) -> bool:
        with self._lock:
            st = self._keys.get(key)
            if st is None or st[0] == "closed":
                return True
            if st[0] == "half_open":
                return False  # a probe is already in flight
            if self.clock.monotonic() - st[2] >= self.cooldown_s:
                st[0] = "half_open"
                return True
            return False

    def record_success(self, key) -> None:
        with self._lock:
            self._keys.pop(key, None)

    def record_failure(self, key) -> None:
        with self._lock:
            st = self._keys.setdefault(key, ["closed", 0, 0.0])
            st[1] += 1
            if st[0] == "half_open" or st[1] >= self.threshold:
                st[0] = "open"
                st[2] = self.clock.monotonic()

    def state(self, key) -> str:
        with self._lock:
            st = self._keys.get(key)
            return "closed" if st is None else st[0]

    def snapshot(self) -> dict:
        """JSON-safe view for /stats: open + half-open keys only."""
        with self._lock:
            return {
                "open": sorted(repr(k) for k, st in self._keys.items()
                               if st[0] == "open"),
                "half_open": sorted(repr(k) for k, st in self._keys.items()
                                    if st[0] == "half_open"),
            }

    def states(self) -> dict:
        """JSON-safe FULL per-key state table for ``GET /health``: every
        tracked batch key with its state, consecutive-failure count, and --
        for open keys -- how long the circuit has been open on this
        breaker's clock."""
        with self._lock:
            now = self.clock.monotonic()
            return {
                repr(k): {
                    "state": st[0],
                    "consecutive_failures": st[1],
                    "open_for_s": (round(now - st[2], 6)
                                   if st[0] == "open" else None),
                }
                for k, st in sorted(self._keys.items(), key=lambda kv: repr(kv[0]))
            }


# ---------------------------------------------------------------------------
# Deadline watchdog.
# ---------------------------------------------------------------------------


def run_with_deadline(fn, deadline_s: float | None, *, label: str = "job"):
    """Run ``fn()`` with a wall-clock deadline.

    With ``deadline_s`` of ``None``, calls ``fn`` inline.  Otherwise runs
    it on a daemon thread and joins with the timeout: an overrun raises
    :class:`JobTimeoutError` and the late result (or late error) is
    *abandoned* -- the box is flagged so nothing from the stale attempt can
    ever be delivered to a tenant.
    """
    if deadline_s is None:
        return fn()
    box = {"value": None, "error": None, "abandoned": False}

    def target():
        try:
            v = fn()
        except BaseException as e:  # analysis: fail-fast-ok (relayed through the box to the waiting caller)
            if not box["abandoned"]:
                box["error"] = e
            return
        if not box["abandoned"]:
            box["value"] = v

    t = threading.Thread(target=target, name=f"deadline-{label}", daemon=True)
    t.start()
    t.join(timeout=deadline_s)
    if t.is_alive():
        box["abandoned"] = True
        raise JobTimeoutError(
            f"{label} overran its {deadline_s:g}s execution deadline")
    if box["error"] is not None:
        raise box["error"]
    return box["value"]
