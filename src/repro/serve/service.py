"""The persistent multi-tenant experiment service.

``ExperimentService`` is the long-lived front of the sweep substrate: tenants
submit one method entry of an :class:`~repro.api.ExperimentSpec` each
(in-process :meth:`~ExperimentService.submit`, or JSON over the stdlib HTTP
front end -- ``python -m repro serve``), and the service

1. **validates at admission** (``spec.validate()``: every registry name plus
   structural invariants, full known-entry listings in the error) so a bad
   spec is rejected synchronously and can never reach a batch and poison its
   cohort;
2. **bounds per-tenant depth** -- submissions past ``max_tenant_depth``
   in-flight jobs raise a typed :class:`BackpressureError` instead of
   queueing unboundedly;
3. **coalesces** compatible requests (same :func:`repro.serve.coalesce.batch_key`)
   into ONE :func:`repro.api.run_sweep_cells` call under the max-wait /
   max-batch policy, round-robin across tenants inside each batch;
4. **streams back** each tenant's typed Round/Sync/Eval/Stop events,
   bit-identical to a solo ``Session`` run (``batch="map"`` default;
   pinned by tests/test_serve.py);
5. keeps the **compile cache warm** across tenants (jit's process cache holds
   the executables; :class:`repro.serve.cache.CompileCache` mirrors its keys
   and reports hit/miss counters through :meth:`stats` / ``GET /stats``).

Requests that cannot share a batch -- group-family protocols,
``target_gap``/``time_budget`` early stop (:func:`repro.core.executor.coalesce_supported`),
and checkpointed runs (``spec.checkpoint_every``: snapshots are per-run
state) -- take the **solo lane**: a per-request ``Session`` streamed through
the same ``JobHandle``, so admission control and the API are uniform.

Self-healing (PR 9; knobs in :class:`repro.serve.recovery.RecoveryPolicy`,
injected failures in :mod:`repro.core.faults`):

* transient batch failures retry with exponential backoff + deterministic
  jitter; persistent ones **quarantine by bisection** -- the cohort splits
  and each half retries independently, so only the poison request fails and
  healthy tenants still get bit-identical results;
* a **watchdog deadline** per dispatch turns overruns into a typed
  :class:`~repro.serve.recovery.JobTimeoutError`; overrun *batches* are
  requeued on the solo lane rather than failed;
* a per-``batch_key`` **circuit breaker** fast-fails keys that keep failing
  (:class:`~repro.serve.recovery.CircuitOpenError`), half-open probe after
  the cooldown;
* **divergence masking**: after every batch, one jitted per-cell finite
  certificate (:func:`repro.core.executor.finite_certificates`) masks
  non-finite cells out of delivery and fails exactly those requests with
  :class:`~repro.serve.recovery.CellDivergenceError`;
* **teardown poison-pill**: if the dispatcher thread dies (or the service
  stops without draining), every unfinished stream terminates with
  :class:`~repro.serve.recovery.ServiceStoppedError` -- never a hang;
* **checkpoint/resume**: specs with ``checkpoint_every`` run as resumable
  scan segments under ``checkpoint_dir``
  (:func:`repro.core.executor.run_lockstep_checkpointed`); a killed service
  resumes them bit-identically from the last snapshot on resubmission.

Threading model: ``submit`` is safe from any thread; one dispatcher thread
(started by :meth:`start`, or driven synchronously by :meth:`drain` for
deterministic tests and batch clients) owns all execution.  Datasets are
built once per distinct ``ProblemSpec`` and memoized.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import math
import threading
from typing import Mapping

import numpy as np

from repro.api import run_sweep_cells
from repro.api.session import Session
from repro.api.sweep import resolve_shard
from repro.api.spec import ExperimentSpec
from repro.core import executor as executor_lib
from repro.core.faults import FaultModel, NoFault
from repro.launch import mesh as mesh_lib
from repro.serve.cache import (
    CompileCache,
    TTLCache,
    result_cache_key,
    sweep_cache_key,
)
from repro.serve.clock import SYSTEM_CLOCK, Clock
from repro.serve.coalesce import CoalescePolicy, Request, batch_key, form_batch
from repro.serve.recovery import (
    CellDivergenceError,
    CircuitBreaker,
    CircuitOpenError,
    JobTimeoutError,
    RecoveryPolicy,
    ServiceStoppedError,
    backoff_delay,
    is_transient,
    run_with_deadline,
)
from repro.serve.streams import JobHandle, deliver


class SpecValidationError(ValueError):
    """Rejected at admission: the spec names unknown registry entries or
    violates a structural invariant (message lists the known entries)."""


class BackpressureError(RuntimeError):
    """Rejected at admission: the tenant already has ``max_tenant_depth``
    unfinished jobs; retry after draining some."""


class ExperimentService:
    """See module docstring.  One instance per process; thread-safe submit."""

    def __init__(self, policy: CoalescePolicy | None = None, *,
                 recovery: RecoveryPolicy | None = None,
                 fault: FaultModel | None = None,
                 checkpoint_dir=None, clock: Clock | None = None,
                 result_cache_entries: int = 0,
                 result_cache_ttl_s: float | None = None,
                 problem_cache_entries: int = 32,
                 problem_cache_ttl_s: float | None = None):
        self.policy = policy or CoalescePolicy()
        self.recovery = recovery or RecoveryPolicy()
        self.fault = fault or NoFault()
        self.checkpoint_dir = checkpoint_dir
        self.clock = clock or SYSTEM_CLOCK
        self.compile_cache = CompileCache()
        # Result cache is OPT-IN (entries=0 disables): serving a repeat from
        # cache skips the dispatch entirely, which is the point -- but would
        # silently invalidate dispatch/trace counter pins in callers that
        # resubmit identical specs to measure warm-compile behavior.
        self.result_cache = TTLCache(max_entries=result_cache_entries,
                                     ttl_s=result_cache_ttl_s,
                                     clock=self.clock)
        self.breaker = CircuitBreaker(self.recovery.breaker_threshold,
                                      self.recovery.breaker_cooldown_s,
                                      clock=self.clock)
        self.cluster_health = None  # set by repro.serve.cluster.ClusterReplica
        self._lock = threading.Condition()
        self._pending: dict[tuple, list[Request]] = {}  # batch_key -> queue
        self._solo: list[Request] = []
        self._group_opened: dict[tuple, float] = {}  # batch_key -> first enqueue time
        self._inflight: dict[str, int] = {}  # tenant -> unfinished jobs
        self._jobs: dict[str, JobHandle] = {}
        self._order = itertools.count()
        self._problems = TTLCache(max_entries=problem_cache_entries,
                                  ttl_s=problem_cache_ttl_s,
                                  clock=self.clock)  # memoized datasets
        self._thread: threading.Thread | None = None
        self._stopping = False
        self._dead: BaseException | None = None  # the teardown poison-pill
        self.counters = {
            "submitted": 0, "rejected_validation": 0,
            "rejected_backpressure": 0, "batches": 0, "batched_requests": 0,
            "solo_requests": 0, "failed": 0,
            # self-healing accounting (PR 9)
            "retries": 0, "bisects": 0, "quarantined": 0, "timeouts": 0,
            "requeued_solo": 0, "masked_cells": 0, "breaker_rejected": 0,
            # result-cache accounting (PR 10)
            "result_cache_hits": 0,
        }

    # -- admission ---------------------------------------------------------

    def submit(self, tenant: str, spec: ExperimentSpec,
               method: str | None = None) -> JobHandle:
        """Admit one request: ``spec``'s method entry named ``method`` (or
        its only entry).  Validates and applies backpressure synchronously;
        returns the tenant's stream handle."""
        if self._dead is not None:
            raise ServiceStoppedError(
                f"service is dead and cannot accept work: {self._dead}")
        try:
            spec.validate()
        except ValueError as e:
            with self._lock:
                self.counters["rejected_validation"] += 1
            raise SpecValidationError(str(e)) from None
        if method is None:
            if len(spec.methods) != 1:
                raise SpecValidationError(
                    f"spec {spec.name!r} has {len(spec.methods)} method "
                    f"entries {[m.config.name for m in spec.methods]}; pass "
                    f"method=<name> to pick one per request")
            entry = spec.methods[0]
        else:
            try:
                entry = spec.method_named(method)
            except KeyError as e:
                with self._lock:
                    self.counters["rejected_validation"] += 1
                raise SpecValidationError(str(e)) from None

        ok, why = executor_lib.coalesce_supported(
            entry.config, spec.cluster, target_gap=spec.target_gap,
            time_budget=spec.time_budget)
        if ok and spec.checkpoint_every is not None:
            ok, why = False, ("checkpoint/resume snapshots are per-run "
                              "state; served per-request on the solo lane")
        if spec.checkpoint_every is not None and self.checkpoint_dir is None:
            raise SpecValidationError(
                f"spec {spec.name!r} sets checkpoint_every but this service "
                f"has no checkpoint_dir; construct "
                f"ExperimentService(checkpoint_dir=...)")

        if self.result_cache.max_entries:
            hit, cached = self.result_cache.get(result_cache_key(spec, entry))
            if hit:
                # Serve the repeat without dispatching: what was cached IS a
                # previously delivered (events, result) pair, so the replay
                # is bit-identical by construction.  No inflight accounting
                # -- the job is already finished when submit returns.
                with self._lock:
                    order = next(self._order)
                    handle = JobHandle(f"job-{order}", tenant)
                    self._jobs[handle.job_id] = handle
                    self.counters["submitted"] += 1
                    self.counters["result_cache_hits"] += 1
                events, result = cached
                for event in events:
                    handle._push(event)
                handle._finish(result)
                return handle

        with self._lock:
            if (self._inflight.get(tenant, 0)
                    >= self.policy.max_tenant_depth):
                self.counters["rejected_backpressure"] += 1
                raise BackpressureError(
                    f"tenant {tenant!r} has {self._inflight[tenant]} "
                    f"unfinished jobs (max_tenant_depth="
                    f"{self.policy.max_tenant_depth}); drain before "
                    f"resubmitting")
            order = next(self._order)
            handle = JobHandle(f"job-{order}", tenant)
            req = Request(tenant=tenant, spec=spec, entry=entry,
                          handle=handle, order=order,
                          solo_reason=None if ok else why)
            if ok:
                key = batch_key(spec, entry, policy=self.policy)
                self._pending.setdefault(key, []).append(req)
                self._group_opened.setdefault(key, self.clock.monotonic())
            else:
                self._solo.append(req)
            self._inflight[tenant] = self._inflight.get(tenant, 0) + 1
            self._jobs[handle.job_id] = handle
            self.counters["submitted"] += 1
            self._lock.notify_all()
        return handle

    def submit_json(self, tenant: str, text: str,
                    method: str | None = None) -> JobHandle:
        try:
            spec = ExperimentSpec.from_dict(json.loads(text))
        except (KeyError, TypeError, ValueError) as e:
            with self._lock:
                self.counters["rejected_validation"] += 1
            raise SpecValidationError(f"unparseable spec JSON: {e}") from None
        return self.submit(tenant, spec, method=method)

    def job(self, job_id: str) -> JobHandle:
        with self._lock:
            try:
                return self._jobs[job_id]
            except KeyError:
                raise KeyError(f"unknown job {job_id!r}") from None

    # -- execution ---------------------------------------------------------

    def _problem_for(self, spec: ExperimentSpec):
        key = (spec.problem.kind, tuple(sorted(spec.problem.params.items())))
        hit, problem = self._problems.get(key)
        if not hit:
            # Deterministic build: eviction (TTL or LRU) only costs a
            # rebuild, never changes what any tenant observes.
            problem = spec.problem.build()
            self._problems.put(key, problem)
        return problem

    def _count(self, **deltas: int) -> None:
        with self._lock:
            for k, v in deltas.items():
                self.counters[k] += v

    def _fail_requests(self, reqs: list[Request], error: BaseException,
                       **extra_counts: int) -> None:
        self._count(failed=len(reqs), **extra_counts)
        for r in reqs:
            r.handle._fail(error)
            self._job_done(r.tenant)

    def _dispatch_cells(self, reqs: list[Request], key: tuple):
        """ONE cohort through ``run_sweep_cells``, with fault injection, the
        watchdog deadline, and transient-retry backoff.  Returns the
        variants; raises the FINAL error (the original exception -- tenants
        and tests see the real cause, not a wrapper) once retries are
        exhausted or the failure is persistent."""
        first = reqs[0]
        problem = self._problem_for(first.spec)
        method = first.entry.config
        poisoned = set(self.fault.poison_cells(len(reqs), key))
        cells = [dataclasses.replace(r.cell, gamma=math.nan)
                 if i in poisoned else r.cell for i, r in enumerate(reqs)]
        plan = resolve_shard(self.policy.shard, protocol=method.protocol,
                             num_workers=first.spec.cluster.num_workers)
        ckey = sweep_cache_key(
            problem, method, len(cells), num_outer=first.entry.num_outer,
            eval_every=first.spec.eval_every, batch=self.policy.batch,
            plan=plan)
        attempt = 0
        while True:
            if attempt:
                self.clock.sleep(backoff_delay(self.recovery, attempt, key))
                self._count(retries=1)

            def one_attempt(attempt=attempt):
                # Injection happens INSIDE the watchdog window so slow-batch
                # faults genuinely overrun the deadline; the cache mirror is
                # noted once per actual run_sweep_cells invocation.
                self.fault.on_dispatch("batch", key, attempt)
                self.compile_cache.note(ckey)
                return run_sweep_cells(
                    problem, method, cells,
                    num_outer=first.entry.num_outer,
                    eval_every=first.spec.eval_every,
                    batch=self.policy.batch, shard=self.policy.shard)

            try:
                return run_with_deadline(
                    one_attempt, self.recovery.batch_deadline_s,
                    label=f"batch of {len(reqs)}")
            except JobTimeoutError:
                raise
            except Exception as e:  # analysis: fail-fast-ok (retried if transient, re-raised verbatim otherwise)
                if is_transient(e) and attempt + 1 < self.recovery.max_attempts:
                    attempt += 1
                    continue
                raise

    def _execute_cohort(self, reqs: list[Request], key: tuple,
                        depth: int) -> None:
        """Dispatch a cohort; on persistent failure quarantine-and-bisect so
        only the poison requests fail; on success mask non-finite cells and
        deliver the rest bit-identically."""
        try:
            variants = self._dispatch_cells(reqs, key)
        except JobTimeoutError:
            # Overrun: requeue everyone on the solo lane (per-request runs
            # under the solo deadline) instead of failing them.
            self._count(timeouts=1, requeued_solo=len(reqs))
            with self._lock:
                for r in reqs:
                    r.solo_reason = "batch execution deadline overrun"
                    self._solo.append(r)
                self._lock.notify_all()
            return
        except Exception as e:  # analysis: fail-fast-ok (bisected or failed to tenants as the original typed error)
            if len(reqs) == 1 or depth >= self.recovery.max_bisect_depth:
                self.breaker.record_failure(key)
                self._fail_requests(
                    reqs, e, quarantined=len(reqs) if depth else 0)
                return
            self._count(bisects=1)
            mid = len(reqs) // 2
            self._execute_cohort(reqs[:mid], key, depth + 1)
            self._execute_cohort(reqs[mid:], key, depth + 1)
            return

        self.breaker.record_success(key)
        finite = executor_lib.finite_certificates(variants)
        self._count(batches=1, batched_requests=len(reqs))
        for r, v, ok in zip(reqs, variants, np.asarray(finite)):
            if ok:
                events, result = deliver(r, v)
                if self.result_cache.max_entries:
                    self.result_cache.put(result_cache_key(r.spec, r.entry),
                                          (events, result))
            else:
                self._count(failed=1, masked_cells=1)
                r.handle._fail(CellDivergenceError(
                    f"job {r.handle.job_id}: cell produced non-finite "
                    f"iterates and was masked out of the coalesced batch "
                    f"(cohort of {len(reqs)} unaffected)"))
            self._job_done(r.tenant)

    def _run_batch(self, reqs: list[Request]) -> None:
        """One coalesced dispatch: every request's cell through
        ``run_sweep_cells`` (with recovery), results demuxed per handle."""
        first = reqs[0]
        key = batch_key(first.spec, first.entry, policy=self.policy)
        if not self.breaker.allow(key):
            self._fail_requests(
                reqs,
                CircuitOpenError(
                    f"circuit open for this batch template after repeated "
                    f"failures; retry after the "
                    f"{self.recovery.breaker_cooldown_s:g}s cooldown"),
                breaker_rejected=len(reqs))
            return
        self._execute_cohort(reqs, key, depth=0)

    def _run_solo(self, req: Request) -> None:
        """The solo lane: one Session, streamed live into the handle."""
        spec = req.spec
        solo_key = (req.tenant, req.handle.job_id)
        seen: list = []  # live-streamed events, for the result cache

        def drive():
            self.fault.on_dispatch("solo", solo_key, 0)
            hook = None
            ckpt_dir = ckpt_every = None
            if spec.checkpoint_every is not None:
                ckpt_dir = self.checkpoint_dir
                ckpt_every = spec.checkpoint_every
                hook = (lambda start:
                        self.fault.on_dispatch("segment", solo_key, start))
            session = Session(
                self._problem_for(spec), req.entry.config, spec.cluster,
                num_outer=req.entry.num_outer, seed=spec.seed,
                eval_every=spec.eval_every,
                target_gap=spec.target_gap, time_budget=spec.time_budget,
                executor=spec.executor, checkpoint_dir=ckpt_dir,
                checkpoint_every=ckpt_every, _segment_hook=hook)
            for event in session.events():
                seen.append(event)
                req.handle._push(event)
            return session.result()

        try:
            result = run_with_deadline(drive, self.recovery.solo_deadline_s,
                                       label=f"solo {req.handle.job_id}")
            req.handle._finish(result)
            if self.result_cache.max_entries:
                self.result_cache.put(result_cache_key(spec, req.entry),
                                      (list(seen), result))
        except Exception as e:  # analysis: fail-fast-ok (delivered to the tenant as the job's typed terminal error)
            req.handle._fail(e)
            self._count(failed=1,
                        timeouts=1 if isinstance(e, JobTimeoutError) else 0)
        else:
            self._count(solo_requests=1)
        self._job_done(req.tenant)

    def _job_done(self, tenant: str) -> None:
        with self._lock:
            self._inflight[tenant] = max(0, self._inflight.get(tenant, 0) - 1)
            self._lock.notify_all()

    # -- dispatch policy ---------------------------------------------------

    def _due_groups(self, now: float, *, flush: bool) -> list[tuple]:
        """Keys whose batch should close now: full, aged out, or flushing."""
        due = []
        for key, reqs in self._pending.items():
            if not reqs:
                continue
            if (flush or len(reqs) >= self.policy.max_batch
                    or now - self._group_opened[key]
                    >= self.policy.max_wait_s):
                due.append(key)
        return due

    def _take_batch(self, key: tuple) -> list[Request]:
        reqs = self._pending[key]
        picked = form_batch(reqs, max_batch=self.policy.max_batch)
        remaining = [r for r in reqs if r not in picked]
        if remaining:
            self._pending[key] = remaining
            self._group_opened[key] = self.clock.monotonic()  # restart the clock
        else:
            del self._pending[key]
            del self._group_opened[key]
        return picked

    def _dispatch_once(self, *, flush: bool) -> bool:
        """Run at most one batch or one solo request; True if work was done.

        Execution happens OUTSIDE the lock -- submissions keep flowing while
        a batch runs.
        """
        with self._lock:
            due = self._due_groups(self.clock.monotonic(), flush=flush)
            if due:
                # oldest group first: bounded wait under cross-key load
                key = min(due, key=lambda k: self._group_opened[k])
                batch = self._take_batch(key)
            elif self._solo:
                batch = None
                solo = self._solo.pop(0)
            else:
                return False
        if due:
            self._run_batch(batch)
        else:
            self._run_solo(solo)
        return True

    def drain(self) -> None:
        """Synchronously run EVERYTHING queued (max-wait ignored: pending
        groups flush at their current size).  The deterministic path for
        tests, benches and one-shot batch clients."""
        while self._dispatch_once(flush=True):
            pass

    # -- the dispatcher thread --------------------------------------------

    def start(self) -> "ExperimentService":
        if self._thread is not None:
            raise RuntimeError("service already started")
        self._stopping = False
        self._thread = threading.Thread(target=self._loop,
                                        name="experiment-service",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self, *, drain: bool = True) -> None:
        with self._lock:
            self._stopping = True
            self._lock.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if drain:
            self.drain()
        else:
            # Teardown poison-pill: whatever never ran terminates with a
            # typed error at every waiting consumer -- never a hang.
            self._poison_all(ServiceStoppedError(
                "service stopped before this job ran (stop(drain=False))"))

    def _loop(self) -> None:
        try:
            while True:
                did = self._dispatch_once(flush=False)
                with self._lock:
                    if self._stopping:
                        return
                    if not did:
                        # sleep until new work or the oldest group ages out
                        timeout = self.policy.max_wait_s
                        if self._group_opened:
                            oldest = min(self._group_opened.values())
                            timeout = max(0.0,
                                          oldest + self.policy.max_wait_s
                                          - self.clock.monotonic())
                        self._lock.wait(timeout=min(timeout,
                                                    self.policy.max_wait_s))
        except BaseException as e:  # analysis: fail-fast-ok (the dispatcher's last act is poisoning every stream with a typed error)
            self._poison_all(ServiceStoppedError(
                f"service dispatcher thread died: {e!r}"))

    def _poison_all(self, error: BaseException) -> None:
        """Terminate every unfinished stream with ``error`` and mark the
        service dead.  Idempotent handle termination makes racing deliveries
        safe; subsequent ``submit`` calls raise ``ServiceStoppedError``."""
        with self._lock:
            self._dead = error
            self._pending.clear()
            self._group_opened.clear()
            self._solo.clear()
            self._inflight.clear()
            handles = list(self._jobs.values())
            self._lock.notify_all()
        for h in handles:
            if not h.done():
                h._fail(error)

    # -- observability -----------------------------------------------------

    def health(self) -> dict:
        """Liveness summary for ``GET /health``."""
        with self._lock:
            pending = sum(len(v) for v in self._pending.values())
            solo = len(self._solo)
            dead = self._dead
        alive = self._thread is not None and self._thread.is_alive()
        info = {
            "status": "dead" if dead is not None else "ok",
            "dispatcher_alive": alive,
            "dead_reason": repr(dead) if dead is not None else None,
            "pending_batched": pending,
            "pending_solo": solo,
            "breaker": self.breaker.snapshot(),
            "breaker_states": self.breaker.states(),
        }
        if self.cluster_health is not None:
            # A ClusterReplica wires its membership/lease/heartbeat view in
            # here so GET /health answers for the replicated deployment too.
            info["cluster"] = self.cluster_health()
        return info

    def stats(self) -> dict:
        with self._lock:
            counters = dict(self.counters)
            pending = sum(len(v) for v in self._pending.values())
            solo = len(self._solo)
            inflight = {t: n for t, n in self._inflight.items() if n}
        batches = counters["batches"]
        counters["coalesce_factor"] = (
            counters["batched_requests"] / batches if batches else 0.0)
        return {
            **counters,
            "pending_batched": pending,
            "pending_solo": solo,
            "inflight_by_tenant": inflight,
            "fault_model": self.fault.fault_name,
            "breaker": self.breaker.snapshot(),
            "compile_cache": self.compile_cache.stats(),
            "result_cache": self.result_cache.stats(),
            "problem_cache": self._problems.stats(),
            "trace_counters": _trace_counters(),
            "devices": mesh_lib.device_summary(),
        }


def _trace_counters() -> dict:
    from repro.serve.cache import warm_trace_counters

    return warm_trace_counters()
