"""The persistent multi-tenant experiment service.

``ExperimentService`` is the long-lived front of the sweep substrate: tenants
submit one method entry of an :class:`~repro.api.ExperimentSpec` each
(in-process :meth:`~ExperimentService.submit`, or JSON over the stdlib HTTP
front end -- ``python -m repro serve``), and the service

1. **validates at admission** (``spec.validate()``: every registry name plus
   structural invariants, full known-entry listings in the error) so a bad
   spec is rejected synchronously and can never reach a batch and poison its
   cohort;
2. **bounds per-tenant depth** -- submissions past ``max_tenant_depth``
   in-flight jobs raise a typed :class:`BackpressureError` instead of
   queueing unboundedly;
3. **coalesces** compatible requests (same :func:`repro.serve.coalesce.batch_key`)
   into ONE :func:`repro.api.run_sweep_cells` call under the max-wait /
   max-batch policy, round-robin across tenants inside each batch;
4. **streams back** each tenant's typed Round/Sync/Eval/Stop events,
   bit-identical to a solo ``Session`` run (``batch="map"`` default;
   pinned by tests/test_serve.py);
5. keeps the **compile cache warm** across tenants (jit's process cache holds
   the executables; :class:`repro.serve.cache.CompileCache` mirrors its keys
   and reports hit/miss counters through :meth:`stats` / ``GET /stats``).

Requests that cannot share a batch -- group-family protocols,
``target_gap``/``time_budget`` early stop (:func:`repro.core.executor.coalesce_supported`)
-- take the **solo lane**: a per-request ``Session`` streamed through the
same ``JobHandle``, so admission control and the API are uniform.

Threading model: ``submit`` is safe from any thread; one dispatcher thread
(started by :meth:`start`, or driven synchronously by :meth:`drain` for
deterministic tests and batch clients) owns all execution.  Datasets are
built once per distinct ``ProblemSpec`` and memoized.
"""

from __future__ import annotations

import itertools
import json
import threading
import time  # analysis: host-ok
from typing import Mapping

from repro.api import run_sweep_cells
from repro.api.session import Session
from repro.api.sweep import resolve_shard
from repro.api.spec import ExperimentSpec
from repro.core import executor as executor_lib
from repro.launch import mesh as mesh_lib
from repro.serve.cache import CompileCache, sweep_cache_key
from repro.serve.coalesce import CoalescePolicy, Request, batch_key, form_batch
from repro.serve.streams import JobHandle, deliver


class SpecValidationError(ValueError):
    """Rejected at admission: the spec names unknown registry entries or
    violates a structural invariant (message lists the known entries)."""


class BackpressureError(RuntimeError):
    """Rejected at admission: the tenant already has ``max_tenant_depth``
    unfinished jobs; retry after draining some."""


class ExperimentService:
    """See module docstring.  One instance per process; thread-safe submit."""

    def __init__(self, policy: CoalescePolicy | None = None):
        self.policy = policy or CoalescePolicy()
        self.compile_cache = CompileCache()
        self._lock = threading.Condition()
        self._pending: dict[tuple, list[Request]] = {}  # batch_key -> queue
        self._solo: list[Request] = []
        self._group_opened: dict[tuple, float] = {}  # batch_key -> first enqueue time
        self._inflight: dict[str, int] = {}  # tenant -> unfinished jobs
        self._jobs: dict[str, JobHandle] = {}
        self._order = itertools.count()
        self._problems: dict[tuple, object] = {}  # memoized datasets
        self._thread: threading.Thread | None = None
        self._stopping = False
        self.counters = {
            "submitted": 0, "rejected_validation": 0,
            "rejected_backpressure": 0, "batches": 0, "batched_requests": 0,
            "solo_requests": 0, "failed": 0,
        }

    # -- admission ---------------------------------------------------------

    def submit(self, tenant: str, spec: ExperimentSpec,
               method: str | None = None) -> JobHandle:
        """Admit one request: ``spec``'s method entry named ``method`` (or
        its only entry).  Validates and applies backpressure synchronously;
        returns the tenant's stream handle."""
        try:
            spec.validate()
        except ValueError as e:
            with self._lock:
                self.counters["rejected_validation"] += 1
            raise SpecValidationError(str(e)) from None
        if method is None:
            if len(spec.methods) != 1:
                raise SpecValidationError(
                    f"spec {spec.name!r} has {len(spec.methods)} method "
                    f"entries {[m.config.name for m in spec.methods]}; pass "
                    f"method=<name> to pick one per request")
            entry = spec.methods[0]
        else:
            try:
                entry = spec.method_named(method)
            except KeyError as e:
                with self._lock:
                    self.counters["rejected_validation"] += 1
                raise SpecValidationError(str(e)) from None

        ok, why = executor_lib.coalesce_supported(
            entry.config, spec.cluster, target_gap=spec.target_gap,
            time_budget=spec.time_budget)

        with self._lock:
            if (self._inflight.get(tenant, 0)
                    >= self.policy.max_tenant_depth):
                self.counters["rejected_backpressure"] += 1
                raise BackpressureError(
                    f"tenant {tenant!r} has {self._inflight[tenant]} "
                    f"unfinished jobs (max_tenant_depth="
                    f"{self.policy.max_tenant_depth}); drain before "
                    f"resubmitting")
            order = next(self._order)
            handle = JobHandle(f"job-{order}", tenant)
            req = Request(tenant=tenant, spec=spec, entry=entry,
                          handle=handle, order=order,
                          solo_reason=None if ok else why)
            if ok:
                key = batch_key(spec, entry, policy=self.policy)
                self._pending.setdefault(key, []).append(req)
                self._group_opened.setdefault(key, time.monotonic())
            else:
                self._solo.append(req)
            self._inflight[tenant] = self._inflight.get(tenant, 0) + 1
            self._jobs[handle.job_id] = handle
            self.counters["submitted"] += 1
            self._lock.notify_all()
        return handle

    def submit_json(self, tenant: str, text: str,
                    method: str | None = None) -> JobHandle:
        try:
            spec = ExperimentSpec.from_dict(json.loads(text))
        except (KeyError, TypeError, ValueError) as e:
            with self._lock:
                self.counters["rejected_validation"] += 1
            raise SpecValidationError(f"unparseable spec JSON: {e}") from None
        return self.submit(tenant, spec, method=method)

    def job(self, job_id: str) -> JobHandle:
        with self._lock:
            try:
                return self._jobs[job_id]
            except KeyError:
                raise KeyError(f"unknown job {job_id!r}") from None

    # -- execution ---------------------------------------------------------

    def _problem_for(self, spec: ExperimentSpec):
        key = (spec.problem.kind, tuple(sorted(spec.problem.params.items())))
        if key not in self._problems:
            self._problems[key] = spec.problem.build()
        return self._problems[key]

    def _run_batch(self, reqs: list[Request]) -> None:
        """One coalesced dispatch: every request's cell through
        ``run_sweep_cells``, results demuxed to each handle."""
        first = reqs[0]
        problem = self._problem_for(first.spec)
        method = first.entry.config
        cells = [r.cell for r in reqs]
        plan = resolve_shard(self.policy.shard, protocol=method.protocol,
                             num_workers=first.spec.cluster.num_workers)
        key = sweep_cache_key(
            problem, method, len(cells), num_outer=first.entry.num_outer,
            eval_every=first.spec.eval_every, batch=self.policy.batch,
            plan=plan)
        self.compile_cache.note(key)
        try:
            variants = run_sweep_cells(
                problem, method, cells, num_outer=first.entry.num_outer,
                eval_every=first.spec.eval_every, batch=self.policy.batch,
                shard=self.policy.shard)
        except Exception as e:  # noqa: BLE001 -- a failed batch must not hang tenants
            for r in reqs:
                r.handle._fail(e)
                self._job_done(r.tenant)
            with self._lock:
                self.counters["failed"] += len(reqs)
            return
        with self._lock:
            self.counters["batches"] += 1
            self.counters["batched_requests"] += len(reqs)
        for r, v in zip(reqs, variants):
            deliver(r, v)
            self._job_done(r.tenant)

    def _run_solo(self, req: Request) -> None:
        """The solo lane: one Session, streamed live into the handle."""
        try:
            spec = req.spec
            session = Session(
                self._problem_for(spec), req.entry.config, spec.cluster,
                num_outer=req.entry.num_outer, seed=spec.seed,
                eval_every=spec.eval_every,
                target_gap=spec.target_gap, time_budget=spec.time_budget,
                executor=spec.executor)
            for event in session.events():
                req.handle._push(event)
            req.handle._finish(session.result())
        except Exception as e:  # noqa: BLE001
            req.handle._fail(e)
            with self._lock:
                self.counters["failed"] += 1
        else:
            with self._lock:
                self.counters["solo_requests"] += 1
        self._job_done(req.tenant)

    def _job_done(self, tenant: str) -> None:
        with self._lock:
            self._inflight[tenant] = max(0, self._inflight.get(tenant, 0) - 1)
            self._lock.notify_all()

    # -- dispatch policy ---------------------------------------------------

    def _due_groups(self, now: float, *, flush: bool) -> list[tuple]:
        """Keys whose batch should close now: full, aged out, or flushing."""
        due = []
        for key, reqs in self._pending.items():
            if not reqs:
                continue
            if (flush or len(reqs) >= self.policy.max_batch
                    or now - self._group_opened[key]
                    >= self.policy.max_wait_s):
                due.append(key)
        return due

    def _take_batch(self, key: tuple) -> list[Request]:
        reqs = self._pending[key]
        picked = form_batch(reqs, max_batch=self.policy.max_batch)
        remaining = [r for r in reqs if r not in picked]
        if remaining:
            self._pending[key] = remaining
            self._group_opened[key] = time.monotonic()  # restart the clock
        else:
            del self._pending[key]
            del self._group_opened[key]
        return picked

    def _dispatch_once(self, *, flush: bool) -> bool:
        """Run at most one batch or one solo request; True if work was done.

        Execution happens OUTSIDE the lock -- submissions keep flowing while
        a batch runs.
        """
        with self._lock:
            due = self._due_groups(time.monotonic(), flush=flush)
            if due:
                # oldest group first: bounded wait under cross-key load
                key = min(due, key=lambda k: self._group_opened[k])
                batch = self._take_batch(key)
            elif self._solo:
                batch = None
                solo = self._solo.pop(0)
            else:
                return False
        if due:
            self._run_batch(batch)
        else:
            self._run_solo(solo)
        return True

    def drain(self) -> None:
        """Synchronously run EVERYTHING queued (max-wait ignored: pending
        groups flush at their current size).  The deterministic path for
        tests, benches and one-shot batch clients."""
        while self._dispatch_once(flush=True):
            pass

    # -- the dispatcher thread --------------------------------------------

    def start(self) -> "ExperimentService":
        if self._thread is not None:
            raise RuntimeError("service already started")
        self._stopping = False
        self._thread = threading.Thread(target=self._loop,
                                        name="experiment-service",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self, *, drain: bool = True) -> None:
        with self._lock:
            self._stopping = True
            self._lock.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if drain:
            self.drain()

    def _loop(self) -> None:
        while True:
            did = self._dispatch_once(flush=False)
            with self._lock:
                if self._stopping:
                    return
                if not did:
                    # sleep until new work or the oldest group ages out
                    timeout = self.policy.max_wait_s
                    if self._group_opened:
                        oldest = min(self._group_opened.values())
                        timeout = max(0.0, oldest + self.policy.max_wait_s
                                      - time.monotonic())
                    self._lock.wait(timeout=min(timeout,
                                                self.policy.max_wait_s))

    # -- observability -----------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            counters = dict(self.counters)
            pending = sum(len(v) for v in self._pending.values())
            solo = len(self._solo)
            inflight = {t: n for t, n in self._inflight.items() if n}
        batches = counters["batches"]
        counters["coalesce_factor"] = (
            counters["batched_requests"] / batches if batches else 0.0)
        return {
            **counters,
            "pending_batched": pending,
            "pending_solo": solo,
            "inflight_by_tenant": inflight,
            "compile_cache": self.compile_cache.stats(),
            "trace_counters": _trace_counters(),
            "devices": mesh_lib.device_summary(),
        }


def _trace_counters() -> dict:
    from repro.serve.cache import warm_trace_counters

    return warm_trace_counters()
