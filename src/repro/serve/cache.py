"""Warm compiled-sweep cache accounting, keyed like ``jax.jit``'s own cache.

The actual compiled executables live in ``jax.jit``'s process-level cache on
:func:`repro.api.sweep._sweep_scan` / ``_lag_sweep_scan`` -- a long-lived
service keeps them warm for free.  What jit does NOT give a service is
*observability*: whether an incoming batch will hit a warm executable or pay
a fresh trace+compile, and therefore what the fleet's compile amortization
actually is.  :class:`CompileCache` mirrors jit's cache key -- ``(static
arguments, operand aval (shape, dtype) tuples)``, the exact construction the
PR-6 trace-time contract ``check_sweep_bucket_sharing`` pins
(:mod:`repro.analysis.contracts`) -- and counts hits/misses per key.

The mirror is honest because ``run_sweep_cells`` routes every batch through
the same pow2 padding helpers the key derivation uses: two batches map to
the same :func:`sweep_cache_key` if and only if jit reuses one executable
(cross-checked against ``executor.STATS`` trace counters in
tests/test_serve.py).
"""

from __future__ import annotations

import threading

from repro.core import compress as compress_lib
from repro.core import engine, executor


def _bucket(n: int) -> int:
    return engine._bucket_size(n)


def sweep_cache_key(problem, method, num_cells: int, *, num_outer: int,
                    eval_every: int, batch: str, plan) -> tuple:
    """The jit cache key a ``run_sweep_cells`` call with this shape maps to.

    Statics and operand avals exactly as the compiled callables see them:
    the cell axis padded to ``max(pow2 bucket, n_shards)``, the eval axis to
    its pow2 bucket -- so heterogeneous tenant batches that pad alike
    collapse to one key (and one compile).
    """
    K, n_k, d = problem.X.shape
    n_shards = plan.n_shards
    V = max(_bucket(num_cells), n_shards)
    if method.protocol == "lag":
        R = num_outer * method.T
        E = _bucket(len(executor._eval_indices(R, eval_every)))
        comp = compress_lib.for_method(method, d)
        dense = isinstance(comp, compress_lib.Dense)
        statics = ("lag", problem.loss, method.H, comp, R,
                   method.lag_window, d * 4 if dense else 0, batch,
                   n_shards if plan.mode == "cells" else 1)
        avals = (
            ((V,), "key"),
            ((K, n_k, d), "float32"), ((K, n_k), "float32"),
            ((K, n_k), "float32"),
            ((), "float32"), ((), "int32"),          # lam, n
            ((V,), "float32"), ((V,), "float32"),    # sigma_ps, gammas
            ((), "float32"),                         # xi
            ((V, R, K), "float64"),                  # durations
            ((R,), "int64"), ((), "int64"), ((), "int64"),
            ((V,), "float64"), ((V,), "float64"),    # lats, bws
            ((V, K), "float64"),                     # link_factors
            ((E,), "int32"),
        )
        return (statics, avals)
    E = _bucket(len(executor._eval_indices(num_outer, eval_every)))
    statics = ("lockstep", problem.loss, method.H,
               executor.lockstep_solver(method), num_outer, batch,
               n_shards if plan.mode != "none" else 1, plan.mode)
    dt = str(problem.X.dtype)
    avals = (
        ((V,), "key"),
        ((K, n_k, d), dt), ((K, n_k), dt), ((K, n_k), dt),
        ((), dt), ((), "int32"),
        ((V,), dt), ((V,), dt),
        ((E,), "int32"),
    )
    return (statics, avals)


class CompileCache:
    """Hit/miss accounting over the warm jit cache (thread-safe).

    ``note(key)`` records one batched dispatch against ``key`` and returns
    whether it was warm.  ``stats()`` reports the counters the bench and
    ``GET /stats`` surface: total hits/misses, distinct entries, hit rate.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._seen: dict[tuple, int] = {}
        self.hits = 0
        self.misses = 0

    def note(self, key: tuple) -> bool:
        with self._lock:
            warm = key in self._seen
            self._seen[key] = self._seen.get(key, 0) + 1
            if warm:
                self.hits += 1
            else:
                self.misses += 1
            return warm

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {
                "entries": len(self._seen),
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0,
            }


def warm_trace_counters() -> dict:
    """The executor's process-wide trace/dispatch counters (ground truth the
    mirror is validated against)."""
    return {k: executor.STATS[k] for k in
            ("sweep_calls", "sweep_traces", "sweep_lag_calls",
             "sweep_lag_traces")}
