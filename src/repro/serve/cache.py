"""Service-side caches: compile-key mirror, result cache, dataset cache.

**Compile mirror.** The actual compiled executables live in ``jax.jit``'s
process-level cache on :func:`repro.api.sweep._sweep_scan` /
``_lag_sweep_scan`` -- a long-lived service keeps them warm for free.  What
jit does NOT give a service is *observability*: whether an incoming batch
will hit a warm executable or pay a fresh trace+compile, and therefore what
the fleet's compile amortization actually is.  :class:`CompileCache` mirrors
jit's cache key -- ``(static arguments, operand aval (shape, dtype)
tuples)``, the exact construction the PR-6 trace-time contract
``check_sweep_bucket_sharing`` pins (:mod:`repro.analysis.contracts`) -- and
counts hits/misses per key.  The mirror is honest because
``run_sweep_cells`` routes every batch through the same pow2 padding helpers
the key derivation uses: two batches map to the same
:func:`sweep_cache_key` if and only if jit reuses one executable
(cross-checked against ``executor.STATS`` trace counters in
tests/test_serve.py).

**Result cache.** Every run here is a pure function of its spec: identical
``(problem, cluster, method entry, seed, stop targets, executor)``
submissions replay the identical event stream.  :class:`TTLCache` keyed by
:func:`result_cache_key` therefore serves repeats without dispatching --
bit-identical by construction, since what is cached IS the delivered
``(events, result)``.  Entries age out after ``ttl_s`` on the service's
injectable clock and the least-recently-USED entry is evicted past
``max_entries`` (an LRU, not FIFO: a hot template stays warm under churn).
The same class bounds the memoized problem datasets (the build is
deterministic, so eviction only costs a rebuild).  Hit/evict counters
surface through ``ExperimentService.stats()``.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict

from repro.core import compress as compress_lib
from repro.core import engine, executor
from repro.serve.clock import SYSTEM_CLOCK, Clock


def _bucket(n: int) -> int:
    return engine._bucket_size(n)


def sweep_cache_key(problem, method, num_cells: int, *, num_outer: int,
                    eval_every: int, batch: str, plan) -> tuple:
    """The jit cache key a ``run_sweep_cells`` call with this shape maps to.

    Statics and operand avals exactly as the compiled callables see them:
    the cell axis padded to ``max(pow2 bucket, n_shards)``, the eval axis to
    its pow2 bucket -- so heterogeneous tenant batches that pad alike
    collapse to one key (and one compile).
    """
    K, n_k, d = problem.X.shape
    n_shards = plan.n_shards
    V = max(_bucket(num_cells), n_shards)
    if method.protocol == "lag":
        R = num_outer * method.T
        E = _bucket(len(executor._eval_indices(R, eval_every)))
        comp = compress_lib.for_method(method, d)
        dense = isinstance(comp, compress_lib.Dense)
        statics = ("lag", problem.loss, method.H, comp, R,
                   method.lag_window, d * 4 if dense else 0, batch,
                   n_shards if plan.mode == "cells" else 1)
        avals = (
            ((V,), "key"),
            ((K, n_k, d), "float32"), ((K, n_k), "float32"),
            ((K, n_k), "float32"),
            ((), "float32"), ((), "int32"),          # lam, n
            ((V,), "float32"), ((V,), "float32"),    # sigma_ps, gammas
            ((), "float32"),                         # xi
            ((V, R, K), "float64"),                  # durations
            ((R,), "int64"), ((), "int64"), ((), "int64"),
            ((V,), "float64"), ((V,), "float64"),    # lats, bws
            ((V, K), "float64"),                     # link_factors
            ((E,), "int32"),
        )
        return (statics, avals)
    E = _bucket(len(executor._eval_indices(num_outer, eval_every)))
    statics = ("lockstep", problem.loss, method.H,
               executor.lockstep_solver(method), num_outer, batch,
               n_shards if plan.mode != "none" else 1, plan.mode)
    dt = str(problem.X.dtype)
    avals = (
        ((V,), "key"),
        ((K, n_k, d), dt), ((K, n_k), dt), ((K, n_k), dt),
        ((), dt), ((), "int32"),
        ((V,), dt), ((V,), dt),
        ((E,), "int32"),
    )
    return (statics, avals)


class CompileCache:
    """Hit/miss accounting over the warm jit cache (thread-safe).

    ``note(key)`` records one batched dispatch against ``key`` and returns
    whether it was warm.  ``stats()`` reports the counters the bench and
    ``GET /stats`` surface: total hits/misses, distinct entries, hit rate.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._seen: dict[tuple, int] = {}
        self.hits = 0
        self.misses = 0

    def note(self, key: tuple) -> bool:
        with self._lock:
            warm = key in self._seen
            self._seen[key] = self._seen.get(key, 0) + 1
            if warm:
                self.hits += 1
            else:
                self.misses += 1
            return warm

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {
                "entries": len(self._seen),
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0,
            }


def warm_trace_counters() -> dict:
    """The executor's process-wide trace/dispatch counters (ground truth the
    mirror is validated against)."""
    return {k: executor.STATS[k] for k in
            ("sweep_calls", "sweep_traces", "sweep_lag_calls",
             "sweep_lag_traces")}


# ---------------------------------------------------------------------------
# TTL + LRU value cache (results, memoized datasets).
# ---------------------------------------------------------------------------


def result_cache_key(spec, entry) -> tuple:
    """The full run identity a delivered ``(events, result)`` depends on.

    Two submissions with equal keys replay bit-identical streams (runs are
    pure functions of the spec; the batch-vs-solo parity pin in
    tests/test_serve.py is what makes lane-independence true), so the
    result cache may serve one from the other -- across tenants, which do
    NOT enter the key on purpose."""
    return (
        spec.problem.kind,
        repr(sorted(spec.problem.params.items())),
        repr(dataclasses.asdict(spec.cluster)),
        repr(dataclasses.asdict(entry.config)),
        int(entry.num_outer), int(spec.seed), int(spec.eval_every),
        spec.target_gap, spec.time_budget, spec.executor,
        spec.checkpoint_every,
    )


class TTLCache:
    """Thread-safe bounded cache: TTL expiry + least-recently-USED eviction.

    ``max_entries=0`` disables the cache entirely (every ``get`` misses,
    ``put`` is a no-op) -- the service's default for RESULTS, because a
    silent result cache would invalidate dispatch-counter pins in existing
    tests and benches; callers opt in.  ``ttl_s=None`` means entries never
    expire by age.  Time comes from the injected :class:`Clock`, so expiry
    is testable with a ``ManualClock``.
    """

    def __init__(self, *, max_entries: int, ttl_s: float | None = None,
                 clock: Clock | None = None):
        if max_entries < 0:
            raise ValueError(f"max_entries must be >= 0, got {max_entries}")
        if ttl_s is not None and ttl_s <= 0:
            raise ValueError(f"ttl_s must be positive or None, got {ttl_s}")
        self.max_entries = int(max_entries)
        self.ttl_s = None if ttl_s is None else float(ttl_s)
        self.clock = clock or SYSTEM_CLOCK
        self._lock = threading.Lock()
        self._entries: OrderedDict = OrderedDict()  # key -> (value, stored_at)
        self.hits = 0
        self.misses = 0
        self.evicted_ttl = 0
        self.evicted_lru = 0

    def _expired(self, stored_at: float, now: float) -> bool:
        return self.ttl_s is not None and now - stored_at >= self.ttl_s

    def get(self, key) -> tuple[bool, object]:
        """``(hit, value)``; a hit refreshes the key's LRU position."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None and not self._expired(entry[1],
                                                       self.clock.monotonic()):
                self._entries.move_to_end(key)
                self.hits += 1
                return True, entry[0]
            if entry is not None:  # present but stale
                del self._entries[key]
                self.evicted_ttl += 1
            self.misses += 1
            return False, None

    def put(self, key, value) -> None:
        if self.max_entries == 0:
            return
        with self._lock:
            now = self.clock.monotonic()
            self._entries[key] = (value, now)
            self._entries.move_to_end(key)
            stale = [k for k, (_, at) in self._entries.items()
                     if self._expired(at, now)]
            for k in stale:
                del self._entries[k]
                self.evicted_ttl += 1
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)  # least recently used
                self.evicted_lru += 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {
                "entries": len(self._entries),
                "max_entries": self.max_entries,
                "ttl_s": self.ttl_s,
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0,
                "evicted_ttl": self.evicted_ttl,
                "evicted_lru": self.evicted_lru,
            }
