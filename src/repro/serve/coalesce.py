"""Request coalescing: which pending requests may share one compiled batch.

A tenant request is ONE method entry of one :class:`ExperimentSpec` -- one
sweep cell.  Two requests can run in the same :func:`repro.api.run_sweep_cells`
call exactly when everything that is STATIC to the compiled computation (or a
shared traced operand) matches; everything that enters per cell may differ
freely:

===================  =====================================================
shared (batch key)   problem entry + params, protocol family statics
                     (H, T, B, rho, compressor, local solver, lag window,
                     lag xi, chunking: n_chunks / pw_quantum / n_racks /
                     rack_b), ``num_outer``, eval cadence, batch mode,
                     resolved shard plan
per cell (free)      ``cluster`` (the WHOLE delay axis: model, params,
                     latency, bandwidth, stragglers, membership), ``seed``,
                     ``gamma``, ``sigma_prime``
===================  =====================================================

WHETHER a request may coalesce at all is the protocol registry's own call:
the service's admission gate (``executor.coalesce_supported``) delegates to
:meth:`repro.core.engine.Protocol.coalesce_supported`, so e.g.
``partial_work`` (per-chunk scan carries) and ``hierarchical_b``
(rack-dependent pop counts) decline batching and ride the solo lane -- one
:class:`repro.api.Session` per request -- while still being admitted.  An
elastic ``membership`` schedule forces the event loop, which only the solo
lane runs.  Checkpointed specs (``checkpoint_every``) are solo for the same
reason chunked protocols are: their snapshots are per-run state
(``repro.core.executor.run_lockstep_checkpointed``), not shared sweep cells.

The per-cell column is what makes coalescing pay off: lockstep timing is
host-side accounting and the lag executor consumes per-cell delay streams as
traced operands, so tenants probing DIFFERENT straggler scenarios against the
same problem/method template still share one compile and one dispatch.
Heterogeneous batch SIZES also share compiles -- ``run_sweep_cells`` pads the
cell axis to pow2 buckets -- so the key deliberately excludes the request
count.

:func:`form_batch` applies the admission-control policy: a batch closes when
it reaches ``max_batch`` cells or the oldest member has waited ``max_wait_s``
(the service's dispatcher enforces the clock; this module is pure grouping
logic so it stays deterministic and directly testable).  Within a batch,
requests are taken round-robin ACROSS tenants (oldest-first within each
tenant), so one tenant flooding its queue cannot starve another --
per-tenant depth is additionally bounded at submit time
(:class:`repro.serve.service.BackpressureError`).
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.api.spec import ExperimentSpec, MethodEntry
from repro.api.sweep import SweepCellSpec, resolve_shard

#: MethodConfig fields that vary PER CELL inside a batch; everything else is
#: part of the batch key.  ``name`` is display-only (restored per request by
#: the stream demultiplexer).
CELL_FIELDS = ("name", "gamma", "sigma_prime")


@dataclasses.dataclass(frozen=True)
class CoalescePolicy:
    """Admission-control knobs for the coalescer.

    * ``max_batch`` -- close a batch at this many cells (one dispatch).
    * ``max_wait_s`` -- close a non-full batch once its oldest request has
      waited this long (latency bound under light load).
    * ``max_tenant_depth`` -- per-tenant bound on queued-but-unfinished
      requests; submissions past it are rejected with a typed
      ``BackpressureError`` instead of queueing unboundedly.
    * ``batch`` -- forwarded to ``run_sweep_cells``; the default ``"map"``
      keeps every coalesced cell bit-identical to its solo ``Session`` run
      (the serve contract); ``"vmap"`` trades that for throughput.
    * ``shard`` -- mesh sharding request, resolved per batch key.
    """

    max_batch: int = 16
    max_wait_s: float = 0.05
    max_tenant_depth: int = 8
    batch: str = "map"
    shard: str = "auto"


@dataclasses.dataclass
class Request:
    """One admitted tenant request (internal to the service)."""

    tenant: str
    spec: ExperimentSpec
    entry: MethodEntry
    handle: Any  # repro.serve.streams.JobHandle
    order: int  # admission sequence number (FIFO within a tenant)
    solo_reason: str | None = None  # non-None => solo lane, why

    @property
    def cell(self) -> SweepCellSpec:
        cfg = self.entry.config
        return SweepCellSpec(cluster=self.spec.cluster, seed=self.spec.seed,
                             gamma=cfg.gamma, sigma_prime=cfg.sigma_prime)


def method_template(cfg) -> tuple:
    """The method's batch-key projection: every field except CELL_FIELDS."""
    return tuple(sorted(
        (f.name, getattr(cfg, f.name)) for f in dataclasses.fields(cfg)
        if f.name not in CELL_FIELDS))


def batch_key(spec: ExperimentSpec, entry: MethodEntry, *,
              policy: CoalescePolicy) -> tuple:
    """The coalescing key: requests with equal keys share one compiled call.

    Includes the resolved :class:`~repro.api.sweep.ShardPlan` (not the raw
    ``shard`` string): ``"auto"`` and ``"cells"`` resolve identically on a
    multi-device host and must coalesce.
    """
    cfg = entry.config
    plan = resolve_shard(policy.shard, protocol=cfg.protocol,
                         num_workers=spec.cluster.num_workers)
    return (
        spec.problem.kind,
        tuple(sorted(spec.problem.params.items())),
        method_template(cfg),
        entry.num_outer,
        spec.eval_every,
        policy.batch,
        plan,
        # Checkpointed specs never reach a batch (the service forces them
        # solo); keyed anyway so a future relaxation cannot silently mix
        # checkpointed and plain runs in one cohort.
        spec.checkpoint_every,
    )


def form_batch(requests: list[Request], *, max_batch: int) -> list[Request]:
    """Pick <= ``max_batch`` requests from one key group, round-robin across
    tenants (oldest-first within each tenant).

    With T waiting tenants each tenant gets ~``max_batch / T`` slots in the
    closing batch regardless of how deep any single tenant's backlog is --
    the in-batch half of the fairness story (the other half is the
    per-tenant depth bound at submit).
    """
    by_tenant: dict[str, list[Request]] = {}
    for r in sorted(requests, key=lambda r: r.order):
        by_tenant.setdefault(r.tenant, []).append(r)
    queues = [by_tenant[t] for t in sorted(by_tenant)]
    picked: list[Request] = []
    while queues and len(picked) < max_batch:
        next_round = []
        for q in queues:
            if len(picked) >= max_batch:
                break
            picked.append(q.pop(0))
            if q:
                next_round.append(q)
        queues = next_round
    return picked
