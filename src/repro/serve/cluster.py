"""Replicated multi-process serving: N replicas over one cluster directory.

The PR-9 serve stack heals everything that can fail INSIDE one process --
this module makes the process itself redundant.  N
:class:`~repro.serve.service.ExperimentService` replicas (in-process objects
for deterministic tests, or separate processes spawned with ``python -m
repro serve --replica-of <cluster-dir> --replica-id <id>``) coordinate
through a shared filesystem **cluster directory**; there is no broker and no
inter-replica socket, just the atomic-rename/link idiom of
:mod:`repro.serve.leases` (and of the PR-9 shareable ``checkpoint_dir``,
which lives inside the cluster directory so every replica can resume every
replica's runs)::

    <cluster-dir>/
      replicas/     heartbeat files        (leases.LeaseManager)
      leases/       per-job ownership      (leases.LeaseManager)
      jobs/         submitted job records  (client -> replicas)
      results/      delivered results      (replicas -> client, link-once)
      checkpoints/  shared lockstep checkpoint segments

**Job flow.**  A :class:`ClusterClient` content-hashes ``(spec, method,
tenant)`` into an idempotent :func:`job_key` and writes a job record; it
re-sends every unfinished record on each :meth:`~ClusterClient.pump` --
at-least-once.  Replicas scan ``jobs/``, claim unowned jobs through
mutually-exclusive lease acquisition, execute through their embedded
``ExperimentService`` (same admission, recovery, and bit-identity contracts
as solo serving), and deliver ``(events, result)`` as a result record.
Delivery is **exactly-once** in the only sense that matters -- at most one
result record per job key ever becomes visible -- because records are
created with ``os.link`` (first writer wins, duplicates count as
``deduped_results``), while the at-least-once re-send loop guarantees the
record eventually appears under message drops.

**Failure detection + takeover.**  A replica that dies (real SIGKILL in
subprocess mode; the uncatchable :class:`~repro.core.faults.ReplicaKilled`
in-process) leaves its heartbeat to go stale and its lease held.  A
surviving replica steals the lease through the raced-rename takeover of
:meth:`~repro.serve.leases.LeaseManager.try_takeover` (epoch bumped), then
simply re-runs the job: ``run_lockstep_checkpointed`` finds the dead
owner's last durable segment under the shared ``checkpoints/`` and resumes
-- the delivered stream is bit-identical to an uninterrupted run.  The
bumped epoch fences the ghost: a presumed-dead owner that comes back fails
:meth:`~repro.serve.leases.LeaseManager.still_owner` and discards its late
result (``fenced_results``) instead of double-delivering.

**Chaos seam.**  Every cross-process interaction -- job records, result
records, heartbeats, and the replica scheduler itself -- routes through
:class:`ClusterTransport` / :meth:`ClusterReplica.step`, where the
:mod:`repro.core.faults` network family (``net_drop`` / ``net_duplicate`` /
``net_reorder`` / ``net_delay`` / ``net_partition`` / ``replica_kill`` /
``cluster_chaos``) applies deterministically: message fates are pure
functions of ``(seed, kind, key, seq)`` and replica fates of ``(replica,
tick)``, so replaying one ``(seed, fault model, submission order)``
schedule reproduces the identical recovery counters.

The PR-9 contracts survive replica death: consumers never hang
(:meth:`ClusterClient.result` bounds its wait and raises the typed
:class:`ClusterUnavailableError`), errors stay typed end-to-end (error
records rebuild the ORIGINAL typed error class client-side, so the pinned
HTTP statuses of ``serve/http.py`` keep applying), and a replica's teardown
still poisons its local streams.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import signal
import tempfile

import numpy as np

from repro.api.spec import ExperimentSpec
from repro.core.acpd import RunRecord, RunResult
from repro.core.faults import FaultModel, NoFault, ReplicaKilled
from repro.serve.clock import SYSTEM_CLOCK, Clock
from repro.serve.http import event_from_dict, event_to_dict
from repro.serve.leases import LeaseManager, _atomic_write, _fname, _read_json
from repro.serve.recovery import (
    CellDivergenceError,
    CircuitOpenError,
    JobTimeoutError,
    ServiceStoppedError,
)
from repro.serve.service import (
    BackpressureError,
    ExperimentService,
    SpecValidationError,
)

# ---------------------------------------------------------------------------
# Typed cluster errors + error-record reconstruction.
# ---------------------------------------------------------------------------


class ClusterUnavailableError(RuntimeError):
    """No replica delivered this job within the caller's wait bound -- the
    cluster is unreachable, partitioned away, or wholly dead.  The bounded
    typed outcome that replaces a hung ``result()``/``events()``."""


class ClusterJobError(RuntimeError):
    """A job failed on a replica with an error type this client cannot
    reconstruct; ``error_type`` carries the original class name."""

    def __init__(self, error_type: str, message: str):
        super().__init__(f"{error_type}: {message}")
        self.error_type = error_type


#: Error classes a result record may carry and the client re-raises AS-IS,
#: preserving the pinned HTTP statuses in serve/http.py end-to-end.
_TYPED_ERRORS = {cls.__name__: cls for cls in (
    SpecValidationError, BackpressureError, JobTimeoutError,
    CellDivergenceError, CircuitOpenError, ServiceStoppedError,
)}


def _raise_from_record(record: dict):
    err = record["error"]
    cls = _TYPED_ERRORS.get(err["error_type"])
    if cls is not None:
        raise cls(err["message"])
    raise ClusterJobError(err["error_type"], err["message"])


# ---------------------------------------------------------------------------
# Idempotent job identity + result (de)serialization.
# ---------------------------------------------------------------------------


def job_key(tenant: str, spec: ExperimentSpec, method: str | None) -> str:
    """Content-hash of ``(spec, method, tenant)``: the idempotency token.

    Two submissions of the same work map to the SAME key, so a duplicated
    or re-sent job record cannot run twice into two deliveries -- the lease
    admits one owner per key and the result link admits one record.  The
    spec enters through its canonical ``to_dict`` JSON (sorted keys), not
    object identity, so the key is stable across processes and restarts."""
    blob = json.dumps({"tenant": tenant, "spec": spec.to_dict(),
                       "method": method}, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:20]


def result_to_record(events, result: RunResult) -> dict:
    """JSON-safe ``(events, result)``: exact round-trip (float repr)."""
    return {
        "events": [event_to_dict(e) for e in events],
        "records": [dataclasses.asdict(r) for r in result.records],
        "w": np.asarray(result.w).tolist(),
        "alpha": np.asarray(result.alpha).tolist(),
        "alpha_applied": (None if result.alpha_applied is None
                          else np.asarray(result.alpha_applied).tolist()),
        "dtype": str(np.asarray(result.w).dtype),
    }


def record_to_result(record: dict, method_config) -> tuple[list, RunResult]:
    """Inverse of :func:`result_to_record`; ``method_config`` comes from the
    CLIENT's own spec (method identity is part of the job key, so it is the
    config the producing replica ran)."""
    dt = record["dtype"]
    result = RunResult(
        method=method_config,
        records=[RunRecord(**r) for r in record["records"]],
        w=np.asarray(record["w"], dtype=dt),
        alpha=np.asarray(record["alpha"], dtype=dt),
        alpha_applied=(None if record["alpha_applied"] is None
                       else np.asarray(record["alpha_applied"], dtype=dt)))
    return [event_from_dict(d) for d in record["events"]], result


# ---------------------------------------------------------------------------
# The fault-injectable transport.
# ---------------------------------------------------------------------------


class ClusterTransport:
    """All cross-process writes of one sender, with the network-fault seam.

    A "message" is a closure performing one atomic filesystem write.  For
    each send the fault model's ``message_fate(kind, key, seq)`` decides
    ``(copies, delay_ticks)``: 0 copies drops the write, 2 duplicates it,
    and a positive delay holds the closure until ``delay_ticks`` calls to
    :meth:`tick` later (1 tick = the next message overtakes = reordering).
    The send SEQUENCE feeds the fate draw, so at-least-once re-senders
    always converge under sub-1.0 drop rates.

    Result records are written with ``os.link`` -- first writer wins -- so
    duplicate copies and racing peers dedupe instead of double-delivering
    (counted in ``deduped_results``).
    """

    def __init__(self, cluster_dir, *, fault: FaultModel | None = None,
                 sender: str = "client"):
        self.cluster_dir = pathlib.Path(cluster_dir)
        self.fault = fault or NoFault()
        self.sender = str(sender)
        self.jobs_dir = self.cluster_dir / "jobs"
        self.results_dir = self.cluster_dir / "results"
        for d in (self.jobs_dir, self.results_dir):
            d.mkdir(parents=True, exist_ok=True)
        self._seq = 0
        self._tick = 0
        self._held: list[tuple[int, int, object]] = []  # (due, seq, closure)
        self.counters = {"sent": 0, "dropped": 0, "duplicated": 0,
                         "delayed": 0, "deduped_results": 0}

    def tick(self) -> None:
        """Advance transport time; deliver every held message now due."""
        self._tick += 1
        due = [h for h in self._held if h[0] <= self._tick]
        self._held = [h for h in self._held if h[0] > self._tick]
        for _, _, write in sorted(due, key=lambda h: (h[0], h[1])):
            write()

    def _send(self, kind: str, key, write) -> None:
        copies, delay = self.fault.message_fate(kind, key, self._seq)
        self._seq += 1
        if copies == 0:
            self.counters["dropped"] += 1
            return
        if copies > 1:
            self.counters["duplicated"] += copies - 1
        for _ in range(copies):
            if delay > 0:
                self.counters["delayed"] += 1
                self._held.append((self._tick + delay, self._seq, write))
            else:
                self.counters["sent"] += 1
                write()

    # -- message kinds -----------------------------------------------------

    def send_job(self, key: str, record: dict) -> None:
        """Idempotent by content: duplicates/re-sends overwrite with the
        identical record (atomic replace)."""
        path = self.jobs_dir / f"{_fname(key)}.json"
        self._send("job", key, lambda: _atomic_write(path, record))

    def send_result(self, key: str, record: dict) -> None:
        """Exactly-once visible: first ``link`` wins, the rest dedupe."""
        path = self.results_dir / f"{_fname(key)}.json"

        def write():
            with tempfile.NamedTemporaryFile("w", dir=self.results_dir,
                                             suffix=".tmp", delete=False) as f:
                f.write(json.dumps(record))
                tmp = pathlib.Path(f.name)
            try:
                os.link(tmp, path)
            except FileExistsError:
                self.counters["deduped_results"] += 1
            finally:
                os.unlink(tmp)

        self._send("result", key, write)

    def send_heartbeat(self, lease: LeaseManager) -> None:
        """The heartbeat is a message too: droppable, delayable."""
        self._send("heartbeat", lease.replica_id, lease.heartbeat)

    # -- reads (fault-free: reads are local) -------------------------------

    def read_job(self, key: str) -> dict | None:
        return _read_json(self.jobs_dir / f"{_fname(key)}.json")

    def read_result(self, key: str) -> dict | None:
        return _read_json(self.results_dir / f"{_fname(key)}.json")

    def list_jobs(self) -> list[str]:
        return sorted(p.stem for p in self.jobs_dir.glob("*.json"))

    def has_result(self, key: str) -> bool:
        return (self.results_dir / f"{_fname(key)}.json").exists()


# ---------------------------------------------------------------------------
# Replica.
# ---------------------------------------------------------------------------


class _ReplicaFault(FaultModel):
    """Adapter handed to the embedded service: forwards the service-level
    hooks to the cluster fault model and turns ``segment_fate`` into death.

    ``on_dispatch(kind="segment", ...)`` is the service's checkpoint-segment
    boundary hook (the previous snapshot is durable when it fires); when the
    schedule says this replica dies there, subprocess replicas take a REAL
    ``SIGKILL`` and in-process replicas raise :class:`ReplicaKilled` -- a
    ``BaseException`` no recovery trap may catch, so the service writes no
    result, releases no lease, and says no goodbye."""

    def __init__(self, inner: FaultModel, replica_id: str, *,
                 subprocess_kill: bool):
        super().__init__(seed=inner.seed)
        self.inner = inner
        self.replica_id = replica_id
        self.subprocess_kill = subprocess_kill
        self.fault_name = f"replica({inner.fault_name})"

    def _die(self, where: str):
        if self.subprocess_kill:
            os.kill(os.getpid(), signal.SIGKILL)  # no cleanup, no goodbye
        raise ReplicaKilled(f"replica {self.replica_id} killed {where}")

    def on_dispatch(self, kind: str, key, attempt: int) -> None:
        if kind == "segment" and self.inner.segment_fate(self.replica_id,
                                                         attempt):
            self._die(f"at checkpoint segment starting round {attempt}")
        return self.inner.on_dispatch(kind, key, attempt)

    def poison_cells(self, n_cells: int, key):
        return self.inner.poison_cells(n_cells, key)


class ClusterReplica:
    """One member: an ``ExperimentService`` plus lease/heartbeat/transport.

    Drive it with :meth:`step` -- one deterministic scheduler tick: check
    this replica's fate, flush the transport, heartbeat, deliver any
    completed-but-unconfirmed results, claim and execute at most one job,
    then attempt takeover of at most one expired lease.  ``run_forever``
    (subprocess mode) is just ``step`` + ``clock.sleep`` in a loop.
    """

    def __init__(self, cluster_dir, replica_id: str, *,
                 fault: FaultModel | None = None, clock: Clock | None = None,
                 lease_ttl_s: float = 10.0, subprocess_kill: bool = False,
                 service_kwargs: dict | None = None):
        self.cluster_dir = pathlib.Path(cluster_dir)
        self.replica_id = str(replica_id)
        self.clock = clock or SYSTEM_CLOCK
        fault = fault or NoFault()
        self.fault = fault
        self.lease = LeaseManager(self.cluster_dir, replica_id,
                                  clock=self.clock, lease_ttl_s=lease_ttl_s)
        self.transport = ClusterTransport(self.cluster_dir, fault=fault,
                                          sender=replica_id)
        kwargs = dict(service_kwargs or {})
        kwargs.setdefault("result_cache_entries", 64)
        self.service = ExperimentService(
            fault=_ReplicaFault(fault, self.replica_id,
                                subprocess_kill=subprocess_kill),
            checkpoint_dir=self.cluster_dir / "checkpoints",
            clock=self.clock, **kwargs)
        self.service.cluster_health = self.cluster_health
        self.tick = 0
        self._undelivered: dict[str, dict] = {}  # job_key -> result record
        self.counters = {"steps": 0, "claims": 0, "takeovers": 0,
                         "completed": 0, "errored": 0, "fenced_results": 0,
                         "partitioned_ticks": 0}

    # -- the scheduler tick ------------------------------------------------

    def step(self) -> bool:
        """One tick; returns True iff this replica executed a job.

        Raises :class:`ReplicaKilled` (never returns) when the fault
        schedule kills this replica here -- in subprocess mode the process
        is already gone."""
        self.tick += 1
        fate = self.fault.replica_fate(self.replica_id, self.tick)
        if fate == "killed":
            self.service.fault._die(f"at scheduler tick {self.tick}")
        if fate == "partitioned":
            # Reads nothing, sends nothing; held messages stay held.
            self.counters["partitioned_ticks"] += 1
            return False
        self.counters["steps"] += 1
        self.transport.tick()
        self.transport.send_heartbeat(self.lease)
        self._redeliver()
        did = self._claim_and_run()
        if not did:
            did = self._try_takeover_one()
        return did

    def _redeliver(self) -> None:
        """At-least-once: re-send completed results until visible."""
        for key in sorted(self._undelivered):
            if self.transport.has_result(key):
                del self._undelivered[key]
            else:
                self.transport.send_result(key, self._undelivered[key])

    def _claimable(self) -> list[str]:
        return [k for k in self.transport.list_jobs()
                if not self.transport.has_result(k)
                and k not in self._undelivered]

    def _claim_and_run(self) -> bool:
        for key in self._claimable():
            if self.lease.read_lease(key) is not None:
                continue
            lease = self.lease.try_acquire(key, epoch=0)
            if lease is None:
                continue  # raced: someone else claimed between read and link
            self.counters["claims"] += 1
            self._execute(key, lease)
            return True
        return False

    def _try_takeover_one(self) -> bool:
        for key in self._claimable():
            lease = self.lease.read_lease(key)
            if lease is None or not self.lease.expired(lease):
                continue
            stolen = self.lease.try_takeover(key)
            if stolen is None:
                continue  # lost the steal race, or the owner was superseded
            self.counters["takeovers"] += 1
            self._execute(key, stolen)
            return True
        return False

    def _execute(self, key: str, lease: dict) -> None:
        """Run one owned job through the embedded service and deliver.

        A mid-run kill (``segment_fate``) escapes as ``ReplicaKilled``
        before any delivery: the lease stays held, the heartbeat goes
        stale, and a peer resumes from the last durable checkpoint segment
        under the shared ``checkpoints/`` directory."""
        record = self.transport.read_job(key)
        if record is None:  # job record vanished (never: records are kept)
            self.lease.release(key, lease["epoch"])
            return
        try:
            spec = ExperimentSpec.from_dict(record["spec"])
            handle = self.service.submit(record["tenant"], spec,
                                         method=record.get("method"))
            self.service.drain()
            events = list(handle.events(timeout=5.0))
            result = handle.result(timeout=5.0)
            payload = {"job": key, "owner": self.replica_id,
                       "epoch": lease["epoch"],
                       **result_to_record(events, result)}
            self.counters["completed"] += 1
        except ReplicaKilled:
            raise
        except Exception as e:  # analysis: fail-fast-ok (delivered as a typed error record, re-raised client-side)
            payload = {"job": key, "owner": self.replica_id,
                       "epoch": lease["epoch"],
                       "error": {"error_type": type(e).__name__,
                                 "message": str(e)}}
            self.counters["errored"] += 1
        # Epoch fencing: if this replica was presumed dead and superseded
        # while running, its lease shows a different (owner, epoch) now --
        # the late result must be DISCARDED, the new owner's delivery wins.
        if not self.lease.still_owner(key, lease["epoch"]):
            self.counters["fenced_results"] += 1
            return
        self._undelivered[key] = payload
        self.transport.send_result(key, payload)
        if self.transport.has_result(key):
            del self._undelivered[key]
        self.lease.release(key, lease["epoch"])

    # -- lifecycle ---------------------------------------------------------

    def run_forever(self, *, interval_s: float = 0.2) -> None:
        """Subprocess main loop (``python -m repro serve --replica-of``)."""
        try:
            while True:
                self.step()
                self.clock.sleep(interval_s)
        finally:
            self.retire()

    def retire(self) -> None:
        """Graceful exit: withdraw the heartbeat, poison local streams."""
        self.lease.retire()
        if self.service._thread is not None:
            self.service.stop(drain=False)
        else:
            self.service._poison_all(ServiceStoppedError(
                f"replica {self.replica_id} retired"))

    # -- observability -----------------------------------------------------

    def cluster_health(self) -> dict:
        """Membership + lease table + heartbeat ages, for ``GET /health``."""
        return {
            "replica_id": self.replica_id,
            "tick": self.tick,
            "membership": self.lease.membership(),
            "leases": self.lease.lease_table(),
            "undelivered": sorted(self._undelivered),
            "transport": dict(self.transport.counters),
        }

    def stats(self) -> dict:
        return {
            "replica_id": self.replica_id,
            **self.counters,
            "transport": dict(self.transport.counters),
            "service": {k: self.service.counters[k]
                        for k in ("submitted", "solo_requests", "failed")},
        }


# ---------------------------------------------------------------------------
# Client.
# ---------------------------------------------------------------------------


class ClusterClient:
    """Submit-and-await against the cluster directory (no replica pinning:
    any live replica may serve any job).

    At-least-once submission: :meth:`pump` re-sends every unfinished job
    record (dropped sends get fresh fate draws).  Bounded waits: both
    :meth:`result` and :meth:`events` raise the typed
    :class:`ClusterUnavailableError` at their deadline instead of hanging,
    whatever the cluster's state -- the cross-process form of the PR-9
    zero-hung-jobs contract.
    """

    def __init__(self, cluster_dir, *, fault: FaultModel | None = None,
                 clock: Clock | None = None):
        self.cluster_dir = pathlib.Path(cluster_dir)
        self.clock = clock or SYSTEM_CLOCK
        self.transport = ClusterTransport(self.cluster_dir, fault=fault,
                                          sender="client")
        self._pending: dict[str, dict] = {}   # key -> job record
        self._methods: dict[str, object] = {}  # key -> MethodConfig
        self.counters = {"submitted": 0, "resent": 0, "completed": 0,
                         "errored": 0, "unavailable": 0}

    def submit(self, tenant: str, spec: ExperimentSpec,
               method: str | None = None) -> str:
        """Validate locally, send the job record, return its idempotent key
        (a resubmission of identical work returns the same key)."""
        try:
            spec.validate()
        except ValueError as e:
            raise SpecValidationError(str(e)) from None
        entry = (spec.methods[0] if method is None
                 else spec.method_named(method))
        key = job_key(tenant, spec, method)
        record = {"job": key, "tenant": tenant, "spec": spec.to_dict(),
                  "method": method}
        self._pending[key] = record
        self._methods[key] = entry.config
        self.counters["submitted"] += 1
        self.transport.send_job(key, record)
        return key

    def pump(self) -> None:
        """Advance transport time and re-send unfinished job records."""
        self.transport.tick()
        for key in sorted(self._pending):
            if self.transport.has_result(key):
                continue
            self.counters["resent"] += 1
            self.transport.send_job(key, self._pending[key])

    def try_result(self, key: str):
        """``(events, result)`` if delivered, ``None`` if still pending;
        raises the job's reconstructed typed error if it failed."""
        record = self.transport.read_result(key)
        if record is None:
            return None
        self._pending.pop(key, None)
        if "error" in record:
            self.counters["errored"] += 1
            _raise_from_record(record)
        self.counters["completed"] += 1
        return record_to_result(record, self._methods.get(key))

    def result(self, key: str, *, timeout_s: float = 30.0,
               poll_s: float = 0.05) -> RunResult:
        """Block (bounded!) for the folded result."""
        return self._await(key, timeout_s, poll_s)[1]

    def events(self, key: str, *, timeout_s: float = 30.0,
               poll_s: float = 0.05) -> list:
        """Block (bounded!) for the full typed event stream."""
        return self._await(key, timeout_s, poll_s)[0]

    def _await(self, key: str, timeout_s: float, poll_s: float):
        deadline = self.clock.monotonic() + timeout_s
        while True:
            out = self.try_result(key)
            if out is not None:
                return out
            if self.clock.monotonic() >= deadline:
                self.counters["unavailable"] += 1
                raise ClusterUnavailableError(
                    f"job {key} not delivered within {timeout_s:g}s -- no "
                    f"live replica completed it (cluster dead, partitioned, "
                    f"or still recovering)")
            self.pump()
            self.clock.sleep(poll_s)

    def unfinished(self) -> list[str]:
        return [k for k in sorted(self._pending)
                if not self.transport.has_result(k)]


# ---------------------------------------------------------------------------
# Deterministic in-process driver (tests, benches, `make cluster-smoke`).
# ---------------------------------------------------------------------------


def run_cluster(replicas: list[ClusterReplica], client: ClusterClient, *,
                max_ticks: int = 200, clock=None,
                advance_s: float = 0.0) -> dict:
    """Drive an in-process cluster to completion, deterministically.

    Round-robin over replicas in list order, one :meth:`ClusterReplica.step`
    each per tick, client :meth:`~ClusterClient.pump` between rounds --
    a fixed schedule, so one ``(seed, fault model, submission order)``
    triple always replays the identical interleaving and the identical
    counters.  Replicas that die (``ReplicaKilled``) are recorded and
    dropped; the loop ends when every submitted job has a result record or
    ``max_ticks`` elapses (it never hangs).

    When the cluster shares one :class:`~repro.serve.clock.ManualClock`,
    pass it as ``clock`` with ``advance_s > 0``: each tick ages the clock
    by that much, so heartbeats go stale and lease takeover happens on the
    fixed schedule instead of wall time.
    """
    dead: dict[str, str] = {}
    ticks = 0
    for _ in range(max_ticks):
        if not client.unfinished():
            break
        ticks += 1
        if clock is not None and advance_s > 0:
            clock.advance(advance_s)
        client.pump()
        for replica in replicas:
            if replica.replica_id in dead:
                continue
            try:
                replica.step()
            except ReplicaKilled as e:
                dead[replica.replica_id] = str(e)
    return {
        "ticks": ticks,
        "dead": dict(dead),
        "hung_jobs": len(client.unfinished()),
        "client": dict(client.counters),
        "replicas": {r.replica_id: r.stats() for r in replicas},
    }
