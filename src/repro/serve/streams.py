"""Per-tenant event streams: demultiplexing a batched sweep result.

A coalesced batch runs many tenants' cells through ONE
:func:`repro.api.run_sweep_cells` call; each tenant still observes the exact
typed event stream a solo :class:`repro.api.Session` would have produced.
:func:`replay_events` reconstructs that stream from a
:class:`~repro.api.sweep.SweepVariant`'s per-round accounting
(``variant.rounds``) and eval-boundary records, mirroring
``Session._generate_scan`` in the deferred eval modes: every round emits a
``RoundEvent`` (plus ``SyncEvent`` on full-K barriers), then all
``EvalEvent`` certificates arrive in one trailing batch, then ``StopEvent``.
Bit-identity is pinned by tests/test_serve.py: same floats, same ordering,
same event types as ``Session(executor="scan")`` -- which is itself pinned
bit-identical to the event-queue engine.

:class:`JobHandle` is the consumer half: a thread-safe queue of events that
the service's dispatcher feeds (batched or solo lane alike) and the tenant
drains -- iterate :meth:`JobHandle.events` live, or call
:meth:`JobHandle.result` to block for the folded ``RunResult``.  Failures
travel the same channel: an executor error surfaces as a raised exception at
the consuming end, never a hang.
"""

from __future__ import annotations

import dataclasses
import queue as queue_lib  # analysis: host-ok
import threading
from typing import Iterator

from repro.api.session import (
    EvalEvent,
    RoundEvent,
    SessionEvent,
    StopEvent,
    SyncEvent,
)
from repro.core.acpd import RunResult


def replay_events(variant) -> list[SessionEvent]:
    """The solo-Session event sequence of one sweep cell (deferred evals).

    Requires ``variant.rounds`` (explicit-cell sweeps populate it); the
    replay is pure host bookkeeping -- the compiled batch already produced
    every number it emits.
    """
    if variant.rounds is None:
        raise ValueError(
            "variant carries no per-round accounting (rounds=None); serve "
            "batches must run through run_sweep_cells, which populates it")
    events: list[SessionEvent] = []
    iteration = 0
    for acct in variant.rounds:
        iteration += 1
        events.append(RoundEvent(
            iteration=iteration, sim_time=acct.sim_time,
            arrivals=acct.arrivals, bytes_up=acct.bytes_up,
            bytes_down=acct.bytes_down, compute_time=acct.compute_time,
            comm_time=acct.comm_time))
        if acct.is_sync:
            events.append(SyncEvent(iteration=iteration,
                                    sim_time=acct.sim_time))
    for rec in variant.result.records:
        events.append(EvalEvent(**dataclasses.asdict(rec)))
    events.append(StopEvent(
        reason="completed", iteration=iteration,
        sim_time=variant.rounds[-1].sim_time if variant.rounds else 0.0))
    return events


class JobHandle:
    """One tenant request's stream endpoint (thread-safe, single consumer)."""

    def __init__(self, job_id: str, tenant: str):
        self.job_id = job_id
        self.tenant = tenant
        self._queue: queue_lib.Queue = queue_lib.Queue()
        self._result: RunResult | None = None
        self._error: BaseException | None = None
        self._done = threading.Event()

    # -- producer side (the service dispatcher) ----------------------------

    def _push(self, event: SessionEvent) -> None:
        self._queue.put(event)

    def _finish(self, result: RunResult) -> None:
        # First terminal outcome wins: the teardown poison-pill and a racing
        # delivery (or an abandoned deadline attempt) must not clobber each
        # other, so termination is idempotent.
        if self._done.is_set():
            return
        self._result = result
        self._done.set()
        self._queue.put(None)  # wake the consumer

    def _fail(self, error: BaseException) -> None:
        if self._done.is_set():
            return
        self._error = error
        self._done.set()
        self._queue.put(None)

    # -- consumer side (the tenant) ----------------------------------------

    def events(self, timeout: float | None = None) -> Iterator[SessionEvent]:
        """Yield events as they arrive until the stream's ``StopEvent``.

        Raises the job's error (executor failure) instead of hanging;
        ``timeout`` bounds the wait for EACH event (``queue.Empty`` on
        expiry), not the whole stream.
        """
        while True:
            item = self._queue.get(timeout=timeout)
            if item is None:
                if self._error is not None:
                    raise self._error
                return
            yield item
            if isinstance(item, StopEvent):
                # the terminal sentinel is still queued; drain it so a
                # second .events() call (or .result()) sees a clean queue
                continue

    def result(self, timeout: float | None = None) -> RunResult:
        """Block until the job finishes; returns the folded RunResult."""
        if not self._done.wait(timeout=timeout):
            raise TimeoutError(
                f"job {self.job_id} did not finish within {timeout}s")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result

    def done(self) -> bool:
        return self._done.is_set()


def deliver(request, variant):
    """Demux one cell of a finished batch into its request's handle.

    Restores the request's OWN method config on the result (the batch ran
    under the shared template; only ``name`` differs -- gamma/sigma_prime
    were per-cell operands) so ``handle.result().method`` round-trips.
    Returns the delivered ``(events, result)`` pair so the service can feed
    its result cache with exactly what the tenant observed.
    """
    result = dataclasses.replace(variant.result, method=request.entry.config)
    events = replay_events(dataclasses.replace(variant, result=result))
    for event in events:
        request.handle._push(event)
    request.handle._finish(result)
    return events, result
