"""One injectable clock for every host-time read in the serve layer.

The serve stack is full of wall-clock-shaped decisions -- batch max-wait
aging, retry backoff sleeps, circuit-breaker cooldowns, heartbeat staleness,
lease expiry -- and before this module each of them called ``time.time()`` /
``time.monotonic()`` / ``time.sleep()`` directly, which forced every test of
a time-dependent behavior to either real-sleep or pick degenerate thresholds
(``cooldown_s=0`` / ``1e9``).  Now every serve-side component takes a
:class:`Clock` (default :data:`SYSTEM_CLOCK`, the real thing) and tests
inject a :class:`ManualClock` they advance explicitly: deadline, backoff,
breaker-cooldown, heartbeat-staleness and lease-expiry behavior all run
deterministically without a single real sleep.

Two clocks matter for the cluster layer (:mod:`repro.serve.cluster`):
replicas in ONE process under test share one ``ManualClock`` so heartbeat
ages are exact; replicas in SEPARATE processes use ``SYSTEM_CLOCK``, whose
``time()`` epoch is comparable across processes on one host (heartbeat files
carry the writer's ``clock.time()``; readers age them against their own).

This is the one module in ``serve/`` allowed to touch :mod:`time` directly.
"""

from __future__ import annotations

import threading
import time  # analysis: host-ok (the single wall-clock seam of the serve layer)


class Clock:
    """The injectable time source: monotonic + epoch reads and sleep."""

    def monotonic(self) -> float:
        """Monotonic seconds; use for intervals within one process."""
        return time.monotonic()

    def time(self) -> float:
        """Epoch seconds; use for cross-process comparisons (heartbeats)."""
        return time.time()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)


#: The default: real host time.  Module-level singleton so components can
#: default to it without each constructing their own.
SYSTEM_CLOCK = Clock()


class ManualClock(Clock):
    """A test clock that only moves when told to (thread-safe).

    ``monotonic()`` and ``time()`` return the same counter (tests don't need
    two epochs); ``sleep(s)`` advances it by ``s`` instead of blocking, so a
    component that "waits out" a backoff or cooldown completes instantly
    while the rest of the system observes the elapsed interval.
    """

    def __init__(self, start: float = 0.0):
        self._lock = threading.Lock()
        self._now = float(start)

    def monotonic(self) -> float:
        with self._lock:
            return self._now

    def time(self) -> float:
        with self._lock:
            return self._now

    def sleep(self, seconds: float) -> None:
        self.advance(max(0.0, seconds))

    def advance(self, seconds: float) -> float:
        """Move time forward by ``seconds``; returns the new now."""
        with self._lock:
            self._now += float(seconds)
            return self._now
