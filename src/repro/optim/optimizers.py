"""Self-contained optimizers (no optax in this container): SGD(+momentum),
AdamW, cosine/linear schedules. State is a plain pytree mirroring params so
the sharding rules that apply to params apply verbatim to optimizer state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"  # "adamw" | "sgd"
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # "cosine" | "linear" | "constant"
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    momentum: float = 0.9
    grad_clip: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: PyTree  # first moment / momentum
    nu: PyTree | None  # second moment (adamw only)


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = jnp.float32(1.0)
    return cfg.learning_rate * warm * decay


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gnorm


def init_state(cfg: OptimizerConfig, params: PyTree) -> OptState:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    if cfg.name == "adamw":
        return OptState(jnp.zeros((), jnp.int32), zeros(), zeros())
    if cfg.name == "sgd":
        return OptState(jnp.zeros((), jnp.int32), zeros(), None)
    raise ValueError(cfg.name)


def apply_update(cfg: OptimizerConfig, params: PyTree, grads: PyTree,
                 state: OptState) -> tuple[PyTree, OptState, dict]:
    """One optimizer step; grads may be any pytree matching params."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = lr_at(cfg, step)

    if cfg.name == "sgd":
        mu = jax.tree.map(lambda m, g: cfg.momentum * m + g.astype(jnp.float32),
                          state.mu, grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * (m + cfg.weight_decay
                          * p.astype(jnp.float32))).astype(p.dtype), params, mu)
        return new_params, OptState(step, mu, None), {"lr": lr, "grad_norm": gnorm}

    if cfg.name == "adamw":
        b1, b2 = cfg.beta1, cfg.beta2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                          * jnp.square(g.astype(jnp.float32)), state.nu, grads)
        t = step.astype(jnp.float32)
        c1, c2 = 1 - b1**t, 1 - b2**t

        def upd(p, m, v):
            mh, vh = m / c1, v / c2
            delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, OptState(step, mu, nu), {"lr": lr, "grad_norm": gnorm}

    raise ValueError(cfg.name)
