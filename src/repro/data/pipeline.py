"""Sharded batch pipeline: deterministic, resumable, device-put to the mesh.

The token pipeline packs a flat stream into (batch, seq) examples with
next-token labels, places each global batch according to the step's batch
sharding, and exposes its cursor for checkpoint/resume. For the linear-model
(paper) side, batching is handled inside core/acpd.py (the partitions are the
workers); this pipeline feeds the deep-net substrate.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.synthetic import make_token_dataset
from repro.models.config import ModelConfig


@dataclasses.dataclass
class TokenPipeline:
    cfg: ModelConfig
    batch_size: int
    seq_len: int
    mesh: Mesh | None = None
    seed: int = 0
    num_tokens: int | None = None  # synthetic stream size (default: 64 batches)
    step: int = 0  # cursor, checkpointable

    def __post_init__(self):
        need = self.num_tokens or 64 * self.batch_size * (self.seq_len + 1)
        self._stream = make_token_dataset(need, self.cfg.vocab_size, self.seed)
        self._per_batch = self.batch_size * (self.seq_len + 1)
        self._num_batches = len(self._stream) // self._per_batch
        if self.mesh is not None:
            daxes = tuple(a for a in ("pod", "data") if a in self.mesh.shape)
            self._sharding = NamedSharding(self.mesh, P(daxes or None, None))
        else:
            self._sharding = None

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()

    def next_batch(self) -> dict:
        i = self.step % self._num_batches
        chunk = self._stream[i * self._per_batch : (i + 1) * self._per_batch]
        arr = chunk.reshape(self.batch_size, self.seq_len + 1)
        batch = self._make_batch(arr)
        self.step += 1
        if self._sharding is not None:
            batch = {k: jax.device_put(v, self._sharding) if v.ndim == 2
                     else v for k, v in batch.items()}
        return batch

    def _make_batch(self, arr: np.ndarray) -> dict:
        tokens = jnp.asarray(arr[:, :-1])
        labels = jnp.asarray(arr[:, 1:])
        cfg = self.cfg
        if cfg.frontend == "text":
            return {"tokens": tokens, "labels": labels}
        if cfg.frontend == "vision_stub":
            p = min(cfg.num_patch_tokens, self.seq_len // 2)
            rng = np.random.default_rng(self.seed + self.step)
            patches = jnp.asarray(
                rng.standard_normal((self.batch_size, p, cfg.d_model))
                .astype(np.float32) * 0.02)
            return {"tokens": tokens[:, : self.seq_len - p],
                    "labels": labels[:, : self.seq_len - p],
                    "patch_embeds": patches.astype(cfg.cdtype)}
        if cfg.frontend == "audio_stub":
            rng = np.random.default_rng(self.seed + self.step)
            frames = jnp.asarray(
                rng.standard_normal((self.batch_size, self.seq_len, cfg.d_model))
                .astype(np.float32) * 0.02)
            return {"frame_embeds": frames.astype(cfg.cdtype), "labels": labels}
        raise ValueError(cfg.frontend)

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, state: dict) -> None:
        self.step = int(state["step"])
