from repro.data.synthetic import make_linear_problem, make_token_dataset  # noqa: F401
