"""Deterministic synthetic datasets.

The container has no network access, so the paper's LIBSVM datasets
(RCV1/URL/KDD) are stood in for by a generator that reproduces their salient
property for this paper: *high-dimensional, sparse, normalized rows* (the paper
normalizes ||x_i|| <= 1, Assumption 1). Feature frequencies follow a Zipf law
(like bag-of-words data), labels come from a sparse ground-truth predictor plus
controllable noise, so the ERM problem has a meaningful optimum and the duality
gap behaves like it does on RCV1 in the paper's figures.

Also provides the token stream used by the deep-net training substrate.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core.objectives import Problem


@dataclasses.dataclass(frozen=True)
class LinearDatasetSpec:
    num_workers: int = 4
    n_per_worker: int = 512
    d: int = 8192
    nnz_per_row: int = 64  # average sparsity like RCV1 (~0.1%)
    label_noise: float = 0.05
    task: str = "classification"  # or "regression"
    seed: int = 0


def make_linear_problem(spec: LinearDatasetSpec, lam: float = 1e-4,
                        loss: str = "ridge") -> Problem:
    """Build a K-partitioned Problem with ||x_i||_2 <= 1 (Assumption 1)."""
    rng = np.random.default_rng(spec.seed)
    K, n_k, d = spec.num_workers, spec.n_per_worker, spec.d
    n = K * n_k

    # Zipf-distributed feature popularity: low-index features are common.
    popularity = 1.0 / np.arange(1, d + 1) ** 0.8
    popularity /= popularity.sum()

    X = np.zeros((n, d), np.float32)
    for i in range(n):
        nnz = max(4, int(rng.poisson(spec.nnz_per_row)))
        cols = rng.choice(d, size=min(nnz, d), replace=False, p=popularity)
        vals = rng.normal(size=cols.size).astype(np.float32)
        X[i, cols] = vals
    row_norms = np.linalg.norm(X, axis=1, keepdims=True)
    X = X / np.maximum(row_norms, 1e-8)  # ||x_i|| = 1

    # Sparse ground-truth predictor.
    w_star = np.zeros(d, np.float32)
    support = rng.choice(d, size=max(8, d // 64), replace=False)
    w_star[support] = rng.normal(size=support.size).astype(np.float32)
    margin = X @ w_star
    if spec.task == "classification":
        flip = rng.random(n) < spec.label_noise
        y = np.sign(margin + 1e-9).astype(np.float32)
        y[flip] *= -1.0
        y[y == 0] = 1.0
    else:
        y = (margin + spec.label_noise * rng.normal(size=n)).astype(np.float32)

    # Shuffle, then partition evenly across K workers (paper Sec. II-B).
    perm = rng.permutation(n)
    X, y = X[perm], y[perm]
    return Problem(
        X=jnp.asarray(X.reshape(K, n_k, d)),
        y=jnp.asarray(y.reshape(K, n_k)),
        lam=lam,
        loss=loss,  # type: ignore[arg-type]
    )


def make_token_dataset(num_tokens: int, vocab_size: int, seed: int = 0) -> np.ndarray:
    """Zipf-distributed token stream for LM-training substrate tests."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1)
    p = 1.0 / ranks**1.1
    p /= p.sum()
    return rng.choice(vocab_size, size=num_tokens, p=p).astype(np.int32)
