"""Engine microbenchmark: the fused protocol engine vs the reference loops.

Measures, at the ISSUE-1 acceptance point (K=16 workers), per simulated round:

* wall-clock of ``engine.run_method`` vs ``acpd.run_method_reference``
  (identical trajectories -- pinned bit-for-bit by tests/test_engine.py);
* host-issued eager device dispatches, counted by wrapping JAX's
  ``apply_primitive`` (every un-jitted op the host Python loop issues).
  Jit-compiled calls bypass this counter on both sides, so the eager count
  isolates exactly the overhead the engine removes: per-message ``.at[]``
  updates, slicing, and the blocking ``int(nnz(...))`` pulls.

The acceptance bar is >= 3x fewer dispatches or >= 2x wall-clock per round;
both are emitted and recorded to experiments/bench/engine_microbench.json.
"""

from __future__ import annotations

import time

from benchmarks.common import cluster, dump, emit, rcv1_like
from repro.core import baselines, engine
from repro.core.acpd import run_method_reference


def _count_eager_dispatches(fn):
    """Run ``fn`` counting eager device dispatches; returns (result, count).

    Counting degrades gracefully (count = -1) if the JAX internal moves.
    """
    try:
        import jax._src.dispatch as jdispatch

        orig = jdispatch.apply_primitive
    except (ImportError, AttributeError):
        return fn(), -1
    calls = [0]

    def counting(*a, **k):
        calls[0] += 1
        return orig(*a, **k)

    jdispatch.apply_primitive = counting
    try:
        out = fn()
    finally:
        jdispatch.apply_primitive = orig
    return out, calls[0]


def main(quick: bool = False) -> None:
    K = 4 if quick else 16
    d = 1024 if quick else 4096
    outer = 1 if quick else 2
    T = 5 if quick else 10
    prob = rcv1_like(K=K, d=d, n_per_worker=64, seed=7)
    m = baselines.acpd(K, d, B=max(1, K // 2), T=T, rho_d=128, gamma=0.5,
                       H=64)
    cl = cluster(K)
    rounds = outer * T

    results = {}
    for label, fn in (("reference", run_method_reference),
                      ("engine", engine.run_method)):
        # Warm-up at the MEASURED shape (the engine's deferred eval compiles
        # per snapshot count, so a smaller warm-up would leave a compile
        # inside the timed region).
        fn(prob, m, cl, num_outer=outer, eval_every=2, seed=0)
        t0 = time.perf_counter()
        _, dispatches = _count_eager_dispatches(
            lambda: fn(prob, m, cl, num_outer=outer, eval_every=2, seed=0))
        dt = time.perf_counter() - t0
        results[label] = {"wall_s": dt, "eager_dispatches": dispatches,
                          "rounds": rounds}
        emit(f"engine/{label}/us_per_round", dt * 1e6 / rounds, dispatches)

    speedup = results["reference"]["wall_s"] / results["engine"]["wall_s"]
    emit(f"engine/K{K}/wallclock_speedup", 0.0, round(speedup, 2))
    if results["engine"]["eager_dispatches"] > 0:
        ratio = (results["reference"]["eager_dispatches"]
                 / results["engine"]["eager_dispatches"])
        emit(f"engine/K{K}/dispatch_ratio", 0.0, round(ratio, 2))
        results["dispatch_ratio"] = ratio
    results["wallclock_speedup"] = speedup
    results["K"] = K
    dump("engine_microbench", results, seed=0)


if __name__ == "__main__":
    main()
