"""Engine microbenchmark: reference loops vs event engine vs scan executor.

Three comparisons:

1. The PR-1 acceptance point (kept): ``engine.run_method`` vs
   ``acpd.run_method_reference`` at K=16 -- wall clock + eager dispatches
   (identical trajectories, pinned bit-for-bit by tests/test_engine.py).

2. Executor scaling (ISSUE-4): the event executor vs the scan-fused
   whole-run executor for a sync K=16 run, across three regimes --
   ``overhead`` (per-round device work ~0: isolates executor cost, the
   regime where the zoo grids live), ``zoo_cell`` (a straggler-zoo-sized
   cell) and ``compute_bound`` (large local solves: both executors converge
   to the math's cost; recorded so the artifact shows the honest
   asymptote).  Dispatches are counted as compiled-function executions (the
   module-level jitted entry points both executors flow through) plus eager
   applies.  Results go to ``experiments/bench/executor_scaling.json``.

3. The vmapped sweep runner: N seeds of the zoo-cell run as one compiled
   ``api.run_lockstep_sweep`` call vs N sequential event sessions.
"""

from __future__ import annotations

import time

from benchmarks.common import cluster, dump, emit, rcv1_like
from repro.core import baselines, engine
from repro.core.acpd import run_method_reference


def _count_eager_dispatches(fn):
    """Run ``fn`` counting eager device dispatches; returns (result, count).

    Counting degrades gracefully (count = -1) if the JAX internal moves.
    """
    try:
        import jax._src.dispatch as jdispatch

        orig = jdispatch.apply_primitive
    except (ImportError, AttributeError):
        return fn(), -1
    calls = [0]

    def counting(*a, **k):
        calls[0] += 1
        return orig(*a, **k)

    jdispatch.apply_primitive = counting
    try:
        out = fn()
    finally:
        jdispatch.apply_primitive = orig
    return out, calls[0]


# The module-level jitted entry points every executor path flows through;
# wrapping them counts compiled executions (the C++ pjit fast path bypasses
# python-level primitive hooks, so this is the reliable count).
_JIT_SITES = (
    ("repro.core.engine", ("_sync_round_fused", "_cocoa_round_fused",
                           "_worker_rounds_fused", "_worker_rounds_lag_fused",
                           "_server_apply_fused", "_lag_window_append",
                           "_eval_batched")),
    ("repro.core.executor", ("_lockstep_scan", "_lockstep_gap_scan",
                             "_lag_scan")),
    ("repro.api.sweep", ("_sweep_scan", "_sweep_scan_workers",
                         "_lag_sweep_scan")),
)


def _count_device_dispatches(fn):
    """(result, total dispatches): compiled jit-entry executions + eager."""
    import importlib

    counts = [0]
    restore = []
    for mod_name, names in _JIT_SITES:
        mod = importlib.import_module(mod_name)
        for name in names:
            orig = getattr(mod, name)

            def wrap(orig):
                def counting(*a, **k):
                    counts[0] += 1
                    return orig(*a, **k)

                return counting

            setattr(mod, name, wrap(orig))
            restore.append((mod, name, orig))
    try:
        out, eager = _count_eager_dispatches(fn)
    finally:
        for mod, name, orig in restore:
            setattr(mod, name, orig)
    return out, counts[0] + max(eager, 0)


def _legacy_section(quick: bool, results: dict) -> None:
    """Reference loops vs event engine (the PR-1 acceptance numbers)."""
    K = 4 if quick else 16
    d = 1024 if quick else 4096
    outer = 1 if quick else 2
    T = 5 if quick else 10
    prob = rcv1_like(K=K, d=d, n_per_worker=64, seed=7)
    m = baselines.acpd(K, d, B=max(1, K // 2), T=T, rho_d=128, gamma=0.5,
                       H=64)
    cl = cluster(K)
    rounds = outer * T

    for label, fn in (("reference", run_method_reference),
                      ("engine", engine.run_method)):
        # Warm-up at the MEASURED shape (the engine's deferred eval compiles
        # per snapshot bucket, so a smaller warm-up could leave a compile
        # inside the timed region).
        fn(prob, m, cl, num_outer=outer, eval_every=2, seed=0)
        t0 = time.perf_counter()
        _, dispatches = _count_eager_dispatches(
            lambda: fn(prob, m, cl, num_outer=outer, eval_every=2, seed=0))
        dt = time.perf_counter() - t0
        results[label] = {"wall_s": dt, "eager_dispatches": dispatches,
                          "rounds": rounds}
        emit(f"engine/{label}/us_per_round", dt * 1e6 / rounds, dispatches)

    speedup = results["reference"]["wall_s"] / results["engine"]["wall_s"]
    emit(f"engine/K{K}/wallclock_speedup", 0.0, round(speedup, 2))
    if results["engine"]["eager_dispatches"] > 0:
        ratio = (results["reference"]["eager_dispatches"]
                 / results["engine"]["eager_dispatches"])
        emit(f"engine/K{K}/dispatch_ratio", 0.0, round(ratio, 2))
        results["dispatch_ratio"] = ratio
    results["wallclock_speedup"] = speedup
    results["K"] = K


# (d, n_per_worker, H, num_outer) per regime; quick shrinks uniformly.
_EXECUTOR_REGIMES = {
    "overhead": dict(d=256, n_per_worker=16, H=1, outer=2000),
    "zoo_cell": dict(d=512, n_per_worker=32, H=16, outer=400),
    "compute_bound": dict(d=2048, n_per_worker=64, H=64, outer=100),
}


def _regime_spec(regime: str, K: int, cfg: dict, outer: int, H: int):
    """The regime's run as a declarative spec (dump provenance)."""
    from repro import api
    from repro.api.presets import rcv1_spec

    return api.ExperimentSpec(
        name=f"executor-scaling-{regime}-K{K}",
        problem=rcv1_spec(K=K, d=cfg["d"],
                          n_per_worker=cfg["n_per_worker"]),
        cluster=cluster(K),
        methods=(api.MethodEntry(baselines.cocoa_plus(K, H=H), outer),),
        eval_every=max(1, outer // 4), seed=0)


def _executor_section(quick: bool, specs: list) -> dict:
    """Event vs scan executor for sync K=16 runs (ISSUE-4 acceptance)."""
    from repro import api

    K = 4 if quick else 16
    out = {"K": K, "regimes": {}}
    for regime, cfg in _EXECUTOR_REGIMES.items():
        d, npw, H, outer = (cfg["d"], cfg["n_per_worker"], cfg["H"],
                            cfg["outer"])
        if quick:
            outer = max(10, outer // 20)
        specs.append(_regime_spec(regime, K, cfg, outer, H))
        prob = rcv1_like(K=K, d=d, n_per_worker=npw, seed=7)
        m = baselines.cocoa_plus(K, H=H)
        cl = cluster(K)
        row = dict(cfg, outer=outer)
        for exe in ("event", "scan"):
            def run(exe=exe):
                return api.Session(prob, m, cl, num_outer=outer,
                                   eval_every=max(1, outer // 4),
                                   executor=exe).run()

            run()  # warm: compile outside the timed region
            # Wall clock on an UNinstrumented run (the dispatch-count
            # wrappers add per-dispatch overhead that would inflate the
            # O(rounds) event side), then count dispatches separately.
            t0 = time.perf_counter()
            run()
            dt = time.perf_counter() - t0
            _, dispatches = _count_device_dispatches(run)
            row[exe] = {"wall_s": dt, "device_dispatches": dispatches}
            emit(f"executor/{regime}/{exe}/us_per_round",
                 dt * 1e6 / outer, dispatches)
        row["wallclock_speedup"] = (row["event"]["wall_s"]
                                    / row["scan"]["wall_s"])
        row["dispatch_ratio"] = (row["event"]["device_dispatches"]
                                 / max(1, row["scan"]["device_dispatches"]))
        emit(f"executor/{regime}/K{K}/speedup", 0.0,
             round(row["wallclock_speedup"], 2))
        emit(f"executor/{regime}/K{K}/dispatch_ratio", 0.0,
             round(row["dispatch_ratio"], 2))
        out["regimes"][regime] = row
    return out


def _sweep_section(quick: bool) -> dict:
    """N-seed sweep: one vmapped compiled call vs N event sessions."""
    from repro import api

    K = 4 if quick else 16
    seeds = tuple(range(2 if quick else 8))
    cfg = _EXECUTOR_REGIMES["zoo_cell"]
    outer = max(10, cfg["outer"] // 20) if quick else cfg["outer"]
    prob = rcv1_like(K=K, d=cfg["d"], n_per_worker=cfg["n_per_worker"],
                     seed=7)
    m = baselines.cocoa_plus(K, H=cfg["H"])
    cl = cluster(K)
    ev = max(1, outer // 4)

    def sequential():
        return [api.Session(prob, m, cl, num_outer=outer, eval_every=ev,
                            seed=s, executor="event").run() for s in seeds]

    def swept():
        return api.run_lockstep_sweep(prob, m, cl, num_outer=outer,
                                      seeds=seeds, eval_every=ev)

    sequential(), swept()  # warm both paths
    t0 = time.perf_counter()
    sequential()
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    swept()
    t_sweep = time.perf_counter() - t0
    speedup = t_seq / t_sweep
    emit(f"sweep/K{K}/seeds{len(seeds)}/speedup", t_sweep * 1e6,
         round(speedup, 2))
    return {"K": K, "seeds": len(seeds), "outer": outer,
            "sequential_wall_s": t_seq, "vmapped_wall_s": t_sweep,
            "wallclock_speedup": speedup}


def main(quick: bool = False) -> None:
    results: dict = {}
    _legacy_section(quick, results)
    dump("engine_microbench", results, seed=0)

    specs: list = []
    scaling = {"executor": _executor_section(quick, specs),
               "sweep": _sweep_section(quick)}
    dump("executor_scaling", scaling, specs=specs, seed=0)


if __name__ == "__main__":
    main()
