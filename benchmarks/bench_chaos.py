"""Chaos bench: serving goodput and recovery latency under injected faults.

Drives a live :class:`repro.serve.ExperimentService` exactly like
benchmarks/bench_serve.py -- open-loop arrivals, dispatcher thread started --
but under the PINNED composite ``chaos`` fault schedule
(:mod:`repro.core.faults`): the first batch dispatch overruns its execution
deadline (watchdog -> solo-lane requeue), the second faults transiently
(backoff retry), and one coalesced cell is NaN-poisoned (masked per-cell by
the finite certificates).  Every waiter tolerates typed errors, so the bench
measures what a tenant actually experiences while the service self-heals:

* ``goodput_req_per_s``    -- SUCCESSFUL results delivered per wall-second
  (failed-by-design poison cells excluded: they are the fault, not the
  service);
* ``hung_jobs``            -- handles that never reached a terminal state
  within the window (the zero-hung-jobs contract; must be 0);
* the service's self-healing counters (retries, timeouts, requeued_solo,
  masked_cells, ...) for the window.

The second scenario measures **checkpoint recovery latency**: a resumable
run is killed at a segment boundary by ``worker_crash(crash_round=...)``,
then resubmitted to a FRESH service over the same checkpoint directory; the
resumed completion is timed against a from-scratch run and verified
bit-identical.

Output: CSV rows plus ``experiments/bench/chaos.json``; the driver folds the
headline numbers into BENCH_SWEEP.json (quick runs included -- like serving
latency, recovery behavior is policy-dominated, not problem-size-dominated).
"""

from __future__ import annotations

import dataclasses
import pathlib
import shutil
import threading
import time

import numpy as np

from benchmarks.common import OUT_DIR, dump, emit

TENANTS = ("alice", "bob", "carol", "dave")
K = 4


def _spec(seed: int, *, quick: bool, checkpoint_every: int | None = None):
    from repro import api
    from repro.core import baselines
    from repro.core.simulate import ClusterModel

    d, n_per_worker = (512, 64) if quick else (2048, 192)
    num_outer = 4 if quick else 8
    return api.ExperimentSpec(
        name=f"chaos-{seed}",
        problem=api.ProblemSpec("rcv1_like", {"K": K, "seed": 7, "d": d,
                                              "n_per_worker": n_per_worker}),
        cluster=ClusterModel(num_workers=K, straggler_sigma=2.0),
        methods=(api.MethodEntry(baselines.cocoa_plus(K, H=8), num_outer),),
        eval_every=2, seed=seed,
        checkpoint_every=checkpoint_every)


def _drive(service, *, n_requests: int, rate_hz: float, quick: bool,
           rng: np.random.Generator):
    """Open-loop submits with typed-error-tolerant waiters.

    Returns (wall_s, outcomes, hung): ``outcomes`` is one
    ``(ok, error_type, latency_s)`` per completed wait; ``hung`` counts
    waiters that never saw a terminal state (the contract says 0).
    """
    from repro.serve import BackpressureError

    outcomes: list[tuple[bool, str | None, float]] = []
    lock = threading.Lock()
    waiters: list[threading.Thread] = []
    rejected = 0
    t_start = time.perf_counter()
    due = 0.0
    for i in range(n_requests):
        due += rng.exponential(1.0 / rate_hz)
        lead = due - (time.perf_counter() - t_start)
        if lead > 0:
            time.sleep(lead)
        spec = _spec(int(rng.integers(16)), quick=quick)
        t0 = time.perf_counter()
        try:
            handle = service.submit(TENANTS[i % len(TENANTS)], spec)
        except BackpressureError:
            rejected += 1
            continue

        def _wait(h=handle, t0=t0):
            try:
                h.result(timeout=600)
                row = (True, None, time.perf_counter() - t0)
            except TimeoutError:
                return  # leaves the thread countable as hung below
            except Exception as e:  # noqa: BLE001 - typed failures ARE data here
                row = (False, type(e).__name__, time.perf_counter() - t0)
            with lock:
                outcomes.append(row)

        th = threading.Thread(target=_wait, daemon=True)
        th.start()
        waiters.append(th)
    for th in waiters:
        th.join(timeout=600)
    hung = sum(th.is_alive() for th in waiters) + rejected * 0
    return time.perf_counter() - t_start, outcomes, hung, rejected


def _chaos_window(quick: bool) -> dict:
    """Scenario 1: open-loop load under the pinned ``chaos`` schedule."""
    from repro.core import faults
    from repro.serve import CoalescePolicy, ExperimentService, RecoveryPolicy

    policy = CoalescePolicy(max_batch=8, max_wait_s=0.05,
                            max_tenant_depth=64, batch="map")

    # Warmup on a fault-free service: populates the process-wide jit cache
    # and calibrates the batch deadline against a genuinely WARM dispatch,
    # so the chaos overrun is the injected sleep, never a cold compile.
    warm_svc = ExperimentService(policy)
    h = warm_svc.submit("warmup", _spec(0, quick=quick))
    warm_svc.submit("warmup", _spec(1, quick=quick))
    t0 = time.perf_counter()
    warm_svc.drain()
    warm_wall = time.perf_counter() - t0
    h.result(timeout=600)
    deadline = max(1.0, 4.0 * warm_wall)

    fault = faults.get_fault("chaos")(seed=0, delay_s=2.0 * deadline,
                                      poison=1)
    service = ExperimentService(
        policy,
        recovery=RecoveryPolicy(max_attempts=3, backoff_base_s=0.02,
                                batch_deadline_s=deadline),
        fault=fault)
    service.start()
    try:
        n_requests = 10 if quick else 32
        rate_hz = 20.0 if quick else 40.0
        wall_s, outcomes, hung, rejected = _drive(
            service, n_requests=n_requests, rate_hz=rate_hz, quick=quick,
            rng=np.random.default_rng(0))
        stats = service.stats()
    finally:
        service.stop()

    ok = [o for o in outcomes if o[0]]
    failed = [o for o in outcomes if not o[0]]
    by_error: dict[str, int] = {}
    for _, etype, _ in failed:
        by_error[etype] = by_error.get(etype, 0) + 1
    lats = sorted(lat for _, _, lat in ok)
    return {
        "n_requests": n_requests,
        "offered_rate_hz": rate_hz,
        "rejected_backpressure": rejected,
        "window_wall_s": wall_s,
        "succeeded": len(ok),
        "failed": len(failed),
        "failed_by_error": by_error,
        "hung_jobs": hung,  # the zero-hung-jobs contract
        "goodput_req_per_s": len(ok) / wall_s if wall_s else 0.0,
        "latency_p50_s": float(np.percentile(lats, 50)) if lats else None,
        "latency_p99_s": float(np.percentile(lats, 99)) if lats else None,
        "batch_deadline_s": deadline,
        "fault": fault.spec(),
        "counters": {k: stats[k] for k in (
            "retries", "bisects", "quarantined", "timeouts", "requeued_solo",
            "masked_cells", "breaker_rejected", "batches",
            "batched_requests", "solo_requests")},
        "policy": dataclasses.asdict(service.policy),
    }


def _recovery_scenario(quick: bool) -> dict:
    """Scenario 2: kill a checkpointed run mid-flight, resume on a fresh
    service, time the resumed completion against a from-scratch run."""
    from repro import api
    from repro.core import executor, faults
    from repro.serve import ExperimentService

    ckpt_dir = OUT_DIR / "chaos_ckpt"
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    num_outer = 6 if quick else 12
    every = 2 if quick else 3
    crash_round = num_outer - every  # killed at the LAST segment boundary
    spec = dataclasses.replace(_spec(3, quick=quick), name="chaos-resume",
                               checkpoint_every=every)

    # run 1: killed by the injected crash after its last pre-crash snapshot
    svc1 = ExperimentService(
        checkpoint_dir=str(ckpt_dir),
        fault=faults.get_fault("worker_crash")(crashes=0,
                                               crash_round=crash_round))
    h1 = svc1.submit("alice", spec)
    t0 = time.perf_counter()
    svc1.drain()
    kill_wall = time.perf_counter() - t0
    killed_as = None
    try:
        h1.result(timeout=1.0)
    except Exception as e:  # noqa: BLE001 - the injected kill IS the scenario
        killed_as = type(e).__name__

    # run 2: fresh service, same checkpoint dir -> resume + finish
    segs_before = executor.STATS["lockstep_segment_calls"]
    svc2 = ExperimentService(checkpoint_dir=str(ckpt_dir))
    h2 = svc2.submit("alice", spec)
    t0 = time.perf_counter()
    svc2.drain()
    resume_wall = time.perf_counter() - t0
    resumed = h2.result(timeout=600)
    segments_resumed = executor.STATS["lockstep_segment_calls"] - segs_before

    # baseline: the same run from scratch, no checkpointing, warm caches
    plain = dataclasses.replace(spec, checkpoint_every=None)
    entry = plain.methods[0]
    t0 = time.perf_counter()
    sess = api.Session(plain.problem.build(), entry.config, plain.cluster,
                       num_outer=entry.num_outer, seed=plain.seed,
                       eval_every=plain.eval_every, executor="scan")
    fresh = sess.run()
    fresh_wall = time.perf_counter() - t0

    checkpoints = sorted(p.name for p in ckpt_dir.rglob("ckpt_*.npz"))
    bit_identical = bool(
        np.array_equal(np.asarray(resumed.w), np.asarray(fresh.w))
        and [r.gap for r in resumed.records]
        == [r.gap for r in fresh.records])
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    return {
        "num_outer": num_outer,
        "checkpoint_every": every,
        "crash_round": crash_round,
        "killed_as": killed_as,
        "kill_wall_s": kill_wall,
        "resume_wall_s": resume_wall,
        "fresh_wall_s": fresh_wall,
        "recovery_speedup_vs_fresh": (fresh_wall / resume_wall
                                      if resume_wall else 0.0),
        "segments_resumed": segments_resumed,
        "checkpoints_written": checkpoints,
        "resume_bit_identical": bit_identical,
    }


def main(quick: bool = False) -> None:
    window = _chaos_window(quick)
    recovery = _recovery_scenario(quick)
    data = {"window": window, "recovery": recovery}

    emit("chaos/goodput",
         window["window_wall_s"] * 1e6 / max(window["succeeded"], 1),
         f"{window['goodput_req_per_s']:.1f}req/s "
         f"hung={window['hung_jobs']} masked="
         f"{window['counters']['masked_cells']}")
    emit("chaos/healing", 0.0,
         f"retries={window['counters']['retries']} "
         f"timeouts={window['counters']['timeouts']} "
         f"requeued={window['counters']['requeued_solo']}")
    emit("chaos/recovery", recovery["resume_wall_s"] * 1e6,
         f"x{recovery['recovery_speedup_vs_fresh']:.2f}_vs_fresh "
         f"bit_identical={recovery['resume_bit_identical']}")
    dump("chaos", data, seed=0)


if __name__ == "__main__":
    main()
