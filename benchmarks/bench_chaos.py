"""Chaos bench: serving goodput and recovery latency under injected faults.

Drives a live :class:`repro.serve.ExperimentService` exactly like
benchmarks/bench_serve.py -- open-loop arrivals, dispatcher thread started --
but under the PINNED composite ``chaos`` fault schedule
(:mod:`repro.core.faults`): the first batch dispatch overruns its execution
deadline (watchdog -> solo-lane requeue), the second faults transiently
(backoff retry), and one coalesced cell is NaN-poisoned (masked per-cell by
the finite certificates).  Every waiter tolerates typed errors, so the bench
measures what a tenant actually experiences while the service self-heals:

* ``goodput_req_per_s``    -- SUCCESSFUL results delivered per wall-second
  (failed-by-design poison cells excluded: they are the fault, not the
  service);
* ``hung_jobs``            -- handles that never reached a terminal state
  within the window (the zero-hung-jobs contract; must be 0);
* the service's self-healing counters (retries, timeouts, requeued_solo,
  masked_cells, ...) for the window.

The second scenario measures **checkpoint recovery latency**: a resumable
run is killed at a segment boundary by ``worker_crash(crash_round=...)``,
then resubmitted to a FRESH service over the same checkpoint directory; the
resumed completion is timed against a from-scratch run and verified
bit-identical.

The third scenario drives the **replicated serve cluster**
(:mod:`repro.serve.cluster`) under the seeded ``cluster_chaos`` composite
(one replica killed mid-checkpoint-segment + message drops): goodput across
the surviving replicas, zero hung jobs, and the takeover recovery latency
in deterministic scheduler ticks from the kill to the stolen job's result
becoming visible.

Output: CSV rows plus ``experiments/bench/chaos.json``; the driver folds the
headline numbers into BENCH_SWEEP.json (quick runs included -- like serving
latency, recovery behavior is policy-dominated, not problem-size-dominated).
"""

from __future__ import annotations

import dataclasses
import pathlib
import shutil
import threading
import time

import numpy as np

from benchmarks.common import OUT_DIR, dump, emit

TENANTS = ("alice", "bob", "carol", "dave")
K = 4


def _spec(seed: int, *, quick: bool, checkpoint_every: int | None = None):
    from repro import api
    from repro.core import baselines
    from repro.core.simulate import ClusterModel

    d, n_per_worker = (512, 64) if quick else (2048, 192)
    num_outer = 4 if quick else 8
    return api.ExperimentSpec(
        name=f"chaos-{seed}",
        problem=api.ProblemSpec("rcv1_like", {"K": K, "seed": 7, "d": d,
                                              "n_per_worker": n_per_worker}),
        cluster=ClusterModel(num_workers=K, straggler_sigma=2.0),
        methods=(api.MethodEntry(baselines.cocoa_plus(K, H=8), num_outer),),
        eval_every=2, seed=seed,
        checkpoint_every=checkpoint_every)


def _drive(service, *, n_requests: int, rate_hz: float, quick: bool,
           rng: np.random.Generator):
    """Open-loop submits with typed-error-tolerant waiters.

    Returns (wall_s, outcomes, hung): ``outcomes`` is one
    ``(ok, error_type, latency_s)`` per completed wait; ``hung`` counts
    waiters that never saw a terminal state (the contract says 0).
    """
    from repro.serve import BackpressureError

    outcomes: list[tuple[bool, str | None, float]] = []
    lock = threading.Lock()
    waiters: list[threading.Thread] = []
    rejected = 0
    t_start = time.perf_counter()
    due = 0.0
    for i in range(n_requests):
        due += rng.exponential(1.0 / rate_hz)
        lead = due - (time.perf_counter() - t_start)
        if lead > 0:
            time.sleep(lead)
        spec = _spec(int(rng.integers(16)), quick=quick)
        t0 = time.perf_counter()
        try:
            handle = service.submit(TENANTS[i % len(TENANTS)], spec)
        except BackpressureError:
            rejected += 1
            continue

        def _wait(h=handle, t0=t0):
            try:
                h.result(timeout=600)
                row = (True, None, time.perf_counter() - t0)
            except TimeoutError:
                return  # leaves the thread countable as hung below
            except Exception as e:  # noqa: BLE001 - typed failures ARE data here
                row = (False, type(e).__name__, time.perf_counter() - t0)
            with lock:
                outcomes.append(row)

        th = threading.Thread(target=_wait, daemon=True)
        th.start()
        waiters.append(th)
    for th in waiters:
        th.join(timeout=600)
    hung = sum(th.is_alive() for th in waiters) + rejected * 0
    return time.perf_counter() - t_start, outcomes, hung, rejected


def _chaos_window(quick: bool) -> dict:
    """Scenario 1: open-loop load under the pinned ``chaos`` schedule."""
    from repro.core import faults
    from repro.serve import CoalescePolicy, ExperimentService, RecoveryPolicy

    policy = CoalescePolicy(max_batch=8, max_wait_s=0.05,
                            max_tenant_depth=64, batch="map")

    # Warmup on a fault-free service: populates the process-wide jit cache
    # and calibrates the batch deadline against a genuinely WARM dispatch,
    # so the chaos overrun is the injected sleep, never a cold compile.
    warm_svc = ExperimentService(policy)
    h = warm_svc.submit("warmup", _spec(0, quick=quick))
    warm_svc.submit("warmup", _spec(1, quick=quick))
    t0 = time.perf_counter()
    warm_svc.drain()
    warm_wall = time.perf_counter() - t0
    h.result(timeout=600)
    deadline = max(1.0, 4.0 * warm_wall)

    fault = faults.get_fault("chaos")(seed=0, delay_s=2.0 * deadline,
                                      poison=1)
    service = ExperimentService(
        policy,
        recovery=RecoveryPolicy(max_attempts=3, backoff_base_s=0.02,
                                batch_deadline_s=deadline),
        fault=fault)
    service.start()
    try:
        n_requests = 10 if quick else 32
        rate_hz = 20.0 if quick else 40.0
        wall_s, outcomes, hung, rejected = _drive(
            service, n_requests=n_requests, rate_hz=rate_hz, quick=quick,
            rng=np.random.default_rng(0))
        stats = service.stats()
    finally:
        service.stop()

    ok = [o for o in outcomes if o[0]]
    failed = [o for o in outcomes if not o[0]]
    by_error: dict[str, int] = {}
    for _, etype, _ in failed:
        by_error[etype] = by_error.get(etype, 0) + 1
    lats = sorted(lat for _, _, lat in ok)
    return {
        "n_requests": n_requests,
        "offered_rate_hz": rate_hz,
        "rejected_backpressure": rejected,
        "window_wall_s": wall_s,
        "succeeded": len(ok),
        "failed": len(failed),
        "failed_by_error": by_error,
        "hung_jobs": hung,  # the zero-hung-jobs contract
        "goodput_req_per_s": len(ok) / wall_s if wall_s else 0.0,
        "latency_p50_s": float(np.percentile(lats, 50)) if lats else None,
        "latency_p99_s": float(np.percentile(lats, 99)) if lats else None,
        "batch_deadline_s": deadline,
        "fault": fault.spec(),
        "counters": {k: stats[k] for k in (
            "retries", "bisects", "quarantined", "timeouts", "requeued_solo",
            "masked_cells", "breaker_rejected", "batches",
            "batched_requests", "solo_requests")},
        "policy": dataclasses.asdict(service.policy),
    }


def _recovery_scenario(quick: bool) -> dict:
    """Scenario 2: kill a checkpointed run mid-flight, resume on a fresh
    service, time the resumed completion against a from-scratch run."""
    from repro import api
    from repro.core import executor, faults
    from repro.serve import ExperimentService

    ckpt_dir = OUT_DIR / "chaos_ckpt"
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    num_outer = 6 if quick else 12
    every = 2 if quick else 3
    crash_round = num_outer - every  # killed at the LAST segment boundary
    spec = dataclasses.replace(_spec(3, quick=quick), name="chaos-resume",
                               checkpoint_every=every)

    # run 1: killed by the injected crash after its last pre-crash snapshot
    svc1 = ExperimentService(
        checkpoint_dir=str(ckpt_dir),
        fault=faults.get_fault("worker_crash")(crashes=0,
                                               crash_round=crash_round))
    h1 = svc1.submit("alice", spec)
    t0 = time.perf_counter()
    svc1.drain()
    kill_wall = time.perf_counter() - t0
    killed_as = None
    try:
        h1.result(timeout=1.0)
    except Exception as e:  # noqa: BLE001 - the injected kill IS the scenario
        killed_as = type(e).__name__

    # run 2: fresh service, same checkpoint dir -> resume + finish
    segs_before = executor.STATS["lockstep_segment_calls"]
    svc2 = ExperimentService(checkpoint_dir=str(ckpt_dir))
    h2 = svc2.submit("alice", spec)
    t0 = time.perf_counter()
    svc2.drain()
    resume_wall = time.perf_counter() - t0
    resumed = h2.result(timeout=600)
    segments_resumed = executor.STATS["lockstep_segment_calls"] - segs_before

    # baseline: the same run from scratch, no checkpointing, warm caches
    plain = dataclasses.replace(spec, checkpoint_every=None)
    entry = plain.methods[0]
    t0 = time.perf_counter()
    sess = api.Session(plain.problem.build(), entry.config, plain.cluster,
                       num_outer=entry.num_outer, seed=plain.seed,
                       eval_every=plain.eval_every, executor="scan")
    fresh = sess.run()
    fresh_wall = time.perf_counter() - t0

    checkpoints = sorted(p.name for p in ckpt_dir.rglob("ckpt_*.npz"))
    bit_identical = bool(
        np.array_equal(np.asarray(resumed.w), np.asarray(fresh.w))
        and [r.gap for r in resumed.records]
        == [r.gap for r in fresh.records])
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    return {
        "num_outer": num_outer,
        "checkpoint_every": every,
        "crash_round": crash_round,
        "killed_as": killed_as,
        "kill_wall_s": kill_wall,
        "resume_wall_s": resume_wall,
        "fresh_wall_s": fresh_wall,
        "recovery_speedup_vs_fresh": (fresh_wall / resume_wall
                                      if resume_wall else 0.0),
        "segments_resumed": segments_resumed,
        "checkpoints_written": checkpoints,
        "resume_bit_identical": bit_identical,
    }


def _cluster_scenario(quick: bool) -> dict:
    """Scenario 3: the replicated cluster under ``cluster_chaos`` -- one
    replica dies mid-segment for real, peers take over from its checkpoint,
    messages drop along the way.  The whole schedule is deterministic (one
    shared ManualClock, fixed round-robin), so the reported counters replay
    exactly for one (seed, fault model, submission order) triple."""
    from repro.core import faults
    from repro.serve import (ClusterClient, ClusterReplica, CoalescePolicy,
                             ManualClock)

    cluster_dir = OUT_DIR / "chaos_cluster"
    shutil.rmtree(cluster_dir, ignore_errors=True)
    clock = ManualClock()
    chaos = faults.get_fault("cluster_chaos")(
        seed=11, kill_replica="r0", at_segment=2, drop_rate=0.15)
    policy = CoalescePolicy(batch="map", shard="none", max_wait_s=0.0)
    replicas = [ClusterReplica(cluster_dir, rid, clock=clock,
                               fault=(chaos if rid == "r0" else None),
                               lease_ttl_s=2.5,
                               service_kwargs=dict(policy=policy))
                for rid in ("r0", "r1", "r2")]
    client = ClusterClient(cluster_dir, clock=clock)

    n_jobs = 3 if quick else 6
    t0 = time.perf_counter()
    keys = [client.submit("bench", dataclasses.replace(
                _spec(i, quick=quick, checkpoint_every=2),
                name=f"cluster-{i}"))
            for i in range(n_jobs)]

    # run_cluster's schedule, instrumented: record the kill tick and the
    # tick each job's result record became visible.
    dead: dict[str, str] = {}
    done_at: dict[str, int] = {}
    death_tick = None
    ticks = 0
    for _ in range(200):
        if not client.unfinished():
            break
        ticks += 1
        clock.advance(1.0)  # ages heartbeats: lease_ttl_s=2.5 -> 3-tick FD
        client.pump()
        for replica in replicas:
            if replica.replica_id in dead:
                continue
            try:
                replica.step()
            except faults.ReplicaKilled as e:
                dead[replica.replica_id] = str(e)
                death_tick = ticks
        for key in keys:
            if key not in done_at and client.transport.has_result(key):
                done_at[key] = ticks
    wall = time.perf_counter() - t0

    # The taken-over job is the one whose result record carries epoch > 0.
    takeover_ticks = None
    for key in keys:
        record = client.transport.read_result(key)
        if record is not None and record.get("epoch", 0) > 0:
            takeover_ticks = done_at[key] - (death_tick or 0)
    completed = sum(r.counters["completed"] for r in replicas)
    hung = len(client.unfinished())  # BEFORE the teardown removes results
    shutil.rmtree(cluster_dir, ignore_errors=True)
    return {
        "n_jobs": n_jobs,
        "n_replicas": len(replicas),
        "fault": chaos.spec(),
        "lease_ttl_s": 2.5,
        "ticks": ticks,
        "wall_s": wall,
        "goodput_jobs_per_s": len(done_at) / wall if wall else 0.0,
        "hung_jobs": hung,  # the contract: 0
        "dead_replicas": dict(dead),
        "kill_tick": death_tick,
        "takeovers": sum(r.counters["takeovers"] for r in replicas),
        "takeover_recovery_ticks": takeover_ticks,
        "completed": completed,
        "fenced_results": sum(r.counters["fenced_results"]
                              for r in replicas),
        "dropped_messages": (client.transport.counters["dropped"]
                             + sum(r.transport.counters["dropped"]
                                   for r in replicas)),
        "deduped_results": sum(r.transport.counters["deduped_results"]
                               for r in replicas),
        "client": dict(client.counters),
    }


def main(quick: bool = False) -> None:
    window = _chaos_window(quick)
    recovery = _recovery_scenario(quick)
    cluster = _cluster_scenario(quick)
    data = {"window": window, "recovery": recovery, "cluster": cluster}

    emit("chaos/goodput",
         window["window_wall_s"] * 1e6 / max(window["succeeded"], 1),
         f"{window['goodput_req_per_s']:.1f}req/s "
         f"hung={window['hung_jobs']} masked="
         f"{window['counters']['masked_cells']}")
    emit("chaos/healing", 0.0,
         f"retries={window['counters']['retries']} "
         f"timeouts={window['counters']['timeouts']} "
         f"requeued={window['counters']['requeued_solo']}")
    emit("chaos/recovery", recovery["resume_wall_s"] * 1e6,
         f"x{recovery['recovery_speedup_vs_fresh']:.2f}_vs_fresh "
         f"bit_identical={recovery['resume_bit_identical']}")
    emit("chaos/cluster", cluster["wall_s"] * 1e6 / max(cluster["n_jobs"], 1),
         f"{cluster['goodput_jobs_per_s']:.1f}jobs/s "
         f"hung={cluster['hung_jobs']} takeovers={cluster['takeovers']} "
         f"recovery={cluster['takeover_recovery_ticks']}ticks")
    dump("chaos", data, seed=0)


if __name__ == "__main__":
    main()
