"""Fig. 4b: scaling the number of workers K -- simulated time to a fixed gap
for ACPD (B=K/2) vs CoCoA+ (plus the engine's async/lag registry protocols),
K in {2, 4, 8}."""

from __future__ import annotations

from benchmarks.common import cluster, dump, emit, timed, rcv1_like
from repro.core import baselines
from repro.core.acpd import run_method

TARGET = 1e-3


def main(quick: bool = False) -> None:
    # Higher d than the other benches: Fig. 4b's regime is communication-bound
    # (the paper's point is that CoCoA+ stops scaling once O(d) messages
    # dominate); at small d the simulated network is too cheap to matter.
    d = 1024 if quick else 8192
    H = 64 if quick else 256
    Ks = (2, 4) if quick else (2, 4, 8)
    results = {}
    for K in Ks:
        prob = rcv1_like(K=K, d=d, n_per_worker=64 if quick else 128,
                         seed=7 + K)
        cl = cluster(K, sigma=1.0)
        # All four registry protocols at this scale: group vs sync is the
        # paper's Fig. 4b; async/lag chart the engine's new design space.
        methods = [
            (baselines.acpd(K, d, B=max(1, K // 2), T=10, rho_d=128,
                            gamma=0.5, H=H), 2 if quick else 8),
            (baselines.cocoa_plus(K, H=H), 10 if quick else 60),
            (baselines.acpd_async(K, d, T=10, rho_d=128, gamma=0.5, H=H),
             4 if quick else 16),
            (baselines.acpd_lag(K, d, B=max(1, K // 2), T=10, rho_d=128,
                                gamma=0.5, H=H), 2 if quick else 8),
        ]
        row = {}
        for m, outer in methods:
            res, us = timed(run_method, prob, m, cl, num_outer=outer,
                            eval_every=2, seed=0)
            t = res.time_to_gap(TARGET)
            emit(f"fig4b/K{K}/{m.name}_time", us,
                 None if t is None else round(t, 4))
            row[m.name] = t
        t_a, t_c = row["ACPD"], row["CoCoA+"]
        if t_a and t_c:
            emit(f"fig4b/K{K}/speedup", 0.0, round(t_c / t_a, 2))
        results[K] = row
    dump("fig4b_scaling", results)


if __name__ == "__main__":
    main()
