"""Fig. 4b: scaling the number of workers K -- simulated time to a fixed gap
for ACPD (B=K/2) vs CoCoA+, K in {2, 4, 8}."""

from __future__ import annotations

from benchmarks.common import cluster, dump, emit, timed, rcv1_like
from repro.core import baselines
from repro.core.acpd import run_method

TARGET = 1e-3


def main() -> None:
    # Higher d than the other benches: Fig. 4b's regime is communication-bound
    # (the paper's point is that CoCoA+ stops scaling once O(d) messages
    # dominate); at small d the simulated network is too cheap to matter.
    d = 8192
    results = {}
    for K in (2, 4, 8):
        prob = rcv1_like(K=K, d=d, n_per_worker=128, seed=7 + K)
        cl = cluster(K, sigma=1.0)
        acpd = baselines.acpd(K, d, B=max(1, K // 2), T=10, rho_d=128,
                              gamma=0.5, H=256)
        coco = baselines.cocoa_plus(K, H=256)
        res_a, us_a = timed(run_method, prob, acpd, cl, num_outer=8,
                            eval_every=2, seed=0)
        res_c, us_c = timed(run_method, prob, coco, cl, num_outer=60,
                            eval_every=2, seed=0)
        t_a, t_c = res_a.time_to_gap(TARGET), res_c.time_to_gap(TARGET)
        emit(f"fig4b/K{K}/acpd_time", us_a, None if t_a is None else round(t_a, 4))
        emit(f"fig4b/K{K}/cocoa+_time", us_c, None if t_c is None else round(t_c, 4))
        if t_a and t_c:
            emit(f"fig4b/K{K}/speedup", 0.0, round(t_c / t_a, 2))
        results[K] = {"acpd": t_a, "cocoa+": t_c}
    dump("fig4b_scaling", results)


if __name__ == "__main__":
    main()
