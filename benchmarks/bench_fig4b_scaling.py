"""Fig. 4b: scaling the number of workers K -- simulated time to a fixed gap
for ACPD (B=K/2) vs CoCoA+ (plus the engine's async/lag registry protocols),
K in {2, 4, 8}.

Spec-driven: one ``repro.api.presets.fig4b`` ExperimentSpec per K (also
exposed as the CLI presets ``fig4b-K2`` / ``fig4b-K4`` / ``fig4b-K8``)."""

from __future__ import annotations

from benchmarks.common import dump, emit, timed
from repro.api import Experiment, presets

TARGET = 1e-3


def main(quick: bool = False) -> None:
    # Higher d than the other benches: Fig. 4b's regime is communication-bound
    # (the paper's point is that CoCoA+ stops scaling once O(d) messages
    # dominate); at small d the simulated network is too cheap to matter.
    Ks = (2, 4) if quick else (2, 4, 8)
    results = {}
    specs = []
    for K in Ks:
        spec = presets.fig4b(K, quick=quick)
        specs.append(spec)
        exp = Experiment(spec)
        row = {}
        for entry in spec.methods:
            res, us = timed(exp.run_entry, entry)
            t = res.time_to_gap(TARGET)
            emit(f"fig4b/K{K}/{entry.config.name}_time", us,
                 None if t is None else round(t, 4))
            row[entry.config.name] = t
        t_a, t_c = row["ACPD"], row["CoCoA+"]
        if t_a and t_c:
            emit(f"fig4b/K{K}/speedup", 0.0, round(t_c / t_a, 2))
        results[K] = row
    dump("fig4b_scaling", results, specs=specs)


if __name__ == "__main__":
    main()
