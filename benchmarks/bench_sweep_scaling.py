"""Sweep scaling: the sharded one-compiled-call grid vs its alternatives.

For each regime (overhead-bound tiny cells, a zoo-sized cell, compute-bound
large cells) this measures the same seed x gamma lockstep grid four ways --
ONE sharded ``api.run_sweep`` call (``shard="auto"``: the cell axis over the
local device mesh), the unsharded vmap call, and per-cell ``Session`` runs
on both executors -- plus a lag x delay x seed grid (the delay axis batched
as traced operands).  Wall clock and device-dispatch counts per regime go to
``experiments/bench/sweep_scaling.json``; ``benchmarks/run.py`` folds the
headline numbers into the top-level ``BENCH_SWEEP.json`` trajectory so perf
regressions are visible across PRs.

Honest-asymptote convention (PR 4): every number is reported against the
hardware actually present.  ``n_devices`` counts XLA devices (CI fakes 4 via
``--xla_force_host_platform_device_count=4``; ``make bench-sweep-quick``
does the same) and ``n_cores`` the physical cores backing them -- on a
2-core host the unsharded vmap baseline already runs at ~1.5 cores of
intra-op parallelism, so cell-sharding can only recover the idle remainder
(~1.5x on compute-bound cells, <1x in the overhead regime, where the
one-compiled-call batching itself -- 3-16x over per-cell sessions -- is the
win that matters).  On hardware with >= 4 real cores the mesh speedup in the
overhead-bound regime is expected to clear 2x; the JSON records whichever
asymptote this machine honestly reaches.

The dump also re-checks (and records) that the sharded grid is
bit-identical to the unsharded one under ``batch="map"`` -- the acceptance
contract tests/test_sweep.py pins in its 4-device subprocess.
"""

from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from benchmarks.common import cluster, dump, emit, run_cell
from repro.core import baselines


# (d, n_per_worker, H, outer, n_seeds, n_gammas) per regime; quick shrinks.
_REGIMES = {
    "overhead": dict(d=256, n_per_worker=16, H=4, outer=200, n_seeds=8,
                     n_gammas=2),
    "zoo_cell": dict(d=512, n_per_worker=32, H=16, outer=100, n_seeds=8,
                     n_gammas=2),
    "compute_bound": dict(d=2048, n_per_worker=64, H=64, outer=20,
                          n_seeds=16, n_gammas=1),
}

# The pre-sampleable zoo delays, derived from the preset registry (not
# hand-copied literals) so the measured grid tracks the zoo's parameters.
# Unlike bench_straggler_zoo's sweep section this grid keeps
# bandwidth_coupled: it defines its own uniform cluster rather than
# cross-checking against per-cell zoo rows.
def _lag_delays():
    from repro.api.presets import ZOO_DELAYS

    return tuple((name, dict(params))
                 for name, params in sorted(ZOO_DELAYS.items())
                 if name != "markov")


def _timed_best(fn, reps: int = 2) -> float:
    fn()  # warm: compile outside the timed region
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _dispatches(fn) -> int:
    from benchmarks.bench_engine import _count_device_dispatches

    _, n = _count_device_dispatches(fn)
    return n


def _identical(a, b) -> bool:
    return all(
        (np.asarray(va.result.w) == np.asarray(vb.result.w)).all()
        and [r.gap for r in va.result.records]
        == [r.gap for r in vb.result.records]
        for va, vb in zip(a, b))


def _regime_row(api, prob, method, cl, *, outer, seeds, gammas, label):
    ev = max(1, outer // 4)
    kw = dict(num_outer=outer, seeds=seeds, gammas=gammas, eval_every=ev)

    def sweep(shard, batch="vmap"):
        return api.run_sweep(prob, method, cl, batch=batch, shard=shard, **kw)

    def percell(exe):
        out = []
        for s in seeds:
            for g in (gammas or (method.gamma,)):
                m = dataclasses.replace(method, gamma=g)
                out.append(api.Session(prob, m, cl, num_outer=outer,
                                       eval_every=ev, seed=s,
                                       executor=exe).run())
        return out

    row = {"cells": len(seeds) * len(gammas or (0,)), "outer": outer,
           "shard_plan": dataclasses.asdict(api.resolve_shard(
               "auto", protocol=method.protocol,
               num_workers=prob.num_workers))}
    row["sweep_sharded_wall_s"] = _timed_best(lambda: sweep("auto"))
    row["sweep_vmap_wall_s"] = _timed_best(lambda: sweep("none"))
    row["percell_scan_wall_s"] = _timed_best(lambda: percell("scan"), reps=1)
    row["percell_event_wall_s"] = _timed_best(lambda: percell("event"),
                                              reps=1)
    row["sweep_dispatches"] = _dispatches(lambda: sweep("auto"))
    row["percell_scan_dispatches"] = _dispatches(lambda: percell("scan"))
    row["mesh_speedup_vs_vmap"] = (row["sweep_vmap_wall_s"]
                                   / row["sweep_sharded_wall_s"])
    row["speedup_vs_percell_scan"] = (row["percell_scan_wall_s"]
                                      / row["sweep_sharded_wall_s"])
    row["speedup_vs_percell_event"] = (row["percell_event_wall_s"]
                                       / row["sweep_sharded_wall_s"])
    # The acceptance contract, re-checked where it is cheap: map-mode cells
    # sharding must not move a single bit.
    row["sharded_bit_identical"] = _identical(sweep("none", "map"),
                                              sweep("auto", "map"))
    emit(f"sweep_scaling/{label}/mesh_vs_vmap",
         row["sweep_sharded_wall_s"] * 1e6,
         round(row["mesh_speedup_vs_vmap"], 2))
    emit(f"sweep_scaling/{label}/vs_percell_event", 0.0,
         round(row["speedup_vs_percell_event"], 2))
    return row


def _lag_grid_row(api, quick: bool):
    """One lag x delay x seed grid: the whole delay axis in one call."""
    lag_delays = _lag_delays()
    K, d = 4, 512 if not quick else 256
    outer = 4 if quick else 8
    seeds = tuple(range(2 if quick else 6))
    prob = api.ProblemSpec(
        "rcv1_like", {"K": K, "d": d, "n_per_worker": 32}).build()
    m = baselines.acpd_lag(K, d, B=2, T=10, rho_d=64, gamma=0.5,
                           H=8 if quick else 16)
    cl = cluster(K, sigma=5.0)
    ev = 5
    kw = dict(num_outer=outer, seeds=seeds, delays=lag_delays, eval_every=ev)

    def sweep(shard, batch="vmap"):
        return api.run_sweep(prob, m, cl, batch=batch, shard=shard, **kw)

    def percell():
        out = []
        for name, params in lag_delays:
            cl_v = dataclasses.replace(
                cl, delay_model=name, delay_params=tuple(params.items()))
            for s in seeds:
                out.append(api.Session(prob, m, cl_v, num_outer=outer,
                                       eval_every=ev, seed=s,
                                       executor="scan").run())
        return out

    row = {"cells": len(lag_delays) * len(seeds), "outer": outer,
           "delays": [n for n, _ in lag_delays]}
    row["sweep_sharded_wall_s"] = _timed_best(lambda: sweep("auto"))
    row["sweep_vmap_wall_s"] = _timed_best(lambda: sweep("none"))
    row["percell_scan_wall_s"] = _timed_best(percell, reps=1)
    row["mesh_speedup_vs_vmap"] = (row["sweep_vmap_wall_s"]
                                   / row["sweep_sharded_wall_s"])
    row["speedup_vs_percell_scan"] = (row["percell_scan_wall_s"]
                                      / row["sweep_sharded_wall_s"])
    row["sharded_bit_identical"] = _identical(sweep("none", "map"),
                                              sweep("auto", "map"))
    emit("sweep_scaling/lag_grid/vs_percell_scan",
         row["sweep_sharded_wall_s"] * 1e6,
         round(row["speedup_vs_percell_scan"], 2))
    return row


def main(quick: bool = False) -> None:
    import jax

    from repro import api
    from repro.api.presets import rcv1_spec

    out = {"n_devices": len(jax.devices()),
           "n_cores": os.cpu_count(),
           "regimes": {}}
    specs = []
    errors: list[dict] = []
    K = 4
    for regime, cfg in _REGIMES.items():
        outer = max(10, cfg["outer"] // 10) if quick else cfg["outer"]
        n_seeds = max(2, cfg["n_seeds"] // 4) if quick else cfg["n_seeds"]
        seeds = tuple(range(n_seeds))
        gammas = (1.0, 0.5)[:cfg["n_gammas"]]
        prob = api.ProblemSpec("rcv1_like",
                               {"K": K, "d": cfg["d"],
                                "n_per_worker": cfg["n_per_worker"]}).build()
        m = baselines.cocoa_plus(K, H=cfg["H"])
        specs.append(api.ExperimentSpec(
            name=f"sweep-scaling-{regime}-K{K}",
            problem=rcv1_spec(K=K, d=cfg["d"],
                              n_per_worker=cfg["n_per_worker"]),
            cluster=cluster(K),
            methods=(api.MethodEntry(m, outer),),
            eval_every=max(1, outer // 4), seed=0))
        row = run_cell(errors, f"sweep_scaling/{regime}", _regime_row,
                       api, prob, m, cluster(K), outer=outer, seeds=seeds,
                       gammas=gammas, label=regime)
        if row is not None:
            out["regimes"][regime] = row
    lag_row = run_cell(errors, "sweep_scaling/lag_grid", _lag_grid_row, api,
                       quick)
    if lag_row is not None:
        out["lag_grid"] = lag_row
    dump("sweep_scaling", out, specs=specs, errors=errors)


if __name__ == "__main__":
    main()
