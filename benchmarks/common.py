"""Shared benchmark plumbing: the RCV1-like problem, timing, CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (one per measured
configuration) so ``python -m benchmarks.run`` output is machine-readable;
``derived`` carries the benchmark's headline metric (speedup, bytes ratio,
rounds-to-gap, ...). Figures' raw curves are also dumped as JSON under
experiments/bench/ for EXPERIMENTS.md.
"""

from __future__ import annotations

import json
import pathlib
import time
from typing import Callable

from repro.core.simulate import ClusterModel
from repro.data.synthetic import LinearDatasetSpec, make_linear_problem

OUT_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "bench"


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def dump(name: str, payload) -> None:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / f"{name}.json").write_text(json.dumps(payload, indent=1))


def rcv1_like(K: int = 4, seed: int = 7, d: int = 2048, n_per_worker: int = 192):
    """Scaled-down stand-in for the paper's RCV1 split (no network access)."""
    spec = LinearDatasetSpec(num_workers=K, n_per_worker=n_per_worker, d=d,
                             nnz_per_row=24, seed=seed)
    return make_linear_problem(spec, lam=1e-3, loss="ridge")


def cluster(K: int, sigma: float = 1.0, jitter: float = 0.0) -> ClusterModel:
    return ClusterModel(num_workers=K, straggler_sigma=sigma, jitter=jitter)


def timed(fn: Callable, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6  # us
