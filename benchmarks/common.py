"""Shared benchmark plumbing: the RCV1-like problem, timing, CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (one per measured
configuration) so ``python -m benchmarks.run`` output is machine-readable;
``derived`` carries the benchmark's headline metric (speedup, bytes ratio,
rounds-to-gap, ...). Figures' raw curves are also dumped as JSON under
experiments/bench/ for EXPERIMENTS.md; every payload is stamped with
provenance (the ExperimentSpec JSON that produced it, the seed, and
``jax.__version__``) so bench trajectories are reproducible from the file
alone (``python -m repro run`` accepts the embedded spec).

Failure policy: a raising grid cell must not silently truncate the dump.
Benchmarks wrap per-cell work in :func:`run_cell`, which records the failing
cell + exception into the payload's ``errors`` list (written by
:func:`dump`) and keeps the rest of the grid running; the driver
(benchmarks/run.py) does the same per benchmark module.
"""

from __future__ import annotations

import json
import pathlib
import time
import traceback
from typing import Callable

from repro.api.problems import rcv1_like as _rcv1_like_builder
from repro.api.spec import ExperimentSpec
from repro.core.simulate import ClusterModel

OUT_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "bench"


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def dump(name: str, payload, *, specs=None, seed=None, errors=None) -> None:
    """Write a bench payload with reproducibility provenance.

    ``specs``: the ExperimentSpec(s) the trajectories came from (single spec
    or a list); ``seed``: the driving seed when no spec applies.
    ``errors``: failed-cell records from :func:`run_cell` -- written into the
    document (as ``errors``) so a raising cell leaves a visible trace in the
    artifact instead of a silently missing row.
    """
    import jax

    if isinstance(specs, ExperimentSpec):
        specs = [specs]
    provenance = {"jax_version": jax.__version__}
    if specs:
        provenance["specs"] = [s.to_dict() for s in specs]
        provenance["seed"] = specs[0].seed if seed is None else seed
    elif seed is not None:
        provenance["seed"] = seed
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    doc = {"provenance": provenance, "data": payload}
    if errors is not None:
        doc["errors"] = list(errors)
    (OUT_DIR / f"{name}.json").write_text(json.dumps(doc, indent=1))


def run_cell(errors: list, cell: str, fn: Callable, *args, **kw):
    """Run one grid cell, recording (not raising) its failure.

    On an exception: appends ``{"cell", "error", "traceback"}`` to
    ``errors``, emits an ``error/<cell>`` CSV row so the live output shows
    the hole, and returns ``None`` (callers skip the row).  Pass ``errors``
    on to :func:`dump` so the artifact carries the record.
    """
    try:
        return fn(*args, **kw)
    except Exception as e:  # noqa: BLE001 - the point is to record, not mask
        errors.append({
            "cell": cell,
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc(limit=10),
        })
        emit(f"error/{cell}", 0.0, type(e).__name__)
        return None


def rcv1_like(K: int = 4, seed: int = 7, d: int = 2048, n_per_worker: int = 192):
    """Scaled-down stand-in for the paper's RCV1 split (no network access).

    Thin wrapper over the ``rcv1_like`` problem-registry entry so ad-hoc
    callers and spec-driven runs build the identical dataset.
    """
    return _rcv1_like_builder(K=K, seed=seed, d=d, n_per_worker=n_per_worker)


def cluster(K: int, sigma: float = 1.0, jitter: float = 0.0) -> ClusterModel:
    return ClusterModel(num_workers=K, straggler_sigma=sigma, jitter=jitter)


def timed(fn: Callable, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6  # us
