"""Shared benchmark plumbing: the RCV1-like problem, timing, CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (one per measured
configuration) so ``python -m benchmarks.run`` output is machine-readable;
``derived`` carries the benchmark's headline metric (speedup, bytes ratio,
rounds-to-gap, ...). Figures' raw curves are also dumped as JSON under
experiments/bench/ for EXPERIMENTS.md; every payload is stamped with
provenance (the ExperimentSpec JSON that produced it, the seed, and
``jax.__version__``) so bench trajectories are reproducible from the file
alone (``python -m repro run`` accepts the embedded spec).

Failure policy: a raising grid cell must not silently truncate the dump.
Benchmarks wrap per-cell work in :func:`run_cell`, which records the failing
cell + exception into the payload's ``errors`` list (written by
:func:`dump`) and keeps the rest of the grid running; the driver
(benchmarks/run.py) does the same per benchmark module.
"""

from __future__ import annotations

import json
import pathlib
import time
import traceback
from typing import Callable

from repro.api.problems import rcv1_like as _rcv1_like_builder
from repro.api.spec import ExperimentSpec
from repro.core.simulate import ClusterModel

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_DIR = ROOT / "experiments" / "bench"
TRAJECTORY = ROOT / "BENCH_SWEEP.json"


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def dump(name: str, payload, *, specs=None, seed=None, errors=None) -> None:
    """Write a bench payload with reproducibility provenance.

    ``specs``: the ExperimentSpec(s) the trajectories came from (single spec
    or a list); ``seed``: the driving seed when no spec applies.
    ``errors``: failed-cell records from :func:`run_cell` -- written into the
    document (as ``errors``) so a raising cell leaves a visible trace in the
    artifact instead of a silently missing row.
    """
    import jax

    if isinstance(specs, ExperimentSpec):
        specs = [specs]
    provenance = {"jax_version": jax.__version__}
    if specs:
        provenance["specs"] = [s.to_dict() for s in specs]
        provenance["seed"] = specs[0].seed if seed is None else seed
    elif seed is not None:
        provenance["seed"] = seed
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    doc = {"provenance": provenance, "data": payload}
    if errors is not None:
        doc["errors"] = list(errors)
    (OUT_DIR / f"{name}.json").write_text(json.dumps(doc, indent=1))


def append_trajectory(entry: dict) -> None:
    """Append one run's headline perf numbers to the top-level
    ``BENCH_SWEEP.json`` trajectory (a JSON list, one entry per
    perf-carrying ``benchmarks/run.py`` invocation) so perf regressions
    are visible across PRs without diffing full bench dumps.

    Entries with no perf section are dropped.  ``--quick`` smoke runs are
    dropped too UNLESS they carry a ``serve`` section: executor/sweep
    wall-clocks are noise at smoke scale, but serving latency and coalesce
    factor are policy-dominated, so the quick serve cell is a real data
    point and the trajectory captures it alongside the full-scale numbers.
    """
    has_perf = ("executor" in entry or "sweep" in entry or "serve" in entry
                or "straggler_zoo" in entry or "chaos" in entry)
    if not has_perf or (entry.get("quick") and "serve" not in entry
                        and "chaos" not in entry):
        return
    doc = []
    if TRAJECTORY.exists():
        try:
            doc = json.loads(TRAJECTORY.read_text())
        except json.JSONDecodeError:
            doc = []  # a corrupt trajectory must not fail the bench run
        if not isinstance(doc, list):
            doc = []
    doc.append(entry)
    TRAJECTORY.write_text(json.dumps(doc, indent=1) + "\n")


def trajectory_entry(quick: bool, failures: list,
                     modules_run: list[str]) -> dict:
    """Summarize ONE bench run into a trajectory entry: wall-clock +
    dispatch counts per regime for the executor and sweep benchmarks.

    A section is included only when its producing module ran -- and did not
    fail -- in THIS invocation (``modules_run`` minus the failures), so
    every number in an entry was measured under the entry's own ``quick``
    flag and device configuration: neither a ``--only`` run nor a crashed
    module ever copies stale numbers from an earlier run's dumps.
    """
    import jax

    entry: dict = {
        "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "quick": quick,
        "jax_version": jax.__version__,
        "modules_run": list(modules_run),
        "failed_modules": [f["cell"] for f in failures],
    }
    fresh = set(modules_run) - set(entry["failed_modules"])
    exec_path = OUT_DIR / "executor_scaling.json"
    if "benchmarks.bench_engine" in fresh and exec_path.exists():
        data = json.loads(exec_path.read_text())["data"]
        entry["executor"] = {
            regime: {"event_wall_s": row["event"]["wall_s"],
                     "scan_wall_s": row["scan"]["wall_s"],
                     "event_dispatches": row["event"]["device_dispatches"],
                     "scan_dispatches": row["scan"]["device_dispatches"]}
            for regime, row in data["executor"]["regimes"].items()}
    sweep_path = OUT_DIR / "sweep_scaling.json"
    if "benchmarks.bench_sweep_scaling" in fresh and sweep_path.exists():
        doc = json.loads(sweep_path.read_text())["data"]
        entry["n_devices"] = doc.get("n_devices")
        entry["n_cores"] = doc.get("n_cores")
        keep = ("sweep_sharded_wall_s", "sweep_vmap_wall_s",
                "percell_scan_wall_s", "percell_event_wall_s",
                "sweep_dispatches", "percell_scan_dispatches",
                "mesh_speedup_vs_vmap", "speedup_vs_percell_event",
                "speedup_vs_percell_scan")
        rows = dict(doc.get("regimes", {}))
        if "lag_grid" in doc:
            rows["lag_grid"] = doc["lag_grid"]
        entry["sweep"] = {
            regime: {k: row[k] for k in keep if k in row}
            for regime, row in rows.items()}
    zoo_path = OUT_DIR / "straggler_zoo.json"
    if ("benchmarks.bench_straggler_zoo" in fresh and zoo_path.exists()
            and not quick):
        # Sim-time-to-gap is a model quantity, not a wall-clock, but it IS
        # the zoo's headline claim (partial_work harvests stragglers); only
        # full-scale runs are trustworthy, quick grids stop too early.
        data = json.loads(zoo_path.read_text())["data"]
        ttg = data.get("time_to_gap") or {}
        if ttg:
            entry["straggler_zoo"] = {
                delay: {k: row.get(k) for k in
                        ("target_gap", "group_s", "partial_s",
                         "sim_time_speedup")}
                for delay, row in ttg.items()}
    serve_path = OUT_DIR / "serve.json"
    if "benchmarks.bench_serve" in fresh and serve_path.exists():
        data = json.loads(serve_path.read_text())["data"]
        entry["serve"] = {k: data.get(k) for k in (
            "sustained_req_per_s", "latency_p50_s", "latency_p99_s",
            "coalesce_factor", "compile_cache_hit_rate", "n_requests",
            "offered_rate_hz", "batches", "solo_requests")}
    chaos_path = OUT_DIR / "chaos.json"
    if "benchmarks.bench_chaos" in fresh and chaos_path.exists():
        data = json.loads(chaos_path.read_text())["data"]
        window, recovery = data.get("window", {}), data.get("recovery", {})
        entry["chaos"] = {
            **{k: window.get(k) for k in (
                "goodput_req_per_s", "hung_jobs", "succeeded", "failed",
                "latency_p50_s", "n_requests")},
            "counters": window.get("counters"),
            "resume_wall_s": recovery.get("resume_wall_s"),
            "recovery_speedup_vs_fresh":
                recovery.get("recovery_speedup_vs_fresh"),
            "resume_bit_identical": recovery.get("resume_bit_identical"),
            "cluster": {k: data.get("cluster", {}).get(k) for k in (
                "goodput_jobs_per_s", "hung_jobs", "n_jobs", "n_replicas",
                "takeovers", "takeover_recovery_ticks", "fenced_results",
                "dropped_messages", "deduped_results")},
        }
    return entry


def run_cell(errors: list, cell: str, fn: Callable, *args, **kw):
    """Run one grid cell, recording (not raising) its failure.

    On an exception: appends ``{"cell", "error", "traceback"}`` to
    ``errors``, emits an ``error/<cell>`` CSV row so the live output shows
    the hole, and returns ``None`` (callers skip the row).  Pass ``errors``
    on to :func:`dump` so the artifact carries the record.
    """
    try:
        return fn(*args, **kw)
    except Exception as e:  # noqa: BLE001 - the point is to record, not mask
        errors.append({
            "cell": cell,
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc(limit=10),
        })
        emit(f"error/{cell}", 0.0, type(e).__name__)
        return None


def rcv1_like(K: int = 4, seed: int = 7, d: int = 2048, n_per_worker: int = 192):
    """Scaled-down stand-in for the paper's RCV1 split (no network access).

    Thin wrapper over the ``rcv1_like`` problem-registry entry so ad-hoc
    callers and spec-driven runs build the identical dataset.
    """
    return _rcv1_like_builder(K=K, seed=seed, d=d, n_per_worker=n_per_worker)


def cluster(K: int, sigma: float = 1.0, jitter: float = 0.0) -> ClusterModel:
    return ClusterModel(num_workers=K, straggler_sigma=sigma, jitter=jitter)


def timed(fn: Callable, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6  # us
