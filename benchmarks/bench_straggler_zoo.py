"""Protocol x delay-model grid: the "straggler-agnostic" claim as a sweep.

For every delay model in the straggler-zoo preset family (constant,
shifted-exponential, Pareto heavy tail, Markov bursty, bandwidth-coupled)
this runs every server discipline in the protocol registry against it via
the declarative ``zoo-<delay>`` specs and reports, per (protocol, delay)
cell: the final duality gap, the simulated time to reach it, and the
up/down byte totals.

Output: ``name,us_per_call,derived`` CSV rows (derived = final gap @ sim
time) plus ``experiments/bench/straggler_zoo.json`` -- a grid document with
one entry per cell and the producing specs embedded as provenance, so each
cell is reproducible with ``python -m repro run``.

Expected shape of the grid: the group-family disciplines (ACPD, adaptive-B,
LAG) keep their sim-time roughly flat across delay shapes while the
synchronous CoCoA-lineage rows inherit every tail (their lockstep round waits
for the slowest worker); adaptive-B tracks ACPD while choosing B itself; the
bandwidth-coupled column rewards sparse payloads specifically.
"""

from __future__ import annotations

from benchmarks.common import dump, emit, run_cell, timed
from repro.api.presets import ZOO_DELAYS, straggler_zoo


def _run_cell(exp, entry, delay):
    session = exp.session(entry)  # executor="auto": scan where eligible
    _, us = timed(session.run)
    res = session.result()
    last = res.records[-1]
    return us, {
        "protocol": entry.config.protocol,
        "delay_model": delay,
        "executor": session.executor,
        "gap": last.gap,
        "sim_time": last.sim_time,
        "bytes_up": last.bytes_up,
        "bytes_down": last.bytes_down,
        "rounds": last.iteration,
    }


def main(quick: bool = False) -> None:
    from repro import api

    grid: dict[str, dict[str, dict]] = {}
    specs = []
    errors: list[dict] = []
    for delay in sorted(ZOO_DELAYS):
        spec = straggler_zoo(delay, quick=quick)
        specs.append(spec)
        exp = api.Experiment(spec)
        for entry in spec.methods:
            # A raising cell is recorded in the dump, not silently dropped.
            out = run_cell(errors, f"{entry.config.name}@{delay}",
                           _run_cell, exp, entry, delay)
            if out is None:
                continue
            us, cell = out
            grid.setdefault(entry.config.name, {})[delay] = cell
            emit(f"zoo/{entry.config.name}@{delay}", us,
                 f"gap={cell['gap']:.3e}@t={cell['sim_time']:.4f}s")
    dump("straggler_zoo", grid, specs=specs, errors=errors)


if __name__ == "__main__":
    main()
