"""Protocol x delay-model grid: the "straggler-agnostic" claim as a sweep.

For every delay model in the straggler-zoo preset family (constant,
shifted-exponential, Pareto heavy tail, Markov bursty, bandwidth-coupled)
this runs every server discipline in the protocol registry against it via
the declarative ``zoo-<delay>`` specs and reports, per (protocol, delay)
cell: the final duality gap, the simulated time to reach it, and the
up/down byte totals.

Output: ``name,us_per_call,derived`` CSV rows (derived = final gap @ sim
time) plus ``experiments/bench/straggler_zoo.json`` -- a grid document with
one entry per cell and the producing specs embedded as provenance, so each
cell is reproducible with ``python -m repro run``.

Expected shape of the grid: the group-family disciplines (ACPD, adaptive-B,
LAG) keep their sim-time roughly flat across delay shapes while the
synchronous CoCoA-lineage rows inherit every tail (their lockstep round waits
for the slowest worker); adaptive-B tracks ACPD while choosing B itself; the
bandwidth-coupled column rewards sparse payloads specifically.
"""

from __future__ import annotations

from benchmarks.common import dump, emit, timed
from repro.api.presets import ZOO_DELAYS, straggler_zoo


def main(quick: bool = False) -> None:
    from repro import api

    grid: dict[str, dict[str, dict]] = {}
    specs = []
    for delay in sorted(ZOO_DELAYS):
        spec = straggler_zoo(delay, quick=quick)
        specs.append(spec)
        exp = api.Experiment(spec)
        for entry in spec.methods:
            session = exp.session(entry)
            _, us = timed(session.run)
            res = session.result()
            last = res.records[-1]
            cell = {
                "protocol": entry.config.protocol,
                "delay_model": delay,
                "gap": last.gap,
                "sim_time": last.sim_time,
                "bytes_up": last.bytes_up,
                "bytes_down": last.bytes_down,
                "rounds": last.iteration,
            }
            grid.setdefault(entry.config.name, {})[delay] = cell
            emit(f"zoo/{entry.config.name}@{delay}", us,
                 f"gap={last.gap:.3e}@t={last.sim_time:.4f}s")
    dump("straggler_zoo", grid, specs=specs)


if __name__ == "__main__":
    main()
