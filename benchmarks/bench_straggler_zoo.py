"""Protocol x delay-model grid: the "straggler-agnostic" claim as a sweep.

For every delay model in the straggler-zoo preset family (constant,
shifted-exponential, Pareto heavy tail, Markov bursty, bandwidth-coupled)
this runs every server discipline in the protocol registry against it via
the declarative ``zoo-<delay>`` specs and reports, per (protocol, delay)
cell: the final duality gap, the simulated time to reach it, and the
up/down byte totals.

Output: ``name,us_per_call,derived`` CSV rows (derived = final gap @ sim
time) plus ``experiments/bench/straggler_zoo.json`` -- a grid document with
one entry per cell and the producing specs embedded as provenance, so each
cell is reproducible with ``python -m repro run``.

Expected shape of the grid: the group-family disciplines (ACPD, adaptive-B,
LAG) keep their sim-time roughly flat across delay shapes while the
synchronous CoCoA-lineage rows inherit every tail (their lockstep round waits
for the slowest worker); adaptive-B tracks ACPD while choosing B itself; the
bandwidth-coupled column rewards sparse payloads specifically.
"""

from __future__ import annotations

import dataclasses

from benchmarks.common import dump, emit, run_cell, timed
from repro.api.presets import ZOO_DELAYS, straggler_zoo

# The slice of the zoo's delay axis a ONE-call api.run_sweep grid can
# cover per protocol: markov cannot pre-sample its (round, worker) stream
# (per-launch chain draws keep it on per-cell sessions, see
# docs/performance.md), and bandwidth_coupled runs the zoo under a
# different cluster (sigma=1: the straggler is a slow LINK), so it cannot
# share the sweep's single base cluster and stay comparable to the
# per-cell reference rows.
SWEEPABLE_DELAYS = tuple(
    (name, dict(params)) for name, params in sorted(ZOO_DELAYS.items())
    if name not in ("markov", "bandwidth_coupled"))


def _sweep_grid(spec, method_name: str, seeds):
    """One protocol's whole delay x seed zoo slice as ONE compiled call."""
    from repro import api

    variants, us = timed(
        lambda: api.sweep_spec(spec, method_name, seeds=seeds,
                               delays=SWEEPABLE_DELAYS))
    return us, {
        "cells": len(variants),
        "delays": [n for n, _ in SWEEPABLE_DELAYS],
        "seeds": list(seeds),
        "shard_plan": dataclasses.asdict(api.resolve_shard(
            "auto", protocol=variants[0].result.method.protocol,
            num_workers=spec.cluster.num_workers)),
        "final_gap": {f"{v.delay}/s{v.seed}": v.result.records[-1].gap
                      for v in variants},
    }


# The straggler-UTILIZING headline: partial_work vs group at EQUAL byte
# budget (acpd_partial_work ships rho_d/n_chunks coordinates per chunk, so a
# full pass costs exactly one acpd() round) under the two heavy-tail delays
# where stragglers actually exist.  The shared target gap is chosen POST HOC
# as the worse of the two final gaps, so both runs provably reached it and
# the sim-time ratio needs no per-delay tuning.
TTG_DELAYS = ("shifted_exponential", "pareto")


def _time_to(records, target: float) -> float:
    for rec in records:
        if rec.gap <= target:
            return rec.sim_time
    return records[-1].sim_time


def _ttg_cell(spec):
    from repro import api

    exp = api.Experiment(spec)
    runs = {}
    for mname in ("ACPD", "ACPD-partial"):
        session = exp.session(spec.method_named(mname))
        _, us = timed(session.run)
        runs[mname] = (session, session.result().records, us)
    target = max(runs[m][1][-1].gap for m in runs)
    group_s = _time_to(runs["ACPD"][1], target)
    partial_s = _time_to(runs["ACPD-partial"][1], target)
    us_total = sum(us for _, _, us in runs.values())
    return us_total, {
        "target_gap": target,
        "group_s": group_s,
        "partial_s": partial_s,
        "sim_time_speedup": group_s / partial_s if partial_s > 0 else None,
        "group_final_gap": runs["ACPD"][1][-1].gap,
        "partial_final_gap": runs["ACPD-partial"][1][-1].gap,
        "group_bytes_up": runs["ACPD"][1][-1].bytes_up,
        "partial_bytes_up": runs["ACPD-partial"][1][-1].bytes_up,
        "group_rounds": runs["ACPD"][1][-1].iteration,
        "partial_rounds": runs["ACPD-partial"][1][-1].iteration,
        "group_executor": runs["ACPD"][0].executor,
        "partial_executor": runs["ACPD-partial"][0].executor,
    }


def _run_cell(exp, entry, delay):
    session = exp.session(entry)  # executor="auto": scan where eligible
    _, us = timed(session.run)
    res = session.result()
    last = res.records[-1]
    return us, {
        "protocol": entry.config.protocol,
        "delay_model": delay,
        "executor": session.executor,
        "gap": last.gap,
        "sim_time": last.sim_time,
        "bytes_up": last.bytes_up,
        "bytes_down": last.bytes_down,
        "rounds": last.iteration,
    }


def main(quick: bool = False) -> None:
    from repro import api

    grid: dict[str, dict[str, dict]] = {}
    specs = []
    errors: list[dict] = []
    for delay in sorted(ZOO_DELAYS):
        spec = straggler_zoo(delay, quick=quick)
        specs.append(spec)
        exp = api.Experiment(spec)
        for entry in spec.methods:
            # A raising cell is recorded in the dump, not silently dropped.
            out = run_cell(errors, f"{entry.config.name}@{delay}",
                           _run_cell, exp, entry, delay)
            if out is None:
                continue
            us, cell = out
            grid.setdefault(entry.config.name, {})[delay] = cell
            emit(f"zoo/{entry.config.name}@{delay}", us,
                 f"gap={cell['gap']:.3e}@t={cell['sim_time']:.4f}s")

    # Sweep-grid section: the scan-capable rows rerun as ONE compiled
    # api.run_sweep call each, spanning the pre-sampleable delay axis x
    # seeds (the per-cell rows above stay the reference; this records the
    # batched path the sharded sweep subsystem adds).
    sweep_grids: dict[str, dict] = {}
    seeds = (0, 1) if quick else (0, 1, 2, 3)
    base = straggler_zoo("constant", quick=quick)
    for method_name in ("ACPD-LAG", "CoCoA+"):
        out = run_cell(errors, f"sweep/{method_name}", _sweep_grid, base,
                       method_name, seeds)
        if out is None:
            continue
        us, row = out
        sweep_grids[method_name] = row
        emit(f"zoo/sweep/{method_name}", us,
             f"{row['cells']}cells@1call")

    # Time-to-gap section: partial_work vs group, same specs as the grid
    # (so the equal-byte-budget construction is the one already recorded as
    # provenance above), reported as sim-time to the shared reachable gap.
    time_to_gap: dict[str, dict] = {}
    for delay in TTG_DELAYS:
        out = run_cell(errors, f"ttg/{delay}", _ttg_cell,
                       straggler_zoo(delay, quick=quick))
        if out is None:
            continue
        us, row = out
        time_to_gap[delay] = row
        emit(f"zoo/ttg/{delay}", us,
             f"partial={row['partial_s']:.4f}s group={row['group_s']:.4f}s "
             f"x{row['sim_time_speedup']:.2f}@gap={row['target_gap']:.3e}")
    dump("straggler_zoo",
         {"grid": grid, "sweep": sweep_grids, "time_to_gap": time_to_gap},
         specs=specs, errors=errors)


if __name__ == "__main__":
    main()
