"""Fig. 5: the 'real distributed environment' proxy -- lognormal compute
jitter on every worker (other tenants), 8 workers, URL/KDD-like higher d.
Reports time to gap and the compute/communication time split.

Spec-driven: ``repro.api.presets.fig5``."""

from __future__ import annotations

from benchmarks.common import dump, emit, timed
from repro.api import Experiment, presets

TARGET = 1e-3


def main(quick: bool = False) -> None:
    spec = presets.fig5(quick=quick)
    exp = Experiment(spec)
    out = {}
    for entry in spec.methods:
        res, us = timed(exp.run_entry, entry)
        t = res.time_to_gap(TARGET)
        last = res.records[-1]
        name = entry.config.name
        emit(f"fig5/{name}/time_to_gap", us, None if t is None else round(t, 4))
        emit(f"fig5/{name}/comm_fraction", us,
             round(last.comm_time / max(last.comm_time + last.compute_time,
                                        1e-9), 4))
        out[name] = {"time_to_gap": t, "comm_time": last.comm_time,
                     "compute_time": last.compute_time}
    if out["ACPD"]["time_to_gap"] and out["CoCoA+"]["time_to_gap"]:
        emit("fig5/speedup", 0.0,
             round(out["CoCoA+"]["time_to_gap"] / out["ACPD"]["time_to_gap"], 2))
    dump("fig5_realenv", out, specs=spec)


if __name__ == "__main__":
    main()
