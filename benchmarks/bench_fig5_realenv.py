"""Fig. 5: the 'real distributed environment' proxy -- lognormal compute
jitter on every worker (other tenants), 8 workers, URL/KDD-like higher d.
Reports time to gap and the compute/communication time split."""

from __future__ import annotations

from benchmarks.common import cluster, dump, emit, rcv1_like, timed
from repro.core import baselines
from repro.core.acpd import run_method

TARGET = 1e-3


def main(quick: bool = False) -> None:
    K, d = (4, 1024) if quick else (8, 4096)
    H = 64 if quick else 256
    prob = rcv1_like(K=K, d=d, n_per_worker=96, seed=31)
    cl = cluster(K, sigma=1.0, jitter=0.6)  # multiplicative lognormal noise
    acpd = baselines.acpd(K, d, B=K // 2, T=10, rho_d=64, gamma=0.5, H=H)
    coco = baselines.cocoa_plus(K, H=H)
    out = {}
    for m, outer in ((acpd, 2 if quick else 8), (coco, 10 if quick else 60)):
        res, us = timed(run_method, prob, m, cl, num_outer=outer,
                        eval_every=2, seed=0)
        t = res.time_to_gap(TARGET)
        last = res.records[-1]
        emit(f"fig5/{m.name}/time_to_gap", us, None if t is None else round(t, 4))
        emit(f"fig5/{m.name}/comm_fraction", us,
             round(last.comm_time / max(last.comm_time + last.compute_time,
                                        1e-9), 4))
        out[m.name] = {"time_to_gap": t, "comm_time": last.comm_time,
                       "compute_time": last.compute_time}
    if out["ACPD"]["time_to_gap"] and out["CoCoA+"]["time_to_gap"]:
        emit("fig5/speedup", 0.0,
             round(out["CoCoA+"]["time_to_gap"] / out["ACPD"]["time_to_gap"], 2))
    dump("fig5_realenv", out)


if __name__ == "__main__":
    main()
