"""Benchmark driver: one module per paper table/figure + the roofline reader.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (bench_fig3_convergence, bench_fig4a_rho,
                            bench_fig4b_scaling, bench_fig5_realenv,
                            bench_table1, roofline)

    print("name,us_per_call,derived")
    t0 = time.time()
    for mod in (bench_table1, bench_fig3_convergence, bench_fig4a_rho,
                bench_fig4b_scaling, bench_fig5_realenv, roofline):
        mod.main()
    print(f"# all benchmarks done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == '__main__':
    main()
