"""Benchmark driver: one module per paper table/figure + the roofline reader
and the engine microbenchmark.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).

``--quick`` runs every benchmark at smoke scale (tiny K, num_outer, H) --
seconds instead of minutes; used by ``make check`` / scripts/check.sh as the
CI-style sanity gate that the whole bench surface still executes.

This is the ONE driver: ``python -m repro bench [--quick] [--only ...]``
forwards here, so the CLI and ``python -m benchmarks.run`` stay in lockstep.
"""

from __future__ import annotations

import argparse
import sys
import time


def _analysis_findings() -> dict:
    """Static-analysis debt alongside the perf numbers: total lint findings
    over src/ plus how many are new vs the checked-in baseline, so the
    trajectory shows contract debt shrinking, not just wall-clock."""
    try:
        from repro.analysis import Baseline, lint_paths
        from repro.analysis.cli import BASELINE_NAME, _repo_root

        root = _repo_root()
        findings = lint_paths([root / "src"], root=root)
        new, accepted, stale = Baseline.load(
            root / BASELINE_NAME).split(findings)
        return {"total": len(findings), "new": len(new),
                "baseline": len(accepted), "stale": len(stale)}
    except Exception as e:  # never fail a bench run over the analyzer
        return {"error": repr(e)}


def main(argv: list[str] | None = None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--quick", action="store_true",
                        help="smoke mode: tiny K/num_outer/H per benchmark")
    parser.add_argument("--only", default=None,
                        help="substring filter on benchmark module names")
    args = parser.parse_args(argv)

    from benchmarks import (bench_chaos, bench_engine,
                            bench_fig3_convergence, bench_fig4a_rho,
                            bench_fig4b_scaling, bench_fig5_realenv,
                            bench_serve, bench_straggler_zoo,
                            bench_sweep_scaling, bench_table1, common,
                            roofline)

    mods = [bench_table1, bench_fig3_convergence, bench_fig4a_rho,
            bench_fig4b_scaling, bench_fig5_realenv, bench_straggler_zoo,
            bench_engine, bench_sweep_scaling, bench_serve, bench_chaos,
            roofline]
    if args.only:
        mods = [m for m in mods if args.only in m.__name__]
        if not mods:
            print(f"# no benchmark matches --only={args.only!r}",
                  file=sys.stderr)
            return

    print("name,us_per_call,derived")
    t0 = time.time()
    failures: list[dict] = []
    for mod in mods:
        # A raising benchmark must not silently truncate the suite: record
        # the failure (CSV row + JSON artifact) and keep going.
        common.run_cell(failures, mod.__name__, mod.main, quick=args.quick)
    failure_file = common.OUT_DIR / "bench_failures.json"
    if failures:
        common.dump("bench_failures", {"failed_modules": failures})
    elif failure_file.exists():
        failure_file.unlink()  # clean run: drop the stale failure record
    # Append this run's headline perf numbers to the top-level trajectory
    # (BENCH_SWEEP.json) so perf regressions are visible across PRs.
    entry = common.trajectory_entry(
        args.quick, failures, [m.__name__ for m in mods])
    entry["analysis_findings"] = _analysis_findings()
    common.append_trajectory(entry)
    print(f"# all benchmarks done in {time.time() - t0:.1f}s"
          + (f" ({len(failures)} FAILED)" if failures else ""),
          file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
