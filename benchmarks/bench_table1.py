"""Table I: per-round communication cost -- O(d) dense vs O(rho d) ACPD.

Measures actual on-wire bytes per communication round for each method on the
RCV1-like problem (and at RCV1's real dimensionality for the static part),
plus the wall time of the message filter itself.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cluster, dump, emit, rcv1_like, timed
from repro.core import baselines
from repro.core.acpd import run_method
from repro.core.filter import dense_bytes, message_bytes, num_kept
from repro.kernels import ops


def main(quick: bool = False) -> None:
    K, d = 4, 512 if quick else 2048
    H = 64 if quick else 256
    prob = rcv1_like(K=K, d=d)
    rows = {}
    for preset, outer in ((baselines.cocoa_plus(K, H=H), 5 if quick else 20),
                          (baselines.acpd(K, d, rho_d=64, H=H), 1 if quick else 2),
                          (baselines.acpd_dense(K, H=H), 1 if quick else 2)):
        res, us = timed(run_method, prob, preset, cluster(K),
                        num_outer=outer, eval_every=5, seed=0)
        rounds = res.records[-1].iteration
        per_round = (res.records[-1].bytes_up + res.records[-1].bytes_down) / rounds
        rows[preset.name] = per_round
        emit(f"table1/bytes_per_round/{preset.name}", us / rounds, int(per_round))

    # Static accounting at the paper's real dataset sizes (Table II).
    for name, dd in (("RCV1", 47_236), ("URL", 3_231_961), ("KDD", 29_890_095)):
        ratio = dense_bytes(dd) / message_bytes(num_kept(dd, 1000 / dd))
        emit(f"table1/static_ratio/{name}", 0.0, round(ratio, 1))

    # The filter hot-spot itself (Pallas kernel, interpret mode on CPU).
    x = jnp.asarray(np.random.default_rng(0).standard_normal(d).astype(np.float32))
    _, us = timed(lambda: jax.block_until_ready(ops.topk_filter(x, 64)),
                  repeats=3)
    emit("table1/topk_filter_us", us, 64)
    dump("table1", rows)


if __name__ == "__main__":
    main()
