"""Table I: per-round communication cost -- O(d) dense vs O(rho d) ACPD.

Measures actual on-wire bytes per communication round for each method on the
RCV1-like problem (and at RCV1's real dimensionality for the static part),
plus the wall time of the message filter itself.

Spec-driven: ``repro.api.presets.table1``; the static accounting rows go
through the shared ``repro.core.compress`` registry (the same byte formulas
the engine and the exchange path bill with).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import dump, emit, timed
from repro.api import Experiment, presets
from repro.core.compress import Dense, TopKExact
from repro.core.filter import num_kept
from repro.kernels import ops


def main(quick: bool = False) -> None:
    spec = presets.table1(quick=quick)
    exp = Experiment(spec)
    rows = {}
    for entry in spec.methods:
        res, us = timed(exp.run_entry, entry)
        rounds = res.records[-1].iteration
        per_round = (res.records[-1].bytes_up + res.records[-1].bytes_down) / rounds
        rows[entry.config.name] = per_round
        emit(f"table1/bytes_per_round/{entry.config.name}", us / rounds,
             int(per_round))

    # Static accounting at the paper's real dataset sizes (Table II), via the
    # unified compressor registry (one byte formula for sim + exchange).
    for name, dd in (("RCV1", 47_236), ("URL", 3_231_961), ("KDD", 29_890_095)):
        k = num_kept(dd, 1000 / dd)
        ratio = Dense().wire_bytes(dd) / TopKExact(k=k).wire_bytes(dd)
        emit(f"table1/static_ratio/{name}", 0.0, round(ratio, 1))

    # The filter hot-spot itself (Pallas kernel, interpret mode on CPU).
    d = 512 if quick else 2048
    x = jnp.asarray(np.random.default_rng(0).standard_normal(d).astype(np.float32))
    _, us = timed(lambda: jax.block_until_ready(ops.topk_filter(x, 64)),
                  repeats=3)
    emit("table1/topk_filter_us", us, 64)
    dump("table1", rows, specs=spec)


if __name__ == "__main__":
    main()
