"""Fig. 4a: effect of the sparsity constant rho on convergence (rounds to a
mid-accuracy gap and the final gap), rho*d from d/256 up to d (dense)."""

from __future__ import annotations

from benchmarks.common import cluster, dump, emit, rcv1_like, timed
from repro.core import baselines
from repro.core.acpd import run_method


def main(quick: bool = False) -> None:
    K, d = 4, 512 if quick else 2048
    H = 64 if quick else 256
    prob = rcv1_like(K=K, d=d)
    curves = {}
    for rho_d in ((8, 128) if quick else (8, 32, 128, 512, 2048)):
        m = baselines.acpd(K, d, B=2, T=10, rho_d=rho_d, gamma=0.5, H=H)
        res, us = timed(run_method, prob, m, cluster(K),
                        num_outer=2 if quick else 8, eval_every=2, seed=0)
        r = res.rounds_to_gap(1e-3)
        final = res.records[-1].gap
        emit(f"fig4a/rho_d{rho_d}/rounds_to_1e-3", us, r)
        emit(f"fig4a/rho_d{rho_d}/final_gap", us, f"{final:.2e}")
        curves[rho_d] = [{"iter": rec.iteration, "gap": rec.gap}
                         for rec in res.records]
    dump("fig4a_rho", curves)


if __name__ == "__main__":
    main()
