"""Fig. 4a: effect of the sparsity constant rho on convergence (rounds to a
mid-accuracy gap and the final gap), rho*d from d/256 up to d (dense).

Spec-driven: the whole sweep is one ``repro.api.presets.fig4a``
ExperimentSpec (one ACPD method entry per rho*d)."""

from __future__ import annotations

from benchmarks.common import dump, emit, timed
from repro.api import Experiment, presets


def main(quick: bool = False) -> None:
    spec = presets.fig4a(quick=quick)
    exp = Experiment(spec)
    curves = {}
    for entry in spec.methods:
        rho_d = entry.config.name.removeprefix("ACPD-rho_d")
        res, us = timed(exp.run_entry, entry)
        r = res.rounds_to_gap(1e-3)
        final = res.records[-1].gap
        emit(f"fig4a/rho_d{rho_d}/rounds_to_1e-3", us, r)
        emit(f"fig4a/rho_d{rho_d}/final_gap", us, f"{final:.2e}")
        curves[rho_d] = [{"iter": rec.iteration, "gap": rec.gap}
                         for rec in res.records]
    dump("fig4a_rho", curves, specs=spec)


if __name__ == "__main__":
    main()
