"""Roofline table from the dry-run artifacts (EXPERIMENTS §Dry-run/§Roofline).

Reads experiments/dryrun/*.json, prints the per-(arch x shape x mesh) terms,
and writes experiments/roofline_table.md for inclusion in EXPERIMENTS.md.
"""

from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit

DRYRUN = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "dryrun"
OUT_MD = DRYRUN.parent / "roofline_table.md"

_SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load_records(tag: str = "", exchange: str = "plain") -> list[dict]:
    recs = []
    suffix = f"-{tag}" if tag else ""
    for fn in sorted(DRYRUN.glob(f"*__{exchange}{suffix}.json")):
        recs.append(json.loads(fn.read_text()))
    recs.sort(key=lambda r: (r["arch"], _SHAPE_ORDER.get(r["shape"], 9),
                             r["mesh"]))
    return recs


def fmt_row(r: dict) -> str:
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | skip | "
                f"{r.get('reason', r.get('error', ''))[:54]} | | | | | |")
    rf = r["roofline"]
    mem = rf["memory_stats"]
    fp = mem.get("footprint_adjusted_bytes", mem.get("footprint_bytes", 0)) / 2**30
    ur = rf["useful_ratio"]
    dom = rf["dominant"]
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {fp:.2f} GiB | {rf['compute_s']*1e3:.2f} | "
            f"{rf['memory_s']*1e3:.2f} | {rf['collective_s']*1e3:.2f} | "
            f"**{dom}** | {ur:.3f} |" if ur is not None else
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {fp:.2f} GiB | "
            f"{rf['compute_s']*1e3:.2f} | {rf['memory_s']*1e3:.2f} | "
            f"{rf['collective_s']*1e3:.2f} | **{dom}** | - |")


def main(quick: bool = False) -> None:
    del quick  # artifact reader: already cheap, nothing to scale down
    recs = load_records()
    if not recs:
        emit("roofline/no_artifacts", 0.0, "run repro.launch.dryrun first")
        return
    lines = [
        "| arch | shape | mesh | status | mem/dev | compute ms | memory ms | "
        "collective ms | dominant | useful ratio |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    n_ok = n_skip = 0
    worst = None
    most_coll = None
    for r in recs:
        lines.append(fmt_row(r))
        if r["status"] == "ok":
            n_ok += 1
            rf = r["roofline"]
            terms = {"compute": rf["compute_s"], "memory": rf["memory_s"],
                     "collective": rf["collective_s"]}
            total = sum(terms.values())
            frac = rf["compute_s"] / total if total else 0
            key = (r["arch"], r["shape"], r["mesh"])
            if worst is None or frac < worst[1]:
                worst = (key, frac)
            cf = rf["collective_s"] / total if total else 0
            if most_coll is None or cf > most_coll[1]:
                most_coll = (key, cf)
        else:
            n_skip += 1
    OUT_MD.write_text("\n".join(lines) + "\n")
    emit("roofline/pairs_ok", 0.0, n_ok)
    emit("roofline/pairs_skipped", 0.0, n_skip)
    emit("roofline/worst_compute_fraction", 0.0,
         f"{worst[0]}:{worst[1]:.4f}" if worst else None)
    emit("roofline/most_collective_bound", 0.0,
         f"{most_coll[0]}:{most_coll[1]:.4f}" if most_coll else None)
    emit("roofline/table_md", 0.0, str(OUT_MD))


if __name__ == "__main__":
    main()
