"""Serving under open-loop Poisson load: throughput, latency, coalescing.

Drives a live :class:`repro.serve.ExperimentService` (dispatcher thread
started, exactly as ``python -m repro serve`` runs it) with an open-loop
Poisson arrival process over a straggler-zoo preset mix: three tenants
submitting CoCoA+ and ACPD-LAG requests against different delay models and
seeds.  Open-loop means arrival times are drawn up front and never slowed
by completions, so the service sees genuine queueing pressure and the
coalescer has real batches to form.

Measured over the post-warmup window (warmup populates the jit/process
compile caches -- the steady state a persistent service exists for):

* ``sustained_req_per_s`` -- completed requests / wall-clock of the window;
* ``latency_p50_s`` / ``latency_p99_s`` -- per-request submit->result();
* ``coalesce_factor`` -- batched requests per compiled dispatch;
* ``compile_cache_hit_rate`` -- warm-cache hits over cache lookups.

Output: CSV rows plus ``experiments/bench/serve.json`` (provenance-stamped);
the driver folds the headline numbers into the BENCH_SWEEP.json trajectory
(including ``--quick`` runs: serving latency is meaningful at smoke scale
because the batch *policy*, not the problem size, dominates it).
"""

from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from benchmarks.common import dump, emit

TENANTS = ("alice", "bob", "carol")
# Pre-sampleable zoo delays only: markov rides the solo lane (per-launch
# chain draws, see docs/performance.md) and would make latency bimodal.
DELAYS = ("constant", "pareto", "shifted_exponential")
METHODS = ("CoCoA+", "ACPD-LAG")  # two batchable templates -> two cohorts


def _specs(quick: bool):
    from repro import api

    return [api.build_preset(f"zoo-{d}", quick=quick) for d in DELAYS]


def _drive(service, specs, *, n_requests: int, rate_hz: float,
           rng: np.random.Generator):
    """Submit ``n_requests`` at Poisson arrivals; return (wall_s, latencies,
    rejected).  Latency is submit -> ``result()`` (full stream delivered)."""
    from repro.serve import BackpressureError

    latencies: list[float] = []
    lat_lock = threading.Lock()
    waiters: list[threading.Thread] = []
    rejected = 0
    t_start = time.perf_counter()
    due = 0.0
    for i in range(n_requests):
        due += rng.exponential(1.0 / rate_hz)
        lead = due - (time.perf_counter() - t_start)
        if lead > 0:
            time.sleep(lead)
        spec = dataclasses.replace(specs[int(rng.integers(len(specs)))],
                                   seed=int(rng.integers(8)))
        method = METHODS[int(rng.integers(len(METHODS)))]
        t0 = time.perf_counter()
        try:
            handle = service.submit(TENANTS[i % len(TENANTS)], spec,
                                    method=method)
        except BackpressureError:
            rejected += 1
            continue

        def _wait(h=handle, t0=t0):
            h.result(timeout=600)
            with lat_lock:
                latencies.append(time.perf_counter() - t0)

        th = threading.Thread(target=_wait, daemon=True)
        th.start()
        waiters.append(th)
    for th in waiters:
        th.join(timeout=600)
    return time.perf_counter() - t_start, sorted(latencies), rejected


def main(quick: bool = False) -> None:
    from repro.serve import CoalescePolicy, ExperimentService

    specs = _specs(quick)
    n_requests = 12 if quick else 48
    rate_hz = 30.0 if quick else 60.0
    rng = np.random.default_rng(0)

    service = ExperimentService(CoalescePolicy(max_batch=16, max_wait_s=0.05,
                                               max_tenant_depth=64,
                                               batch="map"))
    service.start()
    try:
        # Warmup: one request per (preset, template) compiles every shape the
        # measured window will see; the steady state a warm service serves.
        warm = [service.submit("warmup", s, method=m)
                for s in specs for m in METHODS]
        for h in warm:
            h.result(timeout=600)
        before = service.stats()

        wall_s, lats, rejected = _drive(service, specs,
                                        n_requests=n_requests,
                                        rate_hz=rate_hz, rng=rng)
        after = service.stats()
    finally:
        service.stop()

    batches = after["batches"] - before["batches"]
    batched = after["batched_requests"] - before["batched_requests"]
    cache_hits = (after["compile_cache"]["hits"]
                  - before["compile_cache"]["hits"])
    cache_lookups = cache_hits + (after["compile_cache"]["misses"]
                                  - before["compile_cache"]["misses"])
    data = {
        "n_requests": n_requests,
        "offered_rate_hz": rate_hz,
        "completed": len(lats),
        "rejected_backpressure": rejected,
        "window_wall_s": wall_s,
        "sustained_req_per_s": len(lats) / wall_s if wall_s else 0.0,
        "latency_p50_s": float(np.percentile(lats, 50)) if lats else None,
        "latency_p99_s": float(np.percentile(lats, 99)) if lats else None,
        "batches": batches,
        "coalesce_factor": batched / batches if batches else 0.0,
        "compile_cache_hit_rate": (cache_hits / cache_lookups
                                   if cache_lookups else 0.0),
        "solo_requests": after["solo_requests"] - before["solo_requests"],
        "policy": dataclasses.asdict(service.policy),
        "devices": after["devices"],
    }
    emit("serve/throughput", wall_s * 1e6 / max(len(lats), 1),
         f"{data['sustained_req_per_s']:.1f}req/s")
    emit("serve/latency", (data["latency_p50_s"] or 0.0) * 1e6,
         f"p99={data['latency_p99_s']:.3f}s" if lats else "no-completions")
    emit("serve/coalesce", 0.0,
         f"x{data['coalesce_factor']:.2f}@{batches}batches "
         f"cache_hit={data['compile_cache_hit_rate']:.2f}")
    dump("serve", data, specs=specs, seed=0)


if __name__ == "__main__":
    main()
