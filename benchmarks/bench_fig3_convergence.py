"""Fig. 3: duality-gap convergence vs rounds and vs simulated time, for
sigma in {1, 10}, comparing CoCoA+, ACPD, and the two ablations (B=K, rho=1).

Derived metric: simulated time to duality gap 1e-3 (the paper's headline is
the wall-clock ratio under stragglers).
"""

from __future__ import annotations

from benchmarks.common import cluster, dump, emit, rcv1_like, timed
from repro.core import baselines
from repro.core.acpd import run_method

TARGET = 1e-3


def main(quick: bool = False) -> None:
    K, d = 4, 512 if quick else 2048
    H = 64 if quick else 256
    prob = rcv1_like(K=K, d=d)
    curves = {}
    for sigma in ((10.0,) if quick else (1.0, 10.0)):
        cl = cluster(K, sigma=sigma)
        methods = [
            (baselines.cocoa_plus(K, H=H), 10 if quick else 60),
            (baselines.acpd(K, d, B=2, T=10, rho_d=64, gamma=0.5, H=H),
             3 if quick else 12),
            (baselines.acpd_full_barrier(K, d, T=10, rho_d=64, gamma=0.5,
                                         H=H), 2 if quick else 8),
            (baselines.acpd_dense(K, B=2, T=10, gamma=0.5, H=H),
             2 if quick else 8),
        ]
        for m, outer in methods:
            res, us = timed(run_method, prob, m, cl, num_outer=outer,
                            eval_every=2, seed=0)
            t = res.time_to_gap(TARGET)
            r = res.rounds_to_gap(TARGET)
            tag = f"fig3/sigma{int(sigma)}/{m.name}"
            emit(tag + "/time_to_gap_s", us, None if t is None else round(t, 4))
            emit(tag + "/rounds_to_gap", us, r)
            curves[f"{m.name}@sigma{int(sigma)}"] = [
                {"iter": rec.iteration, "time": rec.sim_time, "gap": rec.gap}
                for rec in res.records]
    dump("fig3_convergence", curves)


if __name__ == "__main__":
    main()
