"""Fig. 3: duality-gap convergence vs rounds and vs simulated time, for
sigma in {1, 10}, comparing CoCoA+, ACPD, and the two ablations (B=K, rho=1).

Spec-driven: each sigma is one ``repro.api.presets.fig3`` ExperimentSpec
(round-trippable via ``python -m repro spec fig3``); the dumped JSON embeds
the specs as provenance.

Derived metric: simulated time to duality gap 1e-3 (the paper's headline is
the wall-clock ratio under stragglers).
"""

from __future__ import annotations

from benchmarks.common import dump, emit, timed
from repro.api import Experiment, presets

TARGET = 1e-3


def main(quick: bool = False) -> None:
    curves = {}
    specs = []
    for sigma in ((10.0,) if quick else (1.0, 10.0)):
        spec = presets.fig3(sigma=sigma, quick=quick)
        specs.append(spec)
        exp = Experiment(spec)
        for entry in spec.methods:
            res, us = timed(exp.run_entry, entry)
            t = res.time_to_gap(TARGET)
            r = res.rounds_to_gap(TARGET)
            tag = f"fig3/sigma{int(sigma)}/{entry.config.name}"
            emit(tag + "/time_to_gap_s", us, None if t is None else round(t, 4))
            emit(tag + "/rounds_to_gap", us, r)
            curves[f"{entry.config.name}@sigma{int(sigma)}"] = [
                {"iter": rec.iteration, "time": rec.sim_time, "gap": rec.gap}
                for rec in res.records]
    dump("fig3_convergence", curves, specs=specs)


if __name__ == "__main__":
    main()
