"""Straggler sweep (paper Fig. 3): how the speedup of ACPD over CoCoA+ grows
with the straggler factor sigma, including both ablations and the engine's
new registry protocols (fully-async B=1 and LAG-style lazy uploads).

Run:  PYTHONPATH=src python examples/straggler_simulation.py
"""

from repro.core import baselines, engine
from repro.core.acpd import run_method
from repro.core.simulate import ClusterModel
from repro.data.synthetic import LinearDatasetSpec, make_linear_problem

K, D, TARGET = 4, 2048, 1e-3


def time_to(problem, method, sigma, outer):
    res = run_method(problem, method,
                     ClusterModel(num_workers=K, straggler_sigma=sigma),
                     num_outer=outer, eval_every=2, seed=0)
    return res.time_to_gap(TARGET)


def main() -> None:
    problem = make_linear_problem(
        LinearDatasetSpec(num_workers=K, n_per_worker=192, d=D,
                          nnz_per_row=24, seed=7), lam=1e-3)
    print(f"protocol registry: {', '.join(engine.available_protocols())}")
    print(f"{'sigma':>6s} {'CoCoA+':>9s} {'ACPD':>9s} {'ACPD B=K':>9s} "
          f"{'ACPD rho=1':>10s} {'async':>9s} {'LAG':>9s} {'speedup':>8s}")
    for sigma in (1.0, 2.0, 5.0, 10.0):
        t_c = time_to(problem, baselines.cocoa_plus(K, H=256), sigma, 60)
        t_a = time_to(problem, baselines.acpd(K, D, B=2, T=10, rho_d=64,
                                              gamma=0.5, H=256), sigma, 12)
        t_bk = time_to(problem, baselines.acpd_full_barrier(
            K, D, T=10, rho_d=64, gamma=0.5, H=256), sigma, 8)
        t_r1 = time_to(problem, baselines.acpd_dense(K, B=2, T=10, gamma=0.5,
                                                     H=256), sigma, 8)
        t_as = time_to(problem, baselines.acpd_async(
            K, D, T=10, rho_d=64, gamma=0.5, H=256), sigma, 40)
        t_lg = time_to(problem, baselines.acpd_lag(
            K, D, B=2, T=10, rho_d=64, gamma=0.5, H=256), sigma, 12)
        fmt = lambda t: f"{t:8.3f}s" if t else "     n/a"
        sp = f"{t_c / t_a:7.2f}x" if (t_c and t_a) else "     n/a"
        print(f"{sigma:6.1f} {fmt(t_c)} {fmt(t_a)} {fmt(t_bk)} "
              f"{fmt(t_r1):>10s} {fmt(t_as)} {fmt(t_lg)} {sp}")
    print("\nExpected: ACPD's speedup over CoCoA+ grows with sigma (the "
          "group-wise server never waits for the straggler between syncs); "
          "B=K (full barrier) is slowest. The async protocol (B=1, no "
          "barrier) is immune to the straggler but pays more rounds per unit "
          "progress; LAG tracks ACPD's time while uploading fewer bytes. "
          "Note: at this small d the DENSE group-wise ablation (rho=1) is "
          "fastest -- sparsity costs extra rounds while communication is "
          "cheap, the paper's own observation (2); the sparsity payoff "
          "appears at RCV1+ dimensionality (bench_table1 static rows, "
          "EXPERIMENTS.md §Repro).")


if __name__ == "__main__":
    main()
