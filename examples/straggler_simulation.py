"""Straggler sweep (paper Fig. 3): how the speedup of ACPD over CoCoA+ grows
with the straggler factor sigma, including both ablations and the engine's
registry protocols (fully-async B=1 and LAG-style lazy uploads).

Each sigma is one declarative ``ExperimentSpec`` executed through streaming
``Session``s with early stop at the target gap -- no hand-wired run loops.

Run:  PYTHONPATH=src python examples/straggler_simulation.py
"""

from repro import api
from repro.core import baselines, engine

K, D, TARGET = 4, 2048, 1e-3


def sweep_spec(sigma: float) -> api.ExperimentSpec:
    H = 256
    methods = (
        api.MethodEntry(baselines.cocoa_plus(K, H=H), 60),
        api.MethodEntry(baselines.acpd(K, D, B=2, T=10, rho_d=64, gamma=0.5,
                                       H=H), 12),
        api.MethodEntry(baselines.acpd_full_barrier(K, D, T=10, rho_d=64,
                                                    gamma=0.5, H=H), 8),
        api.MethodEntry(baselines.acpd_dense(K, B=2, T=10, gamma=0.5, H=H), 8),
        api.MethodEntry(baselines.acpd_async(K, D, T=10, rho_d=64, gamma=0.5,
                                             H=H), 40),
        api.MethodEntry(baselines.acpd_lag(K, D, B=2, T=10, rho_d=64,
                                           gamma=0.5, H=H), 12),
        api.MethodEntry(baselines.acpd_adaptive(K, D, T=10, rho_d=64,
                                                gamma=0.5, H=H), 12),
    )
    return api.ExperimentSpec(
        name=f"straggler-sweep-sigma{sigma:g}",
        problem=api.ProblemSpec("linear_synthetic",
                                {"num_workers": K, "n_per_worker": 192,
                                 "d": D, "nnz_per_row": 24, "seed": 7,
                                 "lam": 1e-3}),
        cluster=api.presets.cluster_model(K, sigma=sigma),
        methods=methods, eval_every=2, seed=0, target_gap=TARGET)


def main() -> None:
    print(f"protocol registry: {', '.join(engine.available_protocols())}")
    print(f"compressor registry: {', '.join(api.available_compressors())}")
    print(f"delay registry: {', '.join(api.available_delays())} "
          f"(sweep the full protocol x delay grid with the zoo-* presets / "
          f"benchmarks/bench_straggler_zoo.py)")
    print(f"{'sigma':>6s} {'CoCoA+':>9s} {'ACPD':>9s} {'ACPD B=K':>9s} "
          f"{'ACPD rho=1':>10s} {'async':>9s} {'LAG':>9s} {'adaptB':>9s} "
          f"{'speedup':>8s}")
    for sigma in (1.0, 2.0, 5.0, 10.0):
        spec = sweep_spec(sigma)
        results = api.Experiment(spec).run()
        t = {name: res.time_to_gap(TARGET) for name, res in results.items()}
        fmt = lambda v: f"{v:8.3f}s" if v else "     n/a"
        t_c, t_a = t["CoCoA+"], t["ACPD"]
        sp = f"{t_c / t_a:7.2f}x" if (t_c and t_a) else "     n/a"
        print(f"{sigma:6.1f} {fmt(t_c)} {fmt(t_a)} {fmt(t['ACPD-B=K'])} "
              f"{fmt(t['ACPD-rho=1']):>10s} {fmt(t['ACPD-async'])} "
              f"{fmt(t['ACPD-LAG'])} {fmt(t['ACPD-adaptiveB'])} {sp}")
    print("\nExpected: ACPD's speedup over CoCoA+ grows with sigma (the "
          "group-wise server never waits for the straggler between syncs); "
          "B=K (full barrier) is slowest. The async protocol (B=1, no "
          "barrier) is immune to the straggler but pays more rounds per unit "
          "progress; LAG tracks ACPD's time while uploading fewer bytes; "
          "adaptive-B learns a straggler-excluding group size on its own and "
          "tracks hand-tuned ACPD. "
          "Note: at this small d the DENSE group-wise ablation (rho=1) is "
          "fastest -- sparsity costs extra rounds while communication is "
          "cheap, the paper's own observation (2); the sparsity payoff "
          "appears at RCV1+ dimensionality (bench_table1 static rows, "
          "EXPERIMENTS.md §Repro).")


if __name__ == "__main__":
    main()
