"""Beyond the paper: ACPD as a gradient exchange for transformer training.

Trains a reduced qwen3 config for a few hundred steps with (a) plain dense
data parallelism and (b) the ACPD GroupedDeltaExchange (B-of-K participation +
top-rho sparsification + error feedback), comparing loss and exchanged bytes.
This is the end-to-end driver for the deep-learning integration; on a pod the
same code path runs the full configs via repro.launch.train.

The sparsifier is a ``repro.core.compress`` registry entry
(``ExchangeConfig.compressor``) -- the same objects the primal-dual simulator
uses -- and the exchanged bytes come from the step's
``exchange/bytes_step`` metric, billed with the identical registry formulas.

Run:  PYTHONPATH=src python examples/train_transformer_acpd.py [--steps 200]
"""

import argparse

import jax
import numpy as np

from repro.core import compress as compress_lib

from repro.configs import InputShape, get_config
from repro.core import exchange as exch_lib
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import TrainSetup, build_train_step
from repro.models import model_spec
from repro.models.param import num_params, tree_materialize
from repro.optim.optimizers import OptimizerConfig, init_state


def run(exchange, steps, cfg, tag, bill_groups=1):
    mesh = make_host_mesh()
    shape = InputShape("ex", 128, 8, "train")
    opt = OptimizerConfig(learning_rate=1e-3, warmup_steps=10,
                          total_steps=steps)
    setup = TrainSetup(cfg=cfg, optimizer=opt, exchange=exchange,
                       seq_shard=False, zero1=False, fsdp=False)
    jitted, _, _ = build_train_step(setup, mesh, shape)
    params = tree_materialize(model_spec(cfg), jax.random.key(0))
    opt_state = init_state(opt, params)
    exch_state = (exch_lib.init_state(exchange, params)
                  if exchange is not None else None)
    pipe = TokenPipeline(cfg, 8, 128, seed=0)
    n_params = num_params(model_spec(cfg))
    # Like exchange/bytes_step, bill the dense baseline per participating
    # group (every group ships its full gradient), so the ratio below
    # compares like for like.
    dense_bytes = bill_groups * compress_lib.Dense().payload_bytes(n_params)
    losses, step_bytes = [], []
    with mesh:
        for step in range(steps):
            batch = pipe.next_batch()
            params, opt_state, exch_state, m = jitted(
                params, opt_state, exch_state, batch)
            losses.append(float(m["loss"]))
            # Registry-billed bytes (exchange/bytes_step); the dense baseline
            # has no exchange metrics -- bill one full dense payload.
            step_bytes.append(float(m.get("exchange/bytes_step", dense_bytes)))
            if step % 25 == 0:
                print(f"  [{tag}] step {step:4d} loss {losses[-1]:.4f}")
    mb = np.mean(step_bytes) / 1e6
    return losses, mb


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()
    cfg = get_config("qwen3-14b").reduced()

    exch = exch_lib.ExchangeConfig(num_groups=4, group_size=2, sync_period=10,
                                   rho=1 / 64, gamma=0.9,
                                   compressor="topk_threshold")
    print("dense data-parallel baseline:")
    dense_losses, dense_mb = run(None, args.steps, cfg, "dense",
                                 bill_groups=exch.num_groups)
    print("ACPD exchange (B=2of4, rho=1/64, T=10, compressor=topk_threshold):")
    acpd_losses, acpd_mb = run(exch, args.steps, cfg, "acpd")

    k = max(1, args.steps // 10)
    print(f"\nfinal loss (mean of last {k}): "
          f"dense={np.mean(dense_losses[-k:]):.4f}  "
          f"acpd={np.mean(acpd_losses[-k:]):.4f}")
    print(f"exchanged MB/step (registry-billed): dense={dense_mb:.2f} "
          f"acpd={acpd_mb:.2f}  ({dense_mb / max(acpd_mb, 1e-9):.0f}x less)")


if __name__ == "__main__":
    main()
