"""Two tenants, one service, one compile: the serve layer end to end.

Alice and Bob each submit straggler-zoo presets to a shared
:class:`repro.serve.ExperimentService` -- different delay models (pareto vs
shifted-exponential), different seeds, same method template -- and the
service coalesces the compatible requests into ONE compiled sweep batch
while streaming each tenant's typed Round/Sync/Eval/Stop events back
independently (bit-identical to solo ``Session`` runs; docs/serving.md is
the executed guide).  A third request picks the group-family ``ACPD`` entry,
which cannot batch, so it demonstrates the solo lane through the same
handle API.

Run:  PYTHONPATH=src python examples/serve_experiments.py [--quick]
"""

import argparse
import dataclasses
import itertools

from repro import api
from repro.serve import CoalescePolicy, ExperimentService


def tenant_specs(quick: bool):
    alice = api.build_preset("zoo-pareto", quick=quick)
    bob = dataclasses.replace(
        api.build_preset("zoo-shifted_exponential", quick=quick), seed=3)
    return alice, bob


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke scale (the executed-docs/CI setting)")
    args = ap.parse_args()

    service = ExperimentService(CoalescePolicy(batch="map"))
    alice_spec, bob_spec = tenant_specs(args.quick)
    print(f"tenants: alice={alice_spec.name!r} (delay="
          f"{alice_spec.cluster.delay_model}), bob={bob_spec.name!r} "
          f"(delay={bob_spec.cluster.delay_model}, seed={bob_spec.seed})")

    # Same method template + problem -> the coalescer batches these two into
    # one compiled call; the cluster/seed differences ride per cell.
    jobs = {
        "alice": service.submit("alice", alice_spec, method="CoCoA+"),
        "bob": service.submit("bob", bob_spec, method="CoCoA+"),
        # group-family protocol: solo lane (cannot share a compiled batch)
        "alice-acpd": service.submit("alice", alice_spec, method="ACPD"),
    }
    service.drain()

    # Interleave the tenants' streams round-robin to show they are
    # independent, ordered, and complete.
    streams = {name: h.events() for name, h in jobs.items()}
    shown: dict = {name: 0 for name in streams}
    for name in itertools.cycle(list(streams)):
        if not streams:
            break
        if name not in streams:
            continue
        try:
            ev = next(streams[name])
        except StopIteration:
            del streams[name]
            continue
        kind = type(ev).__name__.replace("Event", "").lower()
        shown[name] += 1
        if shown[name] <= 3 or isinstance(ev, api.StopEvent):
            print(f"  [{name:11s}] {kind:5s} it={ev.iteration:3d} "
                  f"t={ev.sim_time:8.4f}s")
        elif shown[name] == 4:
            print(f"  [{name:11s}] ...")

    for name, handle in jobs.items():
        last = handle.result().records[-1]
        print(f"{name:11s} -> rounds={last.iteration:3d} "
              f"gap={last.gap:.3e} sim_t={last.sim_time:.4f}s")

    stats = service.stats()
    print(f"\nservice: {stats['submitted']} submitted, "
          f"{stats['batches']} batch(es), coalesce factor "
          f"{stats['coalesce_factor']:.1f}, solo {stats['solo_requests']}, "
          f"compile cache {stats['compile_cache']['hits']} hit / "
          f"{stats['compile_cache']['misses']} miss")
    assert stats["coalesce_factor"] >= 2.0, "the CoCoA+ pair must coalesce"


if __name__ == "__main__":
    main()
