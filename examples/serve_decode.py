"""Serving demo: batched prefill + decode across three architecture families,
showing the cache variety (full KV, ring-buffer window, O(1) SSM state).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import make_token_dataset
from repro.models import decode_step, model_spec, prefill
from repro.models.param import tree_materialize


def demo(arch: str, batch=2, prompt_len=48, gen=8):
    cfg = get_config(arch).reduced()
    params = tree_materialize(model_spec(cfg), jax.random.key(0))
    stream = make_token_dataset(batch * prompt_len, cfg.vocab_size, 1)
    prompts = jnp.asarray(stream.reshape(batch, prompt_len))
    t0 = time.time()
    logits, caches, plen = prefill(params, {"tokens": prompts}, cfg,
                                   max_seq=prompt_len + gen)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    toks = [tok]
    step = jax.jit(lambda p, t, c, n: decode_step(p, t, c, n, cfg))
    for i in range(gen - 1):
        logits, caches = step(params, tok, caches, jnp.int32(plen + 1 + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(tok)
    dt = time.time() - t0
    cache_mb = sum(np.asarray(x).nbytes for x in jax.tree.leaves(caches)) / 1e6
    kinds = {l.kind for l in cfg.layout}
    wins = {l.window for l in cfg.layout if l.kind == "attn"}
    print(f"{arch:22s} families={sorted(kinds)} windows={sorted(map(str, wins)) if wins else '-'} "
          f"cache={cache_mb:6.2f}MB  {gen} tokens in {dt:5.2f}s")
    return np.stack([np.asarray(t) for t in toks], 1)


def main() -> None:
    for arch in ("qwen3-14b", "gemma3-27b", "mamba2-780m",
                 "jamba-1.5-large-398b"):
        demo(arch)
    print("\nNote: gemma3's local layers keep ring buffers of `window` slots; "
          "mamba2/jamba carry O(1) SSD state -- at 524k context this is the "
          "difference between GB and MB of cache (see EXPERIMENTS §Dry-run).")


if __name__ == "__main__":
    main()
