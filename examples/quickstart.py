"""Quickstart: the paper in one minute, through the public API.

Distributed ridge regression on a synthetic RCV1-like dataset over 4 simulated
workers, one of which is a 5x straggler. Compares CoCoA+ (synchronous, dense
messages) against ACPD (B-of-K group-wise server + top-rho*d sparse messages)
on duality gap vs simulated wall-clock and on bytes moved.

The experiment is one declarative ``ExperimentSpec`` (print it with
``python -m repro spec quickstart``); each method runs as a streaming
``Session`` that stops early once the duality gap reaches 1e-3.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro import api

TARGET = 1e-3


def main() -> None:
    spec = api.build_preset("quickstart")  # target_gap=1e-3 baked in
    print(f"spec {spec.name!r}: problem={spec.problem.kind}"
          f"{spec.problem.params}, straggler x{spec.cluster.straggler_sigma}")
    print("building synthetic sparse problem...")
    exp = api.Experiment(spec)

    print(f"{'method':10s} {'rounds':>7s} {'sim time':>9s} {'MB moved':>9s} "
          f"{'final gap':>10s}")
    results = {}
    for entry in spec.methods:
        session = exp.session(entry)
        stop = None
        for ev in session:
            if isinstance(ev, api.StopEvent):
                stop = ev
        res = session.result()
        last = res.records[-1]
        t = res.time_to_gap(TARGET)
        results[entry.config.name] = t
        note = (f"(gap {TARGET:g} at t={round(t, 2)}s, "
                f"stop={stop.reason})" if t else f"(stop={stop.reason})")
        print(f"{entry.config.name:10s} {last.iteration:7d} "
              f"{last.sim_time:8.2f}s "
              f"{(last.bytes_up + last.bytes_down) / 1e6:8.2f} "
              f"{last.gap:10.2e}   {note}")
    if all(results.values()):
        print(f"\nACPD speedup to gap {TARGET:g}: "
              f"{results['CoCoA+'] / results['ACPD']:.2f}x "
              f"(paper reports up to 4x at larger d)")


if __name__ == "__main__":
    main()
