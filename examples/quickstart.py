"""Quickstart: the paper in one minute.

Distributed ridge regression on a synthetic RCV1-like dataset over 4 simulated
workers, one of which is a 5x straggler. Compares CoCoA+ (synchronous, dense
messages) against ACPD (B-of-K group-wise server + top-rho*d sparse messages)
on duality gap vs simulated wall-clock and on bytes moved.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import baselines
from repro.core.acpd import run_method
from repro.core.simulate import ClusterModel
from repro.data.synthetic import LinearDatasetSpec, make_linear_problem

K, D = 4, 4096


def main() -> None:
    print("building synthetic sparse problem (K=4 workers, d=4096)...")
    problem = make_linear_problem(
        LinearDatasetSpec(num_workers=K, n_per_worker=256, d=D,
                          nnz_per_row=32, seed=0), lam=1e-3, loss="ridge")
    cluster = ClusterModel(num_workers=K, straggler_sigma=5.0)

    methods = [
        (baselines.cocoa_plus(K, H=512), 40),
        (baselines.acpd(K, D, B=2, T=10, rho_d=128, gamma=0.5, H=512), 8),
    ]
    print(f"{'method':10s} {'rounds':>7s} {'sim time':>9s} {'MB moved':>9s} "
          f"{'final gap':>10s}")
    results = {}
    for method, outer in methods:
        res = run_method(problem, method, cluster, num_outer=outer,
                         eval_every=4, seed=0)
        last = res.records[-1]
        t = res.time_to_gap(1e-3)
        results[method.name] = t
        print(f"{method.name:10s} {last.iteration:7d} {last.sim_time:8.2f}s "
              f"{(last.bytes_up + last.bytes_down) / 1e6:8.2f} {last.gap:10.2e}"
              f"   (reached gap 1e-3 at t={t and round(t, 2)}s)")
    if all(results.values()):
        print(f"\nACPD speedup to gap 1e-3: "
              f"{results['CoCoA+'] / results['ACPD']:.2f}x "
              f"(paper reports up to 4x at larger d)")


if __name__ == "__main__":
    main()
