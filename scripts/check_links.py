#!/usr/bin/env python
"""Offline markdown link checker for README/ROADMAP/docs.

Checks every ``[text](target)`` in the given markdown files (or all ``*.md``
under given directories):

* relative file targets must exist (relative to the containing file);
* ``#fragment`` targets (own-file or ``file.md#fragment``) must match a
  heading in the target file, using GitHub's slugification;
* ``http(s)``/``mailto`` targets are skipped (the container is offline) --
  only their syntax is accepted.

Exit code 0 when every link resolves; 1 otherwise, listing each failure as
``file:line: message``. No dependencies beyond the stdlib, so the CI docs
job and tests/test_docs.py share it.

Usage: python scripts/check_links.py README.md ROADMAP.md docs/
"""

from __future__ import annotations

import pathlib
import re
import sys

# [text](target) -- ignores images' leading "!" (same target rules apply)
_LINK = re.compile(r"\[[^\]\[]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$")
_FENCE = re.compile(r"^\s*(```|~~~)")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces -> dashes."""
    text = re.sub(r"[*_`]", "", heading.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def headings_of(path: pathlib.Path) -> set[str]:
    slugs: set[str] = set()
    in_fence = False
    for line in path.read_text().splitlines():
        if _FENCE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = _HEADING.match(line)
        if m:
            slugs.add(github_slug(m.group(1)))
    return slugs


def iter_links(path: pathlib.Path):
    """Yield (lineno, target) for every markdown link outside code fences."""
    in_fence = False
    for lineno, line in enumerate(path.read_text().splitlines(), start=1):
        if _FENCE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _LINK.finditer(line):
            yield lineno, m.group(1)


def check_file(path: pathlib.Path) -> list[str]:
    errors: list[str] = []
    for lineno, target in iter_links(path):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        base, _, fragment = target.partition("#")
        dest = path if not base else (path.parent / base).resolve()
        if not dest.exists():
            errors.append(f"{path}:{lineno}: broken link target {target!r}")
            continue
        if fragment and dest.suffix == ".md":
            if github_slug(fragment) not in headings_of(dest):
                errors.append(
                    f"{path}:{lineno}: no heading {fragment!r} in {dest}")
    return errors


def collect(args: list[str]) -> list[pathlib.Path]:
    files: list[pathlib.Path] = []
    for a in args:
        p = pathlib.Path(a)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        else:
            files.append(p)
    return files


def main(argv: list[str]) -> int:
    files = collect(argv or ["README.md", "ROADMAP.md", "docs"])
    missing = [f for f in files if not f.exists()]
    errors = [f"{f}: file not found" for f in missing]
    for f in files:
        if f.exists():
            errors.extend(check_file(f))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"# link-check: {len(files)} file(s), {len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
