"""Replicated-serving smoke: 3 replicas, seeded kill + drops, zero hangs.

Drives the deterministic in-process cluster scenario of
``benchmarks/bench_chaos.py`` (the ``cluster_chaos`` composite: one replica
SIGKILLed mid-checkpoint-segment, seeded message drops) and asserts the
hard contracts of docs/fault-tolerance.md "Replicated serving":

* every submitted job completes (``hung_jobs == 0``, ``goodput > 0``);
* the scheduled replica genuinely died and a peer took its lease over
  (``takeovers >= 1``) and resumed from the shared checkpoint directory.

``make cluster-smoke`` (CI job ``cluster``) runs this after the cluster
test suite.
"""

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))          # the benchmarks package
sys.path.insert(0, str(ROOT / "src"))


def main() -> None:
    from benchmarks.bench_chaos import _cluster_scenario

    out = _cluster_scenario(quick=True)
    assert out["hung_jobs"] == 0, out
    assert out["goodput_jobs_per_s"] > 0, out
    assert list(out["dead_replicas"]) == ["r0"], out
    assert out["takeovers"] >= 1, out
    assert out["completed"] == out["n_jobs"], out
    print(f"cluster smoke OK: {out['n_jobs']} jobs on "
          f"{out['n_replicas']} replicas in {out['ticks']} ticks, "
          f"goodput {out['goodput_jobs_per_s']:.1f} jobs/s, "
          f"kill at tick {out['kill_tick']}, takeover recovered in "
          f"{out['takeover_recovery_ticks']} ticks, 0 hung jobs")


if __name__ == "__main__":
    main()
