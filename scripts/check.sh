#!/usr/bin/env bash
# Tier-1 tests + smoke-scale benchmarks, one command (same as `make check`).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q
python -m benchmarks.run --quick
