#!/usr/bin/env bash
# Tier-1 tests + docs checks + smoke-scale benchmarks, one command.
# Delegates to `make check` (the single source of truth for the recipe);
# the inline fallback below exists only for environments without make.
set -euo pipefail
cd "$(dirname "$0")/.."
if command -v make >/dev/null 2>&1; then
    exec make check
fi
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q
python scripts/check_links.py README.md ROADMAP.md docs
python scripts/check_specs.py
python -m repro analyze
python -m benchmarks.run --quick
