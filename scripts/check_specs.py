#!/usr/bin/env python
"""JSON round-trip every shipped preset spec (the CI docs-job half that
needs the package).

For each entry in ``repro.api.PRESETS`` (both quick and full scale):
build the spec, serialize with ``to_json``, parse back with ``from_json``,
and require equality -- the same contract ``python -m repro spec <preset> |
python -m repro run`` relies on. Also re-validates that every method's
registry names (protocol / compressor / local solver) and the cluster's
delay model resolve.

Run from the repo root: PYTHONPATH=src python scripts/check_specs.py
"""

from __future__ import annotations

import sys


def main() -> int:
    from repro import api
    from repro.core import compress, delays, engine, solvers

    failures = []
    count = 0
    for name in sorted(api.PRESETS):
        for quick in (False, True):
            spec = api.build_preset(name, **({"quick": True} if quick else {}))
            count += 1
            back = api.ExperimentSpec.from_json(spec.to_json())
            if back != spec:
                failures.append(f"{spec.name}: JSON round-trip not lossless")
                continue
            try:
                delays.get_delay(spec.cluster.delay_model)
                for entry in spec.methods:
                    engine.get_protocol(entry.config.protocol)
                    solvers.get_solver(entry.config.local_solver)
                    if entry.config.compressor is not None:
                        compress.get_compressor(entry.config.compressor)
            except ValueError as e:
                failures.append(f"{spec.name}: {e}")
    for f in failures:
        print(f, file=sys.stderr)
    print(f"# spec round-trip: {count} spec(s), {len(failures)} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
