# Single entry point for the repo's sanity gate:
#   make check  == tier-1 pytest + smoke-scale benchmarks (see ROADMAP.md)
# Equivalent for environments without make: ./scripts/check.sh

PY ?= python

.PHONY: check test docs-check analyze bench-quick bench-engine-quick \
	bench-sweep-quick serve-smoke chaos-smoke cluster-smoke bench

check: test docs-check analyze bench-quick

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# Static analysis: project lint + trace-time contract checks against the
# checked-in baseline (ANALYSIS_BASELINE.json). Nonzero on any new finding
# or failed contract; see docs/static-analysis.md.
analyze:
	PYTHONPATH=src $(PY) -m repro analyze

# Offline markdown link-check + JSON round-trip of every shipped preset
# (the CI docs job runs exactly this target).
docs-check:
	$(PY) scripts/check_links.py README.md ROADMAP.md docs
	PYTHONPATH=src $(PY) scripts/check_specs.py

bench-quick:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick

# Engine/executor microbenchmark only, at smoke scale: the CI "bench" job's
# it-still-runs gate (no perf thresholds enforced -- numbers are informative).
bench-engine-quick:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick --only engine

# Sharded-sweep smoke on 4 fake host devices: exercises the mesh path
# (shard="cells"/"workers" through launch/mesh + shard_map) on every PR;
# cell failures land in the JSON dump per the bench failure-artifact
# convention (experiments/bench/sweep_scaling.json "errors").
bench-sweep-quick:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
		$(PY) -m benchmarks.run --quick --only sweep

# Multi-tenant serving smoke on 4 fake host devices: a short open-loop
# Poisson burst through the live ExperimentService (benchmarks/bench_serve.py)
# plus the two-tenant streamed demo (examples/serve_experiments.py) -- the
# CI gate that coalescing, stream demux, and the warm-compile cache still
# work end to end under a sharded mesh.
serve-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
		$(PY) -m benchmarks.run --quick --only serve
	XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
		$(PY) examples/serve_experiments.py --quick

# Self-healing smoke under the PINNED composite fault schedule
# (benchmarks/bench_chaos.py + the tests/test_chaos.py suite): injected
# deadline overrun, transient fault, and NaN-poisoned cell; gates retry /
# bisect / breaker / masking / checkpoint-resume with zero hung jobs
# (docs/fault-tolerance.md).
chaos-smoke:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
		$(PY) -m pytest -x -q tests/test_chaos.py tests/test_faults.py
	XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
		$(PY) -m benchmarks.run --quick --only chaos

# Replicated-serving smoke (docs/fault-tolerance.md, "Replicated serving"):
# the cluster test suite (lease mutual exclusion, bit-identical checkpoint
# takeover, exactly-once under duplication, partition no-hang, one REAL
# subprocess SIGKILL) plus a 3-replica run under the seeded cluster_chaos
# composite asserting goodput > 0 and zero hung jobs.
cluster-smoke:
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_cluster.py
	PYTHONPATH=src $(PY) scripts/cluster_smoke.py

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run
