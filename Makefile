# Single entry point for the repo's sanity gate:
#   make check  == tier-1 pytest + smoke-scale benchmarks (see ROADMAP.md)
# Equivalent for environments without make: ./scripts/check.sh

PY ?= python

.PHONY: check test docs-check bench-quick bench-engine-quick bench

check: test docs-check bench-quick

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# Offline markdown link-check + JSON round-trip of every shipped preset
# (the CI docs job runs exactly this target).
docs-check:
	$(PY) scripts/check_links.py README.md ROADMAP.md docs
	PYTHONPATH=src $(PY) scripts/check_specs.py

bench-quick:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick

# Engine/executor microbenchmark only, at smoke scale: the CI "bench" job's
# it-still-runs gate (no perf thresholds enforced -- numbers are informative).
bench-engine-quick:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick --only engine

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run
