# Single entry point for the repo's sanity gate:
#   make check  == tier-1 pytest + smoke-scale benchmarks (see ROADMAP.md)
# Equivalent for environments without make: ./scripts/check.sh

PY ?= python

.PHONY: check test bench-quick bench

check: test bench-quick

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench-quick:
	PYTHONPATH=src $(PY) -m benchmarks.run --quick

bench:
	PYTHONPATH=src $(PY) -m benchmarks.run
