"""Cross-implementation top-k agreement (no hypothesis required).

Three implementations of the paper's message filter must agree with exact
top-k on tie-free inputs:

* ``core.filter.topk_mask_exact``  -- jnp oracle (sort-based, exact by
  construction; included so every case exercises the shared contract);
* ``core.exchange.threshold_for_topk`` -- two-round histogram threshold used
  by the deep-net exchange layer;
* ``kernels.ops.topk_filter``      -- the Pallas histogram-select kernel.

The histogram implementations resolve magnitudes to one refined bucket
(~0.4% ratio), so the shared cases use ladder magnitudes with pairwise gaps
of >= 0.6% -- unambiguous for every implementation, including after bfloat16
quantization (eps = 2^-8 ~ 0.39%) -- with random signs and order.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import exchange
from repro.core import filter as flt
from repro.kernels import ops

# (d, k, seed): shared across all three implementations.
CASES = [
    (257, 1, 0),
    (257, 16, 1),
    (1024, 8, 2),
    (1024, 200, 3),
    (2048, 64, 4),
    (2048, 1024, 5),
]
DTYPES = [jnp.float32, jnp.bfloat16]
_IDS = [f"d{d}-k{k}" for d, k, _ in CASES]


def _tie_free_input(d: int, seed: int, dtype) -> jnp.ndarray:
    """Geometric magnitude ladder, shuffled with random signs.

    The pairwise gap must clear bfloat16's worst-case quantum (2^-7 ~ 0.78%
    just below a power of two) so the values stay distinct after rounding,
    while the total dynamic range stays within the histogram filters' 2^-22
    selection floor for every k we test -- hence the exponent range grows
    with d (gap ~ 2*r/d in log2) but is capped at +-12.
    """
    rng = np.random.default_rng(seed)
    r = min(12.0, 0.0065 * d)
    exponents = np.linspace(-r, r, d)
    mags = np.exp2(exponents).astype(np.float32)
    signs = rng.choice([-1.0, 1.0], size=d).astype(np.float32)
    x = rng.permutation(mags * signs)
    out = jnp.asarray(x).astype(dtype)
    # sanity: the construction really is tie-free at this dtype
    assert len(np.unique(np.abs(np.asarray(out, np.float32)))) == d
    return out


def _exact_topk_indices(x: jnp.ndarray, k: int) -> set[int]:
    mags = np.abs(np.asarray(x, np.float32))
    return set(np.argsort(-mags)[:k].tolist())


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("d,k,seed", CASES, ids=_IDS)
def test_threshold_for_topk_matches_exact(d, k, seed, dtype):
    x = _tie_free_input(d, seed, dtype)
    t = exchange.threshold_for_topk(x, jnp.int32(k))
    kept = np.flatnonzero(np.abs(np.asarray(x, np.float32)) >= float(t))
    assert set(kept.tolist()) == _exact_topk_indices(x, k)


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("d,k,seed", CASES, ids=_IDS)
def test_kernel_topk_filter_matches_exact(d, k, seed, dtype):
    x = _tie_free_input(d, seed, dtype)
    sent, resid, mask = ops.topk_filter(x, k)
    kept = set(np.flatnonzero(np.asarray(mask)).tolist())
    assert kept == _exact_topk_indices(x, k)
    # conservation is part of the shared contract
    assert bool(jnp.all(sent + resid == x))


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("d,k,seed", CASES, ids=_IDS)
def test_jnp_oracle_matches_exact(d, k, seed, dtype):
    x = _tie_free_input(d, seed, dtype)
    res = flt.topk_mask_exact(x, k)
    kept = set(np.flatnonzero(np.asarray(res.mask)).tolist())
    assert kept == _exact_topk_indices(x, k)
    assert bool(jnp.all(res.sent + res.residual == x))
