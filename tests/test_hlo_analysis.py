"""HLO collective parsing + roofline term arithmetic on synthetic text."""

import numpy as np

from repro.launch import hlo_analysis as ha

SAMPLE = """
HloModule jit_f, num_partitions=256
ENTRY %main {
  %ag = bf16[256,4096,1024]{2,1,0} all-gather(%x), channel_id=1, replica_groups=[16,16]<=[16,16]T(1,0), dimensions={0}
  %ar = f32[16,4096]{1,0} all-reduce(%y), channel_id=2, replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[16,256]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[32,16]<=[512], dimensions={1}
  %cp = bf16[8,128]{1,0} collective-permute(%w), channel_id=4, source_target_pairs={{0,1}}
  %a2a-start = (f32[1,8,64]{2,1,0}, f32[1,8,64]{2,1,0}) all-to-all(%v), channel_id=5, replica_groups=[64,8]<=[512]
}
"""


def test_parse_collectives_kinds_and_bytes():
    st = ha.parse_collectives(SAMPLE)
    assert st.counts == {"all-gather": 1, "all-reduce": 1,
                         "reduce-scatter": 1, "collective-permute": 1,
                         "all-to-all": 1}
    ag_bytes = 256 * 4096 * 1024 * 2
    assert st.result_bytes["all-gather"] == ag_bytes
    ar_bytes = 16 * 4096 * 4
    assert st.result_bytes["all-reduce"] == ar_bytes
    # ring models
    ops = {o["kind"]: o for o in st.ops}
    assert ops["all-gather"]["group"] == 16
    assert abs(ops["all-gather"]["wire"] - ag_bytes * 15 / 16) < 1
    assert ops["all-reduce"]["group"] == 4
    assert abs(ops["all-reduce"]["wire"] - 2 * ar_bytes * 3 / 4) < 1
    assert ops["collective-permute"]["wire"] == 8 * 128 * 2
    # reduce-scatter result is 1/n of input -> wire = result * (n-1)
    assert abs(ops["reduce-scatter"]["wire"] - 16 * 256 * 4 * 15) < 1


def test_async_pairs_counted_once():
    txt = """
  %c = f32[4]{0} all-reduce-start(%x), channel_id=9, replica_groups={{0,1}}
  %c.done = f32[4]{0} all-reduce-done(%c)
"""
    st = ha.parse_collectives(txt)
    assert st.counts.get("all-reduce", 0) == 1


def test_shape_bytes_tuple():
    assert ha._shape_bytes("(f32[2,3], bf16[4])") == 2 * 3 * 4 + 4 * 2
    assert ha._shape_bytes("pred[8,128]") == 1024


def test_cpu_upcast_detection():
    txt = "%cv = f32[40,5120,1088]{2,1,0} convert(%w)\n" \
          "%cv2 = f32[16,512]{1,0} convert(%a)\n"
    up = ha.cpu_upcast_bytes(txt, {40})
    assert up == 40 * 5120 * 1088 * 4  # only the stacked >=64MiB one


def test_roofline_terms_hardware_constants():
    assert ha.PEAK_FLOPS == 197e12
    assert ha.HBM_BW == 819e9
    assert ha.ICI_BW == 50e9
