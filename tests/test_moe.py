"""MoE: routing math vs an explicit per-token reference; capacity semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as moe_lib
from repro.models.config import ModelConfig
from repro.models.param import tree_materialize


def _cfg(E=4, K=2, cap=8.0):
    return ModelConfig(arch_id="t", family="moe", num_layers=1, d_model=32,
                       num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                       num_experts=E, experts_per_token=K, d_ff_expert=48,
                       moe_capacity_factor=cap, param_dtype="float32",
                       compute_dtype="float32")


def _reference(params, x, cfg):
    """Explicit per-token loop: softmax -> top-k -> renorm -> expert SwiGLU."""
    B, S, D = x.shape
    xt = np.asarray(x).reshape(-1, D)
    logits = xt @ np.asarray(params["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    out = np.zeros_like(xt)
    for i, (xi, pi) in enumerate(zip(xt, probs)):
        top = np.argsort(-pi)[: cfg.experts_per_token]
        w = pi[top] / pi[top].sum()
        for e, we in zip(top, w):
            g = xi @ np.asarray(params["gate"][e])
            u = xi @ np.asarray(params["up"][e])
            h = (g / (1 + np.exp(-g))) * u
            out[i] += we * (h @ np.asarray(params["down"][e]))
    return out.reshape(B, S, D)


def test_moe_matches_reference_with_ample_capacity():
    cfg = _cfg(cap=16.0)  # no drops
    params = tree_materialize(moe_lib.moe_spec(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model)) * 0.5
    out, aux = moe_lib.moe(params, x, cfg)
    ref = _reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-4)
    assert float(aux) >= 1.0 - 1e-5  # Switch aux loss lower bound is 1


def test_capacity_drops_are_bounded():
    """With capacity factor c, at most (1 - c*K... ) tokens drop; output of a
    dropped slot is zero -- total output norm shrinks but stays finite."""
    cfg_lo = _cfg(cap=0.25)
    cfg_hi = _cfg(cap=16.0)
    params = tree_materialize(moe_lib.moe_spec(cfg_hi), jax.random.key(0))
    x = jax.random.normal(jax.random.key(2), (2, 32, cfg_hi.d_model)) * 0.5
    out_lo, _ = moe_lib.moe(params, x, cfg_lo)
    out_hi, _ = moe_lib.moe(params, x, cfg_hi)
    n_lo = float(jnp.linalg.norm(out_lo))
    n_hi = float(jnp.linalg.norm(out_hi))
    assert np.isfinite(n_lo) and n_lo <= n_hi + 1e-5


def test_moe_grads_finite():
    cfg = _cfg()
    params = tree_materialize(moe_lib.moe_spec(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(3), (2, 16, cfg.d_model))

    def loss(p):
        out, aux = moe_lib.moe(p, x, cfg)
        return jnp.sum(jnp.square(out)) + aux

    grads = jax.grad(loss)(params)
    assert all(np.isfinite(np.asarray(g)).all() for g in jax.tree.leaves(grads))


def test_dispatch_groups_equivalent():
    """Group-local dispatch (mesh path) == single-group when capacity ample."""
    cfg = _cfg(cap=16.0)
    params = tree_materialize(moe_lib.moe_spec(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(4), (4, 8, cfg.d_model)) * 0.5
    out1, _ = moe_lib.moe(params, x, cfg, mesh=None)  # G=1
    # fake a "mesh" with data=2 by calling the internal with a 2-group reshape
    import repro.models.moe as m

    orig = m._num_dispatch_groups
    m._num_dispatch_groups = lambda mesh, n: 2
    try:
        out2, _ = moe_lib.moe(params, x, cfg, mesh=None)
    finally:
        m._num_dispatch_groups = orig
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=2e-3,
                               atol=2e-4)
