"""Fault-model registry: listing/error mechanics, spec round-trips,
determinism of every schedule, transient classification, and the recovery
primitives (backoff jitter, circuit breaker, deadline watchdog) that consume
the injected faults."""

import pytest

from repro.core import faults
from repro.serve import recovery

# ---------------------------------------------------------------------------
# Registry mechanics.
# ---------------------------------------------------------------------------


def test_registry_contents_and_errors():
    names = faults.available_faults()
    for expected in ("none", "transient_executor", "worker_crash",
                     "compile_failure", "nan_poison", "slow_batch", "chaos",
                     "net_drop", "net_duplicate", "net_reorder", "net_delay",
                     "net_partition", "replica_kill", "cluster_chaos"):
        assert expected in names
    assert names == tuple(sorted(names))
    with pytest.raises(ValueError, match="unknown fault model"):
        faults.get_fault("nope")
    with pytest.raises(ValueError, match="unknown fault model"):
        faults.fault_from_spec({"fault_model": "nope"})


def test_bad_params_fail_at_construction():
    with pytest.raises(ValueError, match="failures"):
        faults.get_fault("transient_executor")(failures=-1)
    with pytest.raises(ValueError, match="crashes"):
        faults.get_fault("worker_crash")(crashes=-2)
    with pytest.raises(ValueError, match="count"):
        faults.get_fault("nan_poison")(count=-1)
    with pytest.raises(ValueError, match="delay_s"):
        faults.get_fault("slow_batch")(delay_s=-0.1)
    with pytest.raises(ValueError, match="poison"):
        faults.get_fault("chaos")(poison=-1)
    with pytest.raises(TypeError):
        faults.get_fault("nan_poison")(not_a_param=3)
    with pytest.raises(ValueError, match="rate"):
        faults.get_fault("net_drop")(rate=1.5)
    with pytest.raises(ValueError, match="kinds"):
        faults.get_fault("net_duplicate")(kinds="job,gossip")
    with pytest.raises(ValueError, match="ticks"):
        faults.get_fault("net_delay")(ticks=0)
    with pytest.raises(ValueError, match="replica"):
        faults.get_fault("net_partition")()
    with pytest.raises(ValueError, match="replica"):
        faults.get_fault("replica_kill")(replica="")
    with pytest.raises(ValueError, match="after_steps and/or at_segment"):
        faults.get_fault("replica_kill")(replica="r0")
    with pytest.raises(ValueError, match="at_segment"):
        faults.get_fault("replica_kill")(replica="r0", at_segment=0)


def test_spec_round_trip_every_entry():
    built = {
        "none": faults.NoFault(seed=7),
        "transient_executor": faults.get_fault("transient_executor")(
            seed=1, failures=2),
        "worker_crash": faults.get_fault("worker_crash")(
            seed=2, crashes=0, crash_round=5),
        "compile_failure": faults.get_fault("compile_failure")(seed=3),
        "nan_poison": faults.get_fault("nan_poison")(seed=4, count=2),
        "slow_batch": faults.get_fault("slow_batch")(
            seed=5, delay_s=0.01, slow_attempts=3),
        "chaos": faults.get_fault("chaos")(seed=6, delay_s=0.02, poison=2),
        "net_drop": faults.get_fault("net_drop")(seed=7, rate=0.3),
        "net_duplicate": faults.get_fault("net_duplicate")(
            seed=8, rate=0.5, kinds="result"),
        "net_reorder": faults.get_fault("net_reorder")(seed=9, rate=0.2),
        "net_delay": faults.get_fault("net_delay")(seed=10, rate=1.0, ticks=3),
        "net_partition": faults.get_fault("net_partition")(
            replica="r1", start_tick=2, duration=4),
        "replica_kill": faults.get_fault("replica_kill")(
            replica="r0", at_segment=2),
        "cluster_chaos": faults.get_fault("cluster_chaos")(
            seed=11, kill_replica="r0", after_steps=3, drop_rate=0.25),
    }
    assert set(built) == set(faults.available_faults())
    for name, model in built.items():
        spec = model.spec()
        assert spec["fault_model"] == name == type(model).fault_name
        clone = faults.fault_from_spec(spec)
        assert type(clone) is type(model)
        assert clone.spec() == spec
        # JSON-scalar params only (the serve CLI passes them as JSON).
        for v in spec["fault_params"].values():
            assert v is None or isinstance(v, (int, float, str, bool))


def test_transient_classification():
    assert faults.WorkerCrashError("x").transient
    assert faults.TransientExecutorError("x").transient
    assert not faults.CompileFailureError("x").transient
    assert not faults.InjectedFault("x").transient
    assert recovery.is_transient(faults.WorkerCrashError("x"))
    assert not recovery.is_transient(faults.CompileFailureError("x"))
    assert not recovery.is_transient(RuntimeError("plain"))
    for err in (faults.WorkerCrashError, faults.TransientExecutorError,
                faults.CompileFailureError):
        assert issubclass(err, faults.InjectedFault)
        assert issubclass(err, RuntimeError)


# ---------------------------------------------------------------------------
# Schedule determinism.
# ---------------------------------------------------------------------------


def test_key_digest_is_process_stable():
    # Pinned values: these must never drift (checkpoint/bench contracts).
    assert faults.key_digest(("a", 1)) == faults.key_digest(("a", 1))
    assert faults.key_digest(("a", 1)) != faults.key_digest(("a", 2))
    assert isinstance(faults.key_digest("k"), int)


def test_transient_executor_schedule():
    m = faults.get_fault("transient_executor")(failures=2)
    for attempt in (0, 1):
        with pytest.raises(faults.TransientExecutorError):
            m.on_dispatch("batch", "k", attempt)
    m.on_dispatch("batch", "k", 2)  # recovered
    m.on_dispatch("solo", "k", 0)  # other lanes untouched
    m.on_dispatch("segment", "k", 0)


def test_worker_crash_schedule():
    m = faults.get_fault("worker_crash")(crashes=1, crash_round=4)
    with pytest.raises(faults.WorkerCrashError):
        m.on_dispatch("batch", "k", 0)
    m.on_dispatch("batch", "k", 1)
    m.on_dispatch("segment", "k", 0)  # before the crash round
    with pytest.raises(faults.WorkerCrashError, match="resume"):
        m.on_dispatch("segment", "k", 4)
    with pytest.raises(faults.WorkerCrashError):
        m.on_dispatch("segment", "k", 6)


def test_compile_failure_is_persistent():
    m = faults.get_fault("compile_failure")()
    for attempt in range(4):
        with pytest.raises(faults.CompileFailureError):
            m.on_dispatch("batch", "k", attempt)


def test_nan_poison_is_deterministic_and_attempt_stable():
    m = faults.get_fault("nan_poison")(seed=11, count=2)
    first = m.poison_cells(8, key="batch-key")
    assert len(first) == 2
    assert all(0 <= i < 8 for i in first)
    # Same (seed, key) -> same cells, across instances (attempt-stability).
    again = faults.get_fault("nan_poison")(seed=11, count=2)
    assert again.poison_cells(8, key="batch-key") == first
    assert m.poison_cells(8, key="other-key") != first or True  # may collide
    assert faults.get_fault("nan_poison")(seed=12, count=2) \
        .poison_cells(8, key="batch-key") != first
    # Clamped to the batch size, never out of range.
    assert faults.get_fault("nan_poison")(count=5).poison_cells(2, "k") == (0, 1)
    assert faults.get_fault("nan_poison")(count=0).poison_cells(4, "k") == ()


def test_chaos_schedule_is_reproducible_per_instance():
    def run(model):
        trace = []
        for n in range(3):
            try:
                model.on_dispatch("batch", f"key{n}", 0)
                trace.append("ok")
            except faults.TransientExecutorError:
                trace.append("transient")
        trace.append(model.poison_cells(4, "key0"))
        trace.append(model.poison_cells(4, "key1"))  # not the poison key
        return trace

    a = run(faults.get_fault("chaos")(seed=3, delay_s=0.0, poison=1))
    b = run(faults.get_fault("chaos")(seed=3, delay_s=0.0, poison=1))
    assert a == b
    assert a[:3] == ["ok", "transient", "ok"]  # dispatch 1 is the transient
    assert len(a[3]) == 1  # first-queried key carries the poison...
    assert a[4] == ()  # ...and only that key
    assert faults.get_fault("chaos").stateful
    assert not faults.get_fault("nan_poison").stateful


# ---------------------------------------------------------------------------
# The network-fault family (cluster-transport seam).
# ---------------------------------------------------------------------------


def test_default_transport_hooks_are_no_fault():
    m = faults.NoFault()
    assert m.message_fate("job", "k", 0) == (1, 0)
    assert m.replica_fate("r0", 5) == "ok"
    assert m.segment_fate("r0", 2) is False


def test_message_fate_deterministic_and_resend_is_fresh_draw():
    m = faults.get_fault("net_drop")(seed=3, rate=0.5)
    fates = [m.message_fate("job", ("alice", 1), s) for s in range(32)]
    again = faults.get_fault("net_drop")(seed=3, rate=0.5)
    assert [again.message_fate("job", ("alice", 1), s)
            for s in range(32)] == fates
    # the seq enters the draw: a re-send is a fresh coin flip, so
    # at-least-once senders converge -- some sends survive
    assert (0, 0) in fates and (1, 0) in fates
    # different seed -> different schedule
    other = faults.get_fault("net_drop")(seed=4, rate=0.5)
    assert [other.message_fate("job", ("alice", 1), s)
            for s in range(32)] != fates


def test_per_message_faults_respect_kinds_and_rates():
    dup = faults.get_fault("net_duplicate")(rate=1.0, kinds="result")
    assert dup.message_fate("result", "k", 0) == (2, 0)
    assert dup.message_fate("job", "k", 0) == (1, 0)  # kind not selected
    assert dup.message_fate("heartbeat", "k", 0) == (1, 0)
    reorder = faults.get_fault("net_reorder")(rate=1.0)
    assert reorder.message_fate("job", "k", 0) == (1, 1)
    delay = faults.get_fault("net_delay")(rate=1.0, ticks=4)
    assert delay.message_fate("job", "k", 0) == (1, 4)
    none_selected = faults.get_fault("net_drop")(rate=0.0)
    assert none_selected.message_fate("job", "k", 0) == (1, 0)


def test_partition_window_and_kill_schedules():
    p = faults.get_fault("net_partition")(replica="r1", start_tick=2,
                                          duration=3)
    assert [p.replica_fate("r1", t) for t in range(7)] == \
        ["ok", "ok", "partitioned", "partitioned", "partitioned", "ok", "ok"]
    assert p.replica_fate("r0", 3) == "ok"  # only the named replica
    forever = faults.get_fault("net_partition")(replica="r1", start_tick=1)
    assert forever.replica_fate("r1", 10 ** 6) == "partitioned"

    k = faults.get_fault("replica_kill")(replica="r0", after_steps=4)
    assert [k.replica_fate("r0", t) for t in (3, 4, 5)] == \
        ["ok", "killed", "killed"]
    assert k.segment_fate("r0", 99) is False  # at_segment not set
    seg = faults.get_fault("replica_kill")(replica="r0", at_segment=2)
    assert seg.segment_fate("r0", 1) is False
    assert seg.segment_fate("r0", 2) is True
    assert seg.segment_fate("r1", 2) is False
    assert seg.replica_fate("r0", 100) == "ok"  # after_steps not set


def test_cluster_chaos_composes_kill_and_drop():
    m = faults.get_fault("cluster_chaos")(seed=5, kill_replica="r2",
                                          at_segment=3, drop_rate=0.4)
    assert m.segment_fate("r2", 3) is True
    assert m.segment_fate("r0", 3) is False
    # the drop half matches a same-seed net_drop exactly
    drop = faults.get_fault("net_drop")(seed=5, rate=0.4)
    assert [m.message_fate("job", "k", s) for s in range(16)] == \
        [drop.message_fate("job", "k", s) for s in range(16)]
    assert faults.fault_from_spec(m.spec()).params() == m.params()


def test_replica_killed_is_uncatchable_by_recovery_traps():
    # The in-process SIGKILL analogue: BaseException, so the serve stack's
    # `except Exception` recovery paths can never convert a replica death
    # into a typed job failure.
    assert issubclass(faults.ReplicaKilled, BaseException)
    assert not issubclass(faults.ReplicaKilled, Exception)
    try:
        raise faults.ReplicaKilled("r0")
    except Exception:  # noqa: BLE001 - the point of the test
        pytest.fail("ReplicaKilled must not be catchable as Exception")
    except faults.ReplicaKilled:
        pass


# ---------------------------------------------------------------------------
# Recovery primitives driven by the faults.
# ---------------------------------------------------------------------------


def test_backoff_delay_deterministic_and_bounded():
    policy = recovery.RecoveryPolicy(backoff_base_s=0.1, backoff_factor=2.0,
                                     backoff_jitter=0.25, seed=9)
    d1 = recovery.backoff_delay(policy, 1, key="k")
    d2 = recovery.backoff_delay(policy, 2, key="k")
    assert d1 == recovery.backoff_delay(policy, 1, key="k")
    assert 0.075 <= d1 <= 0.125  # base * (1 +- jitter)
    assert 0.15 <= d2 <= 0.25  # base * factor * (1 +- jitter)
    assert recovery.backoff_delay(policy, 1, key="other") != d1


def test_recovery_policy_validation():
    with pytest.raises(ValueError, match="max_attempts"):
        recovery.RecoveryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="backoff_jitter"):
        recovery.RecoveryPolicy(backoff_jitter=1.5)
    with pytest.raises(ValueError, match="breaker_threshold"):
        recovery.RecoveryPolicy(breaker_threshold=0)


def test_circuit_breaker_lifecycle():
    # Realistic cooldown, no real sleeps: the breaker reads an injected
    # ManualClock (before PR 10 this test needed degenerate 1e9/0.0
    # cooldowns to sidestep wall-clock).
    from repro.serve.clock import ManualClock

    clock = ManualClock()
    br = recovery.CircuitBreaker(threshold=2, cooldown_s=30.0, clock=clock)
    assert br.allow("k")
    br.record_failure("k")
    assert br.allow("k")  # one failure: still closed
    br.record_failure("k")
    assert not br.allow("k")  # threshold hit: open, cooldown not elapsed
    assert br.state("k") == "open"
    assert br.allow("other")  # per-key isolation
    snap = br.snapshot()
    assert snap["open"] == [repr("k")]
    assert snap["half_open"] == []
    states = br.states()
    assert states[repr("k")]["state"] == "open"
    assert states[repr("k")]["consecutive_failures"] == 2
    assert states[repr("k")]["open_for_s"] == 0.0

    clock.advance(29.0)
    assert not br.allow("k")  # still cooling down
    clock.advance(1.0)
    assert br.allow("k")  # cooldown elapsed: half-open probe admitted
    assert br.state("k") == "half_open"
    assert not br.allow("k")  # exactly ONE probe
    br.record_success("k")
    assert br.state("k") == "closed"
    assert br.allow("k")
    assert br.states() == {}  # success clears the key entirely


def test_circuit_breaker_reopens_from_half_open_without_sleeping():
    from repro.serve.clock import ManualClock

    clock = ManualClock()
    br = recovery.CircuitBreaker(threshold=1, cooldown_s=10.0, clock=clock)
    br.record_failure("k")
    clock.advance(10.0)
    assert br.allow("k")  # the probe
    br.record_failure("k")  # probe failed: snaps back open immediately
    assert br.state("k") == "open"
    assert not br.allow("k")
    assert br.states()[repr("k")]["open_for_s"] == 0.0
    clock.advance(5.0)
    assert br.states()[repr("k")]["open_for_s"] == 5.0


def test_run_with_deadline():
    assert recovery.run_with_deadline(lambda: 42, None, label="x") == 42
    assert recovery.run_with_deadline(lambda: 42, 5.0, label="x") == 42
    with pytest.raises(recovery.JobTimeoutError, match="deadline"):
        recovery.run_with_deadline(
            lambda: __import__("time").sleep(2.0), 0.05, label="slow batch")
    with pytest.raises(KeyError):  # errors relayed verbatim, not wrapped
        recovery.run_with_deadline(
            lambda: {}["missing"], 5.0, label="x")
